"""AR point-cloud offloading case study (paper §7.1) — runnable demo.

A phone renders an animated point cloud: per frame it decodes a VPCC
stream, reconstructs points, depth-sorts them and renders. The sort is
the heavy step; this demo runs the *real* sort (numpy argsort as the
kernel payload) locally vs offloaded (with P2P source streaming and the
content-size extension) and reports fps + energy, including a mid-run
connection loss with graceful local fallback.

The multi-UE variant (``--multi``) attaches several phones to one
shared edge cluster (DESIGN.md §4): every UE runs the same sort loop
concurrently, device time is arbitrated by the weighted-fair scheduler,
and one straggler UE flooding the GPU cannot starve the others.

  PYTHONPATH=src python examples/ar_offload.py [--multi]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np               # noqa: E402

from repro.core import (ClientRuntime, Cluster, DeviceSpec,  # noqa: E402
                        LinkSpec, ServerSpec)

N_POINTS = 100_000
FRAMES = 12
N_UE = 6


def make_runtime():
    return ClientRuntime(
        servers=[ServerSpec("edge", [DeviceSpec("gpu", flops=4e12,
                                                mem_bw=192e9)])],
        client_link=LinkSpec(latency=1.5e-3, bandwidth=300e6 / 8),
        peer_link=LinkSpec(latency=0.2e-3, bandwidth=1e9 / 8),
        transport="tcp",
        local_device=DeviceSpec("adreno", flops=0.9e12, mem_bw=34e9))


def main():
    rng = np.random.default_rng(0)
    rt = make_runtime()

    depth_buf = rt.create_buffer(N_POINTS * 4)
    size_buf = rt.create_buffer(4)
    idx_buf = rt.create_buffer(N_POINTS * 4, content_size_buffer=size_buf)
    rt.enqueue_write("edge", size_buf,
                     np.array([N_POINTS * 4], np.uint32))
    rt.finish()

    t_wall0 = rt.clock.now
    results = []
    for frame in range(FRAMES):
        depths = rng.standard_normal(N_POINTS).astype(np.float32) + frame
        if frame == 5:
            rt.inject_disconnect("edge")     # walked out of range
        if frame == 8:
            rt.reconnect("edge")
            rt.finish()

        if rt.sessions["edge"].available:
            e1 = rt.enqueue_write("edge", depth_buf, depths)
            e2 = rt.enqueue_kernel(
                "edge", fn=lambda d: np.argsort(d)[::-1].astype(np.int32),
                inputs=[depth_buf], outputs=[idx_buf],
                bytes_moved=N_POINTS * 17 * 8, wait_for=[e1], name="sort")
            rt.enqueue_read("edge", idx_buf, wait_for=[e2])
            rt.finish()
            mode = "remote"
        else:
            depth_buf.set_data(depths, "client")
            rt.run_local_fallback(
                lambda d: np.argsort(d)[::-1].astype(np.int32),
                [depth_buf], [idx_buf],
                duration=N_POINTS * 17 * 8 / 34e9 * 3.0)  # throttled SoC
            rt.finish()
            mode = "local"
        order = np.asarray(idx_buf.data)
        correct = bool((np.diff(depths[order]) <= 1e-6).all())
        results.append((mode, correct))
    wall = rt.clock.now - t_wall0
    print(f"{FRAMES} frames in {wall*1e3:.1f} ms sim-time "
          f"({FRAMES/wall:.1f} fps average)")
    for i, (mode, ok) in enumerate(results):
        print(f"  frame {i:2d}: {mode:6s} sorted_ok={ok}")
    modes = [m for m, _ in results]
    assert modes[5] == "local" and modes[8] == "remote"
    assert all(ok for _, ok in results)
    print("graceful fallback + recovery: OK")


def multi_ue_main():
    """Several phones on one shared edge box: the fair scheduler keeps
    every UE's sort latency bounded even with a straggler tenant
    hogging the GPU."""
    cluster = Cluster(
        [ServerSpec("edge", [DeviceSpec("gpu", flops=4e12, mem_bw=192e9)])],
        peer_transport="tcp", scheduler="drr", scheduler_quantum=2e-3,
        nic_bandwidth=10e9 / 8)
    ues = [ClientRuntime(
        cluster=cluster, name=f"phone{i}",
        client_link=LinkSpec(latency=1.5e-3, bandwidth=300e6 / 8),
        transport="tcp") for i in range(N_UE)]
    straggler = ClientRuntime(
        cluster=cluster, name="straggler",
        client_link=LinkSpec(latency=1.5e-3, bandwidth=300e6 / 8),
        transport="tcp")
    cluster.run()
    for _ in range(20):          # deep backlog of 10 ms kernels
        straggler.enqueue_kernel("edge", fn=None, duration=10e-3)

    rng = np.random.default_rng(0)
    state = []
    for rt in ues:
        depth_buf = rt.create_buffer(N_POINTS * 4)
        idx_buf = rt.create_buffer(N_POINTS * 4)
        state.append((rt, depth_buf, idx_buf, []))

    t0 = cluster.clock.now
    for frame in range(FRAMES):
        evs = []
        for rt, depth_buf, idx_buf, lats in state:
            depths = rng.standard_normal(N_POINTS).astype(np.float32)
            tq = cluster.clock.now
            e1 = rt.enqueue_write("edge", depth_buf, depths)
            e2 = rt.enqueue_kernel(
                "edge", fn=lambda d: np.argsort(d)[::-1].astype(np.int32),
                inputs=[depth_buf], outputs=[idx_buf],
                bytes_moved=N_POINTS * 17 * 8, wait_for=[e1], name="sort")
            e3 = rt.enqueue_read("edge", idx_buf, wait_for=[e2])
            evs.append((e3, lats, tq, depths, idx_buf))
        cluster.run()
        for e3, lats, tq, depths, idx_buf in evs:
            lats.append(e3.t_end - tq)
            order = np.asarray(idx_buf.data)
            assert bool((np.diff(depths[order]) <= 1e-6).all())
    wall = cluster.clock.now - t0
    print(f"{N_UE} UEs x {FRAMES} frames + 1 straggler tenant in "
          f"{wall*1e3:.1f} ms sim-time")
    worst = 0.0
    for rt, _, _, lats in state:
        p95 = float(np.percentile(np.asarray(lats), 95)) * 1e3
        worst = max(worst, p95)
        print(f"  {rt.name}: p95 frame latency {p95:.1f} ms")
    # DRR bounds every UE's tail despite the 200 ms straggler backlog
    assert worst < 60.0, worst
    print("fair scheduling under a straggler tenant: OK")


if __name__ == "__main__":
    if "--multi" in sys.argv[1:]:
        multi_ue_main()
    else:
        main()
