"""AR point-cloud offloading case study (paper §7.1) — runnable demo.

A phone renders an animated point cloud: per frame it decodes a VPCC
stream, reconstructs points, depth-sorts them and renders. The sort is
the heavy step; this demo runs the *real* sort (numpy argsort as the
kernel payload) locally vs offloaded (with P2P source streaming and the
content-size extension) and reports fps + energy, including a mid-run
connection loss with graceful local fallback.

  PYTHONPATH=src python examples/ar_offload.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np               # noqa: E402

from repro.core import (ClientRuntime, DeviceSpec, LinkSpec,  # noqa: E402
                        ServerSpec)

N_POINTS = 100_000
FRAMES = 12


def make_runtime():
    return ClientRuntime(
        servers=[ServerSpec("edge", [DeviceSpec("gpu", flops=4e12,
                                                mem_bw=192e9)])],
        client_link=LinkSpec(latency=1.5e-3, bandwidth=300e6 / 8),
        peer_link=LinkSpec(latency=0.2e-3, bandwidth=1e9 / 8),
        transport="tcp",
        local_device=DeviceSpec("adreno", flops=0.9e12, mem_bw=34e9))


def main():
    rng = np.random.default_rng(0)
    rt = make_runtime()

    depth_buf = rt.create_buffer(N_POINTS * 4)
    size_buf = rt.create_buffer(4)
    idx_buf = rt.create_buffer(N_POINTS * 4, content_size_buffer=size_buf)
    rt.enqueue_write("edge", size_buf,
                     np.array([N_POINTS * 4], np.uint32))
    rt.finish()

    t_wall0 = rt.clock.now
    results = []
    for frame in range(FRAMES):
        depths = rng.standard_normal(N_POINTS).astype(np.float32) + frame
        if frame == 5:
            rt.inject_disconnect("edge")     # walked out of range
        if frame == 8:
            rt.reconnect("edge")
            rt.finish()

        if rt.sessions["edge"].available:
            e1 = rt.enqueue_write("edge", depth_buf, depths)
            e2 = rt.enqueue_kernel(
                "edge", fn=lambda d: np.argsort(d)[::-1].astype(np.int32),
                inputs=[depth_buf], outputs=[idx_buf],
                bytes_moved=N_POINTS * 17 * 8, wait_for=[e1], name="sort")
            rt.enqueue_read("edge", idx_buf, wait_for=[e2])
            rt.finish()
            mode = "remote"
        else:
            depth_buf.set_data(depths, "client")
            rt.run_local_fallback(
                lambda d: np.argsort(d)[::-1].astype(np.int32),
                [depth_buf], [idx_buf],
                duration=N_POINTS * 17 * 8 / 34e9 * 3.0)  # throttled SoC
            rt.finish()
            mode = "local"
        order = np.asarray(idx_buf.data)
        correct = bool((np.diff(depths[order]) <= 1e-6).all())
        results.append((mode, correct))
    wall = rt.clock.now - t_wall0
    print(f"{FRAMES} frames in {wall*1e3:.1f} ms sim-time "
          f"({FRAMES/wall:.1f} fps average)")
    for i, (mode, ok) in enumerate(results):
        print(f"  frame {i:2d}: {mode:6s} sorted_ok={ok}")
    modes = [m for m, _ in results]
    assert modes[5] == "local" and modes[8] == "remote"
    assert all(ok for _, ok in results)
    print("graceful fallback + recovery: OK")


if __name__ == "__main__":
    main()
