"""Quickstart: the PoCL-R offloading runtime in five minutes.

Builds a 2-server edge cluster, offloads a JAX kernel chain with P2P
buffer migration, demonstrates the content-size extension, survives a
connection loss, and prints the latency/byte accounting.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp          # noqa: E402
import numpy as np               # noqa: E402

from repro.core import (ClientRuntime, DeviceSpec, LinkSpec,  # noqa: E402
                        ServerSpec)


def main():
    # -- a phone on WiFi driving two edge servers on a fast LAN ----------
    rt = ClientRuntime(
        servers=[ServerSpec("edge0", [DeviceSpec("gpu", flops=13e12)]),
                 ServerSpec("edge1", [DeviceSpec("gpu", flops=13e12)])],
        client_link=LinkSpec(latency=1.5e-3, bandwidth=300e6 / 8),  # WiFi6
        peer_link=LinkSpec(latency=20e-6, bandwidth=40e9 / 8),      # 40G
        transport="tcp")

    # -- offload a kernel chain: edge0 → (P2P migration) → edge1 --------
    x = rt.create_buffer(1 << 16, name="x")
    y = rt.create_buffer(1 << 16, name="y")
    z = rt.create_buffer(1 << 16, name="z")
    e1 = rt.enqueue_write("edge0", x, np.arange(16384, dtype=np.float32))
    e2 = rt.enqueue_kernel("edge0", fn=lambda a: np.asarray(jnp.sqrt(a)),
                           inputs=[x], outputs=[y], flops=16384,
                           wait_for=[e1])
    # consuming y on edge1 auto-migrates it server→server, not via us
    e3 = rt.enqueue_kernel("edge1", fn=lambda a: np.asarray(a * 2),
                           inputs=[y], outputs=[z], flops=16384,
                           wait_for=[e2])
    e4 = rt.enqueue_read("edge1", z, wait_for=[e3])
    rt.finish()
    ok = np.allclose(z.data, np.sqrt(np.arange(16384)) * 2)
    print(f"chain result correct: {ok}")
    print(f"client-observed latency: {e4.latency*1e3:.2f} ms")
    st = rt.stats()
    print(f"bytes via client link: {sum(st['client_link_bytes'].values()):,.0f}")
    print(f"bytes via peer link:   {sum(st['peer_link_bytes'].values()):,.0f}")

    # -- content-size extension: ship only the used prefix --------------
    size = rt.create_buffer(4)
    big = rt.create_buffer(1 << 20, content_size_buffer=size)
    rt.enqueue_write("edge0", size, np.array([2048], np.uint32))
    rt.enqueue_write("edge0", big, np.zeros(1 << 18, np.float32))
    rt.finish()
    before = rt.peer_link("edge0", "edge1").bytes_sent
    rt.enqueue_migration(big, "edge1")
    rt.finish()
    print(f"content-size migration moved "
          f"{rt.peer_link('edge0','edge1').bytes_sent-before:,.0f} bytes "
          f"of a {1<<20:,} byte buffer")

    # -- connection loss and session resume -----------------------------
    rt.inject_disconnect("edge0")
    print(f"edge0 available after disconnect: {rt.sessions['edge0'].available}")
    rt.reconnect("edge0")
    rt.finish()
    ev = rt.enqueue_kernel("edge0", fn=None, duration=1e-6)
    rt.finish()
    print(f"after reconnect, command status: {ev.status}")


if __name__ == "__main__":
    main()
