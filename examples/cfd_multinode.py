"""Multi-node CFD (paper §7.2, FluidX3D) — runnable demo.

Runs the real JAX D2Q9 lattice-Boltzmann solver domain-decomposed over
simulated GPU servers, halo buffers migrated P2P by the PoCL-R runtime,
and verifies the distributed result is bit-identical to the monolithic
solver. Reports per-node utilization from the simulated timeline.

  PYTHONPATH=src python examples/cfd_multinode.py [--nodes 2] [--steps 20]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp          # noqa: E402
import numpy as np               # noqa: E402

from repro.apps import lbm       # noqa: E402
from repro.core import (ClientRuntime, DeviceSpec, LinkSpec,  # noqa: E402
                        ServerSpec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--size", type=int, default=64)
    args = ap.parse_args()

    H, W = args.size // 2, args.size
    f0 = lbm.init_shear(H, W)
    slabs = [np.asarray(s) for s in lbm.split_domain(f0, args.nodes)]

    rt = ClientRuntime(
        servers=[ServerSpec(f"s{i}", [DeviceSpec("a6000", flops=38.7e12)])
                 for i in range(args.nodes)],
        client_link=LinkSpec(latency=50e-6, bandwidth=1e9 / 8),
        peer_link=LinkSpec(latency=10e-6, bandwidth=100e9 / 8),
        transport="tcp")

    bufs, evs = [], []
    for i, s in enumerate(slabs):
        b = rt.create_buffer(int(s.nbytes))
        evs.append(rt.enqueue_write(f"s{i}", b, s))
        bufs.append(b)

    step_cost = H * (W // args.nodes) / 4.6e9   # FluidX3D-like LUPs model
    for _ in range(args.steps):
        ks = [rt.enqueue_kernel(
            f"s{i}",
            fn=lambda x: np.asarray(lbm.slab_step(jnp.asarray(x))),
            inputs=[bufs[i]], outputs=[bufs[i]],
            duration=step_cost, wait_for=evs) for i in range(args.nodes)]
        for i in range(args.nodes):
            rt.enqueue_read(f"s{i}", bufs[i], wait_for=ks)
        rt.finish()
        stepped = [jnp.asarray(bufs[i].data) for i in range(args.nodes)]
        exchanged = lbm.exchange_halos(stepped)
        evs = [rt.enqueue_write(f"s{i}", bufs[i], np.asarray(exchanged[i]))
               for i in range(args.nodes)]
    rt.finish()

    got = jnp.concatenate([jnp.asarray(bufs[i].data)[:, :, 1:-1]
                           for i in range(args.nodes)], axis=2)
    ref = f0
    for _ in range(args.steps):
        ref = lbm.lbm_step(ref)
    err = float(jnp.max(jnp.abs(got - ref)))
    print(f"{args.nodes} nodes × {args.steps} steps on a "
          f"{H}×{W} lattice: max|Δ| vs monolithic = {err:.2e}")
    st = rt.stats()
    horizon = rt.clock.now
    for k, busy in st["device_busy"].items():
        print(f"  {k}: utilization {busy/horizon:.1%}")
    assert err < 1e-5
    print("distributed == monolithic: OK")


if __name__ == "__main__":
    main()
