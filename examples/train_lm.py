"""End-to-end training driver: train a ~100M-parameter llama-family model
for a few hundred steps with the full production stack — data pipeline,
AdamW, remat, checkpointing, fault-tolerant loop — on the local device.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                       # noqa: E402

from repro.configs.shapes import ShapeCell          # noqa: E402
from repro.data.pipeline import DataLoader          # noqa: E402
from repro.launch import specs as lspecs            # noqa: E402
from repro.models.config import LayerKind, ModelConfig  # noqa: E402
from repro.configs import RunOverrides              # noqa: E402
from repro.optim import AdamW, cosine_schedule      # noqa: E402
from repro.training.loop import LoopConfig, Trainer  # noqa: E402
from repro.training.step import make_train_step     # noqa: E402


def model_100m() -> ModelConfig:
    # ~93M params: a llama-family config sized for a CPU-hour
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=10, d_model=768,
        n_heads=12, n_kv=4, d_ff=2304, vocab=32000,
        pattern=(LayerKind(),), tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = model_100m()
    run = RunOverrides()
    opt = AdamW(cosine_schedule(3e-4, args.steps // 10, args.steps))
    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=1,
                                      remat="dots"),
                      donate_argnums=(0,))
    cell = ShapeCell("train", "train", args.seq, args.batch)
    loader = DataLoader(cfg, cell, 1, seed=0)
    state = lspecs.init_train_state(cfg, None, run, opt,
                                    jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {n_params/1e6:.1f}M params, "
          f"{args.batch}x{args.seq} tokens/step")

    tr = Trainer(step_fn, state, loader,
                 LoopConfig(total_steps=args.steps,
                            ckpt_every=max(args.steps // 3, 1),
                            ckpt_dir=args.ckpt_dir, log_every=20))
    resumed = tr.maybe_restore()
    if resumed:
        print(f"resumed from checkpoint at step {tr.step}")
    t0 = time.perf_counter()
    out = tr.run()
    dt = time.perf_counter() - t0
    loader.stop()
    for row in out["log"]:
        print(f"step {row['step']:4d}  loss {row['loss']:.4f}  "
              f"lr {row['lr']:.2e}  {row['sec_per_step']*1e3:.0f} ms/step")
    toks = args.batch * args.seq * (args.steps - (tr.step - args.steps))
    print(f"final loss {out['final_loss']:.4f}; "
          f"{dt:.0f}s wall ({args.batch*args.seq/ (dt/args.steps):.0f} tok/s)")


if __name__ == "__main__":
    main()
