"""Content-addressed cross-tenant buffer store + tenant lifecycle
(DESIGN.md §5): dedup'd uploads (resident and in-flight), cross-tenant
migration sourcing, copy-on-write forks, LRU eviction under capacity,
and ClientRuntime.detach()."""
import numpy as np
import pytest

from repro.core import (ClientRuntime, Cluster, DeviceSpec, LinkSpec,
                        ServerSpec, content_digest)
from repro.core.events import COMPLETE, ERROR
from repro.core.scheduler import DRRPolicy, FIFOPolicy

MiB = 1 << 20


def mk_cluster(n=3, store=True, capacity=None, scheduler="fifo",
               peer_bw=40e9 / 8):
    return Cluster([ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                    for i in range(n)],
                   peer_link=LinkSpec(latency=20e-6, bandwidth=peer_bw),
                   peer_transport="tcp", scheduler=scheduler,
                   store=store, store_capacity=capacity)


def attach(cluster, **kw):
    kw.setdefault("client_link", LinkSpec(latency=61e-6, bandwidth=1e9 / 8))
    return ClientRuntime(cluster=cluster, **kw)


def payload(fill=1, words=MiB // 4):
    return np.full(words, fill, np.uint32)


# ---- digesting ----

def test_digest_identity_and_dtype_sensitivity():
    a = np.zeros(64, np.uint32)
    assert content_digest(a) == content_digest(np.zeros(64, np.uint32))
    assert content_digest(a) != content_digest(np.zeros(64, np.int32))
    assert content_digest(a) != content_digest(np.ones(64, np.uint32))


# ---- dedup'd uploads ----

def test_second_identical_upload_is_command_only():
    cluster = mk_cluster()
    a, b = attach(cluster, name="a"), attach(cluster, name="b")
    cluster.run()
    ba, bb = a.create_buffer(MiB), b.create_buffer(MiB)
    a.enqueue_write("s0", ba, payload())
    cluster.run()
    pre = b.c_links["s0"].bytes_sent
    ev = b.enqueue_write("s0", bb, payload())
    cluster.run()
    assert ev.status == COMPLETE
    assert b.c_links["s0"].bytes_sent - pre < 1024   # cmd + digest only
    assert b.dedup_hits == 1
    assert b.dedup_bytes_saved == MiB
    assert cluster.store.stats()["dedup_hits"] == 1
    np.testing.assert_array_equal(bb.data, payload())
    assert "s0" in bb.valid_on


def test_different_content_pays_full_upload():
    cluster = mk_cluster()
    a, b = attach(cluster, name="a"), attach(cluster, name="b")
    cluster.run()
    a.enqueue_write("s0", a.create_buffer(MiB), payload(1))
    cluster.run()
    pre = b.c_links["s0"].bytes_sent
    b.enqueue_write("s0", b.create_buffer(MiB), payload(2))
    cluster.run()
    assert b.c_links["s0"].bytes_sent - pre > MiB
    assert b.dedup_hits == 0


def test_upload_racing_identical_inflight_upload_gates_not_resends():
    """Tenant b enqueues the same content while a's upload is still
    crawling up the radio: b must send only the command, and must not
    complete before the shared replica actually lands."""
    cluster = mk_cluster()
    a, b = attach(cluster, name="a"), attach(cluster, name="b")
    cluster.run()
    ev_a = a.enqueue_write("s0", a.create_buffer(4 * MiB),
                           payload(words=MiB))
    # no drain: a's 4 MiB is still in flight when b enqueues
    pre = b.c_links["s0"].bytes_sent
    ev_b = b.enqueue_write("s0", b.create_buffer(4 * MiB),
                           payload(words=MiB))
    cluster.run()
    assert ev_a.status == COMPLETE and ev_b.status == COMPLETE
    assert b.c_links["s0"].bytes_sent - pre < 1024
    assert b.dedup_hits == 1
    assert ev_b.t_end >= ev_a.t_end     # gated on the replica landing


def test_store_disabled_by_default_keeps_private_copies():
    cluster = mk_cluster(store=False)
    assert cluster.store is None
    a, b = attach(cluster, name="a"), attach(cluster, name="b")
    cluster.run()
    a.enqueue_write("s0", a.create_buffer(MiB), payload())
    cluster.run()
    pre = b.c_links["s0"].bytes_sent
    b.enqueue_write("s0", b.create_buffer(MiB), payload())
    cluster.run()
    assert b.c_links["s0"].bytes_sent - pre > MiB    # full private copy
    assert b.dedup_hits == 0


# ---- cross-tenant migrations ----

def _seed_two_tenants(cluster, nbytes=MiB):
    a, b = attach(cluster, name="a"), attach(cluster, name="b")
    cluster.run()
    ba, bb = a.create_buffer(nbytes), b.create_buffer(nbytes)
    a.enqueue_write("s0", ba, payload(words=nbytes // 4))
    b.enqueue_write("s0", bb, payload(words=nbytes // 4))
    cluster.run()
    return a, b, ba, bb


def peer_bytes(cluster):
    return sum(lk.bytes_sent for lk in cluster.p_links.values())


def test_migration_dedups_against_other_tenants_replica():
    cluster = mk_cluster()
    a, b, ba, bb = _seed_two_tenants(cluster)
    a.enqueue_migration(ba, "s1")
    cluster.run()
    mid = peer_bytes(cluster)
    ev = b.enqueue_migration(bb, "s1")
    cluster.run()
    assert ev.status == COMPLETE
    assert peer_bytes(cluster) == mid       # zero payload bytes moved
    assert "s1" in bb.valid_on
    assert b.dedup_hits >= 1


def test_migration_rides_other_tenants_inflight_transfer():
    cluster = mk_cluster(peer_bw=1e9 / 8)    # slow peers: push takes time
    a, b, ba, bb = _seed_two_tenants(cluster, nbytes=4 * MiB)
    ev_a = a.enqueue_migration(ba, "s1")
    cluster.run(until=cluster.clock.now + 1e-3)   # push mid-flight
    assert ev_a.status != COMPLETE
    mid = peer_bytes(cluster)
    ev_b = b.enqueue_migration(bb, "s1")
    cluster.run()
    assert ev_a.status == COMPLETE and ev_b.status == COMPLETE
    assert peer_bytes(cluster) == mid        # b rode a's payload
    assert ev_b.t_end >= ev_a.t_end
    assert "s1" in bb.valid_on


def test_migration_sources_from_any_tenants_replica():
    """Only tenant a ever put the content on s1; b's migration to s2 can
    still be served from s1 when s0's egress is the worse source."""
    cluster = mk_cluster(n=4)
    a, b, ba, bb = _seed_two_tenants(cluster)
    a.enqueue_migration(ba, "s1")
    cluster.run()
    sentry = cluster.store.entry_for(bb)
    assert sentry.valid_on >= {"s0", "s1"}
    # make s0 an expensive source: its link to s2 is backed up
    cluster.peer_link("s0", "s2")._busy_until = cluster.clock.now + 1.0
    srcs = sorted({s for s in bb.valid_on if s != "client"}
                  | sentry.valid_on)
    assert b._pick_migration_source(bb, srcs, "s2") == "s1"
    link_pre = cluster.peer_link("s1", "s2").bytes_sent
    ev = b.enqueue_migration(bb, "s2")
    cluster.run()
    assert ev.status == COMPLETE
    assert cluster.peer_link("s1", "s2").bytes_sent > link_pre + MiB
    assert "s2" in bb.valid_on


# ---- copy-on-write ----

def test_kernel_write_forks_shared_buffer_and_leaves_replicas():
    cluster = mk_cluster()
    a, b, ba, bb = _seed_two_tenants(cluster)
    sentry = cluster.store.entry_for(ba)
    assert sentry is cluster.store.entry_for(bb)
    assert len(sentry.refs) == 2
    a.enqueue_kernel("s0", fn=lambda x: x + 1, inputs=[ba], outputs=[ba],
                     duration=1e-4)
    cluster.run()
    # a forked to a private buffer; b's attachment and the shared
    # replica set are untouched
    assert ba.store_key is None
    assert cluster.store.entry_for(ba) is None
    assert cluster.store.entry_for(bb) is sentry
    assert sentry.refs == {bb.id}
    assert "s0" in sentry.valid_on
    assert cluster.store.cow_forks == 1
    np.testing.assert_array_equal(ba.data, payload() + 1)
    np.testing.assert_array_equal(bb.data, payload())
    # b still dedups against the surviving replica
    ev = b.enqueue_migration(bb, "s1")
    cluster.run()
    assert ev.status == COMPLETE


def test_rewrite_reattaches_to_new_entry():
    cluster = mk_cluster()
    a = attach(cluster, name="a")
    cluster.run()
    buf = a.create_buffer(MiB)
    a.enqueue_write("s0", buf, payload(1))
    cluster.run()
    k1 = buf.store_key
    a.enqueue_write("s0", buf, payload(2))
    cluster.run()
    assert buf.store_key is not None and buf.store_key != k1
    entry = cluster.store.entry_for(buf)
    assert entry.key == buf.store_key and "s0" in entry.valid_on


# ---- eviction ----

def test_lru_eviction_of_unreferenced_replicas_under_capacity():
    cluster = mk_cluster(n=1, capacity=2 * MiB)
    a = attach(cluster, name="a")
    cluster.run()
    store = cluster.store
    # three distinct 1 MiB contents through the same (rewritten) buffer:
    # each rewrite detaches the previous entry, leaving its replica
    # cached but unreferenced
    buf = a.create_buffer(MiB)
    for fill in (1, 2, 3):
        a.enqueue_write("s0", buf, payload(fill))
        cluster.run()
    assert store.resident_bytes["s0"] <= 2 * MiB
    assert store.evictions >= 1
    # the evicted (least recently used) content was fill=1: uploading it
    # again pays the payload; fill=3 is still resident and dedups
    c = attach(cluster, name="c")
    cluster.run()
    pre = c.c_links["s0"].bytes_sent
    c.enqueue_write("s0", c.create_buffer(MiB), payload(3))
    cluster.run()
    assert c.c_links["s0"].bytes_sent - pre < 1024   # cache hit
    pre = c.c_links["s0"].bytes_sent
    c.enqueue_write("s0", c.create_buffer(MiB), payload(1))
    cluster.run()
    assert c.c_links["s0"].bytes_sent - pre > MiB    # evicted: full pay


def test_referenced_replicas_are_pinned():
    cluster = mk_cluster(n=1, capacity=MiB)
    a = attach(cluster, name="a")
    cluster.run()
    b1, b2 = a.create_buffer(MiB), a.create_buffer(MiB)
    a.enqueue_write("s0", b1, payload(1))
    cluster.run()
    a.enqueue_write("s0", b2, payload(2))
    cluster.run()
    store = cluster.store
    # both entries referenced by live buffers: nothing evictable, the
    # store runs over capacity rather than dropping live data
    assert store.evictions == 0
    assert store.resident_bytes["s0"] == 2 * MiB
    e1 = store.entry_for(b1)
    assert "s0" in e1.valid_on


# ---- tenant detach ----

def test_detach_fails_pending_events_and_cleans_server_state():
    cluster = mk_cluster(scheduler="drr")
    a, b = attach(cluster, name="a"), attach(cluster, name="b")
    cluster.run()
    sid = a.sessions["s0"].session_id
    evs = [a.enqueue_kernel("s0", fn=None, duration=5e-3)
           for _ in range(8)]
    cluster.run(until=cluster.clock.now + 6e-3)   # first kernel done
    a.detach()
    assert a.detached
    done = [e for e in evs if e.status == COMPLETE]
    dead = [e for e in evs if e.status == ERROR]
    assert dead and len(done) + len(dead) == len(evs)
    assert all("detached" in e.error for e in dead)
    # host-side lifecycle: session table entry gone, run queues drained
    assert sid not in cluster.hosts["s0"].sessions
    assert cluster.stats()["sessions"] == {h: 1 for h in cluster.hosts}
    assert cluster.stats()["clients"] == ["b"]
    with pytest.raises(Exception):
        a.enqueue_kernel("s0", fn=None, duration=1e-3)
    with pytest.raises(Exception):
        a.reconnect("s0")
    cluster.run()                                  # cluster still drains
    # bystander unaffected functionally
    ev = b.enqueue_kernel("s0", fn=None, duration=1e-3)
    cluster.run()
    assert ev.status == COMPLETE


def test_detach_mid_flight_does_not_perturb_bystander_timestamps():
    """Tenant a churns s0 and detaches mid-run; the bystander's chain on
    s1 (own device, own links) must be bit-identical to the run where a
    works to completion — detach may only free capacity, never touch
    shared state a bystander's timing derives from."""
    def scenario(detach_mid: bool):
        cluster = mk_cluster(n=2)
        a, b = attach(cluster, name="a"), attach(cluster, name="b")
        cluster.run()
        buf_a = a.create_buffer(MiB)
        a.enqueue_write("s0", buf_a, payload(7))
        prev = ()
        for _ in range(6):
            prev = (a.enqueue_kernel("s0", fn=None, duration=4e-3,
                                     wait_for=prev),)
        bb = b.create_buffer(64)
        prev_b = b.enqueue_write("s1", bb, np.zeros(16, np.float32))
        b_events = [prev_b]
        for _ in range(6):
            prev_b = b.enqueue_kernel("s1", fn=None, duration=2e-3,
                                      wait_for=[prev_b])
            b_events.append(prev_b)
        if detach_mid:
            cluster.clock.schedule(5e-3, a.detach)
        cluster.run()
        assert all(e.status == COMPLETE for e in b_events)
        return [(e.t_submitted, e.t_start, e.t_end, e.t_client_ack)
                for e in b_events]

    assert scenario(detach_mid=True) == scenario(detach_mid=False)


def test_detach_releases_store_refs_making_replicas_evictable():
    cluster = mk_cluster(n=1, capacity=MiB)
    a = attach(cluster, name="a")
    cluster.run()
    buf = a.create_buffer(MiB)
    a.enqueue_write("s0", buf, payload(1))
    cluster.run()
    store = cluster.store
    assert store.entry_for(buf) is not None
    a.detach()
    assert buf.store_key is None
    assert store.stats()["attached_buffers"] == 0
    # the replica is now plain cache: a new tenant's different content
    # evicts it under the 1 MiB capacity
    c = attach(cluster, name="c")
    cluster.run()
    c.enqueue_write("s0", c.create_buffer(MiB), payload(2))
    cluster.run()
    assert store.evictions == 1
    assert store.resident_bytes["s0"] == MiB


def test_detach_then_reattach_does_not_resurrect_replay_dedup():
    """§4.3 + §5: a session id that detached presents as a FRESH session
    — command ids the dead session processed must execute again, not be
    swallowed by resurrected dedup state."""
    cluster = mk_cluster(n=1)
    a = attach(cluster, name="a")
    cluster.run()
    calls = {"n": 0}

    def bump(x):
        calls["n"] += 1
        return x + 1.0

    buf = a.create_buffer(64)
    a.enqueue_write("s0", buf, np.zeros(16, np.float32))
    ev = a.enqueue_kernel("s0", fn=bump, inputs=[buf], outputs=[buf],
                          duration=1e-3)
    cluster.run()
    assert calls["n"] == 1
    cmd_id = ev.command.id
    sid = a.sessions["s0"].session_id
    a.detach()
    assert a.servers["s0"].processed == set()
    # reattach: a new runtime joins; even presenting the recycled
    # session id resolves no daemon state
    c = attach(cluster, name="a2")
    cluster.run()
    assert sid not in cluster.hosts["s0"].sessions
    # replaying the dead session's command id against the new session
    # executes — nothing remembers it was ever processed
    buf2 = c.create_buffer(64)
    c.enqueue_write("s0", buf2, np.zeros(16, np.float32))
    cluster.run()
    replay = c.enqueue_kernel("s0", fn=bump, inputs=[buf2], outputs=[buf2],
                              duration=1e-3)
    replay.command.id = cmd_id        # recycled command id
    cluster.run()
    assert replay.status == COMPLETE
    assert calls["n"] == 2            # executed, not deduped


def test_gated_dedup_write_falls_back_when_uploader_detaches():
    """b and c gated identical uploads on a's in-flight copy; a detaches
    (failing the transfer) — ONE of them must pay the payload (not
    both: the survivors re-resolve against each other's fallback), the
    claimed dedup savings are taken back for the payer, and nobody
    hangs or completes without data."""
    cluster = mk_cluster()
    a, b, c = (attach(cluster, name=n) for n in "abc")
    cluster.run()
    a.enqueue_write("s0", a.create_buffer(4 * MiB), payload(words=MiB))
    ev_b = b.enqueue_write("s0", b.create_buffer(4 * MiB),
                           payload(words=MiB))
    ev_c = c.enqueue_write("s0", c.create_buffer(4 * MiB),
                           payload(words=MiB))
    pre_b = b.c_links["s0"].bytes_sent
    pre_c = c.c_links["s0"].bytes_sent
    a.detach()                        # kills a's in-flight upload event
    cluster.run()
    assert ev_b.status == COMPLETE and ev_c.status == COMPLETE
    paid_b = b.c_links["s0"].bytes_sent - pre_b > 4 * MiB
    paid_c = c.c_links["s0"].bytes_sent - pre_c > 4 * MiB
    assert paid_b != paid_c           # exactly one pays in full
    payer, rider = (b, c) if paid_b else (c, b)
    # the payer's claimed saving was reverted; the rider's stands
    assert payer.dedup_hits == 0 and payer.dedup_bytes_saved == 0.0
    assert rider.dedup_hits == 1 and rider.dedup_bytes_saved == 4 * MiB
    assert cluster.store.stats()["dedup_hits"] == 1


def test_gated_write_superseded_by_later_write_keeps_waw_order():
    """b's write of X gates on a's in-flight upload; b then writes Y to
    the same buffer (sent immediately). When the gate resolves, the
    stale X command must NOT ship after Y — store-less clusters send
    writes FIFO, so the last write applied on the server must be Y."""
    cluster = mk_cluster()
    a, b = attach(cluster, name="a"), attach(cluster, name="b")
    cluster.run()
    a.enqueue_write("s0", a.create_buffer(4 * MiB), payload(1, MiB))
    bb = b.create_buffer(4 * MiB)
    e_x = b.enqueue_write("s0", bb, payload(1, MiB))   # gates on a's
    e_y = b.enqueue_write("s0", bb, payload(2, MiB))   # sent at once
    cluster.run()
    assert e_x.status == COMPLETE and e_y.status == COMPLETE
    # the canonical contents are Y — X was superseded, never applied
    np.testing.assert_array_equal(bb.data, payload(2, MiB))
    assert cluster.store.entry_for(bb).key == content_digest(
        payload(2, MiB))


def test_default_tenant_names_do_not_recycle_after_detach():
    cluster = mk_cluster()
    t0, t1, t2 = (attach(cluster) for _ in range(3))
    assert [t.name for t in (t0, t1, t2)] == ["ue0", "ue1", "ue2"]
    t0.detach()
    t3 = attach(cluster)
    assert t3.name == "ue3"                     # not a recycled "ue2"
    assert len({c.name for c in cluster.clients}) == len(cluster.clients)


def test_ride_retry_does_not_coalesce_onto_dead_ride():
    """b rode a's in-flight migration; a detaches mid-push. b's fallback
    migration must not coalesce onto b's own dead ride (same key, same
    version) — that would wait on an event only the fallback itself can
    complete, hanging forever."""
    cluster = mk_cluster(peer_bw=1e9 / 8)
    a, b, ba, bb = _seed_two_tenants(cluster, nbytes=4 * MiB)
    ev_a = a.enqueue_migration(ba, "s1")
    cluster.run(until=cluster.clock.now + 1e-3)   # a's push mid-flight
    assert ev_a.status != COMPLETE
    saved_pre = b.dedup_bytes_saved   # seed write's (real) dedup credit
    ev_b = b.enqueue_migration(bb, "s1")          # rides a's transfer
    assert b.dedup_bytes_saved == saved_pre + 4 * MiB
    a.detach()                                    # kills the ride
    cluster.run()
    assert ev_b.status == COMPLETE                # fallback ran
    assert "s1" in bb.valid_on
    # the claimed ride saving was reverted when the fallback paid
    assert b.dedup_bytes_saved == saved_pre


def test_rewrite_during_upload_does_not_leak_resident_bytes():
    """Content X's upload is in flight when the buffer is rewritten with
    content Y: X's entry loses its last ref, but when the upload lands
    the replica must register on the still-tracked entry (a refcount-0
    cache replica), not resurrect a garbage-collected orphan whose
    resident bytes could never be reclaimed."""
    cluster = mk_cluster(n=1)
    rt = attach(cluster, name="a")
    cluster.run()
    buf = rt.create_buffer(MiB)
    rt.enqueue_write("s0", buf, payload(1))       # X: in flight
    rt.enqueue_write("s0", buf, payload(2))       # Y: rewrite, X orphaned
    cluster.run()
    store = cluster.store
    tracked = sum(e.nbytes for e in store._entries.values()
                  if "s0" in e.valid_on)
    assert store.resident_bytes["s0"] == tracked == 2 * MiB
    # X's replica is real cache: a later identical upload dedups
    c = attach(cluster, name="c")
    cluster.run()
    pre = c.c_links["s0"].bytes_sent
    c.enqueue_write("s0", c.create_buffer(MiB), payload(1))
    cluster.run()
    assert c.c_links["s0"].bytes_sent - pre < 1024


def test_command_arriving_after_dep_failed_does_not_hang():
    """Loose error-dependency semantics on the wire: a command whose
    dependency FAILS while the command struct is still in flight must
    treat the dep as finished on arrival — registering a completion
    callback on an already-failed event would never fire and the
    command (and every dependent) would hang forever."""
    cluster = mk_cluster(n=2, store=False)
    rt = attach(cluster, name="a")
    cluster.run()
    buf = rt.create_buffer(MiB)
    buf.data = np.zeros(MiB // 4, np.uint32)
    buf.valid_on = {"s0"}
    cluster.peer_link("s0", "s1").up = False      # push will be dropped
    mig = rt.enqueue_migration(buf, "s1")
    # enqueued while mig is still live: the dep ships with the command
    kern = rt.enqueue_kernel("s1", fn=None, duration=1e-3,
                             wait_for=[mig])
    cluster.run()
    assert mig.status == ERROR
    assert kern.status == COMPLETE                # ran despite failed dep


# ---- scheduler removal units ----

def test_fifo_policy_remove_drops_only_that_tenant():
    p = FIFOPolicy()
    for i in range(6):
        p.push("a" if i % 2 else "b", 1.0, 1.0, f"job{i}")
    assert p.remove("a") == 3
    assert [p.pop() for _ in range(3)] == ["job0", "job2", "job4"]
    assert p.pop() is None


def test_drr_policy_remove_mid_rotation():
    p = DRRPolicy(quantum=1.0)
    for i in range(3):
        p.push("a", 1.0, 1.0, f"a{i}")
        p.push("b", 1.0, 1.0, f"b{i}")
    assert p.pop() == "a0"
    assert p.remove("a") == 2
    assert [p.pop() for _ in range(3)] == ["b0", "b1", "b2"]
    assert p.pop() is None
    assert p.remove("missing") == 0
