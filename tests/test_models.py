"""Per-architecture smoke tests: reduced configs, forward + one train
step on CPU, asserting output shapes and finiteness; serving consistency
(prefill + decode == teacher forcing) for deterministic-routing archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import ShapeCell
from repro.data.pipeline import DataLoader
from repro.models import lm, specs
from repro.optim import AdamW, constant_schedule
from repro.training.step import make_train_step

ARCHS = configs.ARCH_IDS


def _params(cfg, seed=0):
    return specs.init_from_specs(jax.random.PRNGKey(seed),
                                 specs.model_param_specs(cfg))


def _batch(cfg, B=2, S=64, A=1, seed=0):
    cell = ShapeCell("t", "train", S, B * A)
    return DataLoader(cfg, cell, A, seed=seed).make_batch(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = configs.get_reduced(arch)
    params = _params(cfg)
    mb = jax.tree.map(lambda x: x[0], _batch(cfg))
    h, aux = lm.forward(params, cfg, mb)
    assert h.shape == (2, 64, cfg.d_model)
    logits = lm.unembed(params, cfg, h)
    assert logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_descends(arch):
    cfg = configs.get_reduced(arch)
    opt = AdamW(constant_schedule(1e-3))
    step = jax.jit(make_train_step(cfg, opt, microbatches=2))
    params = _params(cfg)
    state = __import__("repro.optim.adamw", fromlist=["TrainState"]).TrainState(
        params=params, opt=opt.init(params))
    losses = []
    for i in range(4):
        state, metrics = step(state, _batch(cfg, B=2, S=64, A=2, seed=i))
        assert bool(jnp.isfinite(metrics["loss"])), arch
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (arch, losses)


# bf16 KV-cache accuracy budget per architecture: the serving default is
# a bf16 cache, and this test runs the REAL serving path, so each arch
# gets an honest budget (~2x its measured max relative logit error)
# rather than an f32-cache pin. The reduced gemma3 config (hd=16,
# qk-norm, windowed layers) amplifies bf16 cache rounding to ~8%; the
# wiring itself is exact — a wiring bug produces O(1) relative error and
# still trips every budget below.
BF16_CACHE_REL_TOL = {
    "tinyllama-1.1b": 0.02,   # measured 0.009
    "gemma3-1b": 0.15,        # measured 0.084 (bf16-rounding amplifier)
    "mamba2-780m": 0.05,      # measured 0.023 (SSM residual carry)
    "command-r-35b": 0.02,    # measured 0.008
    "whisper-small": 0.02,    # measured 0.010
}


@pytest.mark.parametrize("arch", sorted(BF16_CACHE_REL_TOL))
def test_prefill_decode_matches_forward(arch):
    """Serving path == teacher forcing (deterministic-routing archs),
    run with the serving-default bf16 cache under the per-arch accuracy
    budget above."""
    cfg = configs.get_reduced(arch)
    params = _params(cfg, seed=1)
    B, S, P = 2, 32, 24
    kd = jax.random.PRNGKey(7)
    tokens = jax.random.randint(kd, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(kd, (B, 16, cfg.d_model))
        batch["enc_embeds"] = enc
    ref = lm.full_logits(params, cfg, batch)

    cache = lm.init_cache(cfg, B, S + 4, dtype=jnp.bfloat16,
                          enc_len=16 if cfg.is_encdec else 0)
    logits, cache = lm.prefill(params, cfg, cache, tokens=tokens[:, :P],
                               enc_embeds=enc, chunk=8)
    errs = [float(jnp.max(jnp.abs(logits - ref[:, P - 1])))]
    for t in range(P, S):
        logits, cache = lm.decode_step(params, cfg, cache, tokens[:, t])
        errs.append(float(jnp.max(jnp.abs(logits - ref[:, t]))))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert max(errs) / scale < BF16_CACHE_REL_TOL[arch], \
        (arch, max(errs), scale)


@pytest.mark.parametrize("arch", ["grok-1-314b", "llama4-scout-17b-a16e",
                                  "jamba-v0.1-52b"])
def test_moe_decode_with_ample_capacity(arch):
    """With no capacity drops, MoE serving matches teacher forcing.

    Runs fp32 end-to-end: in bf16 the router's top-k can legitimately
    flip between the serve and train compute orders (routing-boundary
    instability inherent to MoE), which is not what this test probes."""
    import dataclasses
    cfg = configs.get_reduced(arch)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = _params(cfg, seed=2)
    B, S, P = 2, 16, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    ref = lm.full_logits(params, cfg, {"tokens": tokens},
                         dtype=jnp.float32)
    cache = lm.init_cache(cfg, B, S + 2, dtype=jnp.float32)
    logits, cache = lm.prefill(params, cfg, cache, tokens=tokens[:, :P],
                               dtype=jnp.float32)
    errs = [float(jnp.max(jnp.abs(logits - ref[:, P - 1])))]
    for t in range(P, S):
        logits, cache = lm.decode_step(params, cfg, cache, tokens[:, t],
                                       dtype=jnp.float32)
        errs.append(float(jnp.max(jnp.abs(logits - ref[:, t]))))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert max(errs) / scale < 0.02, (arch, max(errs))


def test_param_counts_match_analytic():
    for arch in ARCHS:
        cfg = configs.get_config(arch)
        n_spec = specs.spec_param_count(specs.model_param_specs(cfg))
        assert n_spec == cfg.param_count(), arch


def test_remat_group_equivalence():
    """Nested remat must not change the math."""
    cfg = configs.get_reduced("llava-next-mistral-7b")  # 3 layers → pad
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=4)
    params = _params(cfg, seed=4)
    mb = {"embeds": jax.random.normal(jax.random.PRNGKey(0),
                                      (2, 32, cfg.d_model)),
          "labels": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                       cfg.vocab)}
    h1, _ = lm.forward(params, cfg, mb, remat="full", remat_group=1)
    h2, _ = lm.forward(params, cfg, mb, remat="full", remat_group=2)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=1e-3)
