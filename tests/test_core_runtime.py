"""PoCL-R runtime semantics: latency model, P2P vs client-routed paths,
content-size migrations, sessions/reconnect, and a hypothesis property
test executing random command DAGs."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback, see _hypothesis_stub
    from _hypothesis_stub import given, settings, st

from repro.core import (ClientRuntime, DeviceSpec, DeviceUnavailable,
                        LinkSpec, ServerSpec)


def mk(transport="tcp", scheduling="decentralized", p2p=True, n=2,
       client_bw=100e6 / 8, peer_bw=40e9 / 8):
    return ClientRuntime(
        servers=[ServerSpec(f"s{i}", [DeviceSpec("gpu0")]) for i in range(n)],
        client_link=LinkSpec(latency=61e-6, bandwidth=client_bw),
        peer_link=LinkSpec(latency=20e-6, bandwidth=peer_bw),
        transport=transport, scheduling=scheduling, p2p_migration=p2p)


def test_noop_latency_near_paper():
    """Paper Fig. 8: no-op command ≈ ping RTT + ~60 µs runtime overhead."""
    rt = mk()
    t0 = rt.clock.now
    ev = rt.enqueue_kernel("s0", fn=None, duration=0.0)
    rt.finish()
    overhead = (ev.t_client_ack - t0) - rt.c_links["s0"].rtt()
    assert 20e-6 < overhead < 120e-6, overhead


def test_p2p_chain_functional():
    rt = mk()
    a = rt.create_buffer(4096)
    out = rt.create_buffer(4096)
    out2 = rt.create_buffer(4096)
    e1 = rt.enqueue_write("s0", a, np.arange(1024, dtype=np.float32))
    e2 = rt.enqueue_kernel("s0", fn=lambda x: x * 2, inputs=[a],
                           outputs=[out], wait_for=[e1])
    e3 = rt.enqueue_kernel("s1", fn=lambda x: x + 1, inputs=[out],
                           outputs=[out2], wait_for=[e2])
    rt.enqueue_read("s1", out2, wait_for=[e3])
    rt.finish()
    np.testing.assert_array_equal(out2.data, np.arange(1024) * 2 + 1)
    # data went over the peer link, not back through the client
    assert rt.stats()["peer_link_bytes"]["s0-s1"] >= 4096


def test_p2p_faster_than_client_routed():
    """Paper §5.1: P2P migration avoids the slow client link entirely."""
    times = {}
    for p2p in (True, False):
        rt = mk(p2p=p2p)
        b = rt.create_buffer(1 << 20)
        e1 = rt.enqueue_write("s0", b, np.zeros(1 << 18, np.float32))
        e2 = rt.enqueue_kernel("s0", fn=lambda x: x + 1, inputs=[b],
                               outputs=[b], duration=1e-6, wait_for=[e1])
        e3 = rt.enqueue_kernel("s1", fn=lambda x: x * 3, inputs=[b],
                               outputs=[b], duration=1e-6, wait_for=[e2])
        rt.finish()
        times[p2p] = e3.t_end
    assert times[True] < times[False] / 2, times


def test_decentralized_beats_client_scheduling():
    """Paper §5.2/Fig. 9: dependent cross-server commands start without a
    client round-trip under decentralized completion propagation."""
    times = {}
    for sched in ("decentralized", "client"):
        rt = mk(scheduling=sched, n=2)
        b = rt.create_buffer(4)
        e1 = rt.enqueue_write("s0", b, np.zeros(1, np.float32))
        e2 = rt.enqueue_kernel("s0", fn=None, inputs=[], outputs=[],
                               duration=1e-6, wait_for=[e1])
        # dependent no-data command on the other server
        e3 = rt.enqueue_kernel("s1", fn=None, duration=1e-6, wait_for=[e2])
        rt.finish()
        times[sched] = e3.t_end
    assert times["decentralized"] < times["client"], times


def test_content_size_migration():
    """Paper §5.3: only the used prefix crosses the wire."""
    rt = mk()
    size_buf = rt.create_buffer(4, name="content_size")
    big = rt.create_buffer(1 << 20, content_size_buffer=size_buf)
    rt.enqueue_write("s0", size_buf, np.array([4096], np.uint32))
    rt.enqueue_write("s0", big, np.zeros(1 << 18, np.float32))
    rt.finish()
    before = rt.peer_link("s0", "s1").bytes_sent
    rt.enqueue_migration(big, "s1")
    rt.finish()
    moved = rt.peer_link("s0", "s1").bytes_sent - before
    assert moved < 16384, moved         # ≈4096/η + command struct
    # without the extension the full MiB would have moved
    rt2 = mk()
    b2 = rt2.create_buffer(1 << 20)
    rt2.enqueue_write("s0", b2, np.zeros(1 << 18, np.float32))
    rt2.finish()
    before2 = rt2.peer_link("s0", "s1").bytes_sent
    rt2.enqueue_migration(b2, "s1")
    rt2.finish()
    assert rt2.peer_link("s0", "s1").bytes_sent - before2 >= (1 << 20)


def test_rdma_faster_than_tcp_for_large_buffers():
    times = {}
    for tr in ("tcp", "rdma"):
        rt = mk(transport=tr)
        b = rt.create_buffer(64 << 20)
        rt.enqueue_write("s0", b, np.zeros(16 << 20, np.float32))
        rt.finish()
        t0 = rt.clock.now
        rt.enqueue_migration(b, "s1")
        rt.finish()
        times[tr] = rt.clock.now - t0
    assert times["rdma"] < times["tcp"], times


def test_disconnect_reconnect_replay():
    """Paper §4.3: device-unavailable error, session resume, replay+dedup."""
    rt = mk()
    rt.inject_disconnect("s0")
    with pytest.raises(DeviceUnavailable):
        rt.enqueue_kernel("s0", fn=None, duration=0)
    sess_before = rt.sessions["s0"].session_id
    rt.reconnect("s0")
    rt.finish()
    assert rt.sessions["s0"].available
    ev = rt.enqueue_kernel("s0", fn=None, duration=0)
    rt.finish()
    assert ev.status == "complete"
    # server must not double-process replayed command ids
    srv = rt.servers["s0"]
    assert len(srv.processed) == len(set(srv.processed))


def test_local_fallback():
    """Fig. 4: compute locally (reduced model) while remotes are gone."""
    rt = mk()
    rt.inject_disconnect("s0")
    b = rt.create_buffer(64)
    b.set_data(np.arange(16, dtype=np.float32), "client")
    ev = rt.run_local_fallback(lambda x: x * 0.5, [b], [b], duration=1e-3)
    rt.finish()
    assert ev.status == "complete"
    np.testing.assert_array_equal(b.data, np.arange(16) * 0.5)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_random_dag_executes_like_serial(data):
    """Property: any command DAG produces the same buffer contents as
    serial single-device evaluation, regardless of server placement."""
    n_cmds = data.draw(st.integers(2, 10))
    n_srv = data.draw(st.integers(1, 3))
    rt = mk(n=n_srv)
    buf = rt.create_buffer(64)
    e0 = rt.enqueue_write("s0", buf, np.ones(16, np.float32))
    events = [e0]
    expected = np.ones(16, np.float32)
    ops = []
    for i in range(n_cmds):
        srv = f"s{data.draw(st.integers(0, n_srv - 1))}"
        mul = data.draw(st.sampled_from([2.0, 3.0, 0.5]))
        add = data.draw(st.sampled_from([0.0, 1.0]))
        dep = events[-1]
        ev = rt.enqueue_kernel(srv, fn=lambda x, m=mul, a=add: x * m + a,
                               inputs=[buf], outputs=[buf],
                               duration=1e-6, wait_for=[dep])
        events.append(ev)
        ops.append((mul, add))
    rt.finish()
    for m, a in ops:
        expected = expected * m + a
    np.testing.assert_allclose(buf.data, expected, rtol=1e-6)
    assert all(e.status == "complete" for e in events)


def test_straggler_redundant_dispatch():
    """First-completion-wins racing across servers: the result arrives at
    the fast server's latency even when another server is 100× slower."""
    import numpy as np
    rt = mk(n=3)
    # make s1 a straggler by pre-loading its device with queued work
    rt.servers["s1"].devices["gpu0"].execute(0.5, lambda: None)
    b = rt.create_buffer(64)
    b.set_data(np.arange(16, dtype=np.float32), "client")
    out = rt.create_buffer(64)
    ev = rt.enqueue_kernel_redundant(
        ["s0", "s1"], inputs=[b], outputs=[out],
        duration=1e-4)
    rt.finish()
    assert ev.status == "complete"
    assert ev.server == "s0"                 # fast server won
    assert ev.t_end - ev.t_queued < 0.4      # not the straggler's 0.5 s
