"""Elastic cluster membership (DESIGN.md §7): server join / drain /
crash, deterministic fault injection, mid-flight chunk drops on link
faults, and the bounded client reconnect path."""
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback, see _hypothesis_stub
    from _hypothesis_stub import given, settings, st

from repro.core import (ACTIVE, COMPLETE, DEAD, ERROR, ClientRuntime,
                        Cluster, DeviceSpec, DeviceUnavailable,
                        FaultSchedule, Link, LinkSpec, ServerSpec,
                        SimClock)

GPU = DeviceSpec("gpu0")
PEER = LinkSpec(latency=20e-6, bandwidth=40e9 / 8)
CLIENT = LinkSpec(latency=61e-6, bandwidth=1e9 / 8)


def mk_cluster(n=2, **kw):
    kw.setdefault("peer_link", PEER)
    kw.setdefault("peer_transport", "tcp")
    return Cluster([ServerSpec(f"s{i}", [GPU]) for i in range(n)], **kw)


def attach(cluster, **kw):
    kw.setdefault("client_link", CLIENT)
    return ClientRuntime(cluster=cluster, **kw)


def ledger(events):
    """Terminal-transition counter per event: the exactly-once probe
    (0 = lost/hung, 2+ = duplicated completion)."""
    counts = {e.id: 0 for e in events}
    for e in events:
        e.on_complete(lambda _x, i=e.id:
                      counts.__setitem__(i, counts[i] + 1))
    return counts


# ---- join ----

def test_join_server_mid_workload_becomes_eligible():
    cluster = mk_cluster(n=2)
    rt = attach(cluster, name="a")
    cluster.run()
    mm = cluster.membership
    assert mm.state("s0") == ACTIVE and not mm.is_eligible("s2")
    buf = rt.create_buffer(64)
    w = rt.enqueue_write("s0", buf, np.ones(16, np.float32))
    k0 = rt.enqueue_kernel("s0", fn=None, duration=5e-3, wait_for=[w])
    activated = []
    cluster.join_server(ServerSpec("s2", [GPU]),
                        at=cluster.clock.now + 1e-3,
                        on_active=lambda:
                        activated.append(cluster.clock.now))
    cluster.run()
    assert activated and mm.state("s2") == ACTIVE
    assert rt.sessions["s2"].available
    assert k0.status == COMPLETE
    # the joined host serves a kernel, dragging the input over the
    # freshly created peer link
    k = rt.enqueue_kernel("s2", fn=lambda x: x + 1.0, inputs=[buf],
                          outputs=[buf], duration=1e-3)
    cluster.run()
    assert k.status == COMPLETE
    np.testing.assert_array_equal(buf.data, np.full(16, 2.0, np.float32))
    assert cluster.stats()["membership"]["joins"] == 1


def test_join_existing_name_rejected():
    cluster = mk_cluster(n=2)
    attach(cluster, name="a")
    cluster.run()
    with pytest.raises(ValueError):
        cluster.join_server(ServerSpec("s0", [GPU]))


# ---- drain ----

def test_drain_requeues_unstarted_exactly_once():
    cluster = mk_cluster(n=2)
    rt = attach(cluster, name="a")
    cluster.run()
    buf = rt.create_buffer(64)
    w = rt.enqueue_write("s0", buf, np.full(16, 1.0, np.float32))
    k1 = rt.enqueue_kernel("s0", fn=lambda x: x * 2.0, inputs=[buf],
                           outputs=[buf], duration=10e-3, wait_for=[w])
    k2 = rt.enqueue_kernel("s0", fn=lambda x: x * 2.0, inputs=[buf],
                           outputs=[buf], duration=1e-3, wait_for=[k1])
    k3 = rt.enqueue_kernel("s0", fn=lambda x: x * 2.0, inputs=[buf],
                           outputs=[buf], duration=1e-3, wait_for=[k2])
    evs = [w, k1, k2, k3]
    counts = ledger(evs)
    # k1 is in service when the drain lands (non-preemptive, it finishes
    # on the draining host); k2/k3 are waiters and must requeue to s1
    drained = []
    cluster.drain_server("s0", at=cluster.clock.now + 2e-3,
                         on_complete=lambda:
                         drained.append(cluster.clock.now))
    cluster.run()
    assert [e.status for e in evs] == [COMPLETE] * 4
    assert all(c == 1 for c in counts.values())
    np.testing.assert_array_equal(buf.data, np.full(16, 8.0, np.float32))
    mm = cluster.stats()["membership"]
    assert mm["states"]["s0"] == DEAD
    assert mm["requeued_commands"] >= 1
    assert drained and mm["drain_ms"]
    assert "s0" not in buf.valid_on
    assert rt.stats()["events_live"] == 0
    with pytest.raises(DeviceUnavailable):
        rt.enqueue_kernel("s0", fn=None, duration=1e-3)


def test_drain_migrates_sole_replica_and_drops_redundant():
    cluster = mk_cluster(n=2)
    rt = attach(cluster, name="a")
    cluster.run()
    sole = rt.create_buffer(256 * 1024)
    both = rt.create_buffer(64)
    w = rt.enqueue_write("s0", sole,
                         np.zeros(256 * 1024 // 4, np.float32))
    rt.enqueue_kernel("s0", fn=lambda x: x + 1.0, inputs=[sole],
                      outputs=[sole], duration=1e-3, wait_for=[w])
    w2 = rt.enqueue_write("s0", both, np.ones(16, np.float32))
    # a read-only use on s1 replicates without invalidating s0
    rt.enqueue_kernel("s1", fn=None, inputs=[both], duration=1e-3,
                      wait_for=[w2])
    cluster.run()
    assert set(sole.valid_on) == {"s0"}
    assert set(both.valid_on) == {"s0", "s1"}
    cluster.drain_server("s0")
    cluster.run()
    mm = cluster.stats()["membership"]
    assert mm["replicas_migrated"] == 1
    assert mm["replicas_dropped"] >= 1
    assert "s0" not in sole.valid_on and "s1" in sole.valid_on
    assert set(both.valid_on) == {"s1"}
    r = rt.enqueue_read("s1", sole)
    cluster.run()
    assert r.status == COMPLETE
    np.testing.assert_array_equal(
        sole.data, np.ones(256 * 1024 // 4, np.float32))


def test_drain_clears_store_replicas():
    cluster = mk_cluster(n=2, store=True)
    rt = attach(cluster, name="a")
    cluster.run()
    buf = rt.create_buffer(1024)
    rt.enqueue_write("s0", buf, np.ones(256, np.float32))
    cluster.run()
    entry = cluster.store.entry_for(buf)
    assert "s0" in entry.valid_on
    cluster.drain_server("s0")
    cluster.run()
    assert "s0" not in entry.valid_on
    assert "s0" not in cluster.store.resident_bytes
    assert "s0" not in buf.valid_on and "s1" in buf.valid_on


# ---- crash ----

def test_crash_fails_fast_and_dependents_do_not_hang():
    cluster = mk_cluster(n=2)
    rt = attach(cluster, name="a")
    cluster.run()
    k1 = rt.enqueue_kernel("s0", fn=None, duration=10e-3)
    k2 = rt.enqueue_kernel("s1", fn=None, duration=1e-3, wait_for=[k1])
    counts = ledger([k1, k2])
    cluster.crash_server("s0", at=cluster.clock.now + 2e-3)
    cluster.run()
    assert k1.status == ERROR and "crash" in k1.error
    # error counts as a finished dependency: the dependent on the
    # survivor observes ERROR and runs, it does not hang
    assert k2.status == COMPLETE
    assert counts[k1.id] == 1 and counts[k2.id] == 1
    assert not rt.sessions["s0"].available
    assert cluster.membership.state("s0") == DEAD
    assert rt.stats()["events_live"] == 0


def test_crash_kills_midflight_migration():
    cluster = mk_cluster(n=2)
    rt = attach(cluster, name="a")
    cluster.run()
    buf = rt.create_buffer(4 * 1024 * 1024)
    rt.enqueue_write("s0", buf, np.zeros(1024 * 1024, np.float32))
    cluster.run()
    mig = rt.enqueue_migration(buf, "s1")
    # 4 MiB over the 40G peer wire takes ~0.8 ms: crash the DESTINATION
    # while chunks are on the wire
    cluster.crash_server("s1", at=cluster.clock.now + 2e-4)
    cluster.run()
    assert mig.status == ERROR
    assert "s1" not in buf.valid_on and "s0" in buf.valid_on
    assert rt.stats()["events_live"] == 0


# ---- bounded reconnect (satellite: §4.3 backoff) ----

def test_reconnect_bounded_retries_then_surfaces_failure():
    cluster = mk_cluster(n=2)
    rt = attach(cluster, name="a", reconnect_retries=2,
                reconnect_backoff=1e-3)
    cluster.run()
    cluster.crash_server("s0")
    rt.reconnect("s0")
    cluster.run()
    stats = rt.stats()
    assert stats["reconnect_attempts"]["s0"] == 3     # 1 + 2 retries
    assert "s0" in stats["reconnect_failures"]
    assert not rt.sessions["s0"].available


def test_reconnect_succeeds_within_budget_after_flap():
    cluster = mk_cluster(n=2)
    rt = attach(cluster, name="a")
    cluster.run()
    rt.c_links["s0"].up = False
    rt.sessions["s0"].available = False
    rt.reconnect("s0")
    cluster.run()
    stats = rt.stats()
    assert rt.sessions["s0"].available
    assert stats["reconnect_attempts"]["s0"] >= 1
    assert "s0" not in stats["reconnect_failures"]


def test_reconnect_config_validation():
    with pytest.raises(ValueError):
        attach(mk_cluster(), name="a", reconnect_retries=-1)
    with pytest.raises(ValueError):
        attach(mk_cluster(), name="a", reconnect_backoff=0.0)


# ---- link faults: mid-flight chunk drops (satellite bugfix) ----

def test_link_flap_mid_chunk_drops_remainder():
    clock = SimClock()
    link = Link(clock, latency=1e-3, bandwidth=1e6)
    got = []
    chunks = [(0.0, 1000.0, 0.0)] * 10            # 10 ms of wire time
    link.send_chunked(chunks, lambda: got.append(("ok", clock.now)),
                      on_dropped=lambda: got.append(("drop", clock.now)))
    clock.schedule_at(5e-3, setattr, link, "up", False)
    clock.run()
    # the receiver never assembles the payload; the drop is reported at
    # the fault time, not at the would-be delivery time
    assert got == [("drop", pytest.approx(5e-3))]


def test_link_flap_after_wire_end_still_delivers():
    clock = SimClock()
    link = Link(clock, latency=1e-3, bandwidth=1e6)
    got = []
    chunks = [(0.0, 1000.0, 0.0)] * 10
    link.send_chunked(chunks, lambda: got.append(("ok", clock.now)),
                      on_dropped=lambda: got.append(("drop", clock.now)))
    # the last chunk leaves the wire at 10 ms; a fault during the final
    # propagation leg loses nothing
    clock.schedule_at(10.5e-3, setattr, link, "up", False)
    clock.run()
    assert got == [("ok", pytest.approx(11e-3))]


def test_closed_link_never_resurrects():
    clock = SimClock()
    link = Link(clock, latency=1e-3, bandwidth=1e6)
    link.close()
    link.up = True
    assert not link.up
    assert link.send(100, lambda: None) is None
    assert link.send_chunked([(0.0, 100.0, 0.0)], lambda: None) is None


# ---- deterministic fault injection ----

def test_fault_schedule_scripts_membership_verbs():
    cluster = mk_cluster(n=3)
    rt = attach(cluster, name="a")
    cluster.run()
    t0 = cluster.clock.now
    seen = []
    (FaultSchedule()
     .join(t0 + 1e-3, ServerSpec("s3", [GPU]),
           on_active=lambda: seen.append("joined"))
     .drain(t0 + 2e-3, "s1",
            on_complete=lambda: seen.append("drained"))
     .crash(t0 + 5e-3, "s2")).apply(cluster)
    k = rt.enqueue_kernel("s0", fn=None, duration=10e-3)
    cluster.run()
    mm = cluster.membership
    assert mm.state("s3") == ACTIVE
    assert mm.state("s1") == DEAD and mm.state("s2") == DEAD
    assert seen.count("joined") == 1 and seen.count("drained") == 1
    assert k.status == COMPLETE
    assert cluster.stats()["membership"]["crashes"] == 1


def test_fault_schedule_flap_window():
    cluster = mk_cluster(n=2)
    attach(cluster, name="a")
    cluster.run()
    link = cluster.p_links[("s0", "s1")]
    t0 = cluster.clock.now
    FaultSchedule().flap(t0 + 1e-3, 2e-3, link).apply(cluster)
    probes = []
    for dt in (0.5e-3, 2e-3, 4e-3):
        cluster.clock.schedule_at(t0 + dt,
                                  lambda: probes.append(link.up))
    cluster.run()
    assert probes == [True, False, True]


# ---- properties: exactly-once under random fault schedules ----

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["none", "drain", "crash"]),
       st.integers(1, 8))
def test_property_faults_never_lose_or_duplicate_completions(
        seed, verb, fault_ms):
    cluster = mk_cluster(n=3)
    rt = attach(cluster, name="a")
    cluster.run()
    rng = random.Random(seed)
    events = []
    for i in range(14):
        deps = ([events[rng.randrange(len(events))]]
                if events and rng.random() < 0.7 else [])
        events.append(rt.enqueue_kernel(
            f"s{rng.randrange(3)}", fn=None,
            duration=rng.choice([1e-4, 1e-3, 3e-3]),
            wait_for=deps, name=f"k{i}"))
    counts = ledger(events)
    if verb != "none":
        target = f"s{rng.randrange(3)}"
        at = cluster.clock.now + fault_ms * 1e-3
        if verb == "drain":
            cluster.drain_server(target, at=at)
        else:
            cluster.crash_server(target, at=at)
    cluster.run()
    for e in events:
        assert e.status in (COMPLETE, ERROR)      # nothing lost or hung
        assert counts[e.id] == 1                  # nothing duplicated
    if verb != "crash":
        # a graceful drain loses no work: survivors absorb everything
        assert all(e.status == COMPLETE for e in events)
    assert rt.stats()["events_live"] == 0


def _bystander_run(crash_at):
    """Tenant A hammers s0/s1; bystander B touches only s2. Returns B's
    event timestamps."""
    cluster = mk_cluster(n=3)
    a = attach(cluster, name="a")
    b = attach(cluster, name="b")
    cluster.run()
    buf_a = a.create_buffer(64 * 1024)
    evs_a = [a.enqueue_write("s0", buf_a,
                             np.zeros(16 * 1024, np.float32))]
    for i in range(6):
        evs_a.append(a.enqueue_kernel(f"s{i % 2}", fn=None,
                                      inputs=[buf_a], duration=2e-3,
                                      wait_for=[evs_a[-1]]))
    buf_b = b.create_buffer(1024)
    evs_b = [b.enqueue_write("s2", buf_b,
                             np.arange(256, dtype=np.float32))]
    for _ in range(6):
        evs_b.append(b.enqueue_kernel("s2", fn=lambda x: x + 1.0,
                                      inputs=[buf_b], outputs=[buf_b],
                                      duration=1e-3,
                                      wait_for=[evs_b[-1]]))
    if crash_at is not None:
        cluster.crash_server("s0", at=crash_at)
    cluster.run()
    assert all(e.status == COMPLETE for e in evs_b)
    return [(e.t_submitted, e.t_start, e.t_end, e.t_client_ack)
            for e in evs_b]


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 12))
def test_property_bystander_timestamps_bit_identical_under_crash(
        fault_ms):
    base = _bystander_run(None)
    faulted = _bystander_run(crash_at=fault_ms * 1e-3)
    assert faulted == base                        # bit-identical floats
