"""Tracing & metrics plane (DESIGN.md §9): the tracer's spans must be
*ground truth* — cross-checked bit-for-bit against the runtime's own
scoreboards — and tracing must be invisible to the simulation: zero
cost when off, zero simulated-time perturbation when on."""
import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import validate_perfetto  # noqa: E402
from repro.core import (ClientRuntime, Cluster, DeviceSpec,  # noqa: E402
                        LinkSpec, ServerSpec, Tracer)
from repro.core import trace as trace_mod  # noqa: E402
from repro.core.netsim import FaultSchedule  # noqa: E402
from repro.core.trace import STAGES, Histogram  # noqa: E402

MiB = 1 << 20
CLIENT = LinkSpec(latency=61e-6, bandwidth=1e9 / 8)
PEER = LinkSpec(latency=20e-6, bandwidth=40e9 / 8)


def mk_cluster(n=2, trace=None, store=None, nic=None, nic_in=None,
               scheduler="fifo"):
    return Cluster([ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                    for i in range(n)],
                   peer_link=PEER, peer_transport="tcp",
                   scheduler=scheduler, store=store,
                   nic_bandwidth=nic, nic_ingress_bandwidth=nic_in,
                   trace=trace)


def attach(cluster, **kw):
    kw.setdefault("client_link", CLIENT)
    return ClientRuntime(cluster=cluster, **kw)


def multi_tenant_workload(cluster):
    """Two tenants, uploads + kernels + read-backs + a cross-server
    migration — touches every span kind except faults."""
    a, b = attach(cluster, name="a"), attach(cluster, name="b")
    cluster.run()
    results = []
    for rt, fill in ((a, 1), (b, 2)):
        buf = rt.create_buffer(MiB)
        rt.enqueue_write("s0", buf, np.full(MiB // 4, fill, np.uint32))
        out = rt.create_buffer(4096)
        rt.enqueue_kernel("s0", fn=None, inputs=[buf], outputs=[out],
                          duration=2 ** -12, name=f"{rt.name}_k0")
        # forces a migration of buf onto s1's replica set
        rt.enqueue_kernel("s1", fn=None, inputs=[buf], outputs=[out],
                          duration=2 ** -12, name=f"{rt.name}_k1")
        rt.enqueue_read("s1", out)
        results.append(rt)
    cluster.run()
    return results


# ---- invariant: tracing never perturbs simulated time ----

def test_traced_run_is_sim_time_identical_to_untraced():
    plain = mk_cluster(nic=10e9 / 8, store=True)
    multi_tenant_workload(plain)
    traced = mk_cluster(nic=10e9 / 8, store=True, trace=Tracer())
    multi_tenant_workload(traced)
    assert traced.clock.now == plain.clock.now
    ps, ts = plain.stats(), traced.stats()
    assert ts["device_busy"] == ps["device_busy"]
    assert ts["nic_busy"] == ps["nic_busy"]
    assert ts["scheduler"] == ps["scheduler"]
    assert ts["peer_link_bytes"] == ps["peer_link_bytes"]


def test_flap_fault_is_sim_time_identical_traced_or_not():
    def run(trace):
        cluster = mk_cluster(trace=trace)
        rt = attach(cluster, name="ue")
        cluster.run()
        link = cluster.peer_link("s0", "s1")
        FaultSchedule().flap(cluster.clock.now + 1e-4, 5e-4,
                             link).apply(cluster)
        buf = rt.create_buffer(MiB)
        rt.enqueue_write("s0", buf, np.full(MiB // 4, 7, np.uint32))
        out = rt.create_buffer(64)
        rt.enqueue_kernel("s1", fn=None, inputs=[buf], outputs=[out],
                          duration=2 ** -12)
        cluster.run()
        return cluster

    traced = run(Tracer())
    plain = run(None)
    assert traced.clock.now == plain.clock.now
    assert traced.trace.faults and plain.trace is None
    kinds = {k for _t, k, _tgt, _d in traced.trace.faults}
    assert kinds == {"flap_down", "flap_up"}


# ---- invariant: tracing off is off ----

def test_untraced_cluster_carries_none_and_false_forces_off():
    assert mk_cluster().trace is None
    trace_mod.set_default(Tracer())
    try:
        assert mk_cluster().trace is trace_mod.get_default()
        assert mk_cluster(trace=False).trace is None
    finally:
        trace_mod.set_default(None)
    assert mk_cluster().trace is None


def test_attach_path_rejects_trace_kwarg():
    cluster = mk_cluster()
    with pytest.raises(ValueError, match="cluster-level"):
        attach(cluster, name="x", trace=Tracer())


# ---- cross-checks: spans vs the runtime's own scoreboards ----

def test_wire_byte_counters_equal_transfer_span_sums():
    tr = Tracer()
    cluster = mk_cluster(nic=10e9 / 8, trace=tr)
    tenants = multi_tenant_workload(cluster)
    for rt in tenants:
        by_kind = {}
        for kind, _l, tenant, _t0, _t1, nbytes, _e, _c in tr.transfers:
            if tenant == rt.name:
                by_kind.setdefault(kind, []).append(nbytes)
        st = rt.stats()
        # identical floats, summed in the order the counters added them
        assert sum(by_kind.get("upload", [])) == \
            st["upload_bytes_on_wire"]
        assert sum(by_kind.get("migration", [])) == st["bytes_on_wire"]
        assert by_kind.get("read_return"), "read-backs must be spanned"


def test_nic_busy_counters_equal_nic_span_sums():
    tr = Tracer()
    cluster = mk_cluster(nic=10e9 / 8, nic_in=10e9 / 8, trace=tr)
    multi_tenant_workload(cluster)
    by_label = {}
    for label, _t0, busy in tr.nic_spans:
        by_label.setdefault(label, []).append(busy)
    st = cluster.stats()
    for host in ("s0", "s1"):
        assert sum(by_label.get(f"{host}.nic", [])) == \
            st["nic_busy"][host]
        assert sum(by_label.get(f"{host}.nic_in", [])) == \
            st["nic_in_busy"][host]
    assert any(by_label.get(f"{h}.nic") for h in ("s0", "s1"))


def test_dedup_bytes_saved_equals_dedup_span_sum():
    tr = Tracer()
    cluster = mk_cluster(store=True, trace=tr)
    a, b = attach(cluster, name="a"), attach(cluster, name="b")
    cluster.run()
    same = np.full(MiB // 4, 9, np.uint32)
    ba, bb = a.create_buffer(MiB), b.create_buffer(MiB)
    a.enqueue_write("s0", ba, same)
    cluster.run()
    b.enqueue_write("s0", bb, same)          # dedup'd: command only
    cluster.run()
    assert b.dedup_bytes_saved == MiB
    for rt in (a, b):
        saved = sum(n for _t, tenant, n in tr.dedups
                    if tenant == rt.name)
        assert saved == rt.stats()["dedup_bytes_saved"]


def test_device_busy_equals_traced_cost_sums():
    tr = Tracer()
    cluster = mk_cluster(trace=tr)
    rt = attach(cluster, name="ue")
    cluster.run()
    # power-of-two durations: float-exact under any summation order
    for i in range(6):
        rt.enqueue_kernel(f"s{i % 2}", fn=None, duration=2.0 ** -(10 + i),
                          name=f"k{i}")
    cluster.run()
    per_dev = {}
    for rec in tr.finished():
        if rec.server is not None and rec.cost:
            key = f"{rec.server}/{rec.device}"
            per_dev[key] = per_dev.get(key, 0.0) + rec.cost
    assert per_dev == {k: v for k, v in
                       cluster.stats()["device_busy"].items() if v}


def test_queued_seconds_probe_matches_unstarted_traced_commands():
    tr = Tracer()
    cluster = mk_cluster(n=1, trace=tr)
    rt = attach(cluster, name="ue")
    cluster.run()
    for i in range(4):                       # 1 runs, 3 queue behind it
        rt.enqueue_kernel("s0", fn=None, duration=2 ** -7, name=f"k{i}")
    probes = []

    def probe():
        want = cluster.hosts["s0"].schedulers["gpu0"].queued_seconds()
        got = sum(r.cost for r in tr.cmds.values()
                  if r.t_ready is not None and r.ev.t_start == 0.0)
        probes.append((want, got))

    cluster.clock.schedule(2 ** -8, probe)   # mid-first-kernel
    cluster.run()
    (want, got), = probes
    assert want == got == 3 * 2 ** -7


# ---- latency decomposition ----

def test_breakdown_stage_sums_equal_total_exactly():
    tr = Tracer()
    cluster = mk_cluster(trace=tr)
    multi_tenant_workload(cluster)
    bd = tr.breakdown(exact=True)
    n = len(bd["total"])
    assert n == len(tr.finished()) > 0
    for i in range(n):
        assert sum(bd[s][i] for s in STAGES) == bd["total"][i]
    table = tr.format_breakdown("t")
    assert all(stage in table for stage in STAGES)


def test_breakdown_forward_fill_gives_unreached_stages_zero():
    tr = Tracer()
    cluster = mk_cluster(trace=tr)
    rt = attach(cluster, name="ue")
    cluster.run()
    buf = rt.create_buffer(4096)
    rt.enqueue_write("s0", buf, np.zeros(1024, np.uint32))
    cluster.run()
    bd = tr.breakdown(exact=True)
    # a bare write never enters a device run queue or executes
    assert sum(bd["queue_wait"]) == 0 and sum(bd["execute"]) == 0
    assert sum(bd["total"]) > 0


# ---- metrics registry ----

def test_metrics_unify_spans_and_cluster_stats():
    tr = Tracer()
    cluster = mk_cluster(nic=10e9 / 8, store=True, trace=tr)
    multi_tenant_workload(cluster)
    reg = tr.metrics()
    summ = reg.summary()
    assert summ["cmd_latency[a]"]["count"] > 0
    assert summ["cmd_latency[b]"]["count"] > 0
    assert summ["execute[s0/gpu0]"]["count"] > 0
    assert any(k.startswith("wire_bytes[") for k in summ)
    # stats() counters flattened into the same namespace
    assert reg.counters["device_busy.s0/gpu0"] == \
        cluster.stats()["device_busy"]["s0/gpu0"]
    assert "placement.decisions" in reg.counters


def test_histogram_windowed_percentiles():
    h = Histogram()
    for i in range(1, 101):
        h.add(float(i), float(i))
    assert h.percentile(50) == 50.0
    assert h.percentile(99) == 99.0
    assert h.percentile(50, t0=91.0) == 95.0      # window [91, 100]
    assert h.summary(t0=1000.0)["count"] == 0


# ---- exporters ----

def test_perfetto_export_is_schema_valid_with_fault_markers(tmp_path):
    tr = Tracer()
    cluster = mk_cluster(n=2, trace=tr)
    rt = attach(cluster, name="ue")
    cluster.run()
    FaultSchedule().drain(cluster.clock.now + 1e-3, "s1").apply(cluster)
    for i in range(8):
        rt.enqueue_kernel(f"s{i % 2}", fn=None, duration=5e-4,
                          name=f"k{i}")
    cluster.run()
    path = tmp_path / "trace.json"
    tr.write_perfetto(str(path))
    data = json.loads(path.read_text())
    assert validate_perfetto(data, require_fault_markers=True) == []
    kinds = {k for _t, k, _tgt, _d in tr.faults}
    assert "drain" in kinds and "drain_complete" in kinds


def test_shared_tracer_namespaces_second_cluster():
    tr = Tracer()
    for _round in range(2):
        cluster = mk_cluster(n=1, trace=tr)
        rt = attach(cluster, name="ue")
        cluster.run()
        rt.enqueue_kernel("s0", fn=None, duration=2 ** -12)
        cluster.run()
    tenants = {rec.tenant for rec in tr.cmds.values()}
    assert tenants == {"ue", "c1:ue"}
    assert validate_perfetto(
        {"traceEvents": tr.perfetto_events()}) == []
