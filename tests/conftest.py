import os
import sys

# Tests run on the single real CPU device (the dry-run, and ONLY the
# dry-run, uses forced host devices — see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
