"""Serving engine: wave batching equals manual greedy decoding."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm, specs
from repro.serving.engine import Request, ServeEngine


def test_engine_matches_manual_greedy():
    cfg = configs.get_reduced("tinyllama-1.1b")
    params = specs.init_from_specs(jax.random.PRNGKey(0),
                                   specs.model_param_specs(cfg))
    P, NEW, B = 12, 6, 2
    rng = np.random.default_rng(1)
    prompts = rng.integers(1, cfg.vocab, (B, P)).astype(np.int32)

    # manual loop (cache dtype fp32 to match engine config below)
    outs_manual = []
    for b in range(B):
        cache = lm.init_cache(cfg, 1, 64, dtype=jnp.float32)
        logits, cache = lm.prefill(params, cfg, cache,
                                   tokens=jnp.asarray(prompts[b:b + 1]))
        toks = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(NEW):
            toks.append(int(tok[0]))
            if toks[-1] == 0:
                break
            logits, cache = lm.decode_step(params, cfg, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs_manual.append(toks)

    eng = ServeEngine(params, cfg, batch_slots=B, max_len=64,
                      cache_dtype=jnp.float32)
    reqs = [Request(prompt=prompts[b], max_new_tokens=NEW) for b in range(B)]
    done = eng.serve(reqs)
    for b in range(B):
        assert done[b].out_tokens == outs_manual[b], b


def test_engine_multi_wave():
    cfg = configs.get_reduced("gemma3-1b")
    params = specs.init_from_specs(jax.random.PRNGKey(1),
                                   specs.model_param_specs(cfg))
    rng = np.random.default_rng(2)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=4) for _ in range(5)]
    eng = ServeEngine(params, cfg, batch_slots=2, max_len=32)
    done = eng.serve(reqs)
    assert len(done) == 5
    assert all(r.done and 1 <= len(r.out_tokens) <= 4 for r in done)
