"""Calendar-queue event engine vs the reference heap (DESIGN.md §8).

The calendar ``SimClock`` must be *bit-exact* with ``HeapSimClock`` —
same ``(t, seq)`` total order, same returned timestamps, same clamping
— because every simulated-time regression baseline in ``benchmarks/``
was pinned under the heap engine and is required to survive the engine
swap unchanged. These tests drive both engines through the same
operation streams (property-based) and the same full runtime scenario
(monkeypatching the engine under ``Cluster``), and require identical
observable behavior.
"""
from __future__ import annotations

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback, see _hypothesis_stub
    from _hypothesis_stub import given, settings, st

import repro.core.runtime as runtime_mod
from repro.core import (COMPLETE, ClientRuntime, Cluster, DeviceSpec,
                        DeviceUnavailable, HeapSimClock, LinkSpec,
                        ServerSpec, SimClock)

FAST = LinkSpec(latency=5e-6, bandwidth=40e9 / 8)
RADIO = LinkSpec(latency=1e-4, bandwidth=1.2e9 / 8)


# ---------------------------------------------------------------------------
# engine-level equivalence


def _drive(clock, ops):
    """Apply one operation stream to ``clock``; return the observable
    log: every callback firing (timestamp + label), every scheduling
    return value, every ``run`` stopping point."""
    log = []

    def fire(label, chain):
        log.append(("fire", clock.now, label))
        for delay, sub in chain:
            clock.schedule(delay, fire, sub, ())

    for op in ops:
        kind = op[0]
        if kind == "sched":
            _, delay, label, chain = op
            log.append(("sched", clock.schedule(delay, fire, label,
                                                chain)))
        elif kind == "sched_at":
            _, t_abs, label = op
            log.append(("sched_at", clock.schedule_at(t_abs, fire,
                                                      label, ())))
        elif kind == "run_until":
            log.append(("ran", clock.run(until=op[1])))
        else:                       # "run"
            log.append(("ran_all", clock.run()))
    log.append(("drain", clock.run()))
    return log


def _gen_ops(data):
    """One random operation stream: same-timestamp bursts, zero and
    negative delays, past ``schedule_at`` targets, delays spanning nine
    orders of magnitude (sub-bucket to far-overflow), interleaved
    ``run(until=)`` slices."""
    ops = []
    label = 0
    for _ in range(data.draw(st.integers(5, 40), label="n_ops")):
        kind = data.draw(st.sampled_from(
            ("sched", "sched", "sched", "burst", "sched_at",
             "run_until")), label="kind")
        if kind in ("sched", "burst"):
            # delay = m * 10^-k: k=0 reaches the overflow heap and the
            # window-wrap retunes, k=7 lands far inside one bucket,
            # m=0 is a zero delay (fires at now, later seq)
            k = data.draw(st.integers(0, 7), label="k")
            m = data.draw(st.integers(0, 25), label="m")
            delay = m * (10.0 ** -k)
            if kind == "sched" and data.draw(st.booleans(),
                                             label="neg"):
                delay = -delay      # negative: clamps to now
            chain = []
            if data.draw(st.booleans(), label="chain"):
                # follow-ups rescheduled from inside the callback,
                # including a zero-delay same-timestamp cascade
                chain = [(0.0, label + 1000), (delay * 0.5, label + 2000)]
            reps = (data.draw(st.integers(2, 6), label="reps")
                    if kind == "burst" else 1)
            for _ in range(reps):   # burst: identical timestamps
                ops.append(("sched", delay, label, tuple(chain)))
                label += 1
        elif kind == "sched_at":
            # absolute target in [0, 2.5]s — often in the past once the
            # clock has advanced, exercising the clamp
            t_abs = data.draw(st.integers(0, 2500),
                              label="t_abs") * 1e-3
            ops.append(("sched_at", t_abs, label))
            label += 1
        else:
            until = data.draw(st.integers(0, 2500), label="until") * 1e-3
            ops.append(("run_until", until))
    ops.append(("run",))
    return ops


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_calendar_matches_heap_pop_order(data):
    ops = _gen_ops(data)
    heap_log = _drive(HeapSimClock(), ops)
    cal = SimClock()
    cal_log = _drive(cal, ops)
    assert cal_log == heap_log
    assert cal.pending() == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64), st.integers(0, 10))
def test_small_calendar_still_exact(nbuckets, seed_k):
    """Tiny bucket counts force constant wrapping, overflow refills,
    and both retune rules — order must survive all of it."""
    import random
    rng = random.Random(0xF1EE7 + seed_k)
    ops = []
    for i in range(200):
        ops.append(("sched", rng.choice((0.0, 1e-7, 3e-5, 2e-3, 0.7)),
                    i, ()))
        if i % 50 == 49:
            ops.append(("run_until", rng.random()))
    ops.append(("run",))
    heap_log = _drive(HeapSimClock(), ops)
    cal = SimClock(nbuckets=nbuckets)
    assert _drive(cal, ops) == heap_log
    assert cal.pending() == 0


def test_overflow_backlog_bit_exact():
    """A backlog far wider than the window (> nbuckets events deep in
    the overflow heap) triggers the span retune; pop order and
    timestamps must still match the heap exactly."""
    import random
    rng = random.Random(7)
    ops = []
    t = 0.0
    for i in range(3000):
        t += rng.choice((1e-7, 1e-6, 5e-4, 0.05))
        ops.append(("sched_at", t, i))
    ops.append(("run",))
    assert _drive(SimClock(), ops) == _drive(HeapSimClock(), ops)


@pytest.mark.parametrize("engine", [SimClock, HeapSimClock])
def test_schedule_returns_effective_time(engine):
    """Both ``schedule`` and ``schedule_at`` return the time the event
    will actually fire — clamped to ``now`` for past targets and
    non-positive delays — so callers can anchor follow-up work without
    re-deriving the clamp."""
    clock = engine()
    assert clock.schedule(1e-3, lambda: None) == 1e-3
    clock.run()
    assert clock.now == 1e-3
    assert clock.schedule(0.0, lambda: None) == clock.now
    assert clock.schedule(-5.0, lambda: None) == clock.now
    assert clock.schedule_at(0.0, lambda: None) == clock.now   # past
    assert clock.schedule_at(2e-3, lambda: None) == 2e-3       # future
    fired_at = []
    clock.schedule_at(1e-9, lambda: fired_at.append(clock.now))
    clock.run()
    assert fired_at == [1e-3]       # clamped to the old now, not 1e-9


# ---------------------------------------------------------------------------
# full-runtime bit-exactness


def _fleet_scenario():
    """A small multi-tenant workload touching every hot path: writes,
    roaming kernels with implicit migrations, reads, an explicit
    migration, batched enqueue, and stepped ``run(until=)`` draining.
    Returns every observable timestamp in completion order."""
    cluster = Cluster([ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                       for i in range(3)],
                      peer_link=FAST, peer_transport="tcp",
                      scheduler="drr")
    rts = [ClientRuntime(cluster=cluster, client_link=RADIO,
                         transport="tcp", name=f"ue{i}")
           for i in range(3)]
    cluster.run()                   # handshakes drained
    times = []
    for i, rt in enumerate(rts):
        a = rt.create_buffer(64 * 1024)
        b = rt.create_buffer(16 * 1024)
        prev = None
        for j in range(5):
            srv = f"s{(i + j) % 3}"     # roam → implicit migrations
            w = rt.enqueue_write(srv, a,
                                 np.full(16 * 1024, i * 100 + j,
                                         np.uint32))
            deps = [w] if prev is None else [w, prev]
            k = rt.enqueue_kernel(srv, fn=None, inputs=[a],
                                  outputs=[b, a], duration=1e-4,
                                  wait_for=deps, name=f"k{i}.{j}")
            r = rt.enqueue_read(srv, b, wait_for=[k])
            for tag, ev in (("w", w), ("k", k), ("r", r)):
                ev.on_complete(lambda _e, t=f"ue{i}.{j}.{tag}", rt=rt:
                               times.append((t, rt.clock.now)))
            prev = r
        m = rt.enqueue_migration(a, f"s{(i + 1) % 3}", wait_for=[prev])
        m.on_complete(lambda _e, t=f"ue{i}.mig", rt=rt:
                      times.append((t, rt.clock.now)))
    batch = rts[0].enqueue_many(
        "s0", [{"duration": 5e-5, "name": f"b{j}",
                "wait_for": [j - 1] if j else []} for j in range(8)])
    batch[-1].on_complete(lambda _e: times.append(("batch",
                                                   rts[0].clock.now)))
    # stepped drain: run(until=) boundaries must not perturb anything
    t = cluster.clock.now
    for _ in range(40):
        t += 7.3e-4
        cluster.run(until=t)
    cluster.run()
    times.append(("final", cluster.clock.now))
    times.append(("live", sum(rt.stats()["events_live"] for rt in rts)))
    return times


def test_runtime_bit_exact_across_engines(monkeypatch):
    """The whole simulated timeline — every completion timestamp, in
    order — is identical under the calendar engine and the reference
    heap (``Cluster`` instantiates whichever ``SimClock`` the runtime
    module's global names)."""
    calendar = _fleet_scenario()
    monkeypatch.setattr(runtime_mod, "SimClock", HeapSimClock)
    heap = _fleet_scenario()
    assert calendar == heap


def test_enqueue_many_matches_loop():
    """``enqueue_many`` is a batching of ``enqueue_kernel`` — same
    placement, same dependency edges, same timestamps — not a different
    semantic. The same DAG submitted both ways must complete every
    command at identical simulated times."""
    def build(batched: bool):
        cluster = Cluster([ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                           for i in range(2)], peer_link=FAST)
        rt = ClientRuntime(cluster=cluster, client_link=RADIO,
                           transport="tcp", name="ue0")
        cluster.run()
        specs = [{"server": f"s{j % 2}", "duration": 3e-5,
                  "name": f"k{j}",
                  "wait_for": ([j - 1, j - 2] if j >= 2 else
                               [j - 1] if j else [])}
                 for j in range(40)]
        if batched:
            evs = rt.enqueue_many("s0", specs)
        else:
            evs = []
            for s in specs:
                evs.append(rt.enqueue_kernel(
                    s["server"], fn=None, duration=s["duration"],
                    name=s["name"],
                    wait_for=[evs[d] for d in s["wait_for"]]))
        rt.finish()
        return [(ev.command.name, ev.t_end, ev.t_client_ack)
                for ev in evs] + [("final", rt.clock.now)]

    assert build(batched=True) == build(batched=False)


# ---------------------------------------------------------------------------
# interning stays invisible at the API boundary


def test_stats_and_errors_render_names_after_churn():
    """Server/tenant ids are interned to small ints internally; every
    user-facing surface (stats dict keys, error messages) must keep
    rendering human-readable *names* — including after lifecycle churn
    that recycles interned ids (detach, drain, rejoin reusing a name)."""
    cluster = Cluster([ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                       for i in range(3)],
                      peer_link=FAST, peer_transport="tcp",
                      scheduler="drr")
    rt = ClientRuntime(cluster=cluster, client_link=RADIO,
                       transport="tcp", name="ue0")
    extra = ClientRuntime(cluster=cluster, client_link=RADIO,
                          transport="tcp", name="ue1")
    cluster.run()
    buf = rt.create_buffer(8192)
    rt.enqueue_write("s1", buf, np.ones(2048, np.uint32))
    rt.finish()
    extra.detach()                              # tenant churn
    drained = []
    cluster.drain_server("s1", on_complete=lambda: drained.append(1))
    cluster.run()
    assert drained
    cluster.join_server(ServerSpec("s1", [DeviceSpec("gpu0")]))
    cluster.run()                               # rejoin reusing the name
    ev = rt.enqueue_kernel("s1", fn=None, duration=1e-5)
    rt.finish()
    assert ev.status == COMPLETE

    cst = cluster.stats()
    assert set(cst["sessions"]) == {"s0", "s1", "s2"}
    assert all(isinstance(k, str) and k.startswith("s")
               for k in cst["sessions"])
    assert cst["clients"] == ["ue0"]            # ue1 detached, by name
    assert set(cst["membership"]["states"]) == {"s0", "s1", "s2"}
    assert all(k.split("/")[0] in ("s0", "s1", "s2")
               for k in cst["device_busy"])
    rst = rt.stats()
    for key in ("client_link_bytes", "replay_window",
                "replay_overflows"):
        assert all(isinstance(k, str) and k.startswith("s")
                   for k in rst[key]), key

    cluster.drain_server("s2")
    cluster.run()
    with pytest.raises(DeviceUnavailable) as exc:
        rt.enqueue_kernel("s2", fn=None, duration=1e-5)
    assert "s2" in str(exc.value)               # name, not interned id
