"""Migration data-plane invariants (DESIGN.md §3): chunked cut-through
pipelining, in-flight coalescing + version invalidation, replica-aware
source selection, content-size clamping, and the naive-path completion
routing regression."""
import numpy as np
import pytest

from repro.core import (Buffer, ClientRuntime, DeviceSpec, LinkSpec,
                        ServerSpec)
from repro.core.transport import CMD_BYTES, COPY_BW, MiB, wire_scale


def mk(n=2, peer_transport=None, p2p=True, routing="subscription",
       peer_bw=40e9 / 8):
    return ClientRuntime(
        servers=[ServerSpec(f"s{i}", [DeviceSpec("gpu0")]) for i in range(n)],
        client_link=LinkSpec(latency=61e-6, bandwidth=1e9 / 8),
        peer_link=LinkSpec(latency=15e-6, bandwidth=peer_bw),
        transport="tcp", peer_transport=peer_transport,
        p2p_migration=p2p, completion_routing=routing)


def _seed_buffer(rt, nbytes, server="s0"):
    buf = rt.create_buffer(nbytes)
    rt.enqueue_write(server, buf, np.zeros(nbytes // 4 or 1, np.uint32))
    rt.finish()
    return buf


# ---- chunked cut-through pipeline ----

def test_chunked_migration_approaches_max_of_copy_and_wire():
    """A multi-chunk TCP migration must cost ~max(copy, wire), not their
    sum: the measured latency stays below the store-and-forward total by
    at least one full payload memcpy."""
    nbytes = 64 * MiB
    rt = mk()
    buf = _seed_buffer(rt, nbytes)
    t0 = rt.clock.now
    rt.enqueue_migration(buf, "s1")
    rt.finish()
    elapsed = rt.clock.now - t0
    link = rt.peer_link("s0", "s1")
    wire = nbytes * wire_scale(rt.peer_transport, link.bandwidth) \
        / link.bandwidth
    copy = nbytes / COPY_BW
    store_forward = copy + wire + copy          # sender + wire + receiver
    assert elapsed < store_forward - copy, (elapsed, store_forward)
    # ...but it can never beat the wire itself
    assert elapsed > wire, (elapsed, wire)


def test_single_chunk_migration_timing_matches_transport_model():
    """Sub-send-buffer transfers take exactly the store-and-forward cost
    on an idle link (Fig. 8/Fig. 11 small-transfer calibration)."""
    nbytes = 256 * 1024
    rt = mk()
    buf = _seed_buffer(rt, nbytes)
    ev = rt.enqueue_migration(buf, "s1")
    rt.finish()
    link = rt.peer_link("s0", "s1")
    cost = rt.peer_transport.command_cost(float(nbytes))
    expect = cost.sender_cpu \
        + cost.wire_bytes * wire_scale(rt.peer_transport, link.bandwidth) \
        / link.bandwidth + link.latency + cost.receiver_cpu
    assert ev.t_end - ev.t_start == pytest.approx(expect, rel=1e-9)


def test_chunk_plan_totals_equal_command_cost():
    """The chunked pipeline redistributes, never adds, protocol cost."""
    from repro.core.transport import RDMATransport, TCPTransport
    for tr in (TCPTransport(), RDMATransport(), RDMATransport(svm=True)):
        for payload in (1.0, 4096.0, float(9 * MiB), float(9 * MiB + 1),
                        float(100 * MiB)):
            cost = tr.command_cost(payload)
            fixed, chunks = tr.chunk_plan(payload)
            assert fixed + sum(c[0] for c in chunks) == \
                pytest.approx(cost.sender_cpu, abs=1e-15)
            assert sum(c[1] for c in chunks) == \
                pytest.approx(cost.wire_bytes)
            assert sum(c[2] for c in chunks) == \
                pytest.approx(cost.receiver_cpu, abs=1e-15)


def test_chunked_transfers_keep_link_fifo():
    """Two back-to-back migrations over the same link may not overtake
    each other, and the second queues behind the first's last chunk."""
    rt = mk()
    a = _seed_buffer(rt, 32 * MiB)
    b = _seed_buffer(rt, 32 * MiB)
    e1 = rt.enqueue_migration(a, "s1")
    e2 = rt.enqueue_migration(b, "s1")
    rt.finish()
    assert e1.t_end < e2.t_end
    # the second transfer could not use the wire while the first held it:
    # both payloads serialized through the FIFO
    link = rt.peer_link("s0", "s1")
    wire_each = 32 * MiB * wire_scale(rt.peer_transport, link.bandwidth) \
        / link.bandwidth
    assert e2.t_end - e1.t_start > 2 * wire_each


def test_chunks_in_flight_scoreboard_drains():
    rt = mk()
    buf = _seed_buffer(rt, 32 * MiB)
    rt.enqueue_migration(buf, "s1")
    rt.finish()
    st = rt.stats()
    assert st["chunks_in_flight"] == 0
    assert st["peak_chunks_in_flight"] >= 4        # 32 MiB / 9 MiB chunks
    assert st["bytes_on_wire"] > 32 * MiB
    assert st["migrations_inflight"] == 0


# ---- in-flight coalescing ----

def test_back_to_back_kernels_coalesce_migration():
    """Two kernels needing the same buffer on the same server push the
    payload once (the second rides the in-flight transfer)."""
    nbytes = 8 * MiB
    times = {}
    for second_kernel in (False, True):
        rt = mk()
        buf = _seed_buffer(rt, nbytes)
        out1, out2 = rt.create_buffer(64), rt.create_buffer(64)
        rt.enqueue_kernel("s1", fn=lambda x: x[:16] * 2.0, inputs=[buf],
                          outputs=[out1], duration=1e-6)
        if second_kernel:
            rt.enqueue_kernel("s1", fn=lambda x: x[:16] + 1.0, inputs=[buf],
                              outputs=[out2], duration=1e-6)
        rt.finish()
        times[second_kernel] = rt.stats()
        if second_kernel:
            np.testing.assert_array_equal(out2.data, np.ones(16))
    with_two, with_one = times[True], times[False]
    assert with_two["migrations_coalesced"] == 1
    # one payload on the wire, not two
    assert with_two["bytes_on_wire"] == with_one["bytes_on_wire"]
    assert with_two["bytes_on_wire"] < 2 * nbytes


def test_coalesced_event_is_shared_dependency():
    rt = mk()
    buf = _seed_buffer(rt, 4 * MiB)
    m1 = rt.enqueue_migration(buf, "s1")
    m2 = rt.enqueue_migration(buf, "s1")
    assert m2 is m1
    assert rt.stats()["migrations_coalesced"] == 1
    rt.finish()
    assert m1.status == "complete"
    assert "s1" in buf.valid_on
    assert rt.stats()["events_live"] == 0          # retirement survives


def test_write_invalidates_inflight_coalescing():
    """A WriteBuffer between two migration requests bumps the content
    version: the second request must start a fresh transfer, not ride
    the now-stale one."""
    rt = mk()
    buf = _seed_buffer(rt, 4 * MiB)
    m1 = rt.enqueue_migration(buf, "s1")
    rt.enqueue_write("s0", buf, np.ones(MiB, np.uint32))
    # the write clears dst validity, so a new migration is required and
    # must not coalesce onto m1's stale payload
    m2 = rt.enqueue_migration(buf, "s1")
    assert m2 is not m1
    assert rt.stats()["migrations_coalesced"] == 0
    rt.finish()
    assert rt.stats()["bytes_on_wire"] > 2 * 4 * MiB


def test_output_clobber_invalidates_inflight_and_arrival_validity():
    """An output clobber (kernel writing the buffer) while a migration is
    in flight: the landed copy must not count as a valid replica, and a
    later consumer re-migrates the fresh contents."""
    rt = mk(n=2)
    buf = _seed_buffer(rt, 4 * MiB)
    rt.enqueue_migration(buf, "s1")
    # clobber on the source while the payload is (or will be) in flight
    rt.enqueue_kernel("s0", fn=None, inputs=[], outputs=[buf],
                      duration=1e-6)
    rt.finish()
    assert buf.valid_on == {"s0"}          # stale copy at s1 not validated
    before = rt.stats()["bytes_on_wire"]
    out = rt.create_buffer(64)
    rt.enqueue_kernel("s1", fn=None, inputs=[buf], outputs=[out],
                      duration=1e-6)
    rt.finish()
    assert rt.stats()["bytes_on_wire"] > before    # re-migrated
    assert "s1" in buf.valid_on


def test_invalidate_except_bumps_version():
    b = Buffer(nbytes=64)
    v0 = b.version
    b.invalidate_except("s0")
    b.set_data(np.zeros(16, np.float32), "s1")
    assert b.version == v0 + 2
    assert b.valid_on == {"s1"}


def test_dropped_transfer_fails_fast_and_does_not_capture_retries():
    """A migration dropped on a dead peer link can never be re-sent
    (replay is deduped server-side), so it must fail fast — not hang —
    and release its in-flight entry: a retry after reconnect starts a
    fresh transfer instead of coalescing onto a dead event."""
    rt = mk(n=2)
    buf = _seed_buffer(rt, 4 * MiB)
    rt.peer_link("s0", "s1").up = False
    m1 = rt.enqueue_migration(buf, "s1")
    rt.finish()
    assert m1.status == "error"
    assert rt.stats()["migrations_inflight"] == 0
    assert rt.stats()["events_live"] == 0
    rt.peer_link("s0", "s1").up = True
    m2 = rt.enqueue_migration(buf, "s1")
    assert m2 is not m1
    assert rt.stats()["migrations_coalesced"] == 0
    rt.finish()
    assert m2.status == "complete"
    assert "s1" in buf.valid_on


def test_coalesced_migration_preserves_wait_for_ordering():
    """A coalesce hit must still honor the caller's wait list: the
    returned handle completes no earlier than both the in-flight
    transfer and the requested dependencies."""
    rt = mk(n=2)
    buf = _seed_buffer(rt, 4 * MiB)
    m1 = rt.enqueue_migration(buf, "s1")
    barrier = rt.enqueue_kernel("s0", fn=None, duration=0.5)
    m2 = rt.enqueue_migration(buf, "s1", wait_for=[barrier])
    assert m2 is not m1
    assert rt.stats()["migrations_coalesced"] == 1   # payload sent once
    rt.finish()
    assert m2.status == "complete"
    assert m2.t_end >= barrier.t_end
    assert m2.t_end >= m1.t_end
    assert rt.stats()["events_live"] == 0


def test_naive_read_leg_dropped_fails_migration_and_releases_entry():
    """p2p_migration=False with the client link dying after the read
    command was delivered: the daemon dedups the replayed command and
    can never re-send the data, so the read and the staged migration
    must fail (not hang) and release the in-flight entry — a retry
    after reconnect starts fresh and succeeds."""
    rt = mk(n=2, p2p=False)
    buf = _seed_buffer(rt, 4 * MiB)
    m1 = rt.enqueue_migration(buf, "s1")   # read command leaves now
    rt.c_links["s0"].up = False            # dies before the data return
    rt.finish()
    assert m1.status == "error"
    assert rt.stats()["migrations_inflight"] == 0
    assert rt.stats()["events_live"] == 0
    rt.c_links["s0"].up = True
    m2 = rt.enqueue_migration(buf, "s1")
    assert m2 is not m1
    rt.finish()
    assert m2.status == "complete"
    assert "s1" in buf.valid_on


def test_naive_migration_clobbered_during_read_leg_not_validated():
    """p2p_migration=False: a write landing while the payload is still on
    the (slow) read leg makes the staged copy stale — the destination
    must not be marked a valid replica when it finally arrives."""
    rt = mk(n=2, p2p=False)
    buf = _seed_buffer(rt, 8 * MiB)     # read leg ≫ kernel latency
    rt.enqueue_migration(buf, "s1")
    rt.enqueue_kernel("s0", fn=None, inputs=[], outputs=[buf],
                      duration=1e-6)
    rt.finish()
    assert buf.valid_on == {"s0"}


# ---- content-size clamping (cl_pocl_content_size, §5.3) ----

def test_transfer_bytes_clamps_negative_and_oversized():
    size_buf = Buffer(nbytes=4)
    big = Buffer(nbytes=4096, content_size_buffer=size_buf)
    size_buf.data = np.array([-7], np.int64)
    assert big.transfer_bytes() == 0.0
    size_buf.data = np.array([1 << 40], np.int64)
    assert big.transfer_bytes() == 4096.0
    size_buf.data = np.array([100], np.int64)
    assert big.transfer_bytes() == 100.0
    assert Buffer(nbytes=64).transfer_bytes() == 64.0


def test_zero_content_migration_moves_command_struct_only():
    rt = mk()
    size_buf = rt.create_buffer(4)
    buf = rt.create_buffer(MiB, content_size_buffer=size_buf)
    rt.enqueue_write("s0", size_buf, np.array([-1], np.int64))
    rt.enqueue_write("s0", buf, np.zeros(MiB // 4, np.uint32))
    rt.finish()
    link = rt.peer_link("s0", "s1")
    before = link.bytes_sent
    rt.enqueue_migration(buf, "s1")
    rt.finish()
    moved = link.bytes_sent - before
    # command struct (+ completion traffic), nothing near the 1 MiB body
    assert moved < 4 * CMD_BYTES, moved


# ---- replica-aware source selection ----

def test_source_selection_prefers_idle_link():
    """With replicas on two servers, a migration pulls over the idle peer
    link instead of queueing behind a busy one."""
    rt = mk(n=3)
    buf = _seed_buffer(rt, 8 * MiB)
    rt.enqueue_migration(buf, "s1")
    rt.finish()
    assert buf.valid_on >= {"s0", "s1"}
    # occupy s0<->s2 so s1 is the cheaper source
    busy = rt.peer_link("s0", "s2")
    busy.send(1e9, lambda: None)
    idle = rt.peer_link("s1", "s2")
    before = idle.bytes_sent
    rt.enqueue_migration(buf, "s2")
    rt.finish()
    assert idle.bytes_sent - before > 8 * MiB
    assert "s2" in buf.valid_on


def test_source_selection_prefers_registered_mr_on_rdma():
    """Equal links: the RDMA path amortizes MR registration by pulling
    from a source that already exchanged keys with the destination."""
    rt = mk(n=3, peer_transport="rdma")
    buf = rt.create_buffer(8 * MiB)
    buf.data = np.zeros(2 * MiB, np.uint32)
    buf.valid_on = {"s0", "s1"}
    rt._mr_registered.add((buf.id, "s1", "s2"))
    via_s1 = rt.peer_link("s1", "s2")
    before = via_s1.bytes_sent
    rt.enqueue_migration(buf, "s2")
    rt.finish()
    assert via_s1.bytes_sent - before > 8 * MiB


def test_source_selection_deterministic_tiebreak():
    """All else equal, the lowest-named replica wins (set iteration order
    must not leak into placement)."""
    rt = mk(n=4)
    buf = rt.create_buffer(MiB)
    buf.data = np.zeros(MiB // 4, np.uint32)
    buf.valid_on = {"s2", "s1"}
    src = rt._pick_migration_source(buf, ["s2", "s1"], "s3")
    assert src == "s1"


# ---- naive-path completion routing (regression) ----

@pytest.mark.parametrize("dependent_server", ["s1", "s2"])
def test_naive_write_completion_respects_routing(dependent_server):
    """p2p_migration=False: the client-staged write's completion must go
    through the same routing logic as every other server completion —
    peers hear about it only under broadcast routing or when subscribed."""
    msgs = {}
    for routing in ("broadcast", "subscription"):
        rt = mk(n=3, p2p=False, routing=routing)
        buf = _seed_buffer(rt, 4096)
        mig = rt.enqueue_migration(buf, "s1")
        ev = rt.enqueue_kernel(dependent_server, fn=None, duration=1e-6,
                               wait_for=[mig])
        rt.finish()
        assert ev.status == "complete"
        assert rt.stats()["events_live"] == 0
        msgs[routing] = rt.stats()["peer_completion_msgs"]
    if dependent_server == "s1":
        # dependent local to the destination: under subscription no peer
        # ever needs to hear any of these completions
        assert msgs["subscription"] == 0
    else:
        # remote dependent: exactly the one subscribed peer is notified
        assert msgs["subscription"] == 1
    assert msgs["subscription"] < msgs["broadcast"]


def test_naive_write_timestamps_equal_across_routings():
    """Dropping unneeded peer notifications must not shift any simulated
    timestamp on a single-dependent chain."""
    stamps = {}
    for routing in ("broadcast", "subscription"):
        rt = mk(n=2, p2p=False, routing=routing)
        buf = _seed_buffer(rt, 64 * 1024)
        mig = rt.enqueue_migration(buf, "s1")
        ev = rt.enqueue_kernel("s1", fn=None, duration=1e-6, wait_for=[mig])
        rt.finish()
        stamps[routing] = (mig.t_end, ev.t_submitted, ev.t_start, ev.t_end)
    assert stamps["broadcast"] == stamps["subscription"]
