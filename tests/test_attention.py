"""Blockwise flash attention (XLA path): fwd + custom-VJP bwd vs naive
oracle, including a hypothesis property sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback, see _hypothesis_stub
    from _hypothesis_stub import given, settings, st

from repro.models.attention import attention, decode_attention
from repro.kernels.flash_attention.ref import attention_ref


def _rand(shape, key):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


CASES = [
    dict(causal=True),
    dict(causal=True, window=37),
    dict(causal=False),
    dict(causal=True, logit_softcap=20.0),
]


@pytest.mark.parametrize("case", CASES)
def test_attention_fwd_bwd_vs_ref(case):
    B, S, H, KV, hd = 2, 160, 4, 2, 32
    q, k, v = _rand((B, S, H, hd), 0), _rand((B, S, KV, hd), 1), \
        _rand((B, S, KV, hd), 2)
    cap = case.pop("logit_softcap", None)
    out = attention(q, k, v, logit_softcap=cap, q_chunk=64, kv_chunk=48,
                    **case)
    ref = attention_ref(q, k, v, logit_softcap=cap, **case)
    np.testing.assert_allclose(out, ref, atol=2e-5)

    f = lambda *a: attention(*a, logit_softcap=cap, q_chunk=64,
                             kv_chunk=48, **case).sum() * 0.01
    g = lambda *a: attention_ref(*a, logit_softcap=cap, **case).sum() * 0.01
    d1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    d2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(d1, d2):
        np.testing.assert_allclose(a, b, atol=2e-5)


def test_chunked_prefill_offset():
    B, S, H, KV, hd = 1, 128, 2, 2, 16
    q = _rand((B, 64, H, hd), 0)
    k, v = _rand((B, S, KV, hd), 1), _rand((B, S, KV, hd), 2)
    out = attention(q, k, v, causal=True, q_offset=64, kv_len=128,
                    q_chunk=32, kv_chunk=32)
    ref = attention_ref(q, k, v, causal=True, q_offset=64, kv_len=128)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_decode_attention_matches_full():
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    k, v = _rand((B, S, KV, hd), 1), _rand((B, S, KV, hd), 2)
    pos = 40
    q = _rand((B, 1, H, hd), 0)
    out = decode_attention(q, k, v, pos)
    ref = attention_ref(q, k, v, causal=True, q_offset=pos, kv_len=pos + 1)
    np.testing.assert_allclose(out, ref, atol=2e-5)
    # window
    out_w = decode_attention(q, k, v, pos, window=9)
    ref_w = attention_ref(q, k, v, causal=True, q_offset=pos,
                          kv_len=pos + 1, window=9)
    np.testing.assert_allclose(out_w, ref_w, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 2),
    nq=st.integers(1, 3),
    H=st.sampled_from([2, 4]),
    g=st.sampled_from([1, 2]),
    hd=st.sampled_from([8, 16]),
    causal=st.booleans(),
    window=st.sampled_from([None, 17]),
    seed=st.integers(0, 2**16),
)
def test_attention_property(B, nq, H, g, hd, causal, window, seed):
    if window is not None and not causal:
        window = None  # windowed attention is causal-only (see attention())
    S = 48 * nq
    KV = H // g
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, S, KV, hd))
    out = attention(q, k, v, causal=causal, window=window,
                    q_chunk=32, kv_chunk=24)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, atol=3e-5)
