"""Dispatch-core invariants for the indexed waiter table, subscription
completion routing, and event retirement (the O(1)-per-command path).

No hypothesis needed: the DAGs are generated with a seeded
``random.Random`` so every run draws the same graph.
"""
import logging
import random

import numpy as np
import pytest

from repro.core import ClientRuntime, DeviceSpec, LinkSpec, ServerSpec


def mk(n=3, routing="subscription", scheduling="decentralized"):
    return ClientRuntime(
        servers=[ServerSpec(f"s{i}", [DeviceSpec("gpu0")]) for i in range(n)],
        client_link=LinkSpec(latency=61e-6, bandwidth=100e6 / 8),
        peer_link=LinkSpec(latency=20e-6, bandwidth=40e9 / 8),
        transport="tcp", scheduling=scheduling,
        completion_routing=routing)


def _run_dag(rt, n_cmds=60, n_srv=3, seed=7):
    """Deterministic random DAG over one shared buffer. Every command
    chains on its predecessor (total order → deterministic contents) and
    adds 0-2 extra dependencies on random earlier events (multi-dep +
    cross-server completion traffic)."""
    rng = random.Random(seed)
    buf = rt.create_buffer(64)
    e0 = rt.enqueue_write("s0", buf, np.ones(16, np.float32))
    events = [e0]
    expected = np.ones(16, np.float32)
    for _ in range(n_cmds):
        srv = f"s{rng.randrange(n_srv)}"
        mul = rng.choice([2.0, 0.5, 3.0])
        add = rng.choice([0.0, 1.0])
        deps = [events[-1]]
        for _ in range(rng.randint(0, 2)):
            deps.append(events[rng.randrange(len(events))])
        ev = rt.enqueue_kernel(srv, fn=lambda x, m=mul, a=add: x * m + a,
                               inputs=[buf], outputs=[buf], duration=1e-6,
                               wait_for=deps)
        events.append(ev)
        expected = expected * mul + add
    return buf, events, expected


def test_chain_timestamps_identical_to_broadcast():
    """Single-dependent chain alternating between two servers: the
    subscription router sends exactly the notifications the broadcast
    baseline sent, so every simulated timestamp must match bit-for-bit."""
    stamps = {}
    for routing in ("broadcast", "subscription"):
        rt = mk(n=2, routing=routing)
        events = []
        prev = ()
        for i in range(40):
            ev = rt.enqueue_kernel(f"s{i % 2}", fn=None, duration=1e-6,
                                   wait_for=prev)
            events.append(ev)
            prev = (ev,)
        rt.finish()
        stamps[routing] = [(e.t_submitted, e.t_start, e.t_end,
                            e.t_client_ack) for e in events]
    assert stamps["broadcast"] == stamps["subscription"]


def test_random_dag_contents_match_and_never_slower():
    """Multi-dependent random DAG: identical buffer contents, and per-event
    completion under subscription routing is never later than under the
    broadcast baseline (dropping unneeded messages can only relieve
    link FIFOs)."""
    results = {}
    for routing in ("broadcast", "subscription"):
        rt = mk(n=3, routing=routing)
        buf, events, expected = _run_dag(rt)
        rt.finish()
        results[routing] = (np.asarray(buf.data).copy(),
                            [e.t_end for e in events], expected)
    b_data, b_end, expected = results["broadcast"]
    s_data, s_end, _ = results["subscription"]
    np.testing.assert_array_equal(b_data, s_data)
    np.testing.assert_allclose(s_data, expected, rtol=1e-6)
    for tb, ts in zip(b_end, s_end):
        assert ts <= tb + 1e-12, (ts, tb)


def test_subscription_sends_fewer_peer_messages():
    """On a DAG where most events have dependents on at most one other
    server, subscription routing must send strictly fewer peer completion
    messages than all-peers broadcast — and never more."""
    msgs = {}
    for routing in ("broadcast", "subscription"):
        rt = mk(n=3, routing=routing)
        _run_dag(rt)
        rt.finish()
        msgs[routing] = rt.stats()["peer_completion_msgs"]
    assert msgs["subscription"] < msgs["broadcast"], msgs


def test_subscription_equals_broadcast_only_when_all_peers_depend():
    """Alternating 2-server chain: every event except the sink has its
    dependent on the one peer, so per-event message counts are equal and
    the totals differ by exactly the sink's wasted broadcast."""
    n = 30
    msgs = {}
    for routing in ("broadcast", "subscription"):
        rt = mk(n=2, routing=routing)
        prev = ()
        for i in range(n):
            prev = (rt.enqueue_kernel(f"s{i % 2}", fn=None, duration=1e-6,
                                      wait_for=prev),)
        rt.finish()
        msgs[routing] = rt.stats()["peer_completion_msgs"]
    # n-1 interior events: every peer (the other server) truly has a
    # dependent → equal counts per event; the sink alone broadcasts for
    # nothing, so the totals differ by exactly one message
    assert msgs["broadcast"] == n
    assert msgs["subscription"] == n - 1


@pytest.mark.parametrize("routing", ["subscription", "broadcast"])
def test_event_retirement_bounds_runtime_tables(routing):
    """After a drained run, every finished event must have been retired
    from the runtime tables (events dict, dedup/resolution sets), while
    user-held Event handles stay readable. Broadcast mode is the sharp
    case: late all-peers notifications must not repopulate
    resolved_remote after retirement."""
    rt = mk(n=3, routing=routing)
    buf, events, expected = _run_dag(rt, n_cmds=100, n_srv=3)
    rt.finish()
    st = rt.stats()
    assert st["events_live"] == 0, st["events_live"]
    for srv in rt.servers.values():
        assert not srv.processed
        assert not srv.resolved_remote
        assert not srv._waiters
        assert not srv._ready
    assert not rt._subs
    # retirement removes table entries, not the handles themselves
    assert all(e.status == "complete" for e in events)
    np.testing.assert_allclose(np.asarray(buf.data), expected, rtol=1e-6)


def test_naive_migration_path_drains_tables():
    """p2p_migration=False routes migrations through the client; the
    migrate event must still complete and retire (no abandoned handle
    left in the events table)."""
    rt = ClientRuntime(
        servers=[ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                 for i in range(2)],
        client_link=LinkSpec(latency=61e-6, bandwidth=100e6 / 8),
        peer_link=LinkSpec(latency=20e-6, bandwidth=40e9 / 8),
        transport="tcp", p2p_migration=False)
    buf = rt.create_buffer(4096)
    rt.enqueue_write("s0", buf, np.arange(1024, dtype=np.float32))
    rt.finish()
    mig = rt.enqueue_migration(buf, "s1")
    rt.finish()
    assert mig.status == "complete"
    assert rt.stats()["events_live"] == 0


def test_replay_window_overflow_is_surfaced(caplog):
    """Deep unacked backlogs used to silently drop replay entries; the
    overflow is now counted per session and logged."""
    rt = mk(n=1)
    with caplog.at_level(logging.WARNING, logger="repro.core.runtime"):
        prev = ()
        for _ in range(200):    # far beyond the 64-entry replay window
            prev = (rt.enqueue_kernel("s0", fn=None, duration=1e-6,
                                      wait_for=prev),)
    assert rt.sessions["s0"].lost_unacked > 0
    assert rt.stats()["replay_overflows"]["s0"] > 0
    assert any("replay window full" in r.message for r in caplog.records)
    rt.finish()


def test_dead_set_ack_removed():
    assert not hasattr(ClientRuntime, "_set_ack")
