"""SLO-aware scheduling and admission control (DESIGN.md §10): the EDF
and LLF device-queue policies, chunk-granularity preemption, the
no-SLO bit-identity guarantee for fifo/drr, and knob validation for
``Cluster(scheduler_opts=)`` / ``Cluster(admission=)`` /
``ClientRuntime(slo_ms=)``.

Property tests run under hypothesis when installed and fall back to the
deterministic sampler in tests/_hypothesis_stub.py otherwise."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback, see _hypothesis_stub
    from _hypothesis_stub import given, settings, st

from repro.core import ClientRuntime, Cluster, DeviceSpec, LinkSpec, \
    ServerSpec
from repro.core.admission import AdmissionController
from repro.core.scheduler import (EDFPolicy, LLFPolicy,
                                  validate_scheduler_opts)

_INF = float("inf")


# ---------------------------------------------------------------------------
# policy-level properties


def _random_stream(data, n_max=40):
    """Draw a random push stream: (tenant, cost, deadline-or-None)."""
    n = data.draw(st.integers(2, n_max), label="n")
    out = []
    for i in range(n):
        tenant = f"t{data.draw(st.integers(0, 3), label='tenant')}"
        cost = data.draw(st.integers(1, 50), label="cost") * 1e-4
        if data.draw(st.booleans(), label="has_deadline"):
            deadline = data.draw(st.integers(0, 1000),
                                 label="deadline") * 1e-3
        else:
            deadline = None
        out.append((tenant, cost, deadline))
    return out


def _drain_pops(policy):
    """Pop everything, returning the labels in dispatch order."""
    order = []
    while True:
        run = policy.pop()
        if run is None:
            return order
        run(order)


def _push_all(policy, stream):
    for i, (tenant, cost, deadline) in enumerate(stream):
        label = (i, tenant, cost, deadline)
        policy.push(tenant, 1.0, cost,
                    (lambda out, lb=label: out.append(lb)),
                    tag=label, deadline=deadline)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_edf_pops_in_deadline_order_with_no_deadline_fifo_tail(data):
    stream = _random_stream(data)
    pol = EDFPolicy()
    _push_all(pol, stream)
    # cost accounting: total vs SLO-only slices
    assert pol.queued_seconds() == pytest.approx(
        sum(c for _, c, _ in stream))
    assert pol.queued_slo_seconds() == pytest.approx(
        sum(c for _, c, d in stream if d is not None))
    order = _drain_pops(pol)
    assert len(order) == len(stream)
    deadlines = [d for _, _, _, d in order if d is not None]
    tail = [i for i, _, _, d in order if d is None]
    # every deadline-carrying command dispatches before any without one
    first_tail = order.index(
        next(e for e in order if e[3] is None)) if tail else len(order)
    assert all(e[3] is not None for e in order[:first_tail])
    assert all(e[3] is None for e in order[first_tail:])
    # EDF: nondecreasing absolute deadline; ties broken by push order
    assert deadlines == sorted(deadlines)
    # deadline-less tail stays FIFO in push order
    assert tail == sorted(tail)
    assert pol.queued_seconds() == pytest.approx(0.0, abs=1e-12)
    assert pol.queued_slo_seconds() == pytest.approx(0.0, abs=1e-12)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_llf_pops_in_laxity_order_and_remove_keeps_accounts(data):
    stream = _random_stream(data)
    pol = LLFPolicy(chunk=5e-4)
    _push_all(pol, stream)
    victim = f"t{data.draw(st.integers(0, 3), label='victim')}"
    removed = pol.remove(victim)
    kept = [(i, t, c, d) for i, (t, c, d) in enumerate(stream)
            if t != victim]
    assert removed == len(stream) - len(kept)
    assert pol.queued_seconds() == pytest.approx(
        sum(c for _, _, c, _ in kept))
    assert pol.queued_slo_seconds() == pytest.approx(
        sum(c for _, _, c, d in kept if d is not None))
    order = _drain_pops(pol)
    assert sorted(order) == sorted(kept)
    # LLF: nondecreasing static laxity key (deadline − cost), with the
    # deadline-less commands last FIFO among themselves
    keys = [(_INF if d is None else d - c) for _, _, c, d in order]
    assert keys == sorted(keys)
    tail = [i for i, _, _, d in order if d is None]
    assert tail == sorted(tail)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_deadline_heap_drain_returns_priority_order_and_resets(data):
    stream = _random_stream(data, n_max=20)
    pol = EDFPolicy()
    _push_all(pol, stream)
    drained = pol.drain_queued()
    assert len(drained) == len(stream)
    keys = [(_INF if tag[3] is None else tag[3]) for _, tag in drained]
    assert keys == sorted(keys)
    assert len(pol) == 0
    assert pol.queued_seconds() == 0.0
    assert pol.queued_slo_seconds() == 0.0
    assert pol.pop() is None


# ---------------------------------------------------------------------------
# runtime integration

FAST = LinkSpec(latency=20e-6, bandwidth=40e9 / 8)
RADIO = LinkSpec(latency=61e-6, bandwidth=1e9 / 8)


def mk_cluster(n=1, scheduler="fifo", scheduler_opts=None, admission=None):
    return Cluster([ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                    for i in range(n)],
                   peer_link=FAST, peer_transport="tcp",
                   scheduler=scheduler, scheduler_opts=scheduler_opts,
                   admission=admission)


def attach(cluster, **kw):
    kw.setdefault("client_link", RADIO)
    return ClientRuntime(cluster=cluster, **kw)


def _preempted(cluster):
    return sum(s.preempted for h in cluster.hosts.values()
               for s in h.schedulers.values())


def _enqueue_backlog(rt, n, duration):
    buf = rt.create_buffer(64)
    evs = [rt.enqueue_write("s0", buf, np.ones(16, np.float32))]
    for _ in range(n):
        evs.append(rt.enqueue_kernel("s0", fn=lambda x: x + 1.0,
                                     inputs=[buf], outputs=[buf],
                                     duration=duration,
                                     wait_for=[evs[-1]]))
    return buf, evs


def test_edf_overtakes_best_effort_backlog():
    """A deadline-carrying command jumps a deep best-effort queue under
    edf but waits behind it under fifo."""
    lat = {}
    for policy in ("fifo", "edf"):
        cluster = mk_cluster(scheduler=policy)
        # six best-effort tenants (own sessions, so per-session command
        # windows cannot pace the backlog away) stack up ~12 ms of
        # device work before the SLO command lands mid-backlog
        bes = [attach(cluster, name=f"be{i}") for i in range(6)]
        slo = attach(cluster, name="slo", slo_ms=5.0)
        be_evs = [rt.enqueue_kernel("s0", fn=None, duration=2e-3)
                  for rt in bes]
        slo_ev = []
        cluster.clock.schedule_at(
            1e-3, lambda: slo_ev.append(
                slo.enqueue_kernel("s0", fn=None, duration=0.5e-3)))
        cluster.run()
        assert all(e.status == "complete" for e in be_evs + slo_ev)
        lat[policy] = slo_ev[0].t_client_ack - slo_ev[0].t_queued
    # fifo: behind the remaining ~11 ms of backlog; edf: behind at most
    # the in-service kernel (non-preemptive) + its own cost
    assert lat["fifo"] > 8e-3
    assert lat["edf"] < 4e-3
    assert lat["edf"] < lat["fifo"] / 3


def test_llf_preempts_bulk_kernel_and_both_complete_exactly_once():
    """A tight command preempts a running 20 ms bulk kernel at a chunk
    boundary; the remainder requeues at residual cost and both events
    complete exactly once with correct data."""
    cluster = mk_cluster(scheduler="llf",
                         scheduler_opts={"chunk": 0.5e-3})
    be = attach(cluster, name="be")
    slo = attach(cluster, name="slo", slo_ms=4.0)
    bulk_buf, bulk_evs = _enqueue_backlog(be, 1, duration=20e-3)
    sbuf = slo.create_buffer(64)
    w = slo.enqueue_write("s0", sbuf, np.full(16, 3.0, np.float32))
    ev = slo.enqueue_kernel("s0", fn=lambda x: x * 2.0, inputs=[sbuf],
                            outputs=[sbuf], duration=1e-3,
                            wait_for=[w])
    cluster.run()
    assert _preempted(cluster) >= 1
    assert all(e.status == "complete" for e in bulk_evs + [w, ev])
    # the SLO command did not wait for the 20 ms bulk remainder
    assert ev.t_client_ack - ev.t_queued < 10e-3
    np.testing.assert_array_equal(bulk_buf.data,
                                  np.full(16, 2.0, np.float32))
    np.testing.assert_array_equal(sbuf.data,
                                  np.full(16, 6.0, np.float32))
    # the write and the kernel are both scored against the 4 ms target
    assert slo.slo_commands == 2 and slo.slo_violations == 0
    # exactly-once: one completion per issued command, no duplicates
    assert be.stats()["events_live"] == 0
    assert slo.stats()["events_live"] == 0


def test_llf_best_effort_only_traffic_never_preempts():
    """Deadline-less commands all carry the +inf key; min_key() < inf
    is never true, so best-effort-only traffic under llf runs sliced
    but is never actually preempted (no thrash without SLO tenants)."""
    cluster = mk_cluster(scheduler="llf",
                         scheduler_opts={"chunk": 0.5e-3})
    a = attach(cluster, name="a")
    b = attach(cluster, name="b")
    evs = [rt.enqueue_kernel("s0", fn=None, duration=2e-3)
           for rt in (a, b, a, b, a)]
    cluster.run()
    assert all(e.status == "complete" for e in evs)
    assert _preempted(cluster) == 0


def _timestamp_log(evs):
    return [(e.t_queued, e.t_submitted, e.t_start, e.t_end,
             e.t_client_ack) for e in evs]


@pytest.mark.parametrize("policy", ["fifo", "drr"])
def test_no_slo_tenant_leaves_fifo_drr_timestamps_bit_identical(policy):
    """fifo/drr clusters must produce bit-identical timestamp streams
    whether a third idle tenant declares an SLO or not — declaring
    ``slo_ms`` on a deadline-blind policy must be observationally free,
    which is what keeps the pre-SLO baselines byte-for-byte valid."""
    logs = []
    for with_slo in (False, True):
        cluster = mk_cluster(n=2, scheduler=policy)
        a = attach(cluster, name="a")
        b = attach(cluster, name="b")
        attach(cluster, name="idle",
               slo_ms=2.0 if with_slo else None)
        evs = []
        for rt, dur in ((a, 1.5e-3), (b, 0.7e-3)):
            buf = rt.create_buffer(256)
            w = rt.enqueue_write("s0", buf, np.ones(64, np.float32))
            evs.append(w)
            for _ in range(4):
                evs.append(rt.enqueue_kernel(
                    "s0", fn=None, duration=dur, wait_for=[evs[-1]]))
        cluster.run()
        assert all(e.status == "complete" for e in evs)
        logs.append(_timestamp_log(evs))
    assert logs[0] == logs[1]


def test_edf_without_deadlines_matches_fifo_order():
    """All-best-effort traffic under edf dispatches in arrival order —
    the +inf key tail is FIFO, so switching the policy with no SLO
    tenants attached changes nothing observable."""
    logs = []
    for policy in ("fifo", "edf"):
        cluster = mk_cluster(scheduler=policy)
        a = attach(cluster, name="a")
        evs = [a.enqueue_kernel("s0", fn=None, duration=1e-3)
               for _ in range(5)]
        cluster.run()
        logs.append(_timestamp_log(evs))
    assert logs[0] == logs[1]


# ---------------------------------------------------------------------------
# knob validation


def test_validate_scheduler_opts():
    assert validate_scheduler_opts("drr", {"quantum": 1e-3}) \
        == {"quantum": 1e-3}
    assert validate_scheduler_opts("llf", None) == {}
    with pytest.raises(ValueError, match="unknown scheduler policy"):
        validate_scheduler_opts("lifo", None)
    with pytest.raises(ValueError, match="unknown scheduler_opts"):
        validate_scheduler_opts("edf", {"quantum": 1e-3})
    with pytest.raises(ValueError, match="unknown scheduler_opts"):
        validate_scheduler_opts("llf", {"chunks": 1e-3})
    with pytest.raises(ValueError, match="positive number"):
        validate_scheduler_opts("llf", {"chunk": 0.0})
    with pytest.raises(ValueError, match="positive number"):
        validate_scheduler_opts("drr", {"quantum": True})
    with pytest.raises(ValueError, match="must be a dict"):
        validate_scheduler_opts("drr", [("quantum", 1e-3)])


def test_cluster_scheduler_opts_validation():
    mk_cluster(scheduler="llf", scheduler_opts={"chunk": 1e-3})
    with pytest.raises(ValueError):
        mk_cluster(scheduler="edf", scheduler_opts={"quantum": 1e-3})
    with pytest.raises(ValueError):
        mk_cluster(scheduler="llf", scheduler_opts={"chunk": -1.0})
    with pytest.raises(ValueError):
        Cluster([ServerSpec("s0", [DeviceSpec("gpu0")])],
                scheduler="drr", scheduler_quantum=1e-3,
                scheduler_opts={"quantum": 2e-3})


def test_client_slo_arg_validation():
    cluster = mk_cluster()
    with pytest.raises(ValueError, match="slo_ms"):
        attach(cluster, slo_ms=0.0)
    with pytest.raises(ValueError, match="slo_ms"):
        attach(cluster, slo_ms=-4.0)
    with pytest.raises(ValueError, match="slo_probe requires"):
        attach(cluster, slo_probe={"cost_s": 1e-3})
    with pytest.raises(ValueError, match="unknown slo_probe"):
        attach(cluster, slo_ms=4.0, slo_probe={"cost": 1e-3})
    with pytest.raises(ValueError, match="non-negative"):
        attach(cluster, slo_ms=4.0, slo_probe={"cost_s": -1e-3})


def test_admission_opts_validation():
    with pytest.raises(ValueError):
        mk_cluster(admission={"bogus": 1.0})
    with pytest.raises(ValueError):
        mk_cluster(admission={"window_s": -0.1})
    with pytest.raises(ValueError):
        mk_cluster(admission={"headroom": 0.0})
    cluster = mk_cluster(scheduler="edf",
                         admission={"window_s": 0.1, "headroom": 0.3,
                                    "degrade_factor": 2.0})
    assert isinstance(cluster.admission, AdmissionController)
    assert mk_cluster().admission is None


# ---------------------------------------------------------------------------
# exactly-once under preemption + drain churn


def test_exactly_once_ledger_under_preemption_and_drain():
    """Preempted remainders and drain-requeued waiters must each
    complete exactly once: drain s0 while llf preemption churn is live,
    then check every chain finished with correct data."""
    cluster = mk_cluster(n=2, scheduler="llf",
                         scheduler_opts={"chunk": 0.4e-3})
    be = attach(cluster, name="be")
    slo = attach(cluster, name="slo", slo_ms=6.0)
    chains = []
    for rt, n, dur, start in ((be, 6, 4e-3, 1.0), (slo, 8, 1e-3, 3.0)):
        buf = rt.create_buffer(64)
        prev = rt.enqueue_write("s0", buf, np.full(16, start, np.float32))
        evs = [prev]
        for _ in range(n):
            prev = rt.enqueue_kernel("s0", fn=lambda x: x * 2.0,
                                     inputs=[buf], outputs=[buf],
                                     duration=dur, wait_for=[prev])
            evs.append(prev)
        chains.append((buf, evs, np.full(16, start, np.float32) * 2 ** n))
    cluster.drain_server("s0", at=cluster.clock.now + 3e-3)
    cluster.run()
    for buf, evs, want in chains:
        assert all(e.status == "complete" for e in evs)
        np.testing.assert_array_equal(buf.data, want)
    assert be.stats()["events_live"] == 0
    assert slo.stats()["events_live"] == 0
