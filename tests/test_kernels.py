"""Pallas kernels validated in interpret mode against pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback, see _hypothesis_stub
    from _hypothesis_stub import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.topk_compress.ops import compress, decompress
from repro.kernels.topk_compress.ref import topk_pack_ref


# ---------------- flash attention ----------------

FA_SHAPES = [
    (2, 256, 4, 2, 64),
    (1, 512, 4, 1, 64),
    (1, 256, 8, 2, 128),
    (2, 128, 2, 2, 32),
]


@pytest.mark.parametrize("B,S,H,KV,hd", FA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_shapes_dtypes(B, S, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


@pytest.mark.parametrize("case", [
    dict(window=100), dict(causal=False), dict(logit_softcap=30.0),
    dict(q_offset=128, kv_len=200),
])
def test_flash_kernel_masking_variants(case):
    B, S, H, KV, hd = 1, 256, 4, 2, 64
    Sq = 128 if case.get("q_offset") else S
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    kv_len = case.pop("kv_len", None)
    out = flash_attention(q, k, v, kv_len=kv_len, q_chunk=64, kv_chunk=64,
                          interpret=True, **case)
    ref = attention_ref(q, k, v, kv_len=kv_len, **case)
    np.testing.assert_allclose(out, ref, atol=3e-5)


# ---------------- SSD scan ----------------

@pytest.mark.parametrize("BH,S,P,N,Q", [
    (4, 256, 64, 128, 64), (2, 512, 64, 64, 128), (8, 128, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_ref(BH, S, P, N, Q, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (BH, S, P), dtype)
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (BH, S))).astype(jnp.float32)
    Bm = jax.random.normal(ks[2], (BH, S, N), dtype)
    Cm = jax.random.normal(ks[3], (BH, S, N), dtype)
    y, fin = ssd(x, dA, Bm, Cm, chunk=Q, interpret=True)
    yr, finr = ssd_ref(x, dA, Bm, Cm, chunk=Q)
    atol = 2e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=atol)
    np.testing.assert_allclose(fin, finr, atol=2e-4 if dtype == jnp.float32
                               else 0.15)


def test_ssd_chunk_invariance():
    """The chunked algorithm must equal the single-chunk (dense) result."""
    BH, S, P, N = 2, 256, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (BH, S, P))
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    Bm = jax.random.normal(ks[2], (BH, S, N))
    Cm = jax.random.normal(ks[3], (BH, S, N))
    y64, f64 = ssd_ref(x, dA, Bm, Cm, chunk=64)
    y256, f256 = ssd_ref(x, dA, Bm, Cm, chunk=256)
    np.testing.assert_allclose(y64, y256, atol=2e-3, rtol=1e-4)
    np.testing.assert_allclose(f64, f256, atol=2e-3, rtol=1e-4)


def test_ssd_decode_step_matches_scan():
    from repro.models.ssm import ssd_decode_step
    BH, S, P, N = 2, 16, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (BH, S, P))
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    Bm = jax.random.normal(ks[2], (BH, S, N))
    Cm = jax.random.normal(ks[3], (BH, S, N))
    y_ref, fin_ref = ssd_ref(x, dA, Bm, Cm, chunk=16)
    # step one token at a time (B, H folded: treat BH as B with H=1)
    state = jnp.zeros((BH, 1, P, N))
    ys = []
    for t in range(S):
        y, state = ssd_decode_step(state, x[:, t, None], dA[:, t, None],
                                   Bm[:, t, None], Cm[:, t, None])
        ys.append(y[:, 0])
    np.testing.assert_allclose(jnp.stack(ys, 1), y_ref, atol=2e-4)
    np.testing.assert_allclose(state[:, 0], fin_ref, atol=2e-4)


# ---------------- topk compress ----------------

@pytest.mark.parametrize("n,block,k", [(4096, 512, 16), (8192, 1024, 32),
                                       (2048, 256, 8), (1024, 1024, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_kernel_vs_ref(n, block, k, dtype):
    x = jax.random.normal(jax.random.PRNGKey(5), (n,), dtype)
    v1, i1, r1, c1 = compress(x, k_per_block=k, block=block, interpret=True)
    v2, i2 = topk_pack_ref(x, k, block)
    np.testing.assert_allclose(np.asarray(v1, np.float32),
                               np.asarray(v2, np.float32), atol=0)
    assert bool(jnp.array_equal(i1, i2))
    dense = decompress(v1, i1, block=block, n=n)
    np.testing.assert_allclose(np.asarray(x - dense, np.float32),
                               np.asarray(r1, np.float32), atol=1e-6)
    assert int(c1) == v1.size * v1.dtype.itemsize + i1.size * 4


@settings(max_examples=10, deadline=None)
@given(nb=st.integers(1, 4), k=st.sampled_from([4, 16]),
       seed=st.integers(0, 2**16))
def test_topk_property_reconstruction(nb, k, seed):
    """residual + unpack(pack(x)) == x, and packed values are the k
    largest magnitudes of each block."""
    block = 256
    n = nb * block
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    vals, idx, resid, _ = compress(x, k_per_block=k, block=block,
                                   interpret=True)
    dense = decompress(vals, idx, block=block, n=n)
    np.testing.assert_allclose(dense + resid, x, atol=1e-6)
    xb = np.asarray(x).reshape(nb, block)
    for b in range(nb):
        top_ref = np.sort(np.abs(xb[b]))[-k:]
        np.testing.assert_allclose(np.sort(np.abs(np.asarray(vals[b]))),
                                   top_ref, atol=1e-6)
