"""Causal critical-path analyzer (DESIGN.md §11): the path must tile
the makespan *exactly* (rational arithmetic) on arbitrary DAGs under
both clock engines, the what-if projections must track ground-truth
re-runs, and the whole analyzer must stay post-hoc — attaching it (or
the tracer features it reads: llf slice spans, admission markers)
never moves simulated time."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "scripts"))

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback, see _hypothesis_stub
    from _hypothesis_stub import given, settings, st

import trace_diff  # noqa: E402
from benchmarks.common import validate_perfetto  # noqa: E402
from repro.core import (ClientRuntime, Cluster, DeviceSpec,  # noqa: E402
                        HeapSimClock, LinkSpec, ServerSpec, SimClock,
                        Tracer)
from repro.core import runtime as runtime_mod  # noqa: E402
from repro.core.trace import _round_shares  # noqa: E402

MiB = 1 << 20
CLIENT = LinkSpec(latency=61e-6, bandwidth=1e9 / 8)
PEER = LinkSpec(latency=20e-6, bandwidth=40e9 / 8)


def mk_cluster(n=2, trace=None, scheduler="fifo", scheduler_opts=None,
               admission=None):
    return Cluster([ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                    for i in range(n)],
                   peer_link=PEER, peer_transport="tcp",
                   scheduler=scheduler, scheduler_opts=scheduler_opts,
                   admission=admission, trace=trace)


def attach(cluster, **kw):
    kw.setdefault("client_link", CLIENT)
    return ClientRuntime(cluster=cluster, **kw)


def random_dag_workload(cluster, rng, n_cmds):
    """Seeded random DAG: uploads, kernels with random wait_for edges,
    cross-server placements (forcing migrations), read-backs."""
    rt = attach(cluster, name="ue")
    cluster.run()
    bufs = []
    events = []
    for i in range(2):
        buf = rt.create_buffer(64 * 1024 * (i + 1))
        rt.enqueue_write(f"s{i % 2}", buf,
                         np.full(16 * 1024 * (i + 1), i, np.uint32))
        bufs.append(buf)
    for i in range(n_cmds):
        srv = f"s{rng.randrange(2)}"
        deps = [events[j] for j in
                sorted(rng.sample(range(len(events)),
                                  min(len(events), rng.randrange(3))))]
        out = rt.create_buffer(4096)
        ev = rt.enqueue_kernel(
            srv, fn=None, inputs=[bufs[rng.randrange(2)]],
            outputs=[out], duration=2.0 ** -rng.randrange(8, 14),
            wait_for=deps, name=f"k{i}")
        events.append(ev)
        bufs.append(out)
    rt.enqueue_read("s1", bufs[-1])
    cluster.run()
    return rt


# ---- the tiling identity, property-tested on both engines ----

@settings(max_examples=12, deadline=None)
@given(st.data())
def test_critical_path_tiles_makespan_exactly(data):
    import random

    seed = data.draw(st.integers(0, 2 ** 20), label="seed")
    engine = data.draw(st.sampled_from([SimClock, HeapSimClock]),
                       label="engine")
    n_cmds = data.draw(st.integers(4, 14), label="n_cmds")
    saved = runtime_mod.SimClock
    runtime_mod.SimClock = engine
    try:
        tr = Tracer()
        cluster = mk_cluster(trace=tr)
        random_dag_workload(cluster, random.Random(seed), n_cmds)
    finally:
        runtime_mod.SimClock = saved
    cp = tr.critical_path(exact=True)
    assert cp.segments, "non-empty workload must yield a path"
    # the identity: rational segment sum == makespan, no float dust
    assert cp.segment_sum() == cp.makespan
    # gap-free tiling in causal order, endpoints anchored
    assert cp.segments[0].t0 == cp.t0
    assert cp.segments[-1].t1 == cp.t1
    for a, b in zip(cp.segments, cp.segments[1:]):
        assert a.t1 == b.t0
        assert a.t1 > a.t0
    # blame shares sum to 1 by the same identity
    assert abs(sum(r["share"] for r in cp.blame()) - 1.0) < 1e-9


def test_empty_trace_yields_empty_path_and_identity():
    tr = Tracer()
    cp = tr.critical_path(exact=True)
    assert cp.segments == [] and cp.makespan == 0
    w = tr.whatif(wire=0.0)
    assert w["recorded_s"] == w["projected_s"] == 0.0


# ---- what-if projections vs ground truth ----

def _compute_dag(speed=1.0):
    """Compute-bound two-server chain: device_speed=2 should ~halve
    the makespan, and a re-run with halved durations is ground truth."""
    import random

    tr = Tracer()
    cluster = mk_cluster(trace=tr)
    rng = random.Random(7)
    rt = attach(cluster, name="ue")
    cluster.run()
    prev = None
    for i in range(12):
        ev = rt.enqueue_kernel(
            f"s{rng.randrange(2)}", fn=None, duration=1e-4 / speed,
            wait_for=[prev] if prev and rng.random() < 0.7 else (),
            name=f"k{i}")
        prev = ev
    cluster.run()
    return tr, cluster


def _migration_run(nic=1.0):
    """Single-phase bulk migration pipeline; nic_bandwidth=2 vs a
    re-run with doubled link bandwidths."""
    tr = Tracer()
    cluster = Cluster(
        [ServerSpec(f"s{i}", [DeviceSpec("gpu0")]) for i in range(2)],
        peer_link=LinkSpec(latency=PEER.latency,
                           bandwidth=PEER.bandwidth * nic),
        peer_transport="tcp", trace=tr)
    rt = ClientRuntime(
        cluster=cluster,
        client_link=LinkSpec(latency=CLIENT.latency,
                             bandwidth=CLIENT.bandwidth * nic))
    big = rt.create_buffer(4 * MiB)
    wev = rt.enqueue_write("s0", big, np.zeros(MiB, np.uint32))
    for j in range(2):
        out = rt.create_buffer(4096)
        rt.enqueue_kernel("s1", fn=None, inputs=[big], outputs=[out],
                          duration=1e-5, wait_for=[wev], name=f"k{j}")
    cluster.run()
    return tr, cluster


def _span(tr):
    stamps = [Tracer._stamps(rec) for rec in tr.finished()]
    return max(s[5] for s in stamps) - min(s[0] for s in stamps)


def test_whatif_no_knobs_reproduces_recorded_makespan():
    tr, _ = _migration_run()
    w = tr.whatif()
    assert w["recorded_s"] == pytest.approx(_span(tr))
    assert w["projected_s"] == pytest.approx(w["recorded_s"], rel=0.01)
    assert w["speedup"] == pytest.approx(1.0, rel=0.01)


def test_whatif_device_speed_matches_ground_truth_rerun():
    tr, _ = _compute_dag()
    w = tr.whatif(device_speed=2.0)
    tr2, _ = _compute_dag(speed=2.0)
    actual = _span(tr2)
    assert abs(w["projected_s"] - actual) / actual <= 0.10
    assert w["projected_s"] < w["recorded_s"]


def test_whatif_nic_bandwidth_matches_ground_truth_rerun():
    tr, _ = _migration_run()
    w = tr.whatif(nic_bandwidth=2.0)
    tr2, _ = _migration_run(nic=2.0)
    actual = _span(tr2)
    assert abs(w["projected_s"] - actual) / actual <= 0.10
    assert w["projected_s"] < w["recorded_s"]


def test_whatif_wire_zero_is_a_lower_bound_and_knobs_validate():
    tr, _ = _migration_run()
    w = tr.whatif(wire=0.0)
    assert 0.0 < w["projected_s"] < w["recorded_s"]
    for bad in ({"device_speed": 0.0}, {"nic_bandwidth": -1.0},
                {"wire": -0.5}):
        with pytest.raises(ValueError):
            tr.whatif(**bad)


# ---- analyzer inputs stay sim-time invisible ----

def _llf_admission_run(trace):
    cluster = mk_cluster(n=1, trace=trace, scheduler="llf",
                         scheduler_opts={"chunk": 0.5e-3},
                         admission={})
    be = attach(cluster, name="be")
    slo = attach(cluster, name="slo", slo_ms=4.0)
    cluster.run()
    buf = be.create_buffer(64)
    w0 = be.enqueue_write("s0", buf, np.zeros(16, np.uint32))
    be.enqueue_kernel("s0", fn=None, inputs=[buf], duration=20e-3,
                      wait_for=[w0], name="bulk")
    sbuf = slo.create_buffer(64)
    w1 = slo.enqueue_write("s0", sbuf, np.zeros(16, np.uint32))
    slo.enqueue_kernel("s0", fn=None, inputs=[sbuf], duration=1e-3,
                       wait_for=[w1], name="tight")
    cluster.run()
    return cluster


def test_llf_admission_traced_run_is_sim_time_identical():
    traced, plain = _llf_admission_run(Tracer()), _llf_admission_run(None)
    assert traced.clock.now == plain.clock.now
    assert traced.stats()["device_busy"] == plain.stats()["device_busy"]


def test_llf_slices_admission_markers_and_histograms_export():
    cluster = _llf_admission_run(Tracer())
    tr = cluster.trace
    # llf slice spans: the preempted bulk kernel's slices tile its cost
    sliced = [r for r in tr.cmds.values() if r.slices]
    assert sliced, "chunked llf execution must record slices"
    for r in sliced:
        assert sum(b - a for a, b in r.slices) == \
            pytest.approx(r.cost, rel=1e-9)
    # admission verdicts recorded and exported
    assert any(entry[2] in ("admit", "degrade", "reject")
               for entry in tr.admissions)
    events = tr.perfetto_events()
    assert validate_perfetto({"traceEvents": events}) == []
    names = {e.get("name") for e in events}
    assert any(n and n.startswith("admission") for n in names)
    # metrics histograms over the same spans
    summ = tr.metrics().summary()
    assert any(k.startswith("admission_predicted") for k in summ)
    assert summ["cmd_latency[slo]"]["count"] > 0


# ---- exporter round-trip + trace-diff forensics ----

def test_gzip_trace_roundtrip_and_diff_finds_the_mover(tmp_path):
    def run(bulk_duration):
        tr = Tracer()
        cluster = mk_cluster(trace=tr)
        rt = attach(cluster, name="ue")
        cluster.run()
        buf = rt.create_buffer(MiB)
        w = rt.enqueue_write("s0", buf, np.zeros(MiB // 4, np.uint32))
        for i in range(4):
            rt.enqueue_kernel(f"s{i % 2}", fn=None, inputs=[buf],
                              duration=bulk_duration, wait_for=[w],
                              name=f"k{i}")
        cluster.run()
        return tr

    base, cand = tmp_path / "base.json", tmp_path / "cand.json.gz"
    run(1e-3).write_perfetto(str(base))
    run(4e-3).write_perfetto(str(cand))     # 4x slower devices
    assert validate_perfetto(str(cand)) == []   # gzip-aware validator
    d = trace_diff.diff(trace_diff.aggregate(trace_diff.load_events(
        str(base))), trace_diff.aggregate(trace_diff.load_events(
            str(cand))), top=5)
    assert d["makespan_delta_s"] > 0
    movers = [m["resource"] for m in d["movers"]]
    assert any(m in ("s0/gpu0", "s1/gpu0", "stage:execute")
               for m in movers)
    out = trace_diff.render(d, markdown=True)
    assert "makespan" in out and "|" in out
    assert trace_diff.main([str(base), str(cand)]) == 0


def test_format_blame_lists_top_contributors():
    tr, _ = _migration_run()
    table = tr.format_blame(top=3, title="mig")
    assert "# mig" in table and "critical path:" in table
    assert "share%" in table
    # the bulk migration dominates this workload: the wire must appear
    assert "transfer" in table or "submit_wire" in table


# ---- display rounding: shares always sum to 100 ----

@settings(max_examples=25, deadline=None)
@given(st.data())
def test_round_shares_sum_to_exactly_100(data):
    import random

    rng = random.Random(data.draw(st.integers(0, 2 ** 20)))
    n = data.draw(st.integers(1, 9))
    raw = [rng.random() + 1e-9 for _ in range(n)]
    tot = sum(raw)
    rounded = _round_shares([x / tot * 100.0 for x in raw])
    assert round(sum(rounded), 2) == 100.0
    assert all(abs(v - round(v, 2)) < 1e-9 for v in rounded)
