"""HLO cost-model unit tests on hand-built programs with known costs."""
import jax
import jax.numpy as jnp

from repro.roofline import analyze_hlo
from repro.roofline.hlo_cost import (parse_module, shape_bytes, shape_dims,
                                     _group_size, _trip_count)


def test_shape_parsing():
    assert shape_bytes("f32[128,512]{1,0}") == 128 * 512 * 4
    assert shape_bytes("bf16[3]{0}") == 6
    assert shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert shape_dims("bf16[4,8]{1,0}") == [4, 8]


def test_group_size_formats():
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert _group_size("replica_groups=[2,4]<=[8]") == 4
    assert _group_size("replica_groups=[4,2]<=[2,4]T(1,0)") == 2


def test_trip_count():
    assert _trip_count('backend_config={"known_trip_count":{"n":"12"}}') == 12
    assert _trip_count("") == 1


def test_matmul_flops_exact():
    M = N = K = 256

    @jax.jit
    def f(a, b):
        return a @ b

    hlo = f.lower(jnp.zeros((M, K)), jnp.zeros((K, N))).compile().as_text()
    tot = analyze_hlo(hlo)
    assert tot.flops == 2 * M * N * K


def test_scan_trip_count_multiplies_flops():
    T, M = 8, 64

    @jax.jit
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    hlo = f.lower(jnp.zeros((M, M)),
                  jnp.zeros((T, M, M))).compile().as_text()
    tot = analyze_hlo(hlo)
    expected = 2 * M * M * M * T
    assert abs(tot.flops - expected) / expected < 0.01, tot.flops


def test_parse_module_entry():
    @jax.jit
    def f(x):
        return x * 2

    hlo = f.lower(jnp.zeros((4,))).compile().as_text()
    comps = parse_module(hlo)
    assert "__entry__" in comps
