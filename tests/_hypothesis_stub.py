"""Minimal deterministic stand-in for the slice of the hypothesis API
this suite uses (``given``, ``settings``, ``strategies.integers/
sampled_from/booleans/data``).

Imported only when hypothesis is not installed: instead of skipping the
property tests outright, each ``@given`` test runs over a fixed
pseudo-random sample of the strategy space (seeded per example, so
failures reproduce). No shrinking, no database — just coverage.
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(options):
    options = list(options)
    return _Strategy(lambda rng: options[rng.randrange(len(options))])


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


class _Data:
    """Interactive draws (``st.data()``) share the example's rng."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy._draw(self._rng)


def data():
    return _Strategy(lambda rng: _Data(rng))


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the wrapped function's strategy parameters (it would try to
        # resolve them as fixtures)
        def wrapper():
            for i in range(wrapper._max_examples):
                rng = random.Random(0xC0FFEE + 7919 * i)
                args = [s._draw(rng) for s in arg_strategies]
                kwargs = {k: s._draw(rng) for k, s in kw_strategies.items()}
                fn(*args, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._max_examples = 20
        return wrapper
    return deco


def settings(max_examples=20, **_ignored):
    def deco(fn):
        if hasattr(fn, "_max_examples"):
            fn._max_examples = max_examples
        return fn
    return deco


class st:
    """Namespace mirror of ``hypothesis.strategies``."""
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)
    data = staticmethod(data)
