"""Cluster placement control plane (DESIGN.md §6): telemetry probes
(scheduler queue depth, store replica locality, NIC occupancy on both
ends), the pinned/locality/hetmec policies, the NIC ingress model, the
decision scoreboard, and cross-tenant isolation."""
import numpy as np
import pytest

from repro.core import (ClientRuntime, Cluster, DeviceSpec, LinkSpec,
                        NIC, ServerSpec, SimClock,
                        make_placement_policy)
from repro.core.netsim import Link
from repro.core.scheduler import DRRPolicy, FIFOPolicy


def mk_cluster(n=3, placement="pinned", nic=None, nic_in=None,
               store=False, peer_bw=40e9 / 8):
    return Cluster([ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                    for i in range(n)],
                   peer_link=LinkSpec(latency=20e-6, bandwidth=peer_bw),
                   peer_transport="tcp", placement=placement,
                   nic_bandwidth=nic, nic_ingress_bandwidth=nic_in,
                   store=store)


def attach(cluster, **kw):
    kw.setdefault("client_link", LinkSpec(latency=61e-6, bandwidth=1e9 / 8))
    return ClientRuntime(cluster=cluster, **kw)


def seed(rt, server, nbytes=1 * 1024 * 1024, fill=1):
    """A buffer made resident on ``server``."""
    buf = rt.create_buffer(nbytes)
    rt.enqueue_write(server, buf, np.full(nbytes // 4, fill, np.uint32))
    rt.finish()
    return buf


def timestamps(events):
    return [(e.t_queued, e.t_submitted, e.t_start, e.t_end,
             e.t_client_ack, e.server) for e in events]


# ---- scheduler queue-depth probe ----

def test_fifo_policy_tracks_queued_seconds():
    p = FIFOPolicy()
    assert p.queued_seconds() == 0.0
    p.push("a", 1.0, 3e-3, lambda r: None)
    p.push("b", 1.0, 2e-3, lambda r: None)
    assert p.queued_seconds() == pytest.approx(5e-3)
    p.pop()
    assert p.queued_seconds() == pytest.approx(2e-3)
    p.push("a", 1.0, 4e-3, lambda r: None)
    p.remove("a")
    assert p.queued_seconds() == pytest.approx(2e-3)


def test_drr_policy_tracks_queued_seconds():
    p = DRRPolicy(quantum=10e-3)
    p.push("a", 1.0, 3e-3, lambda r: None)
    p.push("b", 1.0, 2e-3, lambda r: None)
    assert p.queued_seconds() == pytest.approx(5e-3)
    p.pop()
    assert p.queued_seconds() == pytest.approx(2e-3)
    p.push("a", 1.0, 4e-3, lambda r: None)
    p.remove("a")
    assert p.queued_seconds() == pytest.approx(2e-3)


def test_scheduler_probe_and_engine_queue_depth():
    cluster = mk_cluster(n=2)
    rt = attach(cluster, name="t")
    # a long kernel occupies the device; two more wait in the run queue
    evs = [rt.enqueue_kernel("s0", fn=None, duration=5e-3)
           for _ in range(3)]
    cluster.run(until=cluster.clock.now + 2e-3)  # first one in service
    sch = cluster.hosts["s0"].schedulers["gpu0"]
    assert sch.queued_seconds() == pytest.approx(10e-3)  # 2 queued
    # engine view: queued + in-service remainder on the device timeline
    depth = cluster.placement.queued_device_seconds("s0")
    assert 10e-3 < depth <= 15e-3
    assert cluster.placement.queued_device_seconds("s1") == 0.0
    cluster.run()
    assert all(e.status == "complete" for e in evs)
    assert cluster.placement.queued_device_seconds("s0") == 0.0
    # outstanding tally drained with the events
    assert cluster.placement.queue_depth("s0") == 0.0


def test_outstanding_tally_covers_unresolved_batches():
    """Kernels enqueued behind unresolved deps are invisible to the
    scheduler probe but counted by the engine's outstanding tally
    (maintained once any non-pinned policy exists on the cluster)."""
    cluster = mk_cluster(n=2, placement="hetmec")
    rt = attach(cluster, name="t", placement="pinned")
    gate = rt.enqueue_kernel("s0", fn=None, duration=1e-3)
    rt.enqueue_kernel("s0", fn=None, duration=7e-3, wait_for=[gate])
    # nothing has run yet: scheduler queues are empty...
    assert cluster.hosts["s0"].schedulers["gpu0"].queued_seconds() == 0.0
    # ...but the engine already knows 8 ms were placed on s0
    assert cluster.placement.queue_depth("s0") == pytest.approx(8e-3)
    cluster.run()
    assert cluster.placement.queue_depth("s0") == 0.0


# ---- NIC ingress model ----

def _one_send(nbytes, bw, in_bw=None, preload=0.0):
    clock = SimClock()
    link = Link(clock, 1e-4, bw, "l")
    nic_in = NIC(in_bw, "in") if in_bw else None
    if nic_in is not None:
        nic_in._busy_until = preload
    got = []
    link.send(nbytes, lambda: got.append(clock.now), ingress=nic_in)
    clock.run()
    return got[0], nic_in


def test_uncontended_fat_ingress_is_time_identical():
    t_none, _ = _one_send(1e6, 1e9)
    t_fat, nic = _one_send(1e6, 1e9, in_bw=4e9)
    assert t_fat == t_none
    assert nic.bytes_sent == 1e6
    assert nic.busy_time == pytest.approx(1e6 / 4e9)


def test_contended_or_slow_ingress_delays_delivery():
    t_none, _ = _one_send(1e6, 1e9)
    # port busy when the first byte lands: delivery pushed out
    t_busy, _ = _one_send(1e6, 1e9, in_bw=4e9, preload=5e-3)
    assert t_busy > t_none
    # port slower than the link: it paces delivery
    t_slow, _ = _one_send(1e6, 1e9, in_bw=0.5e9)
    assert t_slow > t_none


def test_chunked_ingress_fat_port_identical_and_slow_port_paces():
    chunks = [(1e-5, 5e5, 1e-5)] * 4
    def send(in_bw=None, egress_bw=None):
        clock = SimClock()
        link = Link(clock, 1e-4, 1e9, "l")
        nic_in = NIC(in_bw, "in") if in_bw else None
        egress = NIC(egress_bw, "out") if egress_bw else None
        got = []
        link.send_chunked(chunks, lambda: got.append(clock.now),
                          egress=egress, ingress=nic_in)
        clock.run()
        return got[0], nic_in
    t_none, _ = send()
    t_fat, nic = send(in_bw=4e9)
    assert t_fat == t_none
    assert nic.bytes_sent == 2e6
    t_slow, _ = send(in_bw=0.25e9)
    assert t_slow > t_none
    # tandem with an egress port on the sending side still holds
    t_both, _ = send(in_bw=4e9, egress_bw=4e9)
    assert t_both == t_none


def test_ingress_contention_on_shared_cluster_and_stats():
    """Two tenants pushing to ONE server at once contend on its ingress
    port; stats account the occupancy."""
    def drain(in_bw):
        cluster = mk_cluster(n=2, nic_in=in_bw, peer_bw=1e9)
        a = attach(cluster, name="a",
                   client_link=LinkSpec(latency=61e-6, bandwidth=1e9))
        b = attach(cluster, name="b",
                   client_link=LinkSpec(latency=61e-6, bandwidth=1e9))
        nbytes = 4 * 1024 * 1024
        for rt in (a, b):
            buf = rt.create_buffer(nbytes)
            rt.enqueue_write("s0", buf, np.zeros(nbytes // 4, np.uint32))
        t0 = cluster.clock.now
        cluster.run()
        return cluster.clock.now - t0, cluster.stats()
    slow_t, slow_st = drain(0.5e9)     # port at half the link rate
    fat_t, fat_st = drain(1e10)        # port far above both links
    assert slow_t > fat_t
    assert slow_st["nic_in_busy"]["s0"] > 0.0
    assert slow_st["nic_in_bytes"]["s0"] > 8 * 1024 * 1024  # both uploads
    # no-ingress cluster reports zeroes, not missing keys
    assert mk_cluster(n=1).stats()["nic_in_busy"] == {"s0": 0.0}


# ---- pinned: bit-exact default ----

def test_pinned_placement_is_pure_bookkeeping():
    """The default engine must not perturb a single timestamp vs an
    engine whose place() is a bare passthrough (the pre-placement
    runtime)."""
    def workload(cluster):
        rt = attach(cluster, name="t")
        bufs = [seed(rt, f"s{i % 3}", nbytes=256 * 1024, fill=i)
                for i in range(3)]
        evs = []
        for i in range(9):
            evs.append(rt.enqueue_kernel(
                f"s{(i + 1) % 3}", fn=None, inputs=[bufs[i % 3]],
                duration=3e-4, wait_for=evs[-1:]))
        rt.finish()
        return timestamps(evs)
    a = workload(mk_cluster(n=3))
    cluster = mk_cluster(n=3)
    cluster.placement.place = \
        lambda rt, requested, *args, **kw: requested  # no engine at all
    b = workload(cluster)
    assert a == b


def test_pinned_keeps_requested_despite_better_options():
    cluster = mk_cluster(n=2)
    rt = attach(cluster, name="t")
    buf = seed(rt, "s1")
    for _ in range(4):
        rt.enqueue_kernel("s0", fn=None, duration=5e-3)
    ev = rt.enqueue_kernel("s0", fn=None, inputs=[buf], duration=1e-3)
    rt.finish()
    assert ev.server == "s0"
    st = cluster.stats()["placement"]
    assert st["policy"] == "pinned"
    assert st["placed_remote"] == 0
    assert st["placed_local"] == st["decisions"] == 5


# ---- locality ----

def test_locality_places_on_replica_holder():
    cluster = mk_cluster(n=3, placement="locality")
    rt = attach(cluster, name="t")
    buf = seed(rt, "s2")
    ev = rt.enqueue_kernel("s0", fn=None, inputs=[buf], duration=1e-3)
    rt.finish()
    assert ev.server == "s2"
    st = rt.stats()["placement"]
    assert st["placed_remote"] == 1
    assert st["placement_bytes_avoided"] == buf.nbytes


def test_locality_without_resident_inputs_stays_pinned():
    cluster = mk_cluster(n=3, placement="locality")
    rt = attach(cluster, name="t")
    ev = rt.enqueue_kernel("s1", fn=None, duration=1e-3)
    rt.finish()
    assert ev.server == "s1"
    assert cluster.stats()["placement"]["placed_local"] == 1


def test_locality_tie_breaks_on_queue_depth_then_name():
    cluster = mk_cluster(n=3, placement="locality")
    rt = attach(cluster, name="t")
    buf = seed(rt, "s1")
    buf.valid_on |= {"s2"}            # equal replicas on s1 and s2
    rt.enqueue_kernel("s1", fn=None, duration=5e-3)   # backlog on s1
    ev = rt.enqueue_kernel("s0", fn=None, inputs=[buf], duration=1e-3)
    rt.finish()
    assert ev.server == "s2"          # same bytes, shallower queue
    # with equal queues too, sorted server name decides
    cluster2 = mk_cluster(n=3, placement="locality")
    rt2 = attach(cluster2, name="t")
    buf2 = seed(rt2, "s1")
    buf2.valid_on |= {"s2"}
    ev2 = rt2.enqueue_kernel("s0", fn=None, inputs=[buf2], duration=1e-3)
    rt2.finish()
    assert ev2.server == "s1"


def test_locality_sees_other_tenants_replicas_through_store():
    cluster = mk_cluster(n=3, placement="locality", store=True)
    a = attach(cluster, name="a")
    b = attach(cluster, name="b")
    payload = np.arange(64 * 1024 // 4, dtype=np.uint32)
    seed_buf = a.create_buffer(64 * 1024)
    a.enqueue_write("s2", seed_buf, payload)
    a.finish()
    # b uploads identical content nowhere near s2, then runs a kernel:
    # the store knows s2 already holds these bytes
    mine = b.create_buffer(64 * 1024)
    b.enqueue_write("s0", mine, payload)
    b.finish()
    mine.valid_on.discard("s0")       # drop b's own copy; content stays
    ev = b.enqueue_kernel("s0", fn=None, inputs=[mine], duration=1e-3)
    b.finish()
    assert ev.server in ("s0", "s2")  # both hold the content
    assert cluster.store.replica_servers(mine) >= {"s2"}


# ---- hetmec ----

def test_hetmec_prefers_idle_far_server_over_backlogged_near_one():
    cluster = mk_cluster(n=2, placement="hetmec")
    rt = attach(cluster, name="t")
    buf = seed(rt, "s0")              # input lives on the near server
    for _ in range(5):
        rt.enqueue_kernel("s0", fn=None, duration=20e-3)  # deep backlog
    ev = rt.enqueue_kernel("s0", fn=None, inputs=[buf], duration=1e-3)
    rt.finish()
    # pulling 1 MiB over a 40G peer link beats 100 ms of queue
    assert ev.server == "s1"
    assert cluster.stats()["placement"]["placed_remote"] >= 1


def test_hetmec_stays_home_when_transfer_outweighs_queue():
    cluster = mk_cluster(n=2, placement="hetmec", peer_bw=100e6 / 8)
    rt = attach(cluster, name="t")
    buf = seed(rt, "s0", nbytes=8 * 1024 * 1024)
    rt.enqueue_kernel("s0", fn=None, duration=2e-3)   # shallow backlog
    ev = rt.enqueue_kernel("s0", fn=None, inputs=[buf], duration=1e-3)
    rt.finish()
    # 8 MiB over a 100 Mbit peer link (~670 ms) dwarfs 2 ms of queue
    assert ev.server == "s0"


def test_hetmec_tie_break_is_sorted_and_batches_spread():
    cluster = mk_cluster(n=3, placement="hetmec")
    rt = attach(cluster, name="t")
    # zero-cost kernels carry no outstanding tally: the tie lands on
    # the sorted-first candidate every time (deterministic)
    evs = [rt.enqueue_kernel("s2", fn=None) for _ in range(3)]
    rt.finish()
    assert [e.server for e in evs] == ["s0", "s0", "s0"]
    # costed kernels spread: each placement's outstanding tally makes
    # the next candidate cheaper
    evs = [rt.enqueue_kernel("s2", fn=None, duration=1e-3)
           for _ in range(3)]
    rt.finish()
    assert sorted(e.server for e in evs) == ["s0", "s1", "s2"]


def test_hetmec_transfer_estimate_sees_receiver_ingress_queue():
    """Receiver-side NIC contention (the ingress satellite) steers
    placement: a destination whose ingress port is backed up is a
    worse target for a kernel that must pull its input."""
    def choose(preload_in):
        cluster = mk_cluster(n=3, placement="hetmec", nic_in=1e9)
        rt = attach(cluster, name="t")
        buf = seed(rt, "s0", nbytes=2 * 1024 * 1024)
        rt.enqueue_kernel("s0", fn=None, duration=50e-3)  # evict home
        cluster.hosts["s1"].nic_in._busy_until = \
            cluster.clock.now + preload_in
        ev = rt.enqueue_kernel("s0", fn=None, inputs=[buf],
                               duration=1e-3)
        rt.finish()
        return ev.server
    assert choose(0.0) == "s1"        # tie → sorted-first target
    assert choose(30e-3) == "s2"      # s1's port is jammed: go s2


def test_per_tenant_policy_override_on_shared_cluster():
    cluster = mk_cluster(n=2, placement="hetmec")
    het = attach(cluster, name="het")
    pin = attach(cluster, name="pin", placement="pinned")
    for _ in range(5):
        pin.enqueue_kernel("s0", fn=None, duration=20e-3)
    ev_pin = pin.enqueue_kernel("s0", fn=None, duration=1e-3)
    ev_het = het.enqueue_kernel("s0", fn=None, duration=1e-3)
    cluster.run()
    assert ev_pin.server == "s0"      # override sticks to the request
    assert ev_het.server == "s1"      # cluster default dodges the pile


def test_cluster_kwarg_rejects_nic_ingress_on_attach():
    cluster = mk_cluster(n=1)
    with pytest.raises(ValueError, match="cluster-level"):
        ClientRuntime(cluster=cluster, nic_ingress_bandwidth=1e9)
    with pytest.raises(ValueError, match="placement policy"):
        ClientRuntime(cluster=cluster, placement="bogus")


# ---- cross-tenant isolation ----

def test_placement_churn_never_perturbs_bystander_timestamps():
    """A tenant bouncing kernels across s0/s1 under hetmec leaves a
    pinned bystander on s2 with bit-identical timing. Attach/seed
    phases advance the shared clock by different amounts between the
    two runs, so the comparison is t0-relative — the simulation is
    time-translation invariant, which makes relative equality exactly
    the 'unperturbed' claim."""
    def bystander_run(with_churn):
        cluster = mk_cluster(n=3, placement="hetmec")
        by = attach(cluster, name="by", placement="pinned")
        if with_churn:
            churn = attach(cluster, name="churn")
            # a fat buffer resident on s0/s1 only: transfer cost keeps
            # every churn placement off the bystander's server
            fat = seed(churn, "s0", nbytes=32 * 1024 * 1024)
            fat.valid_on.add("s1")
            for i in range(12):
                churn.enqueue_kernel("s0", fn=None, inputs=[fat],
                                     duration=4e-3)
        by_evs = []
        for i in range(6):
            by_evs.append(by.enqueue_kernel(
                "s2", fn=None, duration=1e-3, wait_for=by_evs[-1:]))
        cluster.run()
        if with_churn:
            st = cluster.stats()["placement"]
            assert st["placed_remote"] > 0          # churn really churned
        t0 = by_evs[0].t_queued
        return [(tq - t0, ts - t0, t1 - t0, t2 - t0, ta - t0, srv)
                for tq, ts, t1, t2, ta, srv in timestamps(by_evs)]
    alone, shared = bystander_run(False), bystander_run(True)
    for ra, rb in zip(alone, shared):
        assert ra[-1] == rb[-1]                     # same server
        # abs=1e-12: IEEE754 makes time translation inexact at ~1e-17;
        # any REAL perturbation (a queued command, a busy link) is
        # microseconds, 6+ orders of magnitude above this tolerance
        assert ra[:-1] == pytest.approx(rb[:-1], abs=1e-12)


# ---- scoreboard ----

def test_placement_scoreboard_in_stats():
    cluster = mk_cluster(n=2, placement="locality")
    rt = attach(cluster, name="t")
    buf = seed(rt, "s1")
    rt.enqueue_kernel("s0", fn=None, inputs=[buf], duration=1e-3)
    rt.enqueue_kernel("s1", fn=None, inputs=[buf], duration=1e-3)
    rt.finish()
    for st in (cluster.stats()["placement"], rt.stats()["placement"]):
        assert st["policy"] == "locality"
        assert st["decisions"] == 2
        assert st["placed_local"] == 1
        assert st["placed_remote"] == 1
        assert st["placement_bytes_avoided"] == buf.nbytes


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown placement"):
        make_placement_policy("nope")
    with pytest.raises(ValueError, match="unknown placement"):
        mk_cluster(placement="nope")


def test_all_pinned_cluster_skips_outstanding_bookkeeping():
    """No non-pinned policy anywhere → the tally (and its per-kernel
    closure) is skipped on the enqueue hot path; attaching a non-pinned
    tenant flips it on, permanently."""
    cluster = mk_cluster(n=2)                 # default pinned
    rt = attach(cluster, name="t")
    rt.enqueue_kernel("s0", fn=None, duration=5e-3)
    assert not cluster.placement.telemetry_active
    assert cluster.placement.outstanding == {}
    attach(cluster, name="het", placement="locality")
    assert cluster.placement.telemetry_active
    rt.enqueue_kernel("s0", fn=None, duration=5e-3)
    assert cluster.placement.outstanding["s0"] == pytest.approx(5e-3)
    cluster.run()


def test_redirect_respects_explicit_device_name():
    """A kernel naming a device is only redirected to hosts that HAVE
    that device — a locality win on a device-less host would KeyError
    at dispatch."""
    cluster = Cluster([ServerSpec("s0", [DeviceSpec("gpu0")]),
                       ServerSpec("s1", [DeviceSpec("tpu0")])],
                      peer_link=LinkSpec(latency=20e-6,
                                         bandwidth=40e9 / 8),
                      placement="locality")
    rt = attach(cluster, name="t")
    buf = seed(rt, "s1")                     # replica on the TPU host
    ev = rt.enqueue_kernel("s0", device="gpu0", fn=None, inputs=[buf],
                           duration=1e-3)
    rt.finish()
    assert ev.server == "s0"                 # only gpu0-bearing host
    # without a device name the replica holder wins as usual (fresh
    # buffer: ev's implicit migration made `buf` resident on s0 too)
    buf2 = seed(rt, "s1", fill=2)
    ev2 = rt.enqueue_kernel("s0", fn=None, inputs=[buf2], duration=1e-3)
    rt.finish()
    assert ev2.server == "s1"


def test_redundant_race_pins_past_the_engine():
    """enqueue_kernel_redundant's copies land on their explicit
    servers even when a policy would collapse them onto one host."""
    cluster = mk_cluster(n=3, placement="locality")
    rt = attach(cluster, name="t")
    buf = seed(rt, "s1")
    evs = []
    race = rt.enqueue_kernel_redundant(["s0", "s2"], inputs=[buf],
                                       duration=1e-3)
    race.on_complete(lambda e: evs.append(e.server))
    rt.finish()
    assert race.status == "complete"
    # both copies ran where they were sent; locality would have put
    # them both on s1 (the replica holder)
    busy = {s: sum(d.busy_time for d in cluster.hosts[s].devices
                   .values()) for s in cluster.hosts}
    assert busy["s0"] > 0.0 and busy["s2"] > 0.0


def test_engine_outstanding_drains_on_error_too():
    cluster = mk_cluster(n=1, placement="hetmec")
    rt = attach(cluster, name="t")
    rt.enqueue_kernel("s0", fn=None, duration=5e-3)
    assert cluster.placement.outstanding["s0"] == pytest.approx(5e-3)
    rt.detach()                       # fails the live event
    assert cluster.placement.outstanding["s0"] == pytest.approx(0.0)
