"""Optimizer, data pipeline, checkpoint/restart, compression, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt_lib
from repro import configs
from repro.configs.shapes import ShapeCell
from repro.data.pipeline import DataLoader
from repro.distributed.compression import (compressed_psum_tree,
                                           init_error_state)
from repro.optim import AdamW, constant_schedule, cosine_schedule


def test_adamw_quadratic_convergence():
    opt = AdamW(constant_schedule(0.1), weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}        # d/dw ||w||²
        params, state, m = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_clip():
    opt = AdamW(constant_schedule(0.1), clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(metrics["grad_norm"]) > 100.0   # norm reported pre-clip


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert float(f(100)) <= 0.11
    assert float(f(5)) == pytest.approx(0.5)


def test_bf16_moments_update():
    opt = AdamW(constant_schedule(0.01), moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    p2, s2, _ = opt.update({"w": jnp.ones(4)}, state, params)
    assert bool(jnp.all(p2["w"] < params["w"]))


def test_loader_determinism_and_cursor():
    cfg = configs.get_reduced("tinyllama-1.1b")
    cell = ShapeCell("t", "train", 32, 4)
    l1 = DataLoader(cfg, cell, 2, seed=7)
    b0, b1 = l1.make_batch(0), l1.make_batch(1)
    l2 = DataLoader(cfg, cell, 2, seed=7)
    np.testing.assert_array_equal(b0["labels"], l2.make_batch(0)["labels"])
    # cursor restore replays the same stream
    l2.restore({"seed": 7, "step": 1})
    it = iter(l2)
    nxt = next(it)
    np.testing.assert_array_equal(nxt["labels"], b1["labels"])
    l2.stop()


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    ckpt_lib.save(str(tmp_path), 42, state, extras={"loader": {"x": 1}})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored, extras, step = ckpt_lib.restore(str(tmp_path), like)
    assert step == 42 and extras["loader"]["x"] == 1
    np.testing.assert_array_equal(restored["a"], state["a"])
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_gc_and_latest(tmp_path):
    s = {"a": jnp.zeros(2)}
    for step in (1, 2, 3, 4, 5):
        ckpt_lib.save(str(tmp_path), step, s, keep=2)
    assert ckpt_lib.latest_step(str(tmp_path)) == 5
    tags = [t for t in os.listdir(tmp_path) if t.startswith("step_")]
    assert len(tags) == 2


def test_train_restart_equals_continuous(tmp_path):
    """Fault tolerance: (train 6) == (train 3, crash, restore, train 3)."""
    from repro.launch.train import build
    from repro.training.loop import LoopConfig, Trainer

    def run(steps, ckpt_dir, restore):
        cfg, ctx, step_fn, state, loader = build(
            "tinyllama-1.1b", True, batch=4, seq=32, steps=6, seed=3)
        tr = Trainer(step_fn, state, loader,
                     LoopConfig(total_steps=steps, ckpt_every=3,
                                ckpt_dir=ckpt_dir, log_every=1))
        if restore:
            assert tr.maybe_restore()
        out = tr.run()
        loader.stop()
        return out, tr.state

    full, state_full = run(6, str(tmp_path / "a"), False)
    _half, _ = run(3, str(tmp_path / "b"), False)
    resumed, state_resumed = run(6, str(tmp_path / "b"), True)
    assert abs(full["final_loss"] - resumed["final_loss"]) < 1e-4
    for a, b in zip(jax.tree.leaves(state_full.params),
                    jax.tree.leaves(state_resumed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_compressed_psum_error_feedback():
    """Over repeated steps on a constant gradient, error feedback makes
    the compressed reduction converge to the true mean."""
    mesh = jax.make_mesh((1,), ("pod",))
    g_true = {"w": jax.random.normal(jax.random.PRNGKey(0), (2048,))}
    err = init_error_state(g_true, block=256, dtype=jnp.float32)

    import functools
    from jax.sharding import PartitionSpec as P

    from repro.utils import jax_shard_map

    @functools.partial(jax_shard_map, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_vma=False)
    def step(g, e):
        return compressed_psum_tree(g, e, axis="pod", k_per_block=32,
                                    block=256)

    total = jax.tree.map(jnp.zeros_like, g_true)
    err_now = err
    for _ in range(8):
        synced, err_now = step(g_true, err_now)
        total = jax.tree.map(jnp.add, total, synced)
    # mean of synced over steps ≈ g_true (error feedback catches up)
    approx = total["w"] / 8
    corr = float(jnp.corrcoef(approx, g_true["w"])[0, 1])
    assert corr > 0.95, corr


def test_elastic_plan_rescale():
    from repro.distributed.elastic import ElasticPlan
    p = ElasticPlan.rescale(microbatches=4, global_batch=256,
                            old_pods=2, new_pods=1)
    assert p.microbatches == 8 and p.global_batch == 256


def test_compressed_train_step_functional():
    """End-to-end compressed cross-pod step: loss descends, error state
    evolves, per-pod replica layout round-trips."""
    import jax.numpy as jnp
    from repro.launch import specs as lspecs
    from repro.training.step import (make_compressed_train_step,
                                     replicate_state_per_pod)

    cfg = configs.get_reduced("tinyllama-1.1b")
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    opt = AdamW(constant_schedule(1e-3))
    step = make_compressed_train_step(cfg, opt, mesh, microbatches=2,
                                      block=256, k_per_block=32)
    run = configs.RunOverrides()
    state0 = lspecs.init_train_state(cfg, None, run, opt,
                                     jax.random.PRNGKey(0))
    state = replicate_state_per_pod(state0, 1)
    err = replicate_state_per_pod(
        init_error_state(state0.params, block=256), 1)
    loader = DataLoader(cfg, ShapeCell("t", "train", 64, 4), 2, seed=0)
    losses = []
    for i in range(5):
        state, err, m = step(state, loader.make_batch(i), err)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert float(jnp.abs(jax.tree.leaves(err)[0]).max()) > 0  # EF active
