"""End-to-end behaviour of the full system: offload pipelines through the
PoCL-R runtime running real JAX compute, and training-loop integration."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ClientRuntime, DeviceSpec, LinkSpec, ServerSpec


def _rt(n=2):
    return ClientRuntime(
        servers=[ServerSpec(f"s{i}", [DeviceSpec("gpu0", flops=10e12)])
                 for i in range(n)],
        client_link=LinkSpec(latency=61e-6, bandwidth=1e9 / 8),
        peer_link=LinkSpec(latency=20e-6, bandwidth=100e9 / 8),
        transport="tcp")


def test_offloaded_matmul_pipeline():
    """Distribute a blocked matmul over two servers through the runtime;
    result must equal the local product (paper §6.4 setup, miniature)."""
    rng = np.random.default_rng(0)
    A = rng.standard_normal((64, 64)).astype(np.float32)
    B = rng.standard_normal((64, 64)).astype(np.float32)
    rt = _rt(2)
    out_bufs = []
    b_buf = rt.create_buffer(B.nbytes)
    evs = [rt.enqueue_write("s0", b_buf, B)]
    for i, srv in enumerate(["s0", "s1"]):
        a = rt.create_buffer(A.nbytes // 2)
        o = rt.create_buffer(A.nbytes // 2)
        ew = rt.enqueue_write(srv, a, A[i * 32:(i + 1) * 32])
        ek = rt.enqueue_kernel(srv, fn=lambda x, w: x @ w,
                               inputs=[a, b_buf], outputs=[o],
                               flops=2 * 32 * 64 * 64,
                               wait_for=[ew] + evs)
        rt.enqueue_read(srv, o, wait_for=[ek])
        out_bufs.append(o)
    rt.finish()
    got = np.concatenate([np.asarray(o.data) for o in out_bufs])
    np.testing.assert_allclose(got, A @ B, rtol=1e-5)


def test_offload_with_jax_kernels():
    """The runtime executes jitted JAX functions as remote kernels."""
    rt = _rt(1)
    f = jax.jit(lambda x: jnp.cumsum(x) * 2)
    b = rt.create_buffer(64)
    o = rt.create_buffer(64)
    e1 = rt.enqueue_write("s0", b, np.arange(16, dtype=np.float32))
    e2 = rt.enqueue_kernel("s0", fn=lambda x: np.asarray(f(x)),
                           inputs=[b], outputs=[o], wait_for=[e1])
    rt.enqueue_read("s0", o, wait_for=[e2])
    rt.finish()
    np.testing.assert_allclose(o.data, np.cumsum(np.arange(16)) * 2)


def test_fallback_pipeline_recovers():
    """AR-style pipeline keeps producing frames through a disconnect via
    local fallback, then shifts back to remote (paper Fig. 4)."""
    rt = _rt(1)
    frames_out = []
    src = rt.create_buffer(256)
    dst = rt.create_buffer(256)
    data = np.arange(64, dtype=np.float32)
    for frame in range(6):
        if frame == 2:
            rt.inject_disconnect("s0")
        if frame == 4:
            rt.reconnect("s0")
            rt.finish()
        if rt.sessions["s0"].available:
            e1 = rt.enqueue_write("s0", src, data + frame)
            e2 = rt.enqueue_kernel("s0", fn=lambda x: np.sort(x)[::-1],
                                   inputs=[src], outputs=[dst],
                                   duration=1e-4, wait_for=[e1])
            rt.enqueue_read("s0", dst, wait_for=[e2])
            rt.finish()
            frames_out.append(("remote", dst.data.copy()))
        else:
            src.set_data(data + frame, "client")
            rt.run_local_fallback(lambda x: np.sort(x)[::-1], [src], [dst],
                                  duration=1e-3)
            rt.finish()
            frames_out.append(("local", dst.data.copy()))
    kinds = [k for k, _ in frames_out]
    assert kinds == ["remote", "remote", "local", "local", "remote",
                     "remote"]
    for i, (_, arr) in enumerate(frames_out):
        np.testing.assert_array_equal(arr, np.sort(data + i)[::-1])


def test_training_smoke_quickstart():
    """The quickstart path: a tiny model trains and loss descends."""
    from repro.launch.train import build
    from repro.training.loop import LoopConfig, Trainer
    cfg, ctx, step_fn, state, loader = build(
        "tinyllama-1.1b", True, batch=8, seq=64, steps=20, seed=0)
    tr = Trainer(step_fn, state, loader,
                 LoopConfig(total_steps=20, ckpt_every=0, ckpt_dir=None,
                            log_every=5))
    out = tr.run()
    loader.stop()
    losses = [r["loss"] for r in out["log"]]
    assert losses[-1] < losses[0]
