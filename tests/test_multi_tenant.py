"""Multi-tenant server runtime (DESIGN.md §4): shared clusters, the
per-device fairness scheduler, the shared-NIC egress model, the
session-state split, and §4.3 reconnect under multi-tenancy."""
import numpy as np
import pytest

from repro.core import (ClientRuntime, Cluster, DeviceSpec, LinkSpec,
                        ServerSpec)
from repro.core.scheduler import (DeviceScheduler, DRRPolicy, FIFOPolicy,
                                  make_policy)


def mk_cluster(n=2, scheduler="fifo", quantum=None, nic=None):
    return Cluster([ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                    for i in range(n)],
                   peer_link=LinkSpec(latency=20e-6, bandwidth=40e9 / 8),
                   peer_transport="tcp", scheduler=scheduler,
                   scheduler_quantum=quantum, nic_bandwidth=nic)


def attach(cluster, **kw):
    kw.setdefault("client_link", LinkSpec(latency=61e-6, bandwidth=1e9 / 8))
    return ClientRuntime(cluster=cluster, **kw)


def run_chain(rt, server, n, duration=1e-6, start=1.0):
    """Closed multiply-by-two chain on one buffer; returns (buf, events,
    expected final contents)."""
    buf = rt.create_buffer(64)
    prev = rt.enqueue_write(server, buf, np.full(16, start, np.float32))
    events = [prev]
    for _ in range(n):
        prev = rt.enqueue_kernel(server, fn=lambda x: x * 2.0,
                                 inputs=[buf], outputs=[buf],
                                 duration=duration, wait_for=[prev])
        events.append(prev)
    return buf, events, np.full(16, start, np.float32) * 2.0 ** n


# ---- shared-cluster attach + session-state split ----

def test_two_tenants_share_cluster_and_stay_functionally_isolated():
    cluster = mk_cluster(n=2)
    a = attach(cluster, name="a")
    b = attach(cluster, name="b")
    assert a.clock is b.clock is cluster.clock
    assert a.p_links is b.p_links                 # shared peer mesh
    assert a.c_links["s0"] is not b.c_links["s0"]  # own access links
    buf_a, ev_a, want_a = run_chain(a, "s0", 6, start=1.0)
    buf_b, ev_b, want_b = run_chain(b, "s0", 6, start=3.0)
    cluster.run()
    np.testing.assert_array_equal(buf_a.data, want_a)
    np.testing.assert_array_equal(buf_b.data, want_b)
    assert all(e.status == "complete" for e in ev_a + ev_b)
    # per-tenant event tables drained independently
    assert a.stats()["events_live"] == 0
    assert b.stats()["events_live"] == 0


def test_host_session_table_keyed_by_session_id():
    cluster = mk_cluster(n=2)
    a = attach(cluster, name="a")
    b = attach(cluster, name="b")
    cluster.run()
    for host in cluster.hosts.values():
        assert len(host.sessions) == 2
        assert host.sessions[a.sessions[host.name].session_id] \
            is a.servers[host.name]
        assert host.sessions[b.sessions[host.name].session_id] \
            is b.servers[host.name]
    ids = {a.sessions["s0"].session_id, b.sessions["s0"].session_id,
           a.sessions["s1"].session_id, b.sessions["s1"].session_id}
    assert len(ids) == 4                          # ids never collide
    assert cluster.stats()["sessions"] == {"s0": 2, "s1": 2}


def test_private_cluster_backcompat_and_arg_validation():
    rt = ClientRuntime(servers=[ServerSpec("s0", [DeviceSpec("gpu0")])])
    assert rt.cluster.clients == [rt]
    with pytest.raises(ValueError):
        ClientRuntime(servers=[ServerSpec("s0")], cluster=rt.cluster)
    with pytest.raises(ValueError):
        ClientRuntime()
    # cluster-level settings must not be silently dropped on attach
    with pytest.raises(ValueError, match="cluster-level"):
        ClientRuntime(cluster=rt.cluster, scheduler="drr")
    with pytest.raises(ValueError, match="cluster-level"):
        ClientRuntime(cluster=rt.cluster, nic_bandwidth=1e9)
    # a non-positive fair-share weight would zero DRR's quantum grants
    with pytest.raises(ValueError, match="weight"):
        ClientRuntime(cluster=rt.cluster, weight=0.0)


def test_multi_tenant_run_is_deterministic():
    def once():
        cluster = mk_cluster(n=2, scheduler="drr")
        tenants = [attach(cluster, name=f"t{i}") for i in range(4)]
        for i, t in enumerate(tenants):
            run_chain(t, f"s{i % 2}", 10, duration=3e-4)
        return cluster.run()
    assert once() == once()


# ---- scheduler policies (unit level) ----

def test_fifo_policy_is_arrival_order():
    p = FIFOPolicy()
    for i in range(4):
        p.push(f"t{i % 2}", 1.0, 1.0, f"job{i}")
    assert [p.pop() for _ in range(4)] == [f"job{i}" for i in range(4)]
    assert p.pop() is None


def test_drr_interleaves_equal_weights():
    p = DRRPolicy(quantum=1.0)
    for i in range(3):
        p.push("a", 1.0, 1.0, f"a{i}")
    for i in range(3):
        p.push("b", 1.0, 1.0, f"b{i}")
    order = [p.pop() for _ in range(6)]
    assert order == ["a0", "b0", "a1", "b1", "a2", "b2"]


def test_drr_weight_doubles_share():
    p = DRRPolicy(quantum=1.0)
    for i in range(8):
        p.push("heavy", 2.0, 1.0, ("heavy", i))
        p.push("light", 1.0, 1.0, ("light", i))
    first6 = [p.pop()[0] for _ in range(6)]
    assert first6.count("heavy") == 4             # 2:1 service ratio
    assert first6.count("light") == 2


def test_drr_skip_ahead_serves_expensive_head():
    """A command costing many quanta must dispatch in O(ring) pops, and
    a cheap tenant is not starved while the deficit accumulates."""
    p = DRRPolicy(quantum=1.0)
    p.push("big", 1.0, 10.0, "big0")
    p.push("small", 1.0, 1.0, "small0")
    first, second = p.pop(), p.pop()
    assert {first, second} == {"small0", "big0"}
    assert first == "small0"                      # cheap head goes first


def test_drr_idle_tenant_forfeits_deficit():
    p = DRRPolicy(quantum=1.0)
    p.push("a", 1.0, 1.0, "a0")
    assert p.pop() == "a0"                        # queue empties
    # rejoining later starts from zero credit, not banked quanta
    p.push("b", 1.0, 1.0, "b0")
    p.push("a", 1.0, 3.0, "a1")
    assert p.pop() == "b0"
    assert p.pop() == "a1"


def test_device_scheduler_work_conserving():
    ran = []

    def job(tag):
        def run(release):
            ran.append(tag)
            release()
        return run

    s = DeviceScheduler(make_policy("fifo"))
    s.submit("t", 1.0, 1.0, job("x"))
    s.submit("t", 1.0, 1.0, job("y"))
    assert ran == ["x", "y"]
    assert s.dispatched == 2 and s.queue_peak >= 1


# ---- fairness under contention (runtime level) ----

def _straggler_scenario(scheduler):
    cluster = mk_cluster(n=1, scheduler=scheduler, quantum=2e-3)
    straggler = attach(cluster, name="straggler")
    light = attach(cluster, name="light")
    cluster.run()
    for _ in range(30):                     # 30 × 10 ms backlog, no deps
        straggler.enqueue_kernel("s0", fn=None, duration=10e-3)
    # let the whole backlog reach the server's run queue first
    cluster.run(until=cluster.clock.now + 5e-3)
    ev = light.enqueue_kernel("s0", fn=None, duration=1e-3)
    cluster.run()
    assert ev.status == "complete"
    return ev.latency


def test_drr_bounds_light_tenant_latency_under_straggler():
    t_fifo = _straggler_scenario("fifo")
    t_drr = _straggler_scenario("drr")
    # FIFO: the light command queues behind the whole 300 ms backlog;
    # DRR: it waits at most ~one straggler kernel plus its own turn
    assert t_fifo > 0.25, t_fifo
    assert t_drr < 0.05, t_drr
    assert t_drr < t_fifo / 5.0


def test_weighted_tenant_gets_proportional_device_share():
    cluster = mk_cluster(n=1, scheduler="drr", quantum=1e-3)
    heavy = attach(cluster, name="heavy", weight=2.0)
    light = attach(cluster, name="light", weight=1.0)
    cluster.run()
    evs = {}
    for rt in (heavy, light):               # same saturating open loop
        evs[rt.name] = [rt.enqueue_kernel("s0", fn=None, duration=2e-3)
                        for _ in range(60)]
    cluster.run(until=cluster.clock.now + 0.12)
    done = {name: sum(e.status == "complete" for e in lst)
            for name, lst in evs.items()}
    ratio = done["heavy"] / done["light"]
    assert 1.6 < ratio < 2.5, done
    cluster.run()                           # drain the rest


# ---- shared-NIC egress model ----

def _two_push_elapsed(nic):
    cluster = mk_cluster(n=3, nic=nic)
    rt = attach(cluster)
    bufs = []
    for _ in range(2):
        b = rt.create_buffer(8 << 20)
        rt.enqueue_write("s0", b, np.zeros(2 << 20, np.uint32))
        bufs.append(b)
    cluster.run()
    t0 = cluster.clock.now
    rt.enqueue_migration(bufs[0], "s1")     # two concurrent pushes out
    rt.enqueue_migration(bufs[1], "s2")     # of s0 on disjoint links
    cluster.run()
    return cluster.clock.now - t0


def test_nic_serializes_concurrent_egress():
    free = _two_push_elapsed(None)
    shared = _two_push_elapsed(40e9 / 8)    # NIC at link rate
    fat = _two_push_elapsed(400e9 / 8)      # port 10× faster than links
    # at link rate the two transfers share one egress budget: ~2× the
    # independent-link time; a fat port barely staggers them
    assert shared > 1.6 * free, (shared, free)
    assert fat < 1.2 * free, (fat, free)


def test_nic_bytes_accounted():
    cluster = mk_cluster(n=2, nic=40e9 / 8)
    rt = attach(cluster)
    b = rt.create_buffer(4 << 20)
    rt.enqueue_write("s0", b, np.zeros(1 << 20, np.uint32))
    cluster.run()
    rt.enqueue_migration(b, "s1")
    cluster.run()
    st = cluster.stats()
    assert st["nic_bytes"]["s0"] > 4 << 20        # payload left s0's port
    assert st["nic_bytes"]["s1"] > 0              # completions egress too


def test_source_selection_accounts_nic_queue():
    """Replicas on s0 and s1 over equally idle links: s0's port is mid-
    push elsewhere, so the pull must come from s1."""
    cluster = mk_cluster(n=4, nic=40e9 / 8)
    rt = attach(cluster)
    buf = rt.create_buffer(4 << 20)
    buf.data = np.zeros(1 << 20, np.uint32)
    buf.valid_on = {"s0", "s1"}
    cluster.run()
    cluster.hosts["s0"].nic._busy_until = cluster.clock.now + 1.0
    assert rt._pick_migration_source(buf, ["s0", "s1"], "s3") == "s1"
    cluster.hosts["s1"].nic._busy_until = cluster.clock.now + 2.0
    assert rt._pick_migration_source(buf, ["s0", "s1"], "s3") == "s0"


# ---- §4.3 reconnect under multi-tenancy ----

def _bystander_frames(cluster, rt, n=6):
    """Closed-loop kernel chain for the bystander tenant on s1 (its own
    device and links; only the clock and peer mesh are shared)."""
    buf, events, want = run_chain(rt, "s1", n, duration=2e-3)
    return events, (buf, want)


def test_reconnect_replays_dedup_while_other_tenants_run():
    def scenario(drop: bool):
        cluster = mk_cluster(n=2)
        a = attach(cluster, name="a")
        b = attach(cluster, name="b")
        cluster.run()
        calls = {"n": 0}

        def bump(x):
            calls["n"] += 1
            return x + 1.0

        buf = a.create_buffer(64)
        prev = a.enqueue_write("s0", buf, np.zeros(16, np.float32))
        evs = []
        for _ in range(5):                   # 5 × 5 ms chained on s0
            prev = a.enqueue_kernel("s0", fn=bump, inputs=[buf],
                                    outputs=[buf], duration=5e-3,
                                    wait_for=[prev])
            evs.append(prev)
        b_events, (b_buf, b_want) = _bystander_frames(cluster, b)
        sid = a.sessions["s0"].session_id
        if drop:
            # drop after delivery, reconnect "from a new IP" while the
            # kernels are still executing: every replayed command must
            # dedup against the session's processed table
            a.inject_disconnect("s0", at=cluster.clock.now + 1e-3)
            a.reconnect("s0", at=cluster.clock.now + 3e-3)
        cluster.run()
        if drop:
            assert a.sessions["s0"].session_id == sid     # id survives
            assert cluster.hosts["s0"].sessions[sid] is a.servers["s0"]
        assert all(e.status == "complete" for e in evs)
        assert calls["n"] == 5                # replay deduped, not rerun
        np.testing.assert_array_equal(buf.data, np.full(16, 5.0))
        np.testing.assert_array_equal(b_buf.data, b_want)
        return [(e.t_start, e.t_end) for e in b_events]

    # the bystander's frame timestamps are bit-identical with and
    # without tenant a's drop/replay cycle
    assert scenario(drop=True) == scenario(drop=False)


def test_replay_overflow_counted_with_configured_window():
    cluster = mk_cluster(n=1)
    rt = attach(cluster, replay_window=8)
    cluster.run()
    prev = ()
    for _ in range(30):                      # far beyond the 8 slots
        prev = (rt.enqueue_kernel("s0", fn=None, duration=1e-3,
                                  wait_for=prev),)
    st = rt.stats()
    assert st["replay_window"]["s0"] == 8
    assert st["replay_overflows"]["s0"] > 0   # counted, not silent
    assert rt.sessions["s0"].lost_unacked == st["replay_overflows"]["s0"]
    cluster.run()


def test_default_replay_window_unchanged():
    rt = ClientRuntime(servers=[ServerSpec("s0", [DeviceSpec("gpu0")])])
    assert rt.stats()["replay_window"]["s0"] == 64
