"""§Perf hillclimb driver: run named (arch, shape, knobs) experiments,
collect roofline terms + attention-interior estimate, dump JSON.

  PYTHONPATH=src python scripts/hillclimb.py --only cellA --out hc.json
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_DTYPE_BARRIER"] = "1"

import argparse   # noqa: E402
import json       # noqa: E402
import sys        # noqa: E402
import time       # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import dryrun           # noqa: E402
from repro.roofline import analyze_hlo, from_totals, HBM_BW  # noqa: E402
from repro.roofline.attention_est import attention_interior_bytes  # noqa: E402

EXPERIMENTS = {
    # Cell A: worst roofline fraction — tinyllama train_4k
    "cellA": [
        ("tinyllama-1.1b", "train_4k", dict()),                       # base
        ("tinyllama-1.1b", "train_4k", dict(strategy="fsdp")),        # it1
    ],
    # Cell B: most collective-bound — grok-1 train_4k (MoE FSDP gathers)
    "cellB": [
        ("grok-1-314b", "train_4k", dict()),                          # base mb8
        ("grok-1-314b", "train_4k", dict(microbatches=4)),            # it1
        ("grok-1-314b", "train_4k", dict(microbatches=2)),            # it2
        ("grok-1-314b", "train_4k", dict(microbatches=2,
                                         strategy="fsdp")),           # it3
    ],
    # Cell C: paper-representative giant — nemotron train_4k
    "cellC": [
        ("nemotron-4-340b", "train_4k", dict()),                      # base mb16
        ("nemotron-4-340b", "train_4k", dict(microbatches=8)),        # it1
        ("nemotron-4-340b", "train_4k", dict(microbatches=4)),        # it2
    ],
}


def run_exp(arch, shape, knobs, multi_pod=False):
    t0 = time.time()
    compiled, lowered, meta = dryrun.lower_cell(arch, shape,
                                                multi_pod=multi_pod, **knobs)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    tot = analyze_hlo(hlo)
    rf = from_totals(arch, shape, meta["mesh"], meta["chips"], tot,
                     meta["model_flops_global"],
                     arg_bytes=mem.argument_size_in_bytes,
                     temp_bytes=mem.temp_size_in_bytes)
    attn_b = attention_interior_bytes(hlo)
    row = rf.row()
    row.update({
        "knobs": {k: str(v) for k, v in knobs.items()},
        "strategy": meta["strategy"], "microbatches": meta["microbatches"],
        "attn_interior_bytes": attn_b,
        "t_mem_pallas_est": max(rf.hbm_bytes - attn_b, 0) / HBM_BW,
        "coll_by_type": {k: float(v) for k, v in tot.coll_by_type.items()},
        "mem_dev_gib": (mem.argument_size_in_bytes
                        + mem.temp_size_in_bytes) / 2**30,
        "wall_s": time.time() - t0,
    })
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="hillclimb.json")
    args = ap.parse_args()
    results = {}
    for cell, exps in EXPERIMENTS.items():
        if args.only and args.only != cell:
            continue
        results[cell] = []
        for arch, shape, knobs in exps:
            try:
                row = run_exp(arch, shape, knobs)
                results[cell].append(row)
                print(f"{cell} {arch} {shape} {knobs}: "
                      f"t_comp={row['t_compute_s']:.3f} "
                      f"t_mem={row['t_memory_s']:.3f} "
                      f"(pallas_est={row['t_mem_pallas_est']:.3f}) "
                      f"t_coll={row['t_collective_s']:.3f} "
                      f"roofline={row['roofline_frac']:.3f} "
                      f"mem={row['mem_dev_gib']:.1f}GiB", flush=True)
            except Exception as e:
                print(f"{cell} {arch} {shape} {knobs}: FAIL {e!r}",
                      flush=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
