"""Render the ci.sh run as a markdown summary: per-step wall-clock
timings plus every regression gate's remaining margin.

scripts/ci.sh invokes this from its EXIT trap with the step-times TSV
it accumulated (``title<TAB>seconds<TAB>exit-code`` per step) and the
``$CI_GATE_MARGINS`` JSONL that ``benchmarks.common.check_rows``
appended one record per gate comparison to. Output is appended to
``$GITHUB_STEP_SUMMARY`` when set (the Actions job-summary panel) and
always printed to stdout, so local runs get the same table. Stdlib
only; never fails the build (ci.sh invokes it with ``|| true``).

Trace-diff triage (DESIGN.md §11): when a traced gate step failed AND
``$CI_BASELINE_TRACES`` names a directory holding the baseline run's
Perfetto exports (ci.yml restores an actions/cache keyed on the PR
base), the summary appends ``scripts/trace_diff.py`` output for that
step's trace — the top resources where time moved — so a sim-time
regression lands with its forensics attached instead of a bare number.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _read_steps(path: str) -> list:
    steps = []
    try:
        with open(path) as f:
            for line in f:
                parts = line.rstrip("\n").split("\t")
                if len(parts) != 3:
                    continue
                title, secs, rc = parts
                steps.append((title, float(secs), int(rc)))
    except (OSError, ValueError):
        pass
    return steps


def _read_margins(path: str) -> list:
    margins = []
    try:
        with open(path) as f:
            for line in f:
                try:
                    margins.append(json.loads(line))
                except ValueError:
                    continue
    except OSError:
        pass
    return margins


# failing-step keyword -> trace basename (extension probed: the fleet
# and cfd exports are gzipped, chaos is plain JSON)
_TRACE_FOR_STEP = (
    ("chaos", "chaos_trace"),
    ("fleet", "fleet_trace"),
    ("cfd", "cfd_trace"),
)


def _find_trace(dirpath: str, stem: str):
    for ext in (".json.gz", ".json"):
        p = os.path.join(dirpath, stem + ext)
        if os.path.exists(p):
            return p
    return None


def triage(steps: list, artifacts_dir: str, baseline_dir) -> str:
    """Markdown trace-diff section for failed traced steps; empty when
    nothing failed, no baseline traces are cached, or diffing breaks
    (forensics must never fail the summary)."""
    failed = [title for title, _secs, rc in steps if rc != 0]
    if not failed or not baseline_dir or not os.path.isdir(baseline_dir):
        return ""
    try:
        import trace_diff           # sibling module, scripts/ on path
    except ImportError:
        return ""
    out: list = []
    seen: set = set()
    for title in failed:
        low = title.lower()
        for kw, stem in _TRACE_FOR_STEP:
            if kw not in low or stem in seen:
                continue
            seen.add(stem)
            base = _find_trace(baseline_dir, stem)
            cand = _find_trace(artifacts_dir, stem)
            if base is None or cand is None:
                continue
            try:
                d = trace_diff.diff(
                    trace_diff.aggregate(trace_diff.load_events(base)),
                    trace_diff.aggregate(trace_diff.load_events(cand)),
                    top=5)
                body = trace_diff.render(d, markdown=True)
            except Exception as e:  # noqa: BLE001 — never fail the summary
                body = f"(trace_diff failed for {stem}: {e})"
            out += [f"#### {stem}: where the time moved vs the "
                    f"baseline trace", "", body, ""]
    if not out:
        return ""
    return "\n".join(["### Trace-diff triage (failed gate steps)", ""]
                     + out) + "\n"


def render(steps: list, margins: list) -> str:
    out = ["## ci.sh summary", ""]
    if steps:
        total = sum(s[1] for s in steps)
        out += ["### Step timings", "",
                "| step | wall | result |", "| --- | ---: | --- |"]
        for title, secs, rc in steps:
            mark = "✅ ok" if rc == 0 else f"❌ exit {rc}"
            out.append(f"| {title} | {secs:.0f}s | {mark} |")
        out += [f"| **total** | **{total:.0f}s** | |", ""]
    if margins:
        out += ["### Gate margins (headroom left before the bound)", "",
                "| benchmark | row | value | bound | margin | status |",
                "| --- | --- | ---: | ---: | ---: | --- |"]
        for m in sorted(margins, key=lambda m: m.get("margin", 0.0)):
            unit = m.get("unit", "")
            mark = "✅" if m.get("status") == "ok" else "⚠️"
            out.append(
                f"| {m.get('benchmark', '?')} | {m.get('row', '?')} "
                f"| {m.get('got', 0):.3f}{unit} "
                f"| {m.get('bound', 0):.3f}{unit} "
                f"| {m.get('margin', 0) * 100:+.1f}% "
                f"| {mark} {m.get('status', '?')} |")
        out.append("")
    if not steps and not margins:
        out += ["(no step timings or gate margins recorded)", ""]
    return "\n".join(out) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", required=True,
                    help="TSV accumulated by ci.sh run_step")
    ap.add_argument("--margins", required=True,
                    help="JSONL appended by benchmarks.common.check_rows")
    args = ap.parse_args()
    steps = _read_steps(args.steps)
    md = render(steps, _read_margins(args.margins))
    md += triage(steps, os.path.dirname(os.path.abspath(args.steps)),
                 os.environ.get("CI_BASELINE_TRACES"))
    sys.stdout.write(md)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        try:
            with open(summary_path, "a") as f:
                f.write(md)
        except OSError as e:
            print(f"# ci_summary: cannot append to "
                  f"GITHUB_STEP_SUMMARY: {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
