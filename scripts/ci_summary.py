"""Render the ci.sh run as a markdown summary: per-step wall-clock
timings plus every regression gate's remaining margin.

scripts/ci.sh invokes this from its EXIT trap with the step-times TSV
it accumulated (``title<TAB>seconds<TAB>exit-code`` per step) and the
``$CI_GATE_MARGINS`` JSONL that ``benchmarks.common.check_rows``
appended one record per gate comparison to. Output is appended to
``$GITHUB_STEP_SUMMARY`` when set (the Actions job-summary panel) and
always printed to stdout, so local runs get the same table. Stdlib
only; never fails the build (ci.sh invokes it with ``|| true``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _read_steps(path: str) -> list:
    steps = []
    try:
        with open(path) as f:
            for line in f:
                parts = line.rstrip("\n").split("\t")
                if len(parts) != 3:
                    continue
                title, secs, rc = parts
                steps.append((title, float(secs), int(rc)))
    except (OSError, ValueError):
        pass
    return steps


def _read_margins(path: str) -> list:
    margins = []
    try:
        with open(path) as f:
            for line in f:
                try:
                    margins.append(json.loads(line))
                except ValueError:
                    continue
    except OSError:
        pass
    return margins


def render(steps: list, margins: list) -> str:
    out = ["## ci.sh summary", ""]
    if steps:
        total = sum(s[1] for s in steps)
        out += ["### Step timings", "",
                "| step | wall | result |", "| --- | ---: | --- |"]
        for title, secs, rc in steps:
            mark = "✅ ok" if rc == 0 else f"❌ exit {rc}"
            out.append(f"| {title} | {secs:.0f}s | {mark} |")
        out += [f"| **total** | **{total:.0f}s** | |", ""]
    if margins:
        out += ["### Gate margins (headroom left before the bound)", "",
                "| benchmark | row | value | bound | margin | status |",
                "| --- | --- | ---: | ---: | ---: | --- |"]
        for m in sorted(margins, key=lambda m: m.get("margin", 0.0)):
            unit = m.get("unit", "")
            mark = "✅" if m.get("status") == "ok" else "⚠️"
            out.append(
                f"| {m.get('benchmark', '?')} | {m.get('row', '?')} "
                f"| {m.get('got', 0):.3f}{unit} "
                f"| {m.get('bound', 0):.3f}{unit} "
                f"| {m.get('margin', 0) * 100:+.1f}% "
                f"| {mark} {m.get('status', '?')} |")
        out.append("")
    if not steps and not margins:
        out += ["(no step timings or gate margins recorded)", ""]
    return "\n".join(out) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", required=True,
                    help="TSV accumulated by ci.sh run_step")
    ap.add_argument("--margins", required=True,
                    help="JSONL appended by benchmarks.common.check_rows")
    args = ap.parse_args()
    md = render(_read_steps(args.steps), _read_margins(args.margins))
    sys.stdout.write(md)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        try:
            with open(summary_path, "a") as f:
                f.write(md)
        except OSError as e:
            print(f"# ci_summary: cannot append to "
                  f"GITHUB_STEP_SUMMARY: {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
