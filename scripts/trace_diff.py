#!/usr/bin/env python3
"""Trace-diff regression forensics: align two Perfetto traces exported
by ``Tracer.write_perfetto`` and rank where time moved.

Given a baseline trace and a candidate trace (plain ``.json`` or
``.json.gz``), aggregate per-resource busy time — device execution
slices, NIC occupancy, link wire/transfer spans, per-stage command
lifecycle totals — and report the top movers plus the makespan delta.
CI runs this automatically when a sim-time gate fails (the EXIT-trap
summary in ``scripts/ci.sh`` feeds it the cached baseline trace), so a
regression lands with "s1.nic busy +38%, queue_wait +22ms on s0/gpu0"
attached instead of a bare number.

Usage:
    python scripts/trace_diff.py BASELINE CANDIDATE [--top N] [--markdown]

Exit code 0 always (forensics, not a gate).
"""
from __future__ import annotations

import argparse
import gzip
import json
import sys


# mirrors repro.core.trace.STAGES — kept literal so this script stays
# stdlib-only and runnable against a trace from any checkout
_STAGES = frozenset(("submit_wire", "dep_wait", "queue_wait",
                     "execute", "completion"))


def load_events(path: str) -> list:
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        data = json.load(f)
    if isinstance(data, dict):
        data = data.get("traceEvents", [])
    if not isinstance(data, list):
        raise ValueError(f"{path}: not a trace_event list")
    return data


def aggregate(events: list) -> dict:
    """Per-resource busy totals (seconds) plus the trace makespan.

    Resources:
      * ``<server>/<device>``   — exec X slice time
      * ``<server>.nic[_in]``   — NIC occupancy X time
      * ``net:<link>``          — transfer span time (queue-inclusive)
      * ``net:<link>.wire``     — wire occupancy X time
      * ``stage:<stage>``       — summed b/e lifecycle stage time
    """
    proc: dict = {}
    thread: dict = {}
    for ev in events:
        if ev.get("ph") == "M":
            if ev.get("name") == "process_name":
                proc[ev["pid"]] = ev["args"]["name"]
            elif ev.get("name") == "thread_name":
                thread[(ev["pid"], ev["tid"])] = ev["args"]["name"]

    busy: dict = {}
    open_stage: dict = {}
    t_min = None
    t_max = None

    def add(key: str, us: float) -> None:
        busy[key] = busy.get(key, 0.0) + us / 1e6

    for ev in events:
        ph = ev.get("ph")
        ts = ev.get("ts")
        if ts is not None:
            if t_min is None or ts < t_min:
                t_min = ts
            end = ts + ev.get("dur", 0.0)
            if t_max is None or end > t_max:
                t_max = end
        if ph == "X":
            cat = ev.get("cat")
            tname = thread.get((ev.get("pid"), ev.get("tid")), "?")
            dur = ev.get("dur", 0.0)
            if cat == "exec":
                pname = proc.get(ev.get("pid"), "?")
                server = pname.split(":", 1)[-1]
                dev = tname.split(":", 1)[-1]
                add(f"{server}/{dev}", dur)
            elif cat == "nic":
                add(tname, dur)
            elif cat == "net":
                add(f"net:{tname}", dur)
        elif ph == "b" and ev.get("cat") == "cmd":
            open_stage[(ev.get("id"), ev.get("name"))] = ts
        elif ph == "e" and ev.get("cat") == "cmd":
            name = ev.get("name")
            t0 = open_stage.pop((ev.get("id"), name), None)
            # lifecycle-stage children only: the parent span carries
            # the command's NAME, which embeds an event id that shifts
            # between runs — aggregating those would fabricate
            # new/-100% movers out of pure re-numbering
            if t0 is not None and name in _STAGES:
                add(f"stage:{name}", ts - t0)
    makespan = ((t_max - t_min) / 1e6
                if t_max is not None and t_min is not None else 0.0)
    return {"busy": busy, "makespan_s": makespan, "events": len(events)}


def diff(base: dict, cand: dict, top: int = 5) -> dict:
    """Rank resources by absolute busy-time shift, descending."""
    keys = set(base["busy"]) | set(cand["busy"])
    rows = []
    for k in keys:
        b = base["busy"].get(k, 0.0)
        c = cand["busy"].get(k, 0.0)
        d = c - b
        if b == 0.0 and c == 0.0:
            continue
        pct = (d / b * 100.0) if b > 0.0 else float("inf")
        rows.append({"resource": k, "base_s": b, "cand_s": c,
                     "delta_s": d, "delta_pct": pct})
    rows.sort(key=lambda r: (-abs(r["delta_s"]), r["resource"]))
    return {"movers": rows[:top], "total_resources": len(rows),
            "makespan_base_s": base["makespan_s"],
            "makespan_cand_s": cand["makespan_s"],
            "makespan_delta_s": cand["makespan_s"] - base["makespan_s"]}


def _fmt_pct(p: float) -> str:
    return "new" if p == float("inf") else f"{p:+.1f}%"


def render(d: dict, markdown: bool = False) -> str:
    mb, mc = d["makespan_base_s"], d["makespan_cand_s"]
    dm = d["makespan_delta_s"]
    dpct = (dm / mb * 100.0) if mb > 0.0 else 0.0
    lines = []
    if markdown:
        lines.append("#### Trace diff (where the time moved)")
        lines.append(f"makespan: {mb * 1e3:.3f} ms → {mc * 1e3:.3f} ms "
                     f"({dm * 1e3:+.3f} ms, {dpct:+.1f}%)")
        lines.append("")
        lines.append("| resource | baseline ms | candidate ms | Δ ms | Δ% |")
        lines.append("|---|---:|---:|---:|---:|")
        for r in d["movers"]:
            lines.append(f"| `{r['resource']}` | {r['base_s'] * 1e3:.3f} "
                         f"| {r['cand_s'] * 1e3:.3f} "
                         f"| {r['delta_s'] * 1e3:+.3f} "
                         f"| {_fmt_pct(r['delta_pct'])} |")
    else:
        lines.append(f"makespan: {mb * 1e3:.3f} ms -> {mc * 1e3:.3f} ms "
                     f"({dm * 1e3:+.3f} ms, {dpct:+.1f}%)")
        lines.append(f"{'resource':<32}{'base ms':>12}{'cand ms':>12}"
                     f"{'delta ms':>12}{'delta%':>9}")
        for r in d["movers"]:
            lines.append(f"{r['resource']:<32}{r['base_s'] * 1e3:>12.3f}"
                         f"{r['cand_s'] * 1e3:>12.3f}"
                         f"{r['delta_s'] * 1e3:>+12.3f}"
                         f"{_fmt_pct(r['delta_pct']):>9}")
    if d["total_resources"] > len(d["movers"]):
        lines.append(f"... {d['total_resources'] - len(d['movers'])} "
                     f"more resources unchanged or below the cut")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("baseline", help="baseline trace (.json or .json.gz)")
    ap.add_argument("candidate", help="candidate trace (.json or .json.gz)")
    ap.add_argument("--top", type=int, default=5,
                    help="movers to show (default 5)")
    ap.add_argument("--markdown", action="store_true",
                    help="emit a GitHub-flavoured markdown table")
    args = ap.parse_args(argv)
    try:
        base = aggregate(load_events(args.baseline))
        cand = aggregate(load_events(args.candidate))
    except (OSError, ValueError, json.JSONDecodeError) as exc:
        print(f"trace_diff: {exc}", file=sys.stderr)
        return 0                       # forensics must never mask the gate
    print(render(diff(base, cand, top=args.top), markdown=args.markdown))
    return 0


if __name__ == "__main__":
    sys.exit(main())
