#!/usr/bin/env bash
# Tier-1 tests + dispatch hot-path smoke with throughput regression gate.
#
#   scripts/ci.sh
#
# Fails if any test fails, either benchmark errors, or dispatch
# throughput regresses >20% below benchmarks/BENCH_dispatch.json
# (regenerate the baseline on the CI host with:
#   python -m benchmarks.dispatch_throughput --smoke \
#       --write-baseline benchmarks/BENCH_dispatch.json).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== fig8 command-overhead smoke =="
python -m benchmarks.cmd_overhead

echo "== dispatch throughput smoke (20% regression gate) =="
python -m benchmarks.dispatch_throughput --smoke --trials 3 \
    --baseline benchmarks/BENCH_dispatch.json

echo "ci.sh: all checks passed"
