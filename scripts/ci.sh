#!/usr/bin/env bash
# Tier-1 tests + hot-path smokes with regression gates.
#
#   scripts/ci.sh [--simtime-only]
#
# Fails if any baseline file fails the shared schema check (or, with
# CI_BASE_REF set, the stamp-drift guard: row values changed vs that
# git ref without regenerating), any test fails, any benchmark errors,
# dispatch throughput regresses >20% below benchmarks/BENCH_dispatch.json,
# or any simulated-time gate regresses >20% against its baseline
# (migration data plane, multi-tenant scaling/fairness, shared-weights
# dedup — the dedup gate also enforces the >=40% payload-reduction
# floor — the SLO burst gate: tight-class violations under
# EDF/LLF+admission <=20% of the DRR control row, every admitted class
# inside its effective SLO, admission actually rejecting under the
# burst, llf actually preempting, and an exactly-once completion ledger
# under preemption churn — and the CFD halo-exchange placement gate,
# which also enforces the >=0.75 8-server scaling-efficiency floor and
# hetmec beating locality-off placement by >=20%, and the chaos
# membership gate: exactly-once command ledger under drain/crash,
# drain-storm recovery <=1.5x steady, post-crash p95 <=3x the steady
# p95, and the 1000-UE fleet-sweep sim-time gate, whose wall-clock
# ceiling is skipped under CI_SKIP_WALLCLOCK=1).
# Regenerate baselines with the "regenerate" command stamped inside
# each BENCH_*.json.
#
# Observability (DESIGN.md §9) rides the existing gates: the chaos and
# fleet smokes run TRACED, so their sim-time baselines double as proof
# that tracing never perturbs simulated time; the Perfetto exports are
# schema-validated (the chaos one must carry fault markers, the fleet
# one exercises the gzip path) and land in benchmarks/ci-results for
# the workflow artifact upload; the latency-breakdown step gates the
# exact per-stage decomposition; and the non-smoke dispatch gate
# includes the <=2% tracing-off overhead floor.
#
# The causal critical-path analyzer (DESIGN.md §11) gates twice: the
# latency-breakdown step checks the path-tiling identity and the
# what-if projections against ground-truth re-runs plus the
# BENCH_critpath.json makespans, and the CFD step adds a traced run
# whose halo-wait share and hidden-halo projection gate against the
# same baseline (the cfd trace export doubles as the candidate for
# trace-diff triage). When a gate step fails AND $CI_BASELINE_TRACES
# points at a directory of cached baseline traces (ci.yml restores one
# keyed on the PR base), the EXIT-trap summary runs
# scripts/trace_diff.py for the failing step's trace and appends the
# top shifted resources to the job summary.
#
# Every step is timed, and every check_rows gate comparison records its
# remaining margin; on exit (pass or fail) scripts/ci_summary.py
# renders both as markdown — to stdout, and into the Actions
# job-summary panel when $GITHUB_STEP_SUMMARY is set.
#
# The dispatch gate measures WALL-CLOCK commands/sec and is therefore
# host-specific; on shared/virtualized runners it flakes through no
# fault of the code. CI_SKIP_WALLCLOCK=1 (or --simtime-only) keeps the
# dispatch smoke but drops its baseline comparison, while every
# simulated-time gate — deterministic and portable — still gates.
# .github/workflows/ci.yml runs this script in that mode.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

SIMTIME_ONLY=${CI_SKIP_WALLCLOCK:-0}
if [[ "${1:-}" == "--simtime-only" ]]; then
    SIMTIME_ONLY=1
fi

ARTIFACTS=benchmarks/ci-results
mkdir -p "$ARTIFACTS"

STEP_TIMES="$ARTIFACTS/step_times.tsv"
export CI_GATE_MARGINS="$ARTIFACTS/gate_margins.jsonl"
: > "$STEP_TIMES"
: > "$CI_GATE_MARGINS"

summarize() {
    python scripts/ci_summary.py --steps "$STEP_TIMES" \
        --margins "$CI_GATE_MARGINS" || true
}
trap summarize EXIT

run_step() {
    local title="$1"; shift
    echo "== $title =="
    local t0=$SECONDS rc=0
    "$@" || rc=$?
    printf '%s\t%d\t%d\n' "$title" "$((SECONDS - t0))" "$rc" \
        >> "$STEP_TIMES"
    return $rc
}

run_step "baseline schema + drift check" \
    python -m benchmarks.run --check-baselines

run_step "tier-1 tests" python -m pytest -x -q

run_step "fig8 command-overhead smoke" python -m benchmarks.cmd_overhead

if [[ "$SIMTIME_ONLY" == "1" ]]; then
    run_step "dispatch throughput smoke (wall-clock gate SKIPPED)" \
        python -m benchmarks.dispatch_throughput --smoke \
            --json-out "$ARTIFACTS/dispatch.json"
else
    run_step "dispatch throughput smoke (20% regression gate)" \
        python -m benchmarks.dispatch_throughput --smoke --trials 3 \
            --baseline benchmarks/BENCH_dispatch.json \
            --json-out "$ARTIFACTS/dispatch.json"
fi

run_step "migration data-plane smoke (20% regression gate)" \
    python -m benchmarks.migration_pipeline \
        --baseline benchmarks/BENCH_migration.json \
        --json-out "$ARTIFACTS/migration.json"

run_step "multi-tenant + dedup smoke (20% gates + acceptance floors)" \
    python -m benchmarks.multi_tenant \
        --baseline benchmarks/BENCH_multitenant.json \
        --dedup-baseline benchmarks/BENCH_dedup.json \
        --json-out "$ARTIFACTS/multi_tenant.json"

run_step "SLO burst smoke (20% gates + admission/preemption floors)" \
    python -m benchmarks.slo_burst \
        --baseline benchmarks/BENCH_slo.json \
        --json-out "$ARTIFACTS/slo_burst.json"

run_step "CFD halo-exchange placement smoke (20% gates + floors + critpath)" \
    python -m benchmarks.cfd_halo \
        --baseline benchmarks/BENCH_cfd.json \
        --critpath-baseline benchmarks/BENCH_critpath.json \
        --trace "$ARTIFACTS/cfd_trace.json.gz" \
        --json-out "$ARTIFACTS/cfd_halo.json"

run_step "chaos membership smoke (20% gates + exactly-once ledger; traced)" \
    python -m benchmarks.chaos \
        --baseline benchmarks/BENCH_chaos.json \
        --trace "$ARTIFACTS/chaos_trace.json" \
        --json-out "$ARTIFACTS/chaos.json"

if [[ "$SIMTIME_ONLY" == "1" ]]; then
    run_step "1000-UE fleet sweep (sim-time gate; wall ceiling SKIPPED; traced)" \
        python -m benchmarks.fleet_sweep \
            --baseline benchmarks/BENCH_fleet.json \
            --trace "$ARTIFACTS/fleet_trace.json.gz" \
            --json-out "$ARTIFACTS/fleet.json"
else
    run_step "1000-UE fleet sweep (sim-time gate + 30s wall ceiling; traced)" \
        python -m benchmarks.fleet_sweep \
            --baseline benchmarks/BENCH_fleet.json --max-wall-s 30 \
            --trace "$ARTIFACTS/fleet_trace.json.gz" \
            --json-out "$ARTIFACTS/fleet.json"
fi

run_step "latency breakdown (exact decomposition + critical-path gates)" \
    python -m benchmarks.latency_breakdown --check \
        --baseline benchmarks/BENCH_critpath.json \
        --json-out "$ARTIFACTS/latency_breakdown.json"

echo "ci.sh: all checks passed"
