#!/usr/bin/env bash
# Tier-1 tests + hot-path smokes with regression gates.
#
#   scripts/ci.sh [--simtime-only]
#
# Fails if any baseline file fails the shared schema check, any test
# fails, any benchmark errors, dispatch throughput regresses >20% below
# benchmarks/BENCH_dispatch.json, or any simulated-time gate regresses
# >20% against its baseline (migration data plane, multi-tenant
# scaling/fairness, shared-weights dedup — the dedup gate also enforces
# the >=40% payload-reduction floor — and the CFD halo-exchange
# placement gate, which also enforces the >=0.75 8-server scaling-
# efficiency floor and hetmec beating locality-off placement by >=20%,
# and the chaos membership gate: exactly-once command ledger under
# drain/crash, drain-storm recovery <=1.5x steady, post-crash p95
# <=3x the steady p95, and the 1000-UE fleet-sweep sim-time gate,
# whose wall-clock ceiling is skipped under CI_SKIP_WALLCLOCK=1).
# Regenerate baselines with the "regenerate" command stamped inside
# each BENCH_*.json.
#
# Observability (DESIGN.md §9) rides the existing gates: the chaos and
# fleet smokes run TRACED, so their sim-time baselines double as proof
# that tracing never perturbs simulated time; both Perfetto exports are
# schema-validated (the chaos one must carry fault markers) and land in
# benchmarks/ci-results for the workflow artifact upload; the
# latency-breakdown step gates the exact per-stage decomposition; and
# the non-smoke dispatch gate includes the <=2% tracing-off overhead
# floor.
#
# The dispatch gate measures WALL-CLOCK commands/sec and is therefore
# host-specific; on shared/virtualized runners it flakes through no
# fault of the code. CI_SKIP_WALLCLOCK=1 (or --simtime-only) keeps the
# dispatch smoke but drops its baseline comparison, while every
# simulated-time gate — deterministic and portable — still gates.
# .github/workflows/ci.yml runs this script in that mode.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

SIMTIME_ONLY=${CI_SKIP_WALLCLOCK:-0}
if [[ "${1:-}" == "--simtime-only" ]]; then
    SIMTIME_ONLY=1
fi

ARTIFACTS=benchmarks/ci-results
mkdir -p "$ARTIFACTS"

echo "== baseline schema check =="
python -m benchmarks.run --check-baselines

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== fig8 command-overhead smoke =="
python -m benchmarks.cmd_overhead

if [[ "$SIMTIME_ONLY" == "1" ]]; then
    echo "== dispatch throughput smoke (wall-clock gate SKIPPED) =="
    python -m benchmarks.dispatch_throughput --smoke \
        --json-out "$ARTIFACTS/dispatch.json"
else
    echo "== dispatch throughput smoke (20% regression gate) =="
    python -m benchmarks.dispatch_throughput --smoke --trials 3 \
        --baseline benchmarks/BENCH_dispatch.json \
        --json-out "$ARTIFACTS/dispatch.json"
fi

echo "== migration data-plane smoke (20% regression gate) =="
python -m benchmarks.migration_pipeline \
    --baseline benchmarks/BENCH_migration.json \
    --json-out "$ARTIFACTS/migration.json"

echo "== multi-tenant + dedup smoke (20% gates + acceptance floors) =="
python -m benchmarks.multi_tenant \
    --baseline benchmarks/BENCH_multitenant.json \
    --dedup-baseline benchmarks/BENCH_dedup.json \
    --json-out "$ARTIFACTS/multi_tenant.json"

echo "== CFD halo-exchange placement smoke (20% gates + floors) =="
python -m benchmarks.cfd_halo \
    --baseline benchmarks/BENCH_cfd.json \
    --json-out "$ARTIFACTS/cfd_halo.json"

echo "== chaos membership smoke (20% gates + exactly-once ledger; traced) =="
python -m benchmarks.chaos \
    --baseline benchmarks/BENCH_chaos.json \
    --trace "$ARTIFACTS/chaos_trace.json" \
    --json-out "$ARTIFACTS/chaos.json"

if [[ "$SIMTIME_ONLY" == "1" ]]; then
    echo "== 1000-UE fleet sweep (sim-time gate; wall ceiling SKIPPED; traced) =="
    python -m benchmarks.fleet_sweep \
        --baseline benchmarks/BENCH_fleet.json \
        --trace "$ARTIFACTS/fleet_trace.json" \
        --json-out "$ARTIFACTS/fleet.json"
else
    echo "== 1000-UE fleet sweep (sim-time gate + 30s wall ceiling; traced) =="
    python -m benchmarks.fleet_sweep \
        --baseline benchmarks/BENCH_fleet.json --max-wall-s 30 \
        --trace "$ARTIFACTS/fleet_trace.json" \
        --json-out "$ARTIFACTS/fleet.json"
fi

echo "== latency breakdown (exact per-stage decomposition gate) =="
python -m benchmarks.latency_breakdown --check \
    --json-out "$ARTIFACTS/latency_breakdown.json"

echo "ci.sh: all checks passed"
