#!/usr/bin/env bash
# Tier-1 tests + hot-path smokes with regression gates.
#
#   scripts/ci.sh
#
# Fails if any test fails, any benchmark errors, dispatch throughput
# regresses >20% below benchmarks/BENCH_dispatch.json, or the migration
# data-plane's simulated drain time regresses >20% above
# benchmarks/BENCH_migration.json (regenerate baselines with:
#   python -m benchmarks.dispatch_throughput --smoke \
#       --write-baseline benchmarks/BENCH_dispatch.json
#   python -m benchmarks.migration_pipeline \
#       --write-baseline benchmarks/BENCH_migration.json
#   python -m benchmarks.multi_tenant \
#       --write-baseline benchmarks/BENCH_multitenant.json
# — the dispatch baseline is wall-clock and host-specific; the migration
# and multi-tenant baselines are simulated time and portable).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== fig8 command-overhead smoke =="
python -m benchmarks.cmd_overhead

echo "== dispatch throughput smoke (20% regression gate) =="
python -m benchmarks.dispatch_throughput --smoke --trials 3 \
    --baseline benchmarks/BENCH_dispatch.json

echo "== migration data-plane smoke (20% regression gate) =="
python -m benchmarks.migration_pipeline \
    --baseline benchmarks/BENCH_migration.json

echo "== multi-tenant smoke (20% regression gate + acceptance floors) =="
python -m benchmarks.multi_tenant \
    --baseline benchmarks/BENCH_multitenant.json

echo "ci.sh: all checks passed"
