"""Format the dry-run JSON into the EXPERIMENTS.md roofline tables."""
import json
import sys


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def one_liner(row):
    """One-sentence 'what would move the dominant term down'."""
    b = row["bottleneck"]
    arch, shape = row["arch"], row["shape"]
    if row["mode"] == "decode":
        if b == "memory":
            return ("decode is weight/cache-bandwidth bound by nature; "
                    "bigger batch or speculative decoding amortizes reads")
        return ("batch=1 replicates compute across devices; shard "
                "sequence/experts or batch multiple requests")
    if b == "collective":
        if "grok" in arch or "nemotron" in arch or "scout" in arch:
            return ("FSDP weight gathers scale with microbatch count — "
                    "fewer, larger microbatches (see §Perf)")
        return ("TP all-reduces dominate at this width — remap the model "
                "axis to data parallelism (see §Perf fsdp strategy)")
    if b == "memory":
        if shape.startswith("train") or shape.startswith("prefill"):
            return ("attention-interior blocks hit HBM on the XLA path; "
                    "the Pallas flash kernel keeps them in VMEM (§Perf)")
    return "compute-bound: increase per-device arithmetic intensity"


def main(single, multi, out):
    sp = json.load(open(single))["results"]
    mp = {(r["arch"], r["shape"]): r
          for r in json.load(open(multi))["results"]}
    lines = []
    lines.append(
        "| arch | shape | mode | t_compute (s) | t_memory (s) | "
        "t_collective (s) | bound | useful ratio | roofline | "
        "mem/dev GiB | multi-pod compile |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in sp:
        m = mp.get((r["arch"], r["shape"]))
        mp_ok = "OK" if m else "—"
        mem = (r["per_dev_bytes"]["args"]
               + r["per_dev_bytes"]["temps"]) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.3f} | {r['roofline_frac']:.3f} "
            f"| {mem:.1f} | {mp_ok} |")
    notes = ["", "Per-cell bottleneck notes:", ""]
    for r in sp:
        notes.append(f"- **{r['arch']} / {r['shape']}** ({r['bottleneck']}-"
                     f"bound): {one_liner(r)}")
    with open(out, "w") as f:
        f.write("\n".join(lines + notes))
    print(f"wrote {out} ({len(sp)} cells)")


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2], sys.argv[3])
