"""Content-sized cross-pod gradient reduction (paper §5.3 → DCN link).

The paper's ``cl_pocl_content_size`` moves only the meaningful prefix of
a buffer across the slow UE link. The training-framework analogue: the
cross-pod (DCN) gradient all-reduce moves only a top-k packed payload
(values+indices = the "content size") with error feedback accumulating
what was left behind. The intra-pod (ICI) reductions stay exact.

Implemented with partial-manual ``shard_map`` over the 'pod' axis only —
the per-pod body remains auto-sharded over data/model, so the lowered HLO
shows the cross-pod all-gather shrinking to the packed size (visible in
the §Roofline collective term).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.topk_compress.ref import topk_pack_ref, unpack_ref
from repro.utils import jax_axis_size, jax_shard_map

Pytree = Any


def _round_block(n: int, block: int) -> int:
    return max(block, ((n + block - 1) // block) * block)


def compressed_psum_leaf(g: jax.Array, err: jax.Array, *, axis: str,
                         k_per_block: int, block: int):
    """One leaf: top-k pack → all-gather(axis) → sum of unpacked payloads.

    Returns (g_synced, new_err). Mean over the axis is applied."""
    n_pods = jax_axis_size(axis)
    shape = g.shape
    n = int(np.prod(shape))
    npad = _round_block(n, block)
    flat = jnp.pad(g.astype(jnp.float32).reshape(-1), (0, npad - n))
    flat = flat + err.astype(jnp.float32)

    vals, idx = topk_pack_ref(flat, k_per_block, block)
    new_err = flat - unpack_ref(vals, idx, block, npad)

    vals_g = jax.lax.all_gather(vals, axis)          # [pods, nb, k]
    idx_g = jax.lax.all_gather(idx, axis)
    dense = jax.vmap(lambda v, i: unpack_ref(v, i, block, npad))(
        vals_g, idx_g).sum(axis=0) / n_pods

    return dense[:n].reshape(shape).astype(g.dtype), new_err.astype(err.dtype)


def init_error_state(grads_like: Pytree, block: int = 1024,
                     dtype=jnp.bfloat16) -> Pytree:
    def f(g):
        n = _round_block(int(np.prod(g.shape)), block)
        return jnp.zeros((n,), dtype)
    return jax.tree.map(f, grads_like)


def compressed_psum_tree(grads: Pytree, err: Pytree, *, axis: str = "pod",
                         k_per_block: int = 32, block: int = 1024):
    """Apply the compressed reduction to every leaf. Must run inside a
    shard_map manual over ``axis``."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        gs, es = compressed_psum_leaf(g, e, axis=axis,
                                      k_per_block=k_per_block, block=block)
        out_g.append(gs)
        out_e.append(es)
    return jax.tree.unflatten(tdef, out_g), jax.tree.unflatten(tdef, out_e)


def pod_manual_shard_map(fn, mesh, in_specs, out_specs):
    """shard_map manual ONLY over 'pod'; data/model stay compiler-managed.

    Note: partial-manual shard_map requires check_vma (the default); with
    check_vma=False jax treats the region as fully manual."""
    manual = frozenset({"pod"}) & frozenset(mesh.axis_names)
    return jax_shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, axis_names=manual)
