"""Elastic scaling: re-mesh a running job onto surviving hardware.

The paper handles UE↔MEC connection loss with sessions + replay (§4.3);
at cluster scale the analogous event is losing a pod (or slice). The
recovery path implemented here:

  1. failure detected (heartbeat timeout → ``PodFailure``),
  2. rebuild the mesh over the surviving pods (same axis names),
  3. re-shard the last checkpoint onto the new mesh (restore() device_puts
     to the new shardings),
  4. rescale the data plan (smaller global batch or more grad-accum
     microbatches, keeping the *effective* batch constant),
  5. replay the step log from the checkpoint step.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.distributed.context import MeshContext


class PodFailure(RuntimeError):
    def __init__(self, pod_index: int):
        super().__init__(f"pod {pod_index} lost")
        self.pod_index = pod_index


@dataclasses.dataclass
class ElasticPlan:
    """How to keep the same effective batch on fewer pods."""
    microbatches: int
    global_batch: int

    @staticmethod
    def rescale(microbatches: int, global_batch: int,
                old_pods: int, new_pods: int) -> "ElasticPlan":
        # keep effective batch: scale grad-accum up by the pod ratio
        assert old_pods % max(new_pods, 1) == 0
        factor = old_pods // max(new_pods, 1)
        return ElasticPlan(microbatches=microbatches * factor,
                           global_batch=global_batch)


def surviving_mesh(devices, pods_total: int, lost_pods: set,
                   data: int, model: int):
    """Mesh over surviving pods (same axis names, smaller 'pod' extent)."""
    import numpy as np
    alive = [p for p in range(pods_total) if p not in lost_pods]
    per_pod = data * model
    dev = np.asarray(devices)[: pods_total * per_pod]
    dev = dev.reshape(pods_total, data, model)[alive]
    return jax.sharding.Mesh(dev, ("pod", "data", "model"))


def remesh_state(state, new_ctx: MeshContext, param_specs_tree):
    """Re-shard a state pytree onto a new mesh context."""
    from repro.models.specs import is_spec

    def f(leaf, spec):
        sh = new_ctx.sharding(spec.axes, spec.shape)
        return jax.device_put(leaf, sh)

    return jax.tree.map(f, state, param_specs_tree, is_leaf=is_spec)
