"""Mesh context: logical-axis → mesh-axis rules with divisibility fallback.

Model code stays mesh-agnostic; it calls ``shard_act(x, names)`` which is
a no-op outside a mesh context. The launcher installs a ``MeshContext``
that maps logical names to mesh axes, dropping any axis that does not
divide the corresponding dimension (e.g. batch=1 in long_500k, or 4 query
heads on a 16-way model axis).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils import jax_typeof

# Logical activation/param axis → mesh axes (tuple). Tuned per run.
DEFAULT_RULES = {
    # params
    "stack": (), "embed": ("data",), "vocab": ("model",), "q": ("model",),
    "kvh": ("model",), "mlp": ("model",), "expert": (), "inner": ("model",),
    "hssm": ("model",),
    # activations
    "batch": ("pod", "data"), "seq": (), "heads": ("model",),
    "act_mlp": ("model",), "act_inner": ("model",),
    # KV cache layout (set per cell): 'kv_rep' shards padded kv heads on
    # 'model'; 'seq' shards the cache sequence dim instead
    "kv_heads": ("model",), "kv_seq": (),
    # MoE
    "expert_act": (),
}


# Pure-FSDP strategy: no tensor parallelism — the 'model' axis becomes
# extra data parallelism; weights stay sharded across both axes for
# storage (ZeRO-3) and are gathered per layer. The §Perf hillclimb showed
# this is the right regime for small archs (≤2B) where Megatron TP
# all-reduces dominate the roofline at d_model/16-wide per-device tiles.
FSDP_RULES = {
    "embed": ("data",), "vocab": ("model",), "q": ("model",),
    "kvh": ("model",), "mlp": ("model",), "inner": ("model",),
    "hssm": ("model",), "expert": (),
    "batch": ("pod", "data", "model"), "heads": (), "seq": (),
    "act_mlp": (), "act_inner": (),
    "kv_heads": (), "kv_seq": (), "expert_act": (),
}

STRATEGIES = {"megatron": {}, "fsdp": FSDP_RULES}


class MeshContext:
    def __init__(self, mesh: Mesh, rules: Optional[dict] = None,
                 cache_layout: str = "kv_rep", strategy: str = "megatron"):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        self.rules.update(STRATEGIES.get(strategy, {}))
        self.strategy = strategy
        if rules:
            self.rules.update(rules)
        if cache_layout == "seq":
            self.rules["kv_heads"] = ()
            self.rules["kv_seq"] = ("model",)
        self.cache_layout = cache_layout
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _axes_for(self, name, dim: int):
        if name is None:
            return None
        axes = tuple(a for a in self.rules.get(name, ()) if a in self.axis_sizes)
        if not axes:
            return None
        total = int(np.prod([self.axis_sizes[a] for a in axes]))
        if dim % total != 0:
            # try a prefix of the axes before giving up
            for cut in range(len(axes) - 1, 0, -1):
                sub = axes[:cut]
                t = int(np.prod([self.axis_sizes[a] for a in sub]))
                if dim % t == 0:
                    return sub
            return None
        return axes

    def pspec(self, names: Sequence, shape: Sequence[int]) -> P:
        assert len(names) == len(shape), (names, shape)
        parts = [self._axes_for(n, d) for n, d in zip(names, shape)]
        return P(*parts)

    def sharding(self, names: Sequence, shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(names, shape))

    def tp(self) -> int:
        return self.axis_sizes.get("model", 1)

    def kv_pad_factor(self, n_heads: int, n_kv: int) -> int:
        """Megatron-style KV head replication for TP > n_kv (only when the
        alignment works out; otherwise KV stays replicated)."""
        if self.cache_layout != "kv_rep":
            return 1
        tp = self.tp()
        if tp > n_kv and n_heads % tp == 0 and tp % n_kv == 0:
            return tp // n_kv
        return 1


_tls = threading.local()


def current() -> Optional[MeshContext]:
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def mesh_context(ctx: Optional[MeshContext]):
    prev = current()
    _tls.ctx = ctx
    try:
        if ctx is not None:
            with ctx.mesh:
                yield ctx
        else:
            yield None
    finally:
        _tls.ctx = prev


def _manual_variant_mesh(mesh: Mesh, manual_axes: frozenset) -> Mesh:
    """Mesh with the given axes typed Manual (for constraints inside a
    partial-manual shard_map region)."""
    types = tuple(jax.sharding.AxisType.Manual if a in manual_axes
                  else jax.sharding.AxisType.Auto for a in mesh.axis_names)
    return Mesh(mesh.devices, mesh.axis_names, axis_types=types)


def shard_act(x: jax.Array, names: Sequence) -> jax.Array:
    """Apply a sharding constraint if a mesh context is installed.

    Inside a partial-manual shard_map region (compressed cross-pod
    gradient sync), values carry varying-manual-axes; the constraint
    must then (a) not mention the manual axes and (b) use a mesh that
    types them Manual."""
    ctx = current()
    if ctx is None:
        return x
    vma = frozenset(getattr(jax_typeof(x), "vma", None) or frozenset())
    if vma:
        # inside a partial-manual region: skip the constraint — mixing
        # Manual-typed mesh constraints with the outer Auto mesh tickles
        # an XLA SPMD-partitioner check failure (see EXPERIMENTS.md);
        # propagation from the in_specs shardings covers the auto axes
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, ctx.pspec(names, x.shape)))


def kv_pad(n_heads: int, n_kv: int) -> int:
    ctx = current()
    return ctx.kv_pad_factor(n_heads, n_kv) if ctx is not None else 1
