"""Batched serving engine: wave-scheduled prefill/decode over the LM.

Requests are grouped into aligned *waves* (all slots share the position
counter, so cache updates stay a single dynamic_update_slice — the
engine's batching model; noted in DESIGN.md). Per-request completion is
tracked with an EOS/max-token mask; finished slots emit and the wave
retires when all slots are done or the wave budget expires.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 32
    eos_id: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, batch_slots: int = 8,
                 max_len: int = 512, prefill_chunk: Optional[int] = None,
                 greedy: bool = True, cache_dtype=jnp.bfloat16):
        self.params = params
        self.cfg = cfg
        self.slots = batch_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.greedy = greedy
        self.cache_dtype = cache_dtype

        self._prefill = jax.jit(
            lambda p, c, t: lm.prefill(p, cfg, c, tokens=t,
                                       chunk=prefill_chunk))
        self._decode = jax.jit(lambda p, c, t: lm.decode_step(p, cfg, c, t))

    def _sample(self, logits: jax.Array) -> jax.Array:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def run_wave(self, requests: List[Request]) -> List[Request]:
        """Serve up to ``slots`` requests with aligned positions."""
        assert len(requests) <= self.slots
        B = self.slots
        plen = max(len(r.prompt) for r in requests)
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad

        cache = lm.init_cache(self.cfg, B, self.max_len,
                              dtype=self.cache_dtype)
        logits, cache = self._prefill(self.params, cache,
                                      jnp.asarray(prompts))
        tok = self._sample(logits)
        live = np.array([not r.done for r in requests] + [False] * (B - len(requests)))
        budget = max(r.max_new_tokens for r in requests)

        for step in range(budget):
            t_np = np.asarray(tok)
            for i, r in enumerate(requests):
                if live[i]:
                    t = int(t_np[i])
                    r.out_tokens.append(t)
                    if t == r.eos_id or len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
                        live[i] = False
            if not live.any():
                break
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits)
        for r in requests:
            r.done = True
        return requests

    def serve(self, requests: List[Request]) -> List[Request]:
        """Wave-batched serving of an arbitrary request list."""
        out = []
        for i in range(0, len(requests), self.slots):
            out.extend(self.run_wave(requests[i:i + self.slots]))
        return out
