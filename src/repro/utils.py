"""Small shared utilities: pytree arithmetic, dtype policy, shape math."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def jax_typeof(x):
    """Version-compat shim for ``jax.typeof`` (added in jax 0.6).

    Older installs (0.4.x) fall back to the abstract value, which carries
    the same shape/dtype info; extension attributes like ``vma`` are read
    with ``getattr`` defaults at the call sites either way."""
    fn = getattr(jax, "typeof", None)
    if fn is not None:
        return fn(x)
    return jax.core.get_aval(x)


def jax_shard_map(fn, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None):
    """Version-compat shim for ``jax.shard_map`` (top-level in jax 0.6).

    0.4.x only has ``jax.experimental.shard_map.shard_map``, expresses
    partial-manual regions through ``auto=`` (the complement of the new
    API's ``axis_names=``), and calls ``check_vma`` ``check_rep``. The
    0.4.x replication checker does not understand partial-manual
    regions, so it is disabled whenever ``auto`` is non-empty."""
    sm = getattr(jax, "shard_map", None)
    kw = {}
    if sm is not None:
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kw)
    from jax.experimental.shard_map import shard_map as sm_old
    if check_vma is not None:
        kw["check_rep"] = check_vma
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
            kw["check_rep"] = False   # overrides check_vma: the 0.4.x
            # replication checker cannot handle partial-manual regions
    mapped = sm_old(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    **kw)
    # 0.4.x supports partial-manual only under jit (the eager impl raises
    # NotImplementedError for non-empty ``auto``); jitting is a no-op for
    # callers that already jit
    return jax.jit(mapped) if auto else mapped


def jax_axis_size(axis):
    """Version-compat shim for ``jax.lax.axis_size`` (jax 0.6): older
    installs count participants with a unit psum over the axis."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return jax.lax.psum(1, axis)


def pallas_tpu_compiler_params(**kwargs):
    """Version-compat shim: ``pltpu.CompilerParams`` was named
    ``TPUCompilerParams`` before jax 0.6. Imported lazily so utils stays
    light for non-kernel users."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def storage_barrier(x: Pytree) -> Pytree:
    """Optionally pin values as materialized storage (dry-run only).

    XLA-CPU's excess-precision pass deletes f32→bf16→f32 convert pairs,
    so on the CPU backend the mixed-precision structure of the program
    vanishes from the optimized HLO and the roofline analysis would see
    an all-f32 program. The dry-run sets REPRO_DTYPE_BARRIER=1 to wrap
    down-casts in ``optimization_barrier``, preserving the bf16 storage
    points exactly where a TPU compilation would have them. Real runs
    (flag unset) are unaffected."""
    if os.environ.get("REPRO_DTYPE_BARRIER") == "1":
        return jax.lax.optimization_barrier(x)
    return x


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_size(a: Pytree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(a))


def tree_bytes(a: Pytree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(a))


def tree_global_norm(a: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(a)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def tree_cast(a: Pytree, dtype) -> Pytree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def split_key(key, n: int):
    return list(jax.random.split(key, n))


def pattern_cycles(n_layers: int, pattern_len: int) -> tuple[int, int]:
    """Split n_layers into (n_full_cycles, tail_len) for a repeating pattern."""
    return n_layers // pattern_len, n_layers % pattern_len


def vma_like(x: Pytree, template) -> Pytree:
    """Match a fresh value's varying-manual-axes to a template's.

    Under partial-manual shard_map (pod-manual gradient compression),
    scan carries initialized from constants are 'invariant' while the
    data is pod-'varying'; the VMA checker rejects the mismatch. This
    promotes x when (and only when) the template is varying, and is a
    no-op outside shard_map."""
    vma = getattr(jax_typeof(template), "vma", None) or frozenset()
    if not vma:
        return x

    def promote(a):
        have = getattr(jax_typeof(a), "vma", None) or frozenset()
        need = tuple(sorted(vma - have))
        return jax.lax.pcast(a, need, to="varying") if need else a

    return jax.tree.map(promote, x)


def grad_cast(x):
    """Identity whose cotangent is cast back to x's dtype.

    fp32-accumulating einsums (``preferred_element_type=f32``) propagate
    fp32 into their transposed (backward) dots; without a barrier the fp32
    cotangents flow through projections and the residual stream, doubling
    every backward dot, activation store and TP all-reduce. Place this at
    mixed-precision boundaries (loss logits, attention q/k/v)."""
    dtype = x.dtype

    @jax.custom_vjp
    def _f(y):
        return y

    def _fwd(y):
        return y, None

    def _bwd(_, g):
        return (storage_barrier(g.astype(dtype)),)

    _f.defvjp(_fwd, _bwd)
    return _f(x)
