"""Serving driver: batched greedy decoding with the wave engine.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --requests 8 --prompt-len 16 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.distributed.context import MeshContext, mesh_context
from repro.launch.mesh import make_local_mesh
from repro.models import specs as pspecs
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    rng = jax.random.PRNGKey(0)
    params = pspecs.init_from_specs(rng, pspecs.model_param_specs(cfg))
    ctx = MeshContext(make_local_mesh())

    rs = np.random.default_rng(0)
    reqs = [Request(prompt=rs.integers(1, cfg.vocab,
                                       args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]

    with mesh_context(ctx):
        eng = ServeEngine(params, cfg, batch_slots=args.slots,
                          max_len=args.max_len)
        t0 = time.perf_counter()
        done = eng.serve(reqs)
        dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    for i, r in enumerate(done[:4]):
        print(f"req{i}: {r.out_tokens[:12]}...")


if __name__ == "__main__":
    main()
