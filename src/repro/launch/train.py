"""Training driver.

CPU-scale (reduced configs) runs locally in this container; the same
driver drives the production mesh when pods are attached (the dry-run
validates those lowerings). Example:

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.shapes import ShapeCell
from repro.data.pipeline import DataLoader
from repro.distributed.context import MeshContext, mesh_context
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch import specs as lspecs
from repro.optim import AdamW, cosine_schedule
from repro.training.loop import LoopConfig, Trainer
from repro.training.step import make_train_step

_DT = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def build(arch: str, reduced: bool, batch: int, seq: int, steps: int,
          microbatches: int = 1, lr: float = 3e-4, seed: int = 0,
          production_mesh: bool = False, compress_pods: bool = False):
    cfg = configs.get_reduced(arch) if reduced else configs.get_config(arch)
    run = configs.get_overrides(arch)
    mb = microbatches if reduced else run.microbatches
    mesh = (make_production_mesh(multi_pod=True) if production_mesh
            else make_local_mesh())
    ctx = MeshContext(mesh)
    cell = ShapeCell("custom", "train", seq, batch)
    opt = AdamW(cosine_schedule(lr, max(steps // 10, 1), steps),
                moment_dtype=_DT[run.adam_dtype])
    step_fn = make_train_step(cfg, opt, microbatches=mb,
                              remat=run.remat if not reduced else "full",
                              remat_group=run.remat_group if not reduced else 1)
    loader = DataLoader(cfg, cell, mb, seed=seed)
    rng = jax.random.PRNGKey(seed)
    state = lspecs.init_train_state(cfg, None, run, opt, rng)
    return cfg, ctx, jax.jit(step_fn, donate_argnums=(0,)), state, loader


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg, ctx, step_fn, state, loader = build(
        args.arch, args.reduced, args.batch, args.seq, args.steps,
        args.microbatches, args.lr)
    loop_cfg = LoopConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every or args.steps,
                          ckpt_dir=args.ckpt_dir, log_every=10)
    with mesh_context(ctx):
        tr = Trainer(step_fn, state, loader, loop_cfg)
        tr.maybe_restore()
        result = tr.run()
    loader.stop()
    for row in result["log"]:
        print(json.dumps(row))
    print(f"final_loss={result['final_loss']:.4f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
