import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_DTYPE_BARRIER"] = "1"   # keep bf16 storage visible in HLO

# Multi-pod dry-run: lower + compile every (architecture × input shape)
# cell on the production meshes and extract memory/cost/collective data.
#
# The two lines above MUST run before any other import (jax locks the
# device count on first initialization).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
#       --shape train_4k [--multi-pod] [--out results.json]
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                       # noqa: E402
from repro.configs.shapes import SHAPES         # noqa: E402
from repro.distributed.context import MeshContext, mesh_context  # noqa: E402
from repro.launch import specs as lspecs        # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_desc  # noqa: E402
from repro.models import lm                     # noqa: E402
from repro.optim import AdamW, cosine_schedule  # noqa: E402
from repro.roofline import analyze_hlo, from_totals  # noqa: E402
from repro.training.step import make_train_step  # noqa: E402

_DT = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool = False,
               ctx_overrides: dict | None = None,
               strategy: str | None = None,
               microbatches: int | None = None):
    """Lower + compile one cell. Returns (compiled, lowered, meta dict)."""
    cfg = configs.get_config(arch_id)
    run = configs.get_overrides(arch_id)
    if microbatches is not None:
        import dataclasses as _dc
        run = _dc.replace(run, microbatches=microbatches)
    if strategy is None:
        strategy = run.strategy
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    if cell.kind == "decode":
        layout = "seq" if cell.name == "long_500k" else run.decode_cache_layout
    else:
        layout = "kv_rep"
    ctx = MeshContext(mesh, rules=ctx_overrides, cache_layout=layout,
                      strategy=strategy)

    with mesh_context(ctx):
        if cell.kind == "train":
            opt = AdamW(cosine_schedule(3e-4, 100, 10_000),
                        moment_dtype=_DT[run.adam_dtype])
            step = make_train_step(cfg, opt, microbatches=run.microbatches,
                                   remat=run.remat,
                                   remat_group=run.remat_group)
            state = lspecs.abstract_train_state(cfg, ctx, run)
            batch = lspecs.train_batch_specs(cfg, cell, ctx, run)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(state, batch)
            mode = "train"
            tokens = cell.batch * cell.seq
        elif cell.kind == "prefill":
            params = lspecs.abstract_params(cfg, ctx, _DT[run.serve_dtype])
            cache = lspecs.abstract_cache(
                cfg, ctx, cell.batch, cell.seq,
                enc_len=cell.seq if cfg.is_encdec else 0)
            inputs = lspecs.prefill_input_specs(cfg, cell, ctx)

            def prefill_fn(params, cache, inputs):
                return lm.prefill(params, cfg, cache, **inputs,
                                  chunk=run.prefill_chunk)

            lowered = jax.jit(prefill_fn, donate_argnums=(1,)).lower(
                params, cache, inputs)
            mode = "prefill"
            tokens = cell.batch * cell.seq
        else:  # decode
            params = lspecs.abstract_params(cfg, ctx, _DT[run.serve_dtype])
            cache = lspecs.abstract_cache(
                cfg, ctx, cell.batch, cell.seq,
                enc_len=cell.seq if cfg.is_encdec else 0)
            # the cache holds `seq` tokens; mark pos near the end
            token = lspecs.decode_token_specs(cfg, cell, ctx)

            def decode_fn(params, cache, token):
                return lm.decode_step(params, cfg, cache, token)

            lowered = jax.jit(decode_fn, donate_argnums=(1,)).lower(
                params, cache, token)
            mode = "decode"
            tokens = cell.batch

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    meta = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_desc(mesh),
        "mode": mode, "layout": layout, "compile_s": compile_s,
        "strategy": strategy, "microbatches": run.microbatches,
        "chips": mesh.devices.size,
        "model_flops_global": cfg.model_flops_per_token(cell.seq, mode) * tokens,
    }
    return compiled, lowered, meta


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False, strategy: str | None = None,
             microbatches: int | None = None) -> dict:
    compiled, lowered, meta = lower_cell(arch_id, shape_name, multi_pod,
                                         strategy=strategy,
                                         microbatches=microbatches)
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    tot = analyze_hlo(hlo)
    rf = from_totals(arch_id, shape_name, meta["mesh"], meta["chips"],
                     tot, meta["model_flops_global"],
                     arg_bytes=getattr(mem, "argument_size_in_bytes", 0),
                     temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
                     xla_flops_raw=float(ca.get("flops", 0.0)))
    out = dict(meta)
    out.update(rf.row())
    out["coll_by_type"] = {k: float(v) for k, v in tot.coll_by_type.items()}
    out["custom_calls"] = tot.custom_calls
    out["unknown_while"] = tot.unknown_while
    out["per_dev_bytes"] = {
        "args": getattr(mem, "argument_size_in_bytes", 0),
        "temps": getattr(mem, "temp_size_in_bytes", 0),
        "output": getattr(mem, "output_size_in_bytes", 0),
        "alias": getattr(mem, "alias_size_in_bytes", 0),
    }
    if keep_hlo:
        out["hlo"] = hlo
    return out


def all_cells():
    for arch in configs.ARCH_IDS:
        mod = configs.arch_module(arch)
        for name in SHAPES:
            if configs.shapes.applicable(mod, name):
                yield arch, name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default=None,
                    choices=[None, "megatron", "fsdp"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    results, failures = [], []
    for arch, shape in cells:
        t0 = time.time()
        try:
            r = run_cell(arch, shape, args.multi_pod,
                         strategy=args.strategy,
                         microbatches=args.microbatches)
            results.append(r)
            print(f"OK   {arch:26s} {shape:12s} mesh={r['mesh']} "
                  f"compile={r['compile_s']:.1f}s "
                  f"t_comp={r['t_compute_s']:.4f}s t_mem={r['t_memory_s']:.4f}s "
                  f"t_coll={r['t_collective_s']:.4f}s bound={r['bottleneck']} "
                  f"useful={r['useful_ratio']:.3f} "
                  f"roofline={r['roofline_frac']:.3f} "
                  f"mem/dev="
                  f"{(r['per_dev_bytes']['args'] + r['per_dev_bytes']['temps']) / 2 ** 30:.2f}GiB",
                  flush=True)
        except Exception as e:
            failures.append((arch, shape, repr(e)))
            print(f"FAIL {arch:26s} {shape:12s} {time.time()-t0:.1f}s {e!r}",
                  flush=True)
            traceback.print_exc()
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results,
                       "failures": [list(f_) for f_ in failures]}, f, indent=1)
    print(f"\n{len(results)} ok, {len(failures)} failed")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
