"""Abstract input/state builders for the multi-pod dry-run.

Everything is ``jax.ShapeDtypeStruct`` stand-ins with NamedShardings —
weak-type-correct, shardable, no device allocation. The same builders
drive ``launch/train.py`` / ``launch/serve.py`` with real arrays.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import RunOverrides
from repro.configs.shapes import ShapeCell
from repro.distributed.context import MeshContext, mesh_context
from repro.models import lm, specs as pspecs
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW, AdamWState, TrainState

_DT = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def _sds(shape, dtype, ctx: MeshContext, names) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=ctx.sharding(names, shape))


# --------------------------------------------------------------------------
# training inputs + state
# --------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, cell: ShapeCell, ctx: MeshContext,
                      run: RunOverrides) -> dict:
    """Microbatch-major batch: leaves [A, GB/A, ...]."""
    A = run.microbatches
    gb, S = cell.batch, cell.seq
    assert gb % A == 0, (gb, A)
    b = gb // A
    tok = lambda: _sds((A, b, S), jnp.int32, ctx, (None, "batch", None))
    emb = lambda: _sds((A, b, S, cfg.d_model), jnp.bfloat16, ctx,
                       (None, "batch", None, None))
    batch = {"labels": tok()}
    if cfg.is_encdec:
        batch["enc_embeds"] = emb()
        batch["tokens"] = tok()
    elif cfg.frontend is not None:
        batch["embeds"] = emb()
    else:
        batch["tokens"] = tok()
    return batch


def param_sharding_fn(ctx: MeshContext):
    return lambda axes, shape: ctx.sharding(axes, shape)


def abstract_params(cfg: ModelConfig, ctx: MeshContext, dtype=jnp.float32):
    sp = pspecs.model_param_specs(cfg)
    return pspecs.abstract_from_specs(sp, dtype=dtype,
                                      sharding_fn=param_sharding_fn(ctx))


def abstract_train_state(cfg: ModelConfig, ctx: MeshContext,
                         run: RunOverrides) -> TrainState:
    params = abstract_params(cfg, ctx, _DT[run.param_dtype])
    mdt = _DT[run.adam_dtype]
    mom = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, mdt, sharding=p.sharding),
        params)
    opt = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=mom, v=mom)
    return TrainState(params=params, opt=opt)


def init_train_state(cfg: ModelConfig, ctx: Optional[MeshContext],
                     run: RunOverrides, optimizer: AdamW, rng) -> TrainState:
    """Real (materialized) train state, sharded if a ctx is given."""
    sp = pspecs.model_param_specs(cfg)
    params = pspecs.init_from_specs(rng, sp, _DT[run.param_dtype])
    if ctx is not None:
        shard = lambda p, s: jax.device_put(
            p, ctx.sharding(s.axes, s.shape))
        params = jax.tree.map(shard, params, sp,
                              is_leaf=lambda x: hasattr(x, "shape")
                              and not isinstance(x, pspecs.ParamSpec))
    return TrainState(params=params, opt=optimizer.init(params))


# --------------------------------------------------------------------------
# serving state (KV cache) + inputs
# --------------------------------------------------------------------------

def _cache_axes_from_path(path) -> tuple:
    keys = []
    for p in path:
        keys.append(getattr(p, "key", None) or getattr(p, "name", ""))
    leaf = keys[-1]
    parents = keys[:-1]
    if leaf == "pos":
        return ()
    if "xattn" in parents:
        axes = ("batch", None, None, None)
    elif leaf in ("k", "v"):
        axes = ("batch", "kv_seq", "kv_heads", None)
    elif leaf == "state":
        axes = ("batch", "hssm", None, None)
    elif leaf == "conv_x":
        axes = ("batch", None, "act_inner")
    elif leaf in ("conv_B", "conv_C"):
        axes = ("batch", None, None)
    else:
        raise ValueError(f"unknown cache leaf {keys}")
    if "blocks" in parents:
        axes = ("stack",) + axes
    return axes


def abstract_cache(cfg: ModelConfig, ctx: MeshContext, batch: int,
                   max_len: int, dtype=jnp.bfloat16, enc_len: int = 0):
    """ShapeDtypeStruct cache tree with shardings attached per leaf."""
    with mesh_context(ctx):
        shapes = jax.eval_shape(
            functools.partial(lm.init_cache, cfg, batch, max_len,
                              dtype, enc_len))

    def attach(path, sds):
        axes = _cache_axes_from_path(path)
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=ctx.sharding(axes, sds.shape))

    return jax.tree_util.tree_map_with_path(attach, shapes)


def decode_token_specs(cfg: ModelConfig, cell: ShapeCell, ctx: MeshContext):
    return _sds((cell.batch,), jnp.int32, ctx, ("batch",))


def prefill_input_specs(cfg: ModelConfig, cell: ShapeCell, ctx: MeshContext):
    B, S = cell.batch, cell.seq
    out = {}
    if cfg.is_encdec:
        out["enc_embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16, ctx,
                                 ("batch", None, None))
        out["tokens"] = _sds((B, S), jnp.int32, ctx, ("batch", None))
    elif cfg.frontend is not None:
        out["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16, ctx,
                             ("batch", None, None))
    else:
        out["tokens"] = _sds((B, S), jnp.int32, ctx, ("batch", None))
    return out


def input_specs(arch_cfg: ModelConfig, cell: ShapeCell, ctx: MeshContext,
                run: RunOverrides) -> dict:
    """All abstract inputs for a cell (convenience dispatcher)."""
    if cell.kind == "train":
        return {"batch": train_batch_specs(arch_cfg, cell, ctx, run)}
    if cell.kind == "prefill":
        return {"inputs": prefill_input_specs(arch_cfg, cell, ctx),
                "cache": abstract_cache(
                    arch_cfg, ctx, cell.batch, cell.seq,
                    enc_len=cell.seq if arch_cfg.is_encdec else 0)}
    # decode
    return {"token": decode_token_specs(arch_cfg, cell, ctx),
            "cache": abstract_cache(
                arch_cfg, ctx, cell.batch, cell.seq,
                enc_len=cell.seq if arch_cfg.is_encdec else 0)}
