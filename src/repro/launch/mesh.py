"""Production mesh construction.

Kept as FUNCTIONS so importing this module never touches jax device
state. The dry-run sets ``--xla_force_host_platform_device_count=512``
before any jax import; smoke tests and benchmarks see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def mesh_desc(mesh) -> str:
    return "x".join(f"{a}={s}" for a, s
                    in zip(mesh.axis_names, mesh.devices.shape))
