from repro.data.pipeline import DataLoader, SyntheticCorpus  # noqa: F401
