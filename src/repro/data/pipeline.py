"""Data pipeline: deterministic synthetic corpus + sharded host loader.

Production-shaped: documents → tokenization (synthetic zipf stream with
document structure) → packing into fixed-length sequences → microbatch-
major global batches, with background prefetch and a restore-exact cursor
for checkpoint/restart (the loader state is part of the checkpoint, so a
restarted job sees the identical token stream — required for the
fault-tolerance tests).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class LoaderState:
    seed: int
    step: int = 0


class SyntheticCorpus:
    """Deterministic zipf-distributed token documents with EOS structure."""

    def __init__(self, vocab: int, seed: int = 0, mean_doc_len: int = 512):
        self.vocab = vocab
        self.seed = seed
        self.mean_doc_len = mean_doc_len

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        # zipf over vocab, clipped; EOS = 0 separates "documents"
        toks = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
        toks = np.minimum(toks, self.vocab - 1)
        doc_break = rng.random((batch, seq + 1)) < (1.0 / self.mean_doc_len)
        toks = np.where(doc_break, 0, toks)
        return toks.astype(np.int32)


class DataLoader:
    """Microbatch-major batches with background prefetch."""

    def __init__(self, cfg, cell, microbatches: int, seed: int = 0,
                 prefetch: int = 2, d_model: Optional[int] = None):
        self.cfg = cfg
        self.batch = cell.batch
        self.seq = cell.seq
        self.A = microbatches
        self.corpus = SyntheticCorpus(cfg.vocab, seed)
        self.state = LoaderState(seed=seed)
        self.d_model = d_model or cfg.d_model
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---- batch construction ----
    def make_batch(self, step: int) -> dict:
        toks = self.corpus.batch(step, self.batch, self.seq)
        tokens = toks[:, :-1].reshape(self.A, self.batch // self.A, self.seq)
        labels = toks[:, 1:].reshape(self.A, self.batch // self.A, self.seq)
        out = {"labels": labels}
        if self.cfg.is_encdec:
            rng = np.random.default_rng((self.state.seed, step, 1))
            out["enc_embeds"] = rng.standard_normal(
                (self.A, self.batch // self.A, self.seq, self.d_model),
                dtype=np.float32).astype(np.float32) * 0.02
            out["tokens"] = tokens
        elif self.cfg.frontend is not None:
            # stub modality frontend: precomputed patch/frame embeddings
            rng = np.random.default_rng((self.state.seed, step, 2))
            out["embeds"] = rng.standard_normal(
                (self.A, self.batch // self.A, self.seq, self.d_model),
                dtype=np.float32).astype(np.float32) * 0.02
        else:
            out["tokens"] = tokens
        return out

    # ---- iteration with prefetch ----
    def _producer(self):
        step = self.state.step
        while not self._stop.is_set():
            b = self.make_batch(step)
            self._q.put((step, b))
            step += 1

    def start(self):
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._producer,
                                            daemon=True)
            self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            while not self._q.empty():
                self._q.get_nowait()
            self._thread = None

    def __iter__(self) -> Iterator[dict]:
        self.start()
        while True:
            step, b = self._q.get()
            self.state.step = step + 1
            yield b

    # ---- checkpointable cursor ----
    def snapshot(self) -> dict:
        return {"seed": self.state.seed, "step": self.state.step}

    def restore(self, snap: dict):
        self.stop()
        self.state = LoaderState(seed=int(snap["seed"]),
                                 step=int(snap["step"]))
