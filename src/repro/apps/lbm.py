"""D2Q9 lattice-Boltzmann (BGK) in JAX — the FluidX3D case-study payload
(paper §7.2) at laptop scale.

Supports domain decomposition along x with explicit halo exchange, so the
multi-node benchmark runs the *real* kernel per sub-domain while the
PoCL-R runtime moves the boundary buffers (implicit migration — the
"idiomatic OpenCL" mode the paper added to FluidX3D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# D2Q9 velocities and weights
C = np.array([[0, 0], [1, 0], [0, 1], [-1, 0], [0, -1],
              [1, 1], [-1, 1], [-1, -1], [1, -1]])
W = np.array([4 / 9] + [1 / 9] * 4 + [1 / 36] * 4)
OPP = np.array([0, 3, 4, 1, 2, 7, 8, 5, 6])


def equilibrium(rho: jax.Array, u: jax.Array) -> jax.Array:
    """rho [H,W], u [2,H,W] → feq [9,H,W]."""
    cu = jnp.einsum("qd,dhw->qhw", jnp.asarray(C, u.dtype), u)
    usq = jnp.sum(u * u, axis=0)
    w = jnp.asarray(W, u.dtype)[:, None, None]
    return w * rho * (1 + 3 * cu + 4.5 * cu ** 2 - 1.5 * usq)


def macroscopic(f: jax.Array):
    rho = jnp.sum(f, axis=0)
    u = jnp.einsum("qd,qhw->dhw", jnp.asarray(C, f.dtype), f) / \
        jnp.maximum(rho, 1e-12)
    return rho, u


@functools.partial(jax.jit, static_argnames=("tau",))
def lbm_step(f: jax.Array, tau: float = 0.6) -> jax.Array:
    """One collide-and-stream step with periodic boundaries. f: [9,H,W]."""
    rho, u = macroscopic(f)
    feq = equilibrium(rho, u)
    f = f + (feq - f) / tau
    # streaming: shift each population along its velocity
    outs = [jnp.roll(f[q], shift=(int(C[q][1]), int(C[q][0])),
                     axis=(0, 1)) for q in range(9)]
    return jnp.stack(outs)


def init_shear(H: int, W_: int, dtype=jnp.float32) -> jax.Array:
    """Double shear layer initial condition."""
    y = jnp.arange(H)[:, None] / H
    x = jnp.arange(W_)[None, :] / W_
    ux = 0.05 * jnp.tanh((y - 0.5) * 20) * jnp.ones_like(x)
    uy = 0.01 * jnp.sin(2 * jnp.pi * x) * jnp.ones_like(y)
    u = jnp.stack([ux, uy]).astype(dtype)
    rho = jnp.ones((H, W_), dtype)
    return equilibrium(rho, u)


# ---------------- domain decomposition ----------------

def split_domain(f: jax.Array, n: int) -> list:
    """Split [9,H,W] along W into n slabs, each padded with 1-col halos."""
    W_ = f.shape[2]
    assert W_ % n == 0
    w = W_ // n
    slabs = []
    for i in range(n):
        lo = (i * w - 1) % W_
        core = f[:, :, i * w:(i + 1) * w]
        left = f[:, :, lo:lo + 1]
        right = f[:, :, ((i + 1) * w) % W_:((i + 1) * w) % W_ + 1]
        slabs.append(jnp.concatenate([left, core, right], axis=2))
    return slabs


def slab_step(slab: jax.Array, tau: float = 0.6) -> jax.Array:
    """Step a halo-padded slab; interior columns are valid afterwards."""
    return lbm_step(slab, tau)


def exchange_halos(slabs: list) -> list:
    """Copy boundary columns between neighbours (periodic)."""
    n = len(slabs)
    out = []
    for i in range(n):
        left_src = slabs[(i - 1) % n][:, :, -2:-1]   # its last interior col
        right_src = slabs[(i + 1) % n][:, :, 1:2]    # its first interior col
        core = slabs[i][:, :, 1:-1]
        out.append(jnp.concatenate([left_src, core, right_src], axis=2))
    return out


def run_decomposed(f0: jax.Array, n: int, steps: int, tau: float = 0.6):
    slabs = split_domain(f0, n)
    for _ in range(steps):
        slabs = [slab_step(s, tau) for s in slabs]
        slabs = exchange_halos(slabs)
    return jnp.concatenate([s[:, :, 1:-1] for s in slabs], axis=2)
