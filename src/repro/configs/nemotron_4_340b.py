"""nemotron-4-340b [dense] — GQA, squared-ReLU 2-matrix MLP, 256k vocab.
[arXiv:2402.16819]

Largest dense cell: the FSDP×TP sharding stress test. Optimizer runs with
bf16 moments (see configs/__init__.py overrides) to fit v5e HBM.
"""
from repro.models.config import LayerKind, ModelConfig

ARCH_ID = "nemotron-4-340b"
LONG_CONTEXT_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv=8, d_ff=73728,
        vocab=256000, pattern=(LayerKind(mlp="relu2"),),
        rope_theta=1e4, tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=256,
        vocab=512, pattern=(LayerKind(mlp="relu2"),),
        rope_theta=1e4, tie_embeddings=False,
    )
