"""Architecture registry: the 10 assigned archs + per-arch run overrides.

``RunOverrides`` carries the compile/memory knobs that differ per cell
(grad-accumulation microbatches, remat policy, prefill chunking, optimizer
moment dtype) — these are the levers the §Perf hillclimb iterates on.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.configs.shapes import SHAPES, ShapeCell, applicable  # noqa: F401
from repro.models.config import ModelConfig

_MODULES = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "command-r-35b": "command_r_35b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "nemotron-4-340b": "nemotron_4_340b",
    "gemma3-1b": "gemma3_1b",
    "mamba2-780m": "mamba2_780m",
    "grok-1-314b": "grok_1_314b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "whisper-small": "whisper_small",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_IDS = list(_MODULES)


@dataclasses.dataclass(frozen=True)
class RunOverrides:
    """Per-arch execution knobs (hillclimb levers)."""
    microbatches: int = 1          # grad-accumulation steps inside train_step
    remat: str = "full"            # 'full' | 'dots' | 'none'
    remat_group: int = 1           # nested remat: save every g-th cycle
    prefill_chunk: Optional[int] = 4096
    adam_dtype: str = "float32"    # moment dtype; 'bfloat16' for giant archs
    param_dtype: str = "float32"
    serve_dtype: str = "bfloat16"  # params dtype when serving
    # KV cache layout for decode cells: 'kv_rep' (padded kv heads on the
    # model axis) or 'seq' (sequence-sharded, flash-decoding combines).
    # long_500k always uses 'seq'. Prefill always uses 'kv_rep'.
    decode_cache_layout: str = "kv_rep"
    # sharding strategy: 'megatron' (TP over model axis + FSDP over data)
    # or 'fsdp' (no TP; model axis = extra DP; per-layer weight gathers)
    strategy: str = "megatron"


_OVERRIDES: dict[str, RunOverrides] = {
    # giants: bf16 moments + deeper grad accumulation to fit v5e HBM;
    # 'seq' decode cache where padded-kv-head layout would blow HBM
    # (96L×hd192, or unshardable head counts H=40/H=12 — see DESIGN.md);
    # remat_group = nested remat (must divide the arch's cycle count)
    "llava-next-mistral-7b": RunOverrides(microbatches=2, remat_group=8),
    "command-r-35b": RunOverrides(microbatches=2, remat_group=8),
    "tinyllama-1.1b": RunOverrides(remat_group=2),
    # 340B/314B with fp32 master params cannot fit 256×16 GB (params+
    # moments+grads alone = 16 GB/dev); production config is pure-bf16
    # params with stochastic rounding (Gopher-style) — see DESIGN.md.
    "nemotron-4-340b": RunOverrides(microbatches=16, adam_dtype="bfloat16",
                                    param_dtype="bfloat16",
                                    decode_cache_layout="seq",
                                    remat_group=8),
    "gemma3-1b": RunOverrides(remat_group=2),
    "mamba2-780m": RunOverrides(microbatches=2, remat_group=8),
    "grok-1-314b": RunOverrides(microbatches=8, adam_dtype="bfloat16",
                                param_dtype="bfloat16",
                                remat_group=8),
    "llama4-scout-17b-a16e": RunOverrides(microbatches=4,
                                          decode_cache_layout="seq",
                                          remat_group=8),
    "whisper-small": RunOverrides(microbatches=2,
                                  decode_cache_layout="seq", remat_group=4),
    "jamba-v0.1-52b": RunOverrides(microbatches=4, remat_group=2),
}


def arch_module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return arch_module(arch_id).config()


def get_reduced(arch_id: str) -> ModelConfig:
    return arch_module(arch_id).reduced()


def get_overrides(arch_id: str) -> RunOverrides:
    return _OVERRIDES.get(arch_id, RunOverrides())


def long_context_ok(arch_id: str) -> bool:
    return getattr(arch_module(arch_id), "LONG_CONTEXT_OK", False)


def cells(arch_id: str) -> list[ShapeCell]:
    """All applicable shape cells for an arch."""
    mod = arch_module(arch_id)
    return [c for n, c in SHAPES.items() if applicable(mod, n)]
