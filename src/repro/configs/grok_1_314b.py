"""grok-1-314b [moe] — 8 experts top-2, GQA kv=8, attn logit softcap.
[hf:xai-org/grok-1]
"""
from repro.models.config import LayerKind, ModelConfig, MoEConfig

ARCH_ID = "grok-1-314b"
LONG_CONTEXT_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv=8, d_ff=32768,
        vocab=131072, pattern=(LayerKind(mlp="moe"),),
        moe=MoEConfig(n_experts=8, top_k=2),
        rope_theta=1e4, tie_embeddings=False,
        attn_logit_softcap=30.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, pattern=(LayerKind(mlp="moe"),),
        moe=MoEConfig(n_experts=4, top_k=2),
        rope_theta=1e4, tie_embeddings=False,
        attn_logit_softcap=30.0,
    )
