"""Assigned input-shape cells and applicability rules.

Every LM-family arch is paired with the same four shape cells. ``decode_*``
and ``long_*`` lower ``serve`` steps (one new token against a KV cache of
``seq``), not ``train_step``. ``long_500k`` requires sub-quadratic
attention and is skipped for pure full-attention archs (see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def applicable(arch_module, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return getattr(arch_module, "LONG_CONTEXT_OK", False)
    return True
