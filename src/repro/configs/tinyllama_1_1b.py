"""tinyllama-1.1b [dense] — llama2-arch small. [arXiv:2401.02385; hf]"""
from repro.models.config import LayerKind, ModelConfig

ARCH_ID = "tinyllama-1.1b"
LONG_CONTEXT_OK = False  # pure full attention → skip long_500k


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv=4, d_ff=5632,
        vocab=32000, pattern=(LayerKind(),),
        rope_theta=1e4, tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, pattern=(LayerKind(),),
        rope_theta=1e4, tie_embeddings=False,
    )
