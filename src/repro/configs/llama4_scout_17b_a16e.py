"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert, early
fusion. [hf:meta-llama/Llama-4-Scout-17B-16E]

Early-fusion multimodality is a stub per the assignment (text tokens in
input_specs); the MoE backbone is what is exercised.
"""
from repro.models.config import LayerKind, ModelConfig, MoEConfig

ARCH_ID = "llama4-scout-17b-a16e"
LONG_CONTEXT_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=8192,
        vocab=202048, pattern=(LayerKind(mlp="moe"),),
        moe=MoEConfig(n_experts=16, top_k=1, shared_expert=True),
        rope_theta=5e5, tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, pattern=(LayerKind(mlp="moe"),),
        moe=MoEConfig(n_experts=4, top_k=1, shared_expert=True),
        rope_theta=5e5, tie_embeddings=False,
    )
