"""mamba2-780m [ssm] — attention-free SSD (state-space duality), state 128.
[arXiv:2405.21060]

long_500k RUNS: decode state is constant-size (no KV cache at all).
"""
from repro.models.config import LayerKind, ModelConfig, SSMConfig

ARCH_ID = "mamba2-780m"
LONG_CONTEXT_OK = True

_SSM = LayerKind(mixer="ssm", mlp="none")


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=48, d_model=1536, n_heads=24, n_kv=24, d_ff=0,
        vocab=50280, pattern=(_SSM,),
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1,
                      conv_width=4, chunk=256),
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced", family="ssm",
        n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=0,
        vocab=512, pattern=(_SSM,),
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, n_groups=1,
                      conv_width=4, chunk=32),
        tie_embeddings=True,
    )
