"""gemma3-1b [dense] — 5:1 local:global sliding window (512), kv=1,
head_dim 256, qk-norm, sandwich norms, 262k vocab. [hf:google/gemma-3-1b-pt]

long_500k RUNS for this arch: 5/6 of layers are window-512 local; the
global layers decode O(L) per token with a sequence-sharded KV cache.
"""
from repro.models.config import LayerKind, ModelConfig

ARCH_ID = "gemma3-1b"
LONG_CONTEXT_OK = True

_LOCAL = LayerKind(window=512, global_rope=False)
_GLOBAL = LayerKind(window=None, global_rope=True)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv=1, d_ff=6912,
        vocab=262144, head_dim=256,
        pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
        rope_theta=1e6, rope_theta_local=1e4,
        qk_norm=True, sandwich_norm=True, norm_plus_one=True,
        embed_scale=True, tie_embeddings=True, norm_eps=1e-6,
    )


def reduced() -> ModelConfig:
    # 8 layers = 1 full cycle (6) + tail (2) → exercises the tail path
    return ModelConfig(
        name=ARCH_ID + "-reduced", family="dense",
        n_layers=8, d_model=64, n_heads=4, n_kv=1, d_ff=128,
        vocab=512, head_dim=16,
        pattern=(LayerKind(window=16, global_rope=False),) * 5 + (_GLOBAL,),
        rope_theta=1e6, rope_theta_local=1e4,
        qk_norm=True, sandwich_norm=True, norm_plus_one=True,
        embed_scale=True, tie_embeddings=True, norm_eps=1e-6,
    )
