"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887; hf]

Layer pattern (period 8, offsets from the HF config): attention at offset
4, MoE MLP at odd offsets. Deviation noted in DESIGN.md: SSM layers use
our Mamba2 SSD block (d_state 16) instead of mamba-1 — SSD subsumes it
and shares the Pallas kernel.

long_500k RUNS: only 4/32 layers keep a KV cache.
"""
from repro.models.config import LayerKind, ModelConfig, MoEConfig, SSMConfig

ARCH_ID = "jamba-v0.1-52b"
LONG_CONTEXT_OK = True


def _pattern(window=None):
    kinds = []
    for i in range(8):
        mixer = "attn" if i == 4 else "ssm"
        mlp = "moe" if i % 2 == 1 else "swiglu"
        kinds.append(LayerKind(mixer=mixer, mlp=mlp, window=window))
    return tuple(kinds)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
        vocab=65536, pattern=_pattern(),
        moe=MoEConfig(n_experts=16, top_k=2),
        ssm=SSMConfig(d_state=16, head_dim=64, expand=2, n_groups=1,
                      conv_width=4, chunk=256),
        rope_theta=1e4, tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, pattern=_pattern(),
        moe=MoEConfig(n_experts=4, top_k=2),
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, n_groups=1,
                      conv_width=4, chunk=32),
        rope_theta=1e4, tie_embeddings=False,
    )
