"""llava-next-mistral-7b [vlm] — mistral-7B backbone, anyres patch tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

The vision tower + anyres tiling is a STUB per the assignment:
``input_specs()`` supplies precomputed patch/text embeddings [B, S, d];
the backbone below is the transformer that consumes them.
"""
from repro.models.config import LayerKind, ModelConfig

ARCH_ID = "llava-next-mistral-7b"
LONG_CONTEXT_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
        vocab=32000, pattern=(LayerKind(),),
        rope_theta=1e6, tie_embeddings=False, frontend="patches",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced", family="vlm",
        n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, pattern=(LayerKind(),),
        rope_theta=1e6, tie_embeddings=False, frontend="patches",
    )
