"""command-r-35b [dense] — GQA, no-bias, parallel residual blocks, tied
embeddings, 256k vocab. [hf:CohereForAI/c4ai-command-r-v01]

Deviation noted in DESIGN.md: Cohere uses (non-RMS) LayerNorm; we use
RMSNorm uniformly across the framework.
"""
from repro.models.config import LayerKind, ModelConfig

ARCH_ID = "command-r-35b"
LONG_CONTEXT_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv=8, d_ff=22528,
        vocab=256000, pattern=(LayerKind(),),
        rope_theta=8e6, tie_embeddings=True, parallel_block=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, pattern=(LayerKind(),),
        rope_theta=8e6, tie_embeddings=True, parallel_block=True,
    )
