"""whisper-small [audio] — encoder-decoder, conv frontend (STUB).
[arXiv:2212.04356]

``input_specs()`` provides precomputed frame embeddings [B, S, d] (the
conv1d×2 + sinusoidal-position frontend is stubbed per the assignment).
Deviations noted in DESIGN.md: RoPE instead of learned absolute positions.
"""
from repro.models.config import LayerKind, ModelConfig

ARCH_ID = "whisper-small"
LONG_CONTEXT_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072,
        vocab=51865, pattern=(LayerKind(mlp="gelu"),),
        encoder_layers=12, cross_attention=True,
        tie_embeddings=True, frontend="audio",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
        vocab=512, pattern=(LayerKind(mlp="gelu"),),
        encoder_layers=2, cross_attention=True,
        tie_embeddings=True, frontend="audio",
    )
