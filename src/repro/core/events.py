"""OpenCL-style events: dependency handles with profiling timestamps."""
from __future__ import annotations

from typing import Callable, Optional

QUEUED, SUBMITTED, RUNNING, COMPLETE, ERROR = (
    "queued", "submitted", "running", "complete", "error")

_next_id = 0


class Event:
    """Dependency handle with profiling timestamps.

    A plain ``__slots__`` class rather than a dataclass: the dispatch
    hot path allocates one per command, and the generated dataclass
    ``__init__`` (15 keyword defaults + two default factories) showed up
    as a top-ten cost in the dispatch profile. Field set and semantics
    are unchanged.

    Lifecycle refcounting (runtime table retirement): holders are the
    client (until it observes completion) and every not-yet-resolved
    dependent command. When the count drops to zero on a finished
    event, ``on_retire`` fires once so the runtime can drop the event
    from its lookup tables. The Event object itself is never mutated by
    retirement — user code can keep reading timestamps."""

    __slots__ = ("command", "server", "status", "user", "id",
                 "t_queued", "t_submitted", "t_start", "t_end",
                 "t_client_ack", "deadline", "error", "data_version",
                 "_callbacks", "_refs", "retired", "on_retire")

    def __init__(self, command=None, server: Optional[str] = None,
                 status: str = QUEUED, user: bool = False):
        global _next_id
        _next_id += 1
        self.id = _next_id
        self.command = command
        self.server = server                # executing server ('' = client)
        self.status = status
        self.user = user                    # user event (client-controlled)
        # profiling (sim seconds)
        self.t_queued = 0.0
        self.t_submitted = 0.0
        self.t_start = 0.0
        self.t_end = 0.0
        self.t_client_ack = 0.0   # when the client observed completion
        # absolute SLO deadline (t_queued + tenant SLO) stamped by the
        # runtime for tenants with a latency target; None otherwise
        self.deadline: Optional[float] = None
        self.error: Optional[str] = None
        # for ReadBuffer events: the buffer's content generation at the
        # moment the bytes left the server (consumers of the read — e.g.
        # the staged naive-migration write — must judge staleness against
        # this, not against the version at delivery time)
        self.data_version: Optional[int] = None
        self._callbacks = None    # lazily allocated list
        self._refs = 0
        self.retired = False
        self.on_retire: Optional[Callable] = None

    def retain(self):
        self._refs += 1

    def release(self):
        self._refs -= 1
        if self._refs <= 0 and not self.retired \
                and (self.status == COMPLETE or self.status == ERROR):
            self.retired = True
            cb, self.on_retire = self.on_retire, None
            if cb is not None:
                cb(self)

    def _maybe_retire(self):
        if self._refs <= 0 and not self.retired \
                and self.status in (COMPLETE, ERROR):
            self.retired = True
            cb, self.on_retire = self.on_retire, None
            if cb is not None:
                cb(self)

    def on_complete(self, fn: Callable):
        if self.status == COMPLETE:
            fn(self)
        elif self._callbacks is None:
            self._callbacks = [fn]
        else:
            self._callbacks.append(fn)

    def complete(self, t: float):
        self.status = COMPLETE
        self.t_end = t
        cbs = self._callbacks
        if cbs is not None:
            self._callbacks = None
            for fn in cbs:
                fn(self)
        self._maybe_retire()

    def fail(self, t: float, reason: str):
        self.status = ERROR
        self.error = reason
        self.t_end = t
        cbs = self._callbacks
        if cbs is not None:
            self._callbacks = None
            for fn in cbs:
                fn(self)
        self._maybe_retire()

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    @property
    def latency(self) -> float:
        """Client-observed: queued → complete."""
        return self.t_end - self.t_queued

    def __repr__(self):  # debugging/error messages only
        return (f"Event(id={self.id}, status={self.status!r}, "
                f"server={self.server!r}, command={self.command!r})")
