"""OpenCL-style events: dependency handles with profiling timestamps."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional

_ids = itertools.count(1)

QUEUED, SUBMITTED, RUNNING, COMPLETE, ERROR = (
    "queued", "submitted", "running", "complete", "error")


@dataclasses.dataclass
class Event:
    command: object = None
    server: Optional[str] = None          # executing server ('' = client)
    status: str = QUEUED
    user: bool = False                    # user event (client-controlled)
    id: int = dataclasses.field(default_factory=lambda: next(_ids))
    # profiling (sim seconds)
    t_queued: float = 0.0
    t_submitted: float = 0.0
    t_start: float = 0.0
    t_end: float = 0.0
    t_client_ack: float = 0.0   # when the client observed completion
    error: Optional[str] = None
    _callbacks: list = dataclasses.field(default_factory=list)

    def on_complete(self, fn: Callable):
        if self.status == COMPLETE:
            fn(self)
        else:
            self._callbacks.append(fn)

    def complete(self, t: float):
        self.status = COMPLETE
        self.t_end = t
        cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            fn(self)

    def fail(self, t: float, reason: str):
        self.status = ERROR
        self.error = reason
        self.t_end = t
        cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            fn(self)

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    @property
    def latency(self) -> float:
        """Client-observed: queued → complete."""
        return self.t_end - self.t_queued
