"""OpenCL-style events: dependency handles with profiling timestamps."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional

_ids = itertools.count(1)

QUEUED, SUBMITTED, RUNNING, COMPLETE, ERROR = (
    "queued", "submitted", "running", "complete", "error")


@dataclasses.dataclass
class Event:
    command: object = None
    server: Optional[str] = None          # executing server ('' = client)
    status: str = QUEUED
    user: bool = False                    # user event (client-controlled)
    id: int = dataclasses.field(default_factory=lambda: next(_ids))
    # profiling (sim seconds)
    t_queued: float = 0.0
    t_submitted: float = 0.0
    t_start: float = 0.0
    t_end: float = 0.0
    t_client_ack: float = 0.0   # when the client observed completion
    error: Optional[str] = None
    # for ReadBuffer events: the buffer's content generation at the
    # moment the bytes left the server (consumers of the read — e.g. the
    # staged naive-migration write — must judge staleness against this,
    # not against the version at delivery time)
    data_version: Optional[int] = None
    _callbacks: list = dataclasses.field(default_factory=list)
    # ---- lifecycle refcounting (runtime table retirement) ----
    # Holders: the client (until it observes completion) and every
    # not-yet-resolved dependent command. When the count drops to zero on
    # a finished event, ``on_retire`` fires once so the runtime can drop
    # the event from its lookup tables. The Event object itself is never
    # mutated by retirement — user code can keep reading timestamps.
    _refs: int = 0
    retired: bool = False
    on_retire: Optional[Callable] = None

    def retain(self):
        self._refs += 1

    def release(self):
        self._refs -= 1
        self._maybe_retire()

    def _maybe_retire(self):
        if self._refs <= 0 and not self.retired \
                and self.status in (COMPLETE, ERROR):
            self.retired = True
            cb, self.on_retire = self.on_retire, None
            if cb is not None:
                cb(self)

    def on_complete(self, fn: Callable):
        if self.status == COMPLETE:
            fn(self)
        else:
            self._callbacks.append(fn)

    def complete(self, t: float):
        self.status = COMPLETE
        self.t_end = t
        cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            fn(self)
        self._maybe_retire()

    def fail(self, t: float, reason: str):
        self.status = ERROR
        self.error = reason
        self.t_end = t
        cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            fn(self)
        self._maybe_retire()

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    @property
    def latency(self) -> float:
        """Client-observed: queued → complete."""
        return self.t_end - self.t_queued
