"""Probe-driven SLO admission control (DESIGN.md §10).

A tenant that declares a latency target (``ClientRuntime(slo_ms=)``)
on a cluster with admission enabled is screened at attach time. The
controller spends no simulated time and mutates nothing: it reads the
same live telemetry the placement engine trusts —

* ``PlacementEngine.queue_depth``: run-queue backlog plus the
  in-service remainder per server, in device-seconds;
* ``PlacementEngine.transfer_eta``: access-link wire time (incl. NIC
  ingress queueing) for the tenant's declared per-frame working set;
* egress-NIC occupancy (``NIC.queue_seconds``) for the result's return
  leg;
* the PR 8 windowed per-class p99 latency histograms, fed back by the
  runtime's client-ack path.

and predicts the best-case end-to-end latency a new frame would see:
``min over ACTIVE servers of (queue_depth + transfer_eta + cost_s +
nic_egress)``. Against the requested SLO this yields an
``AdmissionDecision``:

* **admit** — predicted latency fits inside ``headroom * slo``;
* **degrade** — it fits inside ``headroom * slo * degrade_factor``:
  the tenant is admitted at the relaxed target ``slo *
  degrade_factor`` (its deadlines, class accounting, and violation
  gates all use the degraded target — that is the contract it got);
* **reject** — the cluster cannot hold even the degraded target, or
  an already-admitted class is currently blowing its windowed p99
  (taking more load while in breach only deepens the breach).

Tail-probability constraints per "Latency and Reliability-Aware Task
Offloading and Resource Allocation for MEC" (arXiv:1710.00590): the
p99-vs-SLO guard is their reliability constraint in windowed form.
"""
from __future__ import annotations

from typing import Optional

from repro.core.buffers import Buffer
from repro.core.membership import ACTIVE
from repro.core.trace import MetricsRegistry

ADMIT = "admit"
DEGRADE = "degrade"
REJECT = "reject"

_INF = float("inf")

# knob -> (default, validator description)
_KNOB_DEFAULTS = {
    "window_s": 0.25,       # sliding window for the p99 breach guard
    "headroom": 0.5,        # fraction of the SLO prediction may consume
    "degrade_factor": 2.0,  # SLO multiplier for degraded admission
}


class AdmissionDecision:
    """Outcome of one admission screening. ``slo_s`` is the *effective*
    target the tenant runs under (degraded when status == degrade);
    ``predicted_s`` the controller's best-case latency estimate."""

    __slots__ = ("status", "tenant", "t", "requested_slo_s", "slo_s",
                 "predicted_s", "reason")

    def __init__(self, status: str, tenant: str, t: float,
                 requested_slo_s: float, slo_s: Optional[float],
                 predicted_s: float, reason: str):
        self.status = status
        self.tenant = tenant
        self.t = t
        self.requested_slo_s = requested_slo_s
        self.slo_s = slo_s
        self.predicted_s = predicted_s
        self.reason = reason

    def __repr__(self):
        return (f"AdmissionDecision({self.status}, tenant={self.tenant!r},"
                f" predicted={self.predicted_s * 1e3:.3f}ms,"
                f" reason={self.reason!r})")


class AdmissionRejected(RuntimeError):
    """Raised by ClientRuntime() when admission control rejects the
    tenant. Carries the ``AdmissionDecision`` for inspection."""

    def __init__(self, tenant: str, decision: AdmissionDecision):
        super().__init__(
            f"tenant {tenant!r} rejected by admission control: "
            f"{decision.reason}")
        self.decision = decision


def _validate_opts(opts: Optional[dict]) -> dict:
    out = dict(_KNOB_DEFAULTS)
    if opts is None:
        return out
    if not isinstance(opts, dict):
        raise ValueError(
            f"admission opts must be a dict, got {type(opts).__name__}")
    unknown = sorted(set(opts) - set(_KNOB_DEFAULTS))
    if unknown:
        raise ValueError(
            f"unknown admission opts: {unknown} "
            f"(allowed: {sorted(_KNOB_DEFAULTS)})")
    for k, v in opts.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)) \
                or not v > 0.0:
            raise ValueError(
                f"admission opts[{k!r}] must be a positive number, "
                f"got {v!r}")
    out.update(opts)
    if out["headroom"] > 1.0:
        raise ValueError(
            f"admission headroom must be <= 1.0, got {out['headroom']!r}")
    if out["degrade_factor"] < 1.0:
        raise ValueError(
            f"admission degrade_factor must be >= 1.0, "
            f"got {out['degrade_factor']!r}")
    return out


class AdmissionController:
    """One per cluster (``Cluster(admission=...)``). Screens SLO tenants
    at attach time (``request``) and accumulates per-class latency /
    violation telemetry at client-ack time (``observe``)."""

    def __init__(self, cluster, opts: Optional[dict] = None):
        opts = _validate_opts(opts)
        self.cluster = cluster
        self.window_s = opts["window_s"]
        self.headroom = opts["headroom"]
        self.degrade_factor = opts["degrade_factor"]
        self.metrics = MetricsRegistry()
        self.class_slo: dict = {}     # class key -> effective slo_s
        self.decisions: list = []     # every AdmissionDecision, in order
        self.counts = {ADMIT: 0, DEGRADE: 0, REJECT: 0}

    # -- probe math ----------------------------------------------------

    def predict_latency(self, rt, cost_s: float, nbytes: int) -> float:
        """Best-case end-to-end seconds for one frame of ``cost_s``
        device work over an ``nbytes`` input, across the tenant's ACTIVE
        servers: device backlog + access-link transfer ETA (incl.
        ingress NIC) + kernel cost + egress-NIC occupancy for the
        return leg. +inf when the tenant can reach no ACTIVE server.

        The backlog term is scheduler-aware: under a deadline-ordered
        policy (edf/llf) a new SLO command overtakes every deadline-less
        command, so only the deadline-carrying queue
        (``queued_slo_seconds``) plus the in-service remainders count —
        a cluster saturated with best-effort work still admits SLO
        tenants it can serve. Deadline-blind policies (fifo/drr) make
        the command wait behind everything: full ``queue_depth``."""
        cluster = self.cluster
        engine = cluster.placement
        now = cluster.clock.now
        deadline_aware = cluster.scheduler_policy in ("edf", "llf")
        probe = None
        if nbytes > 0:
            # a client-held probe buffer routes transfer_eta down the
            # access-link branch — the same arithmetic a real first
            # frame's input write would pay
            probe = Buffer(nbytes=int(nbytes))
            probe.valid_on = {"client"}
        best = _INF
        for s in sorted(rt.servers):
            host = cluster.hosts.get(s)
            if host is None or host.state != ACTIVE:
                continue
            if deadline_aware:
                eta = engine.queued_slo_seconds(s)
                for dev in host.devices.values():
                    rem = dev._busy_until - now
                    if rem > 0.0:
                        eta += rem
            else:
                eta = engine.queue_depth(s)
            eta += cost_s
            if probe is not None:
                eta += engine.transfer_eta(rt, probe, s)
            nic = host.nic
            if nic is not None:
                eta += nic.queue_seconds(now)
            if eta < best:
                best = eta
        return best

    def breached_class(self, now: float) -> Optional[str]:
        """Class key of an admitted SLO class whose windowed p99 latency
        currently exceeds its effective SLO, or None. Deterministic:
        classes are scanned in sorted order."""
        t0 = now - self.window_s
        for key in sorted(self.class_slo):
            slo = self.class_slo[key]
            h = self.metrics.hist("slo_latency", key)
            if h.samples and h.percentile(99, t0, now) > slo:
                return key
        return None

    # -- decision ------------------------------------------------------

    def request(self, rt) -> AdmissionDecision:
        """Screen ``rt`` (which has ``_slo_s`` set). Pure telemetry
        reads; records and returns the decision."""
        now = self.cluster.clock.now
        slo = rt._slo_s
        probe = rt._slo_probe or {}
        predicted = self.predict_latency(
            rt, probe.get("cost_s", 0.0), probe.get("nbytes", 0))

        breached = self.breached_class(now)
        if breached is not None:
            decision = AdmissionDecision(
                REJECT, rt.name, now, slo, None, predicted,
                f"admitted class {breached} over its windowed p99 SLO")
        elif predicted <= self.headroom * slo:
            decision = AdmissionDecision(
                ADMIT, rt.name, now, slo, slo, predicted,
                f"predicted {predicted * 1e3:.3f} ms within "
                f"{self.headroom:g}x of {slo * 1e3:g} ms SLO")
        elif predicted <= self.headroom * slo * self.degrade_factor:
            decision = AdmissionDecision(
                DEGRADE, rt.name, now, slo, slo * self.degrade_factor,
                predicted,
                f"predicted {predicted * 1e3:.3f} ms holds only the "
                f"{self.degrade_factor:g}x-degraded target")
        else:
            decision = AdmissionDecision(
                REJECT, rt.name, now, slo, None, predicted,
                f"predicted {predicted * 1e3:.3f} ms cannot hold even "
                f"the {self.degrade_factor:g}x-degraded target")
        self.decisions.append(decision)
        self.counts[decision.status] += 1
        if decision.slo_s is not None:
            key = _class_key(decision.slo_s)
            self.class_slo.setdefault(key, decision.slo_s)
        return decision

    # -- feedback ------------------------------------------------------

    def observe(self, class_key: str, t: float, latency: float,
                violated: bool) -> None:
        """Client-ack feedback from the runtime: one completed command's
        end-to-end latency, keyed by the tenant's SLO class."""
        m = self.metrics
        m.observe("slo_latency", class_key, t, latency)
        m.observe("slo_violation", class_key, t, 1.0 if violated else 0.0)

    def violation_rate(self, class_key: str,
                       t0: Optional[float] = None,
                       t1: Optional[float] = None) -> float:
        return self.metrics.rate("slo_violation", class_key, t0, t1)

    def stats(self) -> dict:
        out = {
            "admitted": self.counts[ADMIT],
            "degraded": self.counts[DEGRADE],
            "rejected": self.counts[REJECT],
            "classes": {},
        }
        for key in sorted(self.class_slo):
            h = self.metrics.hist("slo_latency", key)
            out["classes"][key] = {
                "slo_ms": self.class_slo[key] * 1e3,
                "commands": len(h.samples),
                "p99_ms": h.percentile(99) * 1e3,
                "violation_rate": self.violation_rate(key),
            }
        return out


def _class_key(slo_s: float) -> str:
    """SLO class label: tenants sharing an effective target form one
    class (degraded tenants land in the relaxed class they actually
    got)."""
    return f"{slo_s * 1e3:g}ms"
