"""Discrete-event simulation substrate for the PoCL-R runtime.

The paper's daemon is built around blocking-socket reader/writer threads;
we adapt that to a deterministic event-loop driven by a logical clock
(DESIGN.md §2, adaptation note 1). Functional compute (real JAX calls)
executes in causal order as the simulated clock reaches each kernel's
start time, so timing semantics and numerical semantics stay unified and
the whole runtime is testable on one CPU device.

Link bandwidth is modeled with per-link FIFO serialization: a message
occupies the link for ``bytes / bandwidth`` after the sender's protocol
overheads, then arrives ``latency`` later. This reproduces the paper's
observation that routing 12 Gb/s of inter-server traffic through the
client is "impractical at best" (§7.2): the client's single link becomes
the contended FIFO.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional


class SimClock:
    def __init__(self):
        self._q: list = []
        self._seq = itertools.count()
        self.now = 0.0

    def schedule(self, delay: float, fn: Callable, *args):
        t = self.now + max(delay, 0.0)
        heapq.heappush(self._q, (t, next(self._seq), fn, args))
        return t

    def schedule_at(self, t: float, fn: Callable, *args):
        heapq.heappush(self._q, (max(t, self.now), next(self._seq), fn, args))

    def run(self, until: Optional[float] = None) -> float:
        while self._q:
            t, _, fn, args = self._q[0]
            if until is not None and t > until:
                break
            heapq.heappop(self._q)
            self.now = t
            fn(*args)
        return self.now


class Link:
    """Point-to-point link with FIFO serialization + propagation latency.

    ``latency`` is one-way propagation (s); ``bandwidth`` in B/s.
    """

    def __init__(self, clock: SimClock, latency: float, bandwidth: float,
                 name: str = ""):
        self.clock = clock
        self.latency = latency
        self.bandwidth = bandwidth
        self.name = name
        self._busy_until = 0.0
        self.bytes_sent = 0
        self.up = True

    def rtt(self) -> float:
        return 2.0 * self.latency

    def send(self, nbytes: float, on_delivered: Callable,
             serialize_overhead: float = 0.0):
        """Queue a message; ``on_delivered`` fires at the receiver."""
        if not self.up:
            return None  # dropped — sender times out via its own logic
        start = max(self.clock.now, self._busy_until) + serialize_overhead
        tx = nbytes / self.bandwidth if self.bandwidth > 0 else 0.0
        self._busy_until = start + tx
        self.bytes_sent += nbytes
        arrive = self._busy_until + self.latency
        self.clock.schedule_at(arrive, on_delivered)
        return arrive


class DeviceSim:
    """A compute device with a busy-until timeline and an analytic or
    measured kernel cost model."""

    def __init__(self, clock: SimClock, name: str,
                 flops: float = 10e12, mem_bw: float = 500e9):
        self.clock = clock
        self.name = name
        self.flops = flops
        self.mem_bw = mem_bw
        self._busy_until = 0.0
        self.busy_time = 0.0

    def kernel_cost(self, flop_count: float = 0.0, bytes_moved: float = 0.0,
                    duration: Optional[float] = None) -> float:
        if duration is not None:
            return duration
        return max(flop_count / self.flops if self.flops else 0.0,
                   bytes_moved / self.mem_bw if self.mem_bw else 0.0)

    def execute(self, cost: float, on_done: Callable) -> tuple[float, float]:
        """Schedule a kernel; returns (start, end) sim times."""
        start = max(self.clock.now, self._busy_until)
        end = start + cost
        self._busy_until = end
        self.busy_time += cost
        self.clock.schedule_at(end, on_done)
        return start, end

    def utilization(self, horizon: float) -> float:
        return self.busy_time / horizon if horizon > 0 else 0.0
