"""Discrete-event simulation substrate for the PoCL-R runtime.

The paper's daemon is built around blocking-socket reader/writer threads;
we adapt that to a deterministic event-loop driven by a logical clock
(DESIGN.md §2, adaptation note 1). Functional compute (real JAX calls)
executes in causal order as the simulated clock reaches each kernel's
start time, so timing semantics and numerical semantics stay unified and
the whole runtime is testable on one CPU device.

Link bandwidth is modeled with per-link FIFO serialization: a message
occupies the link for ``bytes / bandwidth`` after the sender's protocol
overheads, then arrives ``latency`` later. This reproduces the paper's
observation that routing 12 Gb/s of inter-server traffic through the
client is "impractical at best" (§7.2): the client's single link becomes
the contended FIFO.

Bulk payloads use the chunked cut-through path (``Link.send_chunked``,
DESIGN.md §3): the transport splits the payload at its natural
granularity (TCP send buffer / HCA staging fragment) and the sender-side
copy, wire serialization, and receiver-side copy pipeline per chunk, so
a large migration costs ~``max(copy, wire)`` instead of their sum.
"""
from __future__ import annotations

import heapq
from typing import Callable, Optional


class HeapSimClock:
    """Reference logical clock over a binary heap — the original engine.

    Kept as the correctness oracle for the calendar-queue ``SimClock``:
    the property tests in ``tests/test_event_engine.py`` assert both
    engines pop identical ``(t, seq)`` sequences for arbitrary schedules.
    The hot methods avoid per-call allocation beyond the heap entry
    itself: a plain int sequence counter, module functions bound once,
    and ``run`` keeps the queue and pop in locals."""

    __slots__ = ("_q", "_seq", "now", "_push")

    def __init__(self):
        self._q: list = []
        self._seq = 0
        self.now = 0.0
        self._push = heapq.heappush

    def schedule(self, delay: float, fn: Callable, *args):
        t = self.now + delay if delay > 0.0 else self.now
        self._seq = seq = self._seq + 1
        self._push(self._q, (t, seq, fn, args))
        return t

    def schedule_at(self, t: float, fn: Callable, *args):
        now = self.now
        if t < now:
            t = now
        self._seq = seq = self._seq + 1
        self._push(self._q, (t, seq, fn, args))
        return t

    def run(self, until: Optional[float] = None) -> float:
        q = self._q
        pop = heapq.heappop
        if until is None:
            while q:
                t, _, fn, args = pop(q)
                self.now = t
                fn(*args)
        else:
            while q and q[0][0] <= until:
                t, _, fn, args = pop(q)
                self.now = t
                fn(*args)
        return self.now


class SimClock:
    """Logical clock over a calendar queue (bucketed timeline).

    The event mix every benchmark produces is near-future dominated:
    almost all of the O(100k) pending-at-peak events land within a few
    milliseconds of ``now``. A binary heap pays O(log n) tuple
    comparisons per push *and* per pop against that whole backlog; the
    calendar queue instead hashes each event into one of ``_NBUCKETS``
    fixed-width time buckets covering a sliding window
    ``[base, base + _NBUCKETS * width)``:

    * the *current* bucket (index ``_cur``) is kept as a heap — it is
      heapified once when the cursor lands on it, and any insert at or
      behind the cursor (including past-deadline clamps and float
      truncation artifacts) goes through ``heappush`` into it;
    * future in-window buckets are plain lists — insert is one float
      multiply plus ``list.append``;
    * events beyond the window go to an overflow heap and are pulled
      forward bucket-by-bucket when the window advances past them.

    Ordering is bit-exact with the heap engine: the bucket index
    ``int((t - base) * inv_width)`` is monotone non-decreasing in ``t``,
    so the bucket partition refines the global ``(t, seq)`` order —
    equal timestamps always share a bucket, and draining the current
    bucket's heap before advancing reproduces heapq's total order
    exactly. ``seq`` assignment (one per schedule call) is identical.

    Width retunes itself at window wraps: if a whole window went by with
    far fewer events than buckets (cursor scans dominated), the width
    doubles toward the observed event spacing; if the current backlog
    would overflow the window, it grows to span it. Retuning only moves
    bucket *boundaries*, never the (t, seq) order, so it is invisible to
    simulation results. See DESIGN.md §8."""

    __slots__ = ("now", "_seq", "_base", "_width", "_inv", "_cur",
                 "_buckets", "_overflow", "_n", "_popped")

    _MAX_WIDTH = 1e3

    def __init__(self, nbuckets: int = 1024):
        self.now = 0.0
        self._seq = 0
        self._base = 0.0
        self._width = 1e-5          # ~10 µs: typical inter-event gap here
        self._inv = 1.0 / self._width
        self._cur = 0
        self._n = nbuckets
        self._buckets = [[] for _ in range(nbuckets)]
        self._overflow: list = []   # heap of (t, seq, fn, args)
        self._popped = 0            # ~events drained since last wrap

    # -- scheduling -----------------------------------------------------
    # No per-event size bookkeeping: emptiness is detected by `_advance`
    # (a full scan finding nothing with an empty overflow), so the
    # per-event cost here is one index computation plus a list append.

    def schedule(self, delay: float, fn: Callable, *args):
        t = self.now + delay if delay > 0.0 else self.now
        self._seq = seq = self._seq + 1
        idx = int((t - self._base) * self._inv)
        cur = self._cur
        if cur < idx < self._n:
            self._buckets[idx].append((t, seq, fn, args))
        elif idx <= cur:
            heapq.heappush(self._buckets[cur], (t, seq, fn, args))
        else:
            heapq.heappush(self._overflow, (t, seq, fn, args))
        return t

    def schedule_at(self, t: float, fn: Callable, *args):
        now = self.now
        if t < now:
            t = now
        self._seq = seq = self._seq + 1
        idx = int((t - self._base) * self._inv)
        cur = self._cur
        if cur < idx < self._n:
            self._buckets[idx].append((t, seq, fn, args))
        elif idx <= cur:
            heapq.heappush(self._buckets[cur], (t, seq, fn, args))
        else:
            heapq.heappush(self._overflow, (t, seq, fn, args))
        return t

    def pending(self) -> int:
        """Number of scheduled-but-undrained events (diagnostics/tests
        only — the hot path never tracks this)."""
        return sum(len(b) for b in self._buckets) + len(self._overflow)

    # -- window management ----------------------------------------------

    def _advance(self) -> int:
        """Move the cursor to the next non-empty bucket (heapifying it),
        wrapping the window — and pulling overflow forward — as needed.
        Returns the new cursor index, or ``-1`` if the queue is empty
        (the window is then re-anchored at ``now`` for future inserts).
        Pre-condition: the current bucket is empty."""
        buckets = self._buckets
        n = self._n
        cur = self._cur
        while True:
            cur += 1
            if cur >= n:
                nxt = self._wrap()
                if nxt >= 0:
                    self._cur = nxt
                    self._popped += len(buckets[nxt])
                    return nxt
                if nxt == -1:   # empty queue
                    self._cur = 0
                    return -1
                cur = -1        # rounding edge: rescan → next wrap jumps
                continue
            b = buckets[cur]
            if b:
                heapq.heapify(b)
                self._cur = cur
                self._popped += len(b)
                return cur

    def _wrap(self):
        """Advance the window one span (jumping over dead spans and
        retuning the width as needed), refill buckets from overflow, and
        return the new cursor position — the first bucket holding an
        event, already heapified. Returns ``-1`` when the queue is empty
        (every bucket was empty and so is the overflow), or ``-2`` in
        the rare rounding edge where the overflow head computes to
        exactly bucket ``N``; the caller rescans and the next wrap jumps
        the base onto the head, which then lands at bucket 0.

        Pre-condition: every bucket is empty (the cursor scanned the
        whole window), so all pending events live in the overflow heap
        and any ``base``/``width`` change is safe — rebucketing only
        moves partition boundaries, never the ``(t, seq)`` pop order."""
        n = self._n
        ovf = self._overflow
        width = self._width

        # Retune 1: the window drained with cursor scans dominating the
        # events actually popped → buckets far finer than the observed
        # event spacing. Widen toward the spacing.
        if self._popped < (n >> 3) and width < self._MAX_WIDTH:
            width = width * 8.0
            if width > self._MAX_WIDTH:
                width = self._MAX_WIDTH
            self._width = width
            self._inv = 1.0 / width
        self._popped = 0
        span = n * width

        if not ovf:
            # Every bucket is empty and so is the overflow → the queue
            # is empty. Re-anchor the window at `now` for whatever gets
            # scheduled next.
            self._base = self.now
            return -1

        head_t = ovf[0][0]
        new_base = self._base + span
        if head_t < new_base or head_t >= new_base + span:
            # Either the width grew past the head (a plain advance would
            # overshoot → negative bucket indices), or whole dead spans
            # sit ahead of it. Jump the window onto the head.
            new_base = head_t

        # Retune 2: the whole backlog lives in the overflow here (every
        # bucket is empty), so if it outnumbers the buckets the window
        # is too narrow for the live span. Widen so it spreads out.
        if len(ovf) > n:
            last_t = max(e[0] for e in ovf)
            need = (last_t - new_base) / (n - 1)
            if need > width:
                width = need * 1.5
                if width > self._MAX_WIDTH:
                    width = self._MAX_WIDTH
                self._width = width
                self._inv = 1.0 / width

        self._base = new_base
        inv = self._inv
        buckets = self._buckets
        pop = heapq.heappop
        first = n
        while ovf:
            idx = int((ovf[0][0] - new_base) * inv)
            if idx >= n:
                break
            buckets[idx].append(pop(ovf))
            if idx < first:
                first = idx
        if first == n:
            return -2
        b = buckets[first]
        heapq.heapify(b)
        return first

    # -- draining -------------------------------------------------------

    def _peek(self):
        """Earliest pending timestamp (positions the cursor on its
        bucket), or ``None`` when the queue is empty."""
        b = self._buckets[self._cur]
        if b:
            return b[0][0]
        nxt = self._advance()
        return self._buckets[nxt][0][0] if nxt >= 0 else None

    def run(self, until: Optional[float] = None) -> float:
        # Per-event work is identical to the heap engine's loop (pop,
        # stamp, call); bucket bookkeeping happens only on the (much
        # rarer) bucket transitions. The current bucket is re-read from
        # self._cur on each transition so reentrant run() calls from
        # inside a callback (the client-handshake pattern) stay safe.
        pop = heapq.heappop
        buckets = self._buckets
        if until is None:
            while True:
                b = buckets[self._cur]
                while b:
                    t, _, fn, args = pop(b)
                    self.now = t
                    fn(*args)
                if buckets[self._cur]:
                    continue    # reentrant run() moved the cursor
                if self._advance() < 0:
                    return self.now
        else:
            while True:
                b = buckets[self._cur]
                while b:
                    t = b[0][0]
                    if t > until:
                        return self.now
                    _, _, fn, args = pop(b)
                    self.now = t
                    fn(*args)
                if buckets[self._cur]:
                    continue    # reentrant run() moved the cursor
                if self._advance() < 0:
                    return self.now


class NIC:
    """Shared egress budget for one host (DESIGN.md §4).

    A server's peer and client links are separate point-to-point FIFOs,
    but physically they all drain through one NIC: pushing to N peers at
    once cannot exceed the port's line rate. ``NIC`` is a second
    serialization timeline every send *from* the owning host passes
    through, in tandem ahead of the link's own FIFO: the port takes the
    message when the sender is ready and the port is free (``bytes /
    nic.bandwidth`` of occupancy), then the link drains it cut-through
    (``bytes / link.bandwidth``), finishing no earlier than the port
    does. A message whose *link* is backed up never holds the port — one
    tenant's slow radio must not head-of-line block every other flow out
    of the server. A fat NIC feeding thin links (e.g. 25 Gb port, 1 Gb
    UE radios) therefore only staggers flow starts; a NIC at or below
    link rate becomes the contended resource — the shared-egress cost
    the pre-NIC model let a busy server skip entirely.

    The same class models the RECEIVE side (DESIGN.md §6): an
    ``ingress`` NIC sits in tandem *after* the link, mirroring the
    egress model — the port starts taking a message when its first byte
    arrives (wire start + propagation) and the port is free, occupies
    ``bytes / nic.bandwidth``, and delivery fires no earlier than the
    port drains. An uncontended ingress port at or above link rate is
    time-identical to no ingress NIC at all; N senders converging on
    one receiving host contend on it — the receiver-side cost the
    egress-only model let a popular destination skip.
    """

    __slots__ = ("bandwidth", "name", "_busy_until", "bytes_sent",
                 "busy_time", "trace", "trace_label")

    def __init__(self, bandwidth: float, name: str = ""):
        self.bandwidth = bandwidth
        self.name = name
        self._busy_until = 0.0
        self.bytes_sent = 0
        # cumulative port occupancy (s): the shared-egress cost a tenant
        # actually charges the host — the dedup benchmarks gate on its
        # reduction, not just wall clock (DESIGN.md §5)
        self.busy_time = 0.0
        # observability (DESIGN.md §9): when the owning cluster traces,
        # ``ServerHost`` points this at the Tracer so every busy_time
        # increment below is mirrored as an occupancy span. None keeps
        # the hot path a single slot load + branch.
        self.trace = None
        self.trace_label = name

    def queue_seconds(self, now: float) -> float:
        """Occupancy probe (DESIGN.md §6): how long a message handed to
        this port right now would wait before it starts draining."""
        q = self._busy_until - now
        return q if q > 0.0 else 0.0

class _Inflight:
    """A chunked transfer currently occupying the wire: registered by
    ``Link.send_chunked`` so a mid-flight ``up = False`` can drop the
    not-yet-delivered remainder instead of letting it arrive anyway."""

    __slots__ = ("wire_end", "on_dropped", "killed")

    def __init__(self, wire_end: float, on_dropped: Optional[Callable]):
        self.wire_end = wire_end
        self.on_dropped = on_dropped
        self.killed = False


class Link:
    """Point-to-point link with FIFO serialization + propagation latency.

    ``latency`` is one-way propagation (s); ``bandwidth`` in B/s. Sends
    may name an ``egress`` NIC (the sending host's shared port); see
    ``NIC`` for the tandem-serialization model.
    """

    __slots__ = ("clock", "latency", "bandwidth", "name", "_busy_until",
                 "bytes_sent", "_up", "_closed", "_inflight",
                 "_schedule_at", "trace", "trace_label")

    def __init__(self, clock: SimClock, latency: float, bandwidth: float,
                 name: str = ""):
        self.clock = clock
        self.latency = latency
        self.bandwidth = bandwidth
        self.name = name
        self._busy_until = 0.0
        self.bytes_sent = 0
        self._up = True
        self._closed = False
        self._inflight: list = []
        self._schedule_at = clock.schedule_at   # bound once: send is hot
        # observability (DESIGN.md §9/§11): a traced cluster points
        # these at its Tracer; wire-occupancy spans then record when
        # each message's serialization actually held the link — the
        # per-link ordering edge of the critical-path DAG. Untraced:
        # one slot load + branch per send, same gate as NIC.trace.
        self.trace = None
        self.trace_label = name

    @property
    def up(self) -> bool:
        return self._up

    @up.setter
    def up(self, value: bool):
        value = bool(value)
        if self._up and not value:
            self._kill_inflight()
        if value and self._closed:
            return                  # closed links never come back up
        self._up = value

    def _kill_inflight(self):
        """The link just went down: chunked transfers whose wire leg has
        not finished lose their remaining chunks — the receiver never
        assembles the payload, so delivery is cancelled and the sender's
        ``on_dropped`` fires now (deterministically, at fault time). A
        transfer already fully off the wire (only receiver-side copy
        left) still delivers."""
        now = self.clock.now
        keep = []
        for tok in self._inflight:
            if tok.wire_end > now:
                tok.killed = True
                if tok.on_dropped is not None:
                    self._schedule_at(now, tok.on_dropped)
            else:
                keep.append(tok)
        self._inflight = keep

    def rtt(self) -> float:
        return 2.0 * self.latency

    def queue_seconds(self, now: float) -> float:
        """Occupancy probe (DESIGN.md §6): how long a message queued on
        this link right now would wait before its wire leg starts."""
        q = self._busy_until - now
        return q if q > 0.0 else 0.0

    def close(self):
        """Administratively down (tenant detach, server death): later
        sends drop, mid-flight chunked transfers drop, and unlike a
        transient ``up = False`` fault nothing re-raises it."""
        if self._up:
            self._kill_inflight()
        self._up = False
        self._closed = True

    def send(self, nbytes: float, on_delivered: Callable,
             serialize_overhead: float = 0.0, egress: Optional[NIC] = None,
             ingress: Optional[NIC] = None, args: tuple = ()):
        """Queue a message; ``on_delivered(*args)`` fires at the
        receiver (``args`` lets hot senders pass a bound method plus
        arguments instead of allocating a closure per send).
        ``egress`` is the sending host's shared port (tandem ahead of
        the link), ``ingress`` the receiving host's (tandem after it) —
        see ``NIC`` for both models."""
        if not self._up:     # slot read, not the property: send is hot
            return None  # dropped — sender times out via its own logic
        start = self.clock.now
        bw = self.bandwidth
        if egress is None:
            busy = self._busy_until
            if busy > start:
                start = busy
            start += serialize_overhead
            busy = start + (nbytes / bw if bw > 0 else 0.0)
        else:
            # tandem NIC → link: the port takes the message once the
            # sender has staged it (``now + overhead``, as send_chunked
            # gates staging) and the port is free — a busy LINK must not
            # hold the shared NIC (that would let one tenant's slow
            # radio head-of-line block every other flow out of the
            # server). The wire leg then starts at the later of the
            # egress-free schedule and the NIC hand-off, so an
            # uncontended (fat) NIC is time-identical to ``egress=None``
            nic_start = start + serialize_overhead
            if egress._busy_until > nic_start:
                nic_start = egress._busy_until
            nic_bw = egress.bandwidth
            nic_end = nic_start + (nbytes / nic_bw if nic_bw > 0 else 0.0)
            egress._busy_until = nic_end
            egress.bytes_sent += nbytes
            egress.busy_time += nic_end - nic_start
            tr = egress.trace
            if tr is not None:
                # the identical float added to busy_time, in the same
                # order, so span sums reproduce the counter bit-exactly
                tr.nic_span(egress.trace_label, nic_start,
                            nic_end - nic_start)
            busy = self._busy_until
            if busy > start:
                start = busy
            start += serialize_overhead     # egress-free wire start
            if nic_start > start:
                start = nic_start
            busy = start + (nbytes / bw if bw > 0 else 0.0)
            if nic_end > busy:
                busy = nic_end     # NIC slower than the link: it governs
        ltr = self.trace
        if ltr is not None:
            # wire occupancy: serialization start → link freed (includes
            # a slower egress NIC pacing the tail, which held the link)
            ltr.link_span(self.trace_label, start, busy - start)
        self._busy_until = busy
        self.bytes_sent += nbytes
        arrive = busy + self.latency
        if ingress is not None:
            # tandem link → NIC on the receive side, mirroring egress:
            # the port starts taking the message when its first byte
            # lands (wire start + propagation) and the port is free;
            # delivery fires no earlier than the port drains. A free
            # ingress port at or above link rate changes nothing.
            in_start = start + self.latency
            if ingress._busy_until > in_start:
                in_start = ingress._busy_until
            in_bw = ingress.bandwidth
            in_end = in_start + (nbytes / in_bw if in_bw > 0 else 0.0)
            ingress._busy_until = in_end
            ingress.bytes_sent += nbytes
            ingress.busy_time += in_end - in_start
            tr = ingress.trace
            if tr is not None:
                tr.nic_span(ingress.trace_label, in_start,
                            in_end - in_start)
            if in_end > arrive:
                arrive = in_end
        self._schedule_at(arrive, on_delivered, *args)
        return arrive

    def send_chunked(self, chunks, on_delivered: Callable,
                     serialize_overhead: float = 0.0,
                     egress: Optional[NIC] = None,
                     ingress: Optional[NIC] = None,
                     on_dropped: Optional[Callable] = None,
                     chunk_arrivals: Optional[list] = None):
        """Pipelined (cut-through) multi-chunk transfer.

        ``chunks`` is a sequence of ``(sender_cpu, wire_bytes,
        receiver_cpu)`` tuples, one per chunk. Three timelines overlap:
        the sender CPU copies chunk i+1 while chunk i is on the wire,
        and the receiver CPU copies chunk i while chunk i+1 is on the
        wire, so a large transfer's latency approaches
        ``max(total_copy, total_wire)`` instead of their sum. The wire
        itself stays a FIFO: chunks occupy the link in order, after any
        message already queued, and ``_busy_until`` advances to the last
        chunk's wire end so later messages queue behind the whole
        transfer. ``on_delivered`` fires once, when the final chunk's
        receiver-side work completes; the entire schedule is computed
        analytically here, so one heap event covers the whole transfer
        regardless of chunk count.

        With a single chunk and an idle link this is time-identical to
        ``send`` + a receiver-side ``schedule`` (the store-and-forward
        path); on a busy link the sender-side work overlaps the wait
        instead of following it.

        If the link goes down before the final chunk's wire leg ends,
        the remaining chunks are lost: ``on_delivered`` never fires and
        ``on_dropped`` (if given) fires at the fault time instead.

        ``chunk_arrivals``, when given a list, receives each chunk's
        landfall time (wire arrival, post-ingress-NIC, before the
        receiver-side copy) in chunk order — the tracer's per-chunk
        landfall spans and the planned cut-through-into-compute overlap
        (ROADMAP) both read this; it is pure observation, the computed
        schedule is untouched.
        """
        if not self._up:
            return None  # dropped — sender times out via its own logic
        snd_free = self.clock.now + serialize_overhead
        wire_free = self._busy_until
        nic_free = egress._busy_until if egress is not None else 0.0
        nic_bw = egress.bandwidth if egress is not None else 0.0
        in_free = ingress._busy_until if ingress is not None else 0.0
        in_bw = ingress.bandwidth if ingress is not None else 0.0
        bw = self.bandwidth
        lat = self.latency
        rcv_free = 0.0
        total = 0.0
        nic_occupied = 0.0
        in_occupied = 0.0
        nic_t0 = in_t0 = -1.0        # first port occupancy (trace spans)
        ltr = self.trace
        wire_t0 = -1.0               # first wire occupancy (trace span)
        wire_occupied = 0.0
        for snd_cpu, wire_bytes, rcv_cpu in chunks:
            snd_free += snd_cpu                  # chunk copied/staged
            if egress is None:
                start = snd_free if snd_free > wire_free else wire_free
                wire_free = start + (wire_bytes / bw if bw > 0 else 0.0)
            else:
                # NIC → link tandem per chunk (see ``send``): the port
                # takes the chunk when staged and free; the link drains
                # cut-through behind it, never gating the shared port
                nic_start = snd_free if snd_free > nic_free else nic_free
                nic_free = nic_start + (wire_bytes / nic_bw if nic_bw > 0
                                        else 0.0)
                nic_occupied += nic_free - nic_start
                if nic_t0 < 0.0:
                    nic_t0 = nic_start
                start = nic_start if nic_start > wire_free else wire_free
                wire_free = start + (wire_bytes / bw if bw > 0 else 0.0)
                if nic_free > wire_free:
                    wire_free = nic_free  # NIC slower: it paces the chunk
            if ltr is not None:
                wire_occupied += wire_free - start
                if wire_t0 < 0.0:
                    wire_t0 = start
            total += wire_bytes
            arrive = wire_free + lat
            if ingress is not None:
                # link → NIC tandem per chunk (receive-side mirror of
                # the egress model): the port takes the chunk when its
                # first byte lands and the port is free; the chunk is
                # delivered no earlier than the port drains it
                in_start = start + lat
                if in_free > in_start:
                    in_start = in_free
                in_free = in_start + (wire_bytes / in_bw if in_bw > 0
                                      else 0.0)
                in_occupied += in_free - in_start
                if in_t0 < 0.0:
                    in_t0 = in_start
                if in_free > arrive:
                    arrive = in_free
            if chunk_arrivals is not None:
                chunk_arrivals.append(arrive)
            if arrive > rcv_free:
                rcv_free = arrive
            rcv_free += rcv_cpu                  # receiver-side copy
        self._busy_until = wire_free
        if egress is not None:
            egress._busy_until = nic_free
            egress.bytes_sent += total
            egress.busy_time += nic_occupied
            tr = egress.trace
            if tr is not None and nic_t0 >= 0.0:
                # one span per transfer, carrying the identical float
                # added to busy_time (aggregate order matches counter)
                tr.nic_span(egress.trace_label, nic_t0, nic_occupied)
        if ingress is not None:
            ingress._busy_until = in_free
            ingress.bytes_sent += total
            ingress.busy_time += in_occupied
            tr = ingress.trace
            if tr is not None and in_t0 >= 0.0:
                tr.nic_span(ingress.trace_label, in_t0, in_occupied)
        if ltr is not None and wire_t0 >= 0.0:
            # one aggregated span per transfer, like the NIC spans
            ltr.link_span(self.trace_label, wire_t0, wire_occupied)
        self.bytes_sent += total
        # register the transfer so a mid-flight down drops the remainder
        # (the pre-flap time-accounting above stands: the wire WAS held
        # until the fault; the fault model charges it, as TCP would keep
        # retransmitting into the dead window)
        tok = _Inflight(wire_free, on_dropped)
        self._inflight.append(tok)

        def _deliver():
            if tok.killed:
                return
            self._inflight.remove(tok)
            on_delivered()
        self._schedule_at(rcv_free, _deliver)
        return rcv_free


class DeviceSim:
    """A compute device with a busy-until timeline and an analytic or
    measured kernel cost model."""

    __slots__ = ("clock", "name", "flops", "mem_bw", "_busy_until",
                 "busy_time", "_schedule_at")

    def __init__(self, clock: SimClock, name: str,
                 flops: float = 10e12, mem_bw: float = 500e9):
        self.clock = clock
        self.name = name
        self.flops = flops
        self.mem_bw = mem_bw
        self._busy_until = 0.0
        self.busy_time = 0.0
        self._schedule_at = clock.schedule_at   # bound once: execute is hot

    def kernel_cost(self, flop_count: float = 0.0, bytes_moved: float = 0.0,
                    duration: Optional[float] = None) -> float:
        if duration is not None:
            return duration
        return max(flop_count / self.flops if self.flops else 0.0,
                   bytes_moved / self.mem_bw if self.mem_bw else 0.0)

    def execute(self, cost: float, on_done: Callable) -> tuple[float, float]:
        """Schedule a kernel; returns (start, end) sim times."""
        start = self.clock.now
        busy = self._busy_until
        if busy > start:
            start = busy
        end = start + cost
        self._busy_until = end
        self.busy_time += cost
        self._schedule_at(end, on_done)
        return start, end

    def utilization(self, horizon: float) -> float:
        return self.busy_time / horizon if horizon > 0 else 0.0


def _flap_link(link: Link, up: bool, tracer, t: float) -> None:
    """Scheduled flap callback. Always used — traced or not — so the
    schedule_at count (and therefore every event seq number) is
    identical with tracing on and off; the marker is pure observation
    at the statically known fault time."""
    link.up = up
    if tracer is not None:
        tracer.fault(t, "flap_up" if up else "flap_down", link.name)


class FaultSchedule:
    """Deterministic scripted fault injection (DESIGN.md §7).

    Chaos runs must be bit-reproducible so their sim-time gates are
    portable: every fault is pinned to a sim timestamp up front and
    ``apply`` arms them all on the cluster's clock before the workload
    starts. Verbs mirror the membership state machine (``crash``,
    ``drain``, ``join`` dispatch to the ``Cluster`` delegates,
    duck-typed so netsim keeps zero runtime imports) plus ``flap``,
    which takes any ``Link`` down for a window — a flap that lands
    mid-chunked-transfer drops the in-flight remainder (see
    ``Link.send_chunked``). Builder-style: each verb returns ``self``.
    """

    def __init__(self):
        self._faults: list = []

    def crash(self, at: float, server: str) -> "FaultSchedule":
        self._faults.append(("crash", at, (server,)))
        return self

    def drain(self, at: float, server: str,
              on_complete: Optional[Callable] = None) -> "FaultSchedule":
        self._faults.append(("drain", at, (server, on_complete)))
        return self

    def join(self, at: float, spec,
             on_active: Optional[Callable] = None) -> "FaultSchedule":
        self._faults.append(("join", at, (spec, on_active)))
        return self

    def flap(self, at: float, duration: float,
             link: Link) -> "FaultSchedule":
        self._faults.append(("flap", at, (duration, link)))
        return self

    def apply(self, cluster) -> "FaultSchedule":
        """Arm every scheduled fault on ``cluster.clock``."""
        clock = cluster.clock
        for kind, at, args in self._faults:
            if kind == "crash":
                clock.schedule_at(at, cluster.crash_server, args[0])
            elif kind == "drain":
                name, cb = args
                clock.schedule_at(
                    at, lambda n=name, c=cb:
                    cluster.drain_server(n, on_complete=c))
            elif kind == "join":
                spec, cb = args
                clock.schedule_at(
                    at, lambda s=spec, c=cb:
                    cluster.join_server(s, on_active=c))
            elif kind == "flap":
                duration, link = args
                tr = getattr(cluster, "trace", None)
                clock.schedule_at(at, _flap_link, link, False, tr, at)
                clock.schedule_at(at + duration, _flap_link, link, True,
                                  tr, at + duration)
        return self
