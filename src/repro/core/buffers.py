"""Buffer objects with per-server validity and dynamic content size.

``content_size_buffer`` implements the paper's ``cl_pocl_content_size``
extension (§5.3): a designated 4-byte buffer holds the number of
meaningful bytes; migrations move only that prefix. The canonical array
lives host-side in the simulation (all copies are bit-identical); what
the runtime tracks is *where* valid copies exist and what moving them
costs.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

_buf_ids = itertools.count(1)


@dataclasses.dataclass
class Buffer:
    nbytes: int
    content_size_buffer: Optional["Buffer"] = None
    name: str = ""
    id: int = dataclasses.field(default_factory=lambda: next(_buf_ids))
    data: Optional[np.ndarray] = None           # canonical contents
    valid_on: set = dataclasses.field(default_factory=set)  # server names
    registered_mr: set = dataclasses.field(default_factory=set)
    # content generation: bumped on every write/clobber. The runtime's
    # in-flight migration table snapshots it to detect transfers whose
    # payload went stale mid-flight (DESIGN.md §3): a coalesce hit or an
    # arrival-side validity update is only honored when the version still
    # matches the snapshot.
    version: int = 0
    # content-addressed store attachment (DESIGN.md §5): digest of the
    # content this buffer shares through the cluster's BufferStore, or
    # None when private. Managed by the store (attach/detach/cow_fork);
    # a write always forks the buffer back to private first.
    store_key: Optional[bytes] = None

    def transfer_bytes(self) -> float:
        """Bytes a migration must move (content-size aware). Clamped to
        ``[0, nbytes]``: a corrupt or stale ``cl_pocl_content_size``
        value must never produce a negative or over-long transfer."""
        if self.content_size_buffer is not None \
                and self.content_size_buffer.data is not None:
            used = int(np.asarray(
                self.content_size_buffer.data).reshape(-1)[0])
            return float(min(max(used, 0), self.nbytes))
        return float(self.nbytes)

    def set_data(self, arr, on: str):
        self.data = arr
        self.valid_on = {on}
        self.version += 1

    def invalidate_except(self, server: str):
        self.valid_on = {server}
        self.version += 1
