"""Cluster-level content-addressed buffer store (DESIGN.md §5).

PR 3 made the runtime multi-tenant, but every tenant still uploads its
own private copy of identical payloads — 32 AR UEs loading the same
model push the same bytes through the radio links and the shared NICs
dozens of times, exactly the redundant-transfer cost the paper's P2P
data plane exists to avoid (§IV, Fig. 11). The store keys uploads by a
content digest computed at enqueue time: identical payloads resolve to
one shared *physical* replica set per server, refcounted per attached
logical buffer, with copy-on-write on tenant writes and LRU eviction of
unreferenced replicas under a configurable per-server capacity.

The store tracks *where content is resident* and what moving it costs;
the canonical numpy array still lives on each ``Buffer`` (bit-identical
across attached buffers by construction — same digest, same bytes), so
nothing about the functional execution model changes. What changes is
the wire: an upload whose content is already resident on the target
server sends only the command struct + digest, an upload racing an
identical in-flight copy gates on that transfer instead of re-sending
the bytes, and a migration can be served from (or deduplicated against)
*any* tenant's valid replica, not just the requesting tenant's.

Sharing is deliberately opt-in (``Cluster(store=True)``): a cluster
built without a store keeps the PR 3 private-copy behavior bit-exact,
which is also the baseline the dedup benchmark measures against.
"""
from __future__ import annotations

import hashlib
from typing import Optional

import numpy as np

from repro.core.buffers import Buffer

# wire size of a content digest carried by a dedup'd (payload-free)
# write command — the daemon needs it to resolve the shared replica
DIGEST_BYTES = 16


def content_digest(data) -> bytes:
    """Digest of a payload's bytes + dtype (two buffers holding the same
    raw bytes under different dtypes are different contents to a kernel).
    Computed client-side at enqueue, like the command struct itself."""
    arr = np.ascontiguousarray(data)
    h = hashlib.blake2b(digest_size=DIGEST_BYTES)
    h.update(str(arr.dtype).encode())
    h.update(arr.data)      # zero-copy: hash the array's own buffer
    return h.digest()


class StoreEntry:
    """One content hash's cluster-wide replica set."""

    __slots__ = ("key", "nbytes", "refs", "valid_on", "pending",
                 "last_used")

    def __init__(self, key: bytes, nbytes: int):
        self.key = key
        self.nbytes = nbytes
        self.refs: set = set()        # attached Buffer ids
        self.valid_on: set = set()    # servers with a resident replica
        self.pending: dict = {}       # server -> in-flight transfer Event
        self.last_used = 0.0          # LRU clock (sim time)


class BufferStore:
    """Content digest → shared replica set, with per-buffer refcounts.

    * ``attach``/``detach`` manage which logical buffers currently hold
      the entry's content. A write to an attached buffer is always a
      copy-on-write **fork**: the buffer detaches to a private copy (its
      ``version`` bump is the runtime's existing clobber bookkeeping)
      and the shared replicas stay intact for the other holders — a
      shared physical allocation is never mutated in place.
    * ``replica_landed`` records a physical replica arriving on a server
      (upload completion or migration arrival) and charges it against
      the per-server ``capacity``, evicting least-recently-used
      **unreferenced** replicas to make room. Replicas of entries with
      live refs or in-flight transfers are pinned.
    * Entries with no refs and no replicas are dropped entirely.
    """

    def __init__(self, clock, capacity: Optional[float] = None):
        self.clock = clock
        self.capacity = capacity      # bytes per server (None: unbounded)
        self._entries: dict = {}      # digest -> StoreEntry
        self._by_buffer: dict = {}    # Buffer id -> StoreEntry
        self.resident_bytes: dict = {}  # server -> resident replica bytes
        # scoreboard
        self.dedup_hits = 0           # uploads/migrations served by a replica
        self.bytes_deduped = 0.0      # payload bytes that never hit a wire
        self.cow_forks = 0            # writes forked off a shared entry
        self.evictions = 0
        self.evicted_bytes = 0.0

    # ---- attachment lifecycle ----
    def attach(self, buf: Buffer, key: bytes, nbytes: int) -> StoreEntry:
        """Bind ``buf`` to the entry for ``key`` (detaching it from any
        previous entry first — a rewrite is a fork plus a reattach).
        ``nbytes`` is the PAYLOAD size the digest covers — a replica
        occupies what the content needs, not the (possibly larger)
        buffer allocation it was written into."""
        old = self._by_buffer.get(buf.id)
        if old is not None and old.key != key:
            self.detach(buf)
        entry = self._entries.get(key)
        if entry is None:
            entry = self._entries[key] = StoreEntry(key, nbytes)
        entry.refs.add(buf.id)
        entry.last_used = self.clock.now
        self._by_buffer[buf.id] = entry
        buf.store_key = key
        return entry

    def _maybe_gc(self, entry: StoreEntry) -> None:
        """Drop an entry nothing points at anymore: no attached buffers,
        no resident replicas, no in-flight transfers. The single place
        the pin/GC rule lives."""
        if not entry.refs and not entry.valid_on and not entry.pending:
            self._entries.pop(entry.key, None)

    def detach(self, buf: Buffer) -> None:
        """Drop ``buf``'s reference; unreferenced entries stay cached
        (their replicas remain dedup sources) until evicted."""
        entry = self._by_buffer.pop(buf.id, None)
        buf.store_key = None
        if entry is None:
            return
        entry.refs.discard(buf.id)
        self._maybe_gc(entry)

    def cow_fork(self, buf: Buffer) -> bool:
        """A tenant is about to write ``buf`` while it holds shared
        content: fork it to a private buffer (the caller bumps
        ``Buffer.version`` via its normal clobber path). Returns True if
        a fork actually happened — the runtime charges the device-side
        copy only then."""
        if buf.id not in self._by_buffer:
            return False
        self.cow_forks += 1
        self.detach(buf)
        return True

    def release(self, buf: Buffer) -> None:
        """Tenant lifecycle: the owning client detached — identical to
        ``detach`` but named for the caller's intent."""
        self.detach(buf)

    # ---- lookups ----
    def entry_for(self, buf: Buffer) -> Optional[StoreEntry]:
        return self._by_buffer.get(buf.id)

    def replica_servers(self, buf: Buffer) -> set:
        """Replica-location probe (DESIGN.md §6): the servers holding a
        resident physical replica of ``buf``'s content — ANY tenant's.
        Placement uses it to send kernels where their inputs already
        live instead of dragging content to the kernel. Empty when the
        buffer shares nothing through the store."""
        entry = self._by_buffer.get(buf.id)
        return set(entry.valid_on) if entry is not None else set()

    def lookup(self, key: bytes) -> Optional[StoreEntry]:
        return self._entries.get(key)

    def touch(self, entry: StoreEntry) -> None:
        entry.last_used = self.clock.now

    def record_dedup(self, entry: StoreEntry, nbytes: float) -> None:
        self.dedup_hits += 1
        self.bytes_deduped += nbytes
        entry.last_used = self.clock.now

    def unrecord_dedup(self, nbytes: float) -> None:
        """A claimed saving did not materialize (the rider's transfer
        died and the payload was paid after all): take it back so the
        scoreboard reports only bytes that really never hit a wire."""
        self.dedup_hits -= 1
        self.bytes_deduped -= nbytes

    # ---- replica arrival / in-flight tracking ----
    def add_pending(self, entry: StoreEntry, server: str, ev) -> None:
        """An upload or migration of this content to ``server`` is in
        flight: later identical requests gate on ``ev`` instead of
        re-sending the payload. Cleared on the event's completion or
        failure (``Event`` callbacks fire for both)."""
        entry.pending[server] = ev

        def clear(_e, entry=entry, server=server, ev=ev):
            if entry.pending.get(server) is ev:
                del entry.pending[server]
            self._maybe_gc(entry)

        ev.on_complete(clear)

    def replica_landed(self, entry: StoreEntry, server: str) -> None:
        if server in entry.valid_on:
            entry.last_used = self.clock.now
            return
        self._reserve(server, entry.nbytes)
        entry.valid_on.add(server)
        entry.last_used = self.clock.now
        self.resident_bytes[server] = \
            self.resident_bytes.get(server, 0.0) + entry.nbytes

    def _reserve(self, server: str, nbytes: float) -> None:
        """Make room on ``server`` by evicting LRU unreferenced replicas.
        Referenced or in-flight entries are pinned, so the store can run
        over capacity when every resident byte is live — capacity bounds
        the *cache*, not the tenants' working set."""
        cap = self.capacity
        if cap is None:
            return
        used = self.resident_bytes.get(server, 0.0)
        if used + nbytes <= cap:
            return
        victims = sorted(
            (e for e in self._entries.values()
             if server in e.valid_on and not e.refs
             and server not in e.pending),
            key=lambda e: e.last_used)
        for e in victims:
            if used + nbytes <= cap:
                break
            e.valid_on.discard(server)
            used -= e.nbytes
            self.evictions += 1
            self.evicted_bytes += e.nbytes
            self._maybe_gc(e)
        self.resident_bytes[server] = used

    # ---- server lifecycle ----
    def server_retired(self, server: str) -> int:
        """``server`` left the cluster (drain finished or crash): its
        resident replicas vanish and its in-flight arrivals will never
        land. Pending events are NOT failed here — the transfer's own
        failure path (link kill / fail-fast) owns that; this only drops
        the pending registration so no later request gates on a transfer
        into a corpse. Riders on those transfers fall back through the
        normal ride-death settle (they observe the event's terminal
        status). Returns the number of replicas dropped."""
        dropped = 0
        for entry in list(self._entries.values()):
            if server in entry.valid_on:
                entry.valid_on.discard(server)
                dropped += 1
            entry.pending.pop(server, None)
            self._maybe_gc(entry)
        self.resident_bytes.pop(server, None)
        return dropped

    # ---- reporting ----
    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "attached_buffers": len(self._by_buffer),
            "resident_bytes": dict(self.resident_bytes),
            "dedup_hits": self.dedup_hits,
            "bytes_deduped": self.bytes_deduped,
            "cow_forks": self.cow_forks,
            "evictions": self.evictions,
            "evicted_bytes": self.evicted_bytes,
        }
