"""Command types carried through the runtime (paper §4.2's command union).

The wire representation is kept identical to the in-memory one (the
paper's zero-translation design) — in the simulation this simply means
commands are passed by reference and only their *sizes* hit the modeled
wire.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional, Sequence

_cmd_ids = itertools.count(1)


@dataclasses.dataclass
class Command:
    id: int = dataclasses.field(default_factory=lambda: next(_cmd_ids),
                                init=False)


@dataclasses.dataclass
class NDRangeKernel(Command):
    """A compute kernel. ``fn(*input_arrays) -> output_array(s)`` runs
    functionally; cost comes from flops/bytes or an explicit duration."""
    fn: Optional[Callable] = None
    inputs: Sequence = ()
    outputs: Sequence = ()
    flops: float = 0.0
    bytes_moved: float = 0.0
    duration: Optional[float] = None
    name: str = "kernel"


@dataclasses.dataclass
class BuiltinKernel(NDRangeKernel):
    """Paper §7.1: CL_DEVICE_TYPE_CUSTOM built-in kernels (e.g. the HEVC
    'decode' device, or the stream-source device)."""
    builtin: str = ""


@dataclasses.dataclass
class MigrateBuffer(Command):
    buffer: object = None
    dst_server: str = ""
    dst_device: str = ""


@dataclasses.dataclass
class WriteBuffer(Command):
    """Client → server upload."""
    buffer: object = None
    data: object = None
    nbytes: float = 0.0


@dataclasses.dataclass
class ReadBuffer(Command):
    """Server → client download."""
    buffer: object = None


@dataclasses.dataclass
class Marker(Command):
    pass
