"""Command types carried through the runtime (paper §4.2's command union).

The wire representation is kept identical to the in-memory one (the
paper's zero-translation design) — in the simulation this simply means
commands are passed by reference and only their *sizes* hit the modeled
wire.

These are plain ``__slots__`` classes rather than dataclasses: the
dispatch hot path allocates one per enqueue, and the generated dataclass
``__init__`` chain (base id factory + subclass defaults) was measurable
in the dispatch profile. Construction signatures are unchanged.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

_next_cmd_id = 0


class Command:
    __slots__ = ("id",)

    def __init__(self):
        global _next_cmd_id
        _next_cmd_id += 1
        self.id = _next_cmd_id

    def __repr__(self):  # debugging/error messages only
        return f"{type(self).__name__}(id={self.id})"


class NDRangeKernel(Command):
    """A compute kernel. ``fn(*input_arrays) -> output_array(s)`` runs
    functionally; cost comes from flops/bytes or an explicit duration."""

    __slots__ = ("fn", "inputs", "outputs", "flops", "bytes_moved",
                 "duration", "name")

    def __init__(self, fn: Optional[Callable] = None, inputs: Sequence = (),
                 outputs: Sequence = (), flops: float = 0.0,
                 bytes_moved: float = 0.0, duration: Optional[float] = None,
                 name: str = "kernel"):
        global _next_cmd_id
        _next_cmd_id += 1
        self.id = _next_cmd_id
        self.fn = fn
        self.inputs = inputs
        self.outputs = outputs
        self.flops = flops
        self.bytes_moved = bytes_moved
        self.duration = duration
        self.name = name


class BuiltinKernel(NDRangeKernel):
    """Paper §7.1: CL_DEVICE_TYPE_CUSTOM built-in kernels (e.g. the HEVC
    'decode' device, or the stream-source device)."""

    __slots__ = ("builtin",)

    def __init__(self, fn: Optional[Callable] = None, inputs: Sequence = (),
                 outputs: Sequence = (), flops: float = 0.0,
                 bytes_moved: float = 0.0, duration: Optional[float] = None,
                 name: str = "kernel", builtin: str = ""):
        NDRangeKernel.__init__(self, fn, inputs, outputs, flops,
                               bytes_moved, duration, name)
        self.builtin = builtin


class MigrateBuffer(Command):
    __slots__ = ("buffer", "dst_server", "dst_device")

    def __init__(self, buffer: object = None, dst_server: str = "",
                 dst_device: str = ""):
        global _next_cmd_id
        _next_cmd_id += 1
        self.id = _next_cmd_id
        self.buffer = buffer
        self.dst_server = dst_server
        self.dst_device = dst_device


class WriteBuffer(Command):
    """Client → server upload."""

    __slots__ = ("buffer", "data", "nbytes")

    def __init__(self, buffer: object = None, data: object = None,
                 nbytes: float = 0.0):
        global _next_cmd_id
        _next_cmd_id += 1
        self.id = _next_cmd_id
        self.buffer = buffer
        self.data = data
        self.nbytes = nbytes


class ReadBuffer(Command):
    """Server → client download."""

    __slots__ = ("buffer",)

    def __init__(self, buffer: object = None):
        global _next_cmd_id
        _next_cmd_id += 1
        self.id = _next_cmd_id
        self.buffer = buffer


class Marker(Command):
    __slots__ = ()
