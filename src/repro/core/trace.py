"""Cluster-wide tracing & metrics plane (DESIGN.md §9).

A ``Tracer`` records one span per command lifecycle stage — enqueue,
placement decision, client→server wire (incl. NIC egress/ingress
queueing and per-chunk landfall), device run-queue wait, execution,
completion routing — plus transfer spans, dedup events, requeue
annotations, and fault markers from the membership plane. Everything is
stamped with *simulated* time, so a trace is as deterministic and
bit-reproducible as the run that produced it.

Two invariants, both load-bearing:

* **Tracing off is free.** Every hook site in the runtime is gated the
  same way ``PlacementEngine.telemetry_active`` gates the placement
  tally: one attribute load and a ``None`` check on the hot path, no
  call, no allocation. A ``Cluster`` built without ``trace=`` carries
  ``trace=None`` and executes byte-identical code.
* **Tracing on never perturbs simulated time.** Hooks *observe* the
  clock (or are handed timestamps the caller already computed); the
  tracer never calls ``clock.schedule*``, so the event sequence — and
  therefore every simulated timestamp — is identical with tracing on
  and off.

Exporters: Chrome/Perfetto ``trace_event`` JSON (``write_perfetto``;
load the file in https://ui.perfetto.dev) and a terminal latency-
breakdown table (``format_breakdown``) reproducing the paper's Fig. 9
command-latency decomposition. ``MetricsRegistry`` layers windowed
p50/p95/p99 histograms per tenant/server/device/link on top of the raw
spans and can flatten ``Cluster.stats()`` counters into the same
namespace, unifying the ad-hoc scoreboards.
"""
from __future__ import annotations

import gzip
import json
import math
from fractions import Fraction
from typing import Optional

__all__ = ["Tracer", "CmdRecord", "MetricsRegistry", "Histogram",
           "set_default", "get_default", "STAGES"]

# Lifecycle stages of the latency decomposition, in causal order. Each
# is the delta between two adjacent stamps of the forward-filled stamp
# chain (see Tracer.breakdown): queued → submitted → ready → start →
# end-of-lifecycle (client ack when observed, else device completion).
STAGES = ("submit_wire", "dep_wait", "queue_wait", "execute",
          "completion")

# ---------------------------------------------------------------------------
# module-level default tracer: ``Cluster(trace=None)`` falls back to
# this, so harnesses like ``benchmarks/run.py --trace=FILE`` can trace
# every cluster a benchmark builds without threading a parameter
# through each module.
_DEFAULT: Optional["Tracer"] = None


def set_default(tracer: Optional["Tracer"]) -> None:
    global _DEFAULT
    _DEFAULT = tracer


def get_default() -> Optional["Tracer"]:
    return _DEFAULT


def _round_shares(shares: list, decimals: int = 2) -> list:
    """Largest-remainder rounding of percentage shares: the returned
    values, each a multiple of ``10**-decimals``, sum to exactly 100 at
    that precision — so a printed share column never drifts off 100.0
    by display rounding. Tolerates inputs whose float sum is slightly
    off 100 (telescoping error): the correction lands on the entries
    with the largest (or smallest) fractional remainders."""
    scale = 10 ** decimals
    scaled = [s * scale for s in shares]
    floors = [math.floor(x) for x in scaled]
    short = round(100 * scale) - sum(floors)
    order = sorted(range(len(shares)),
                   key=lambda i: (scaled[i] - floors[i], shares[i]),
                   reverse=True)
    out = list(floors)
    i = 0
    while short > 0 and order:
        out[order[i % len(order)]] += 1
        short -= 1
        i += 1
    i = len(order) - 1
    while short < 0 and order:
        out[order[i % len(order)]] -= 1
        short += 1
        i -= 1
    return [v / scale for v in out]


class CmdRecord:
    """Per-command lifecycle record. Timestamps other than ``t_ready``
    live on the ``Event`` itself (``t_queued``/``t_submitted``/
    ``t_start``/``t_end``/``t_client_ack``); the tracer only adds what
    the Event does not carry: the run-queue entry time, the placed
    server/device, the modeled execution cost, and any drain requeues.

    Causal edges for the critical-path analyzer (DESIGN.md §11) ride
    the same record: ``deps`` holds the dependency event ids the client
    classified at enqueue time, ``slices`` the actual device occupancy
    intervals when a preemptive policy ran the command in chunks."""

    __slots__ = ("ev", "tenant", "t_ready", "server", "device", "cost",
                 "requeues", "deps", "slices")

    def __init__(self, ev, tenant: str):
        self.ev = ev
        self.tenant = tenant
        self.t_ready: Optional[float] = None
        self.server: Optional[str] = None
        self.device: Optional[str] = None
        self.cost = 0.0
        self.requeues = None          # lazily [(t, src_server, reason)]
        self.deps = None              # lazily [dep_event_id, ...]
        self.slices = None            # lazily [(t0, t1), ...] llf slices


class Histogram:
    """Windowed histogram over ``(sim_time, value)`` samples. Nearest-
    rank percentiles, optional ``[t0, t1)`` window — cheap and exact
    (samples are kept; the benchmark scales here are thousands, not
    billions)."""

    __slots__ = ("samples",)

    def __init__(self):
        self.samples: list = []       # (t, value) in observation order

    def add(self, t: float, value: float) -> None:
        self.samples.append((t, value))

    def _window(self, t0: Optional[float], t1: Optional[float]) -> list:
        vals = [v for t, v in self.samples
                if (t0 is None or t >= t0) and (t1 is None or t < t1)]
        vals.sort()
        return vals

    def percentile(self, q: float, t0: Optional[float] = None,
                   t1: Optional[float] = None) -> float:
        vals = self._window(t0, t1)
        if not vals:
            return 0.0
        # nearest-rank: smallest value with cum. frequency >= q%
        rank = max(1, -(-len(vals) * q // 100))  # ceil without floats
        return vals[int(rank) - 1]

    def summary(self, t0: Optional[float] = None,
                t1: Optional[float] = None) -> dict:
        vals = self._window(t0, t1)
        if not vals:
            return {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                    "p99": 0.0}

        def pct(q):
            rank = max(1, -(-len(vals) * q // 100))
            return vals[int(rank) - 1]

        return {"count": len(vals), "mean": sum(vals) / len(vals),
                "p50": pct(50), "p95": pct(95), "p99": pct(99)}


class MetricsRegistry:
    """Namespaced histograms + flat counters. ``observe`` feeds a
    ``(metric, key)`` histogram; ``ingest_stats`` flattens a nested
    ``stats()`` dict into dotted counters, so the scoreboards scattered
    across runtime/netsim/scheduler/store/placement all land in one
    queryable namespace."""

    def __init__(self):
        self._hists: dict = {}        # (metric, key) -> Histogram
        self.counters: dict = {}      # dotted name -> number

    def hist(self, metric: str, key: str = "") -> Histogram:
        h = self._hists.get((metric, key))
        if h is None:
            h = self._hists[(metric, key)] = Histogram()
        return h

    def observe(self, metric: str, key: str, t: float,
                value: float) -> None:
        self.hist(metric, key).add(t, value)

    def ingest_stats(self, prefix: str, stats: dict) -> None:
        for k, v in stats.items():
            name = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, dict):
                self.ingest_stats(name, v)
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                self.counters[name] = self.counters.get(name, 0) + v

    def rate(self, metric: str, key: str = "",
             t0: Optional[float] = None,
             t1: Optional[float] = None) -> float:
        """Windowed rate of a 0/1 sample stream: the fraction of samples
        in ``[t0, t1)`` that are nonzero; 0.0 when no samples landed in
        the window. Feeds the per-class SLO violation-rate gates
        (DESIGN.md §10)."""
        h = self._hists.get((metric, key))
        if h is None:
            return 0.0
        vals = h._window(t0, t1)
        if not vals:
            return 0.0
        return sum(1 for v in vals if v > 0.0) / len(vals)

    def summary(self, t0: Optional[float] = None,
                t1: Optional[float] = None) -> dict:
        return {f"{m}[{k}]" if k else m: h.summary(t0, t1)
                for (m, k), h in sorted(self._hists.items())}


class Tracer:
    """Append-only span store + exporters. One tracer may serve several
    clusters (``benchmarks/fleet_sweep.py`` builds one per fleet size);
    entities of the second and later clusters are namespaced with a
    ``c<i>:`` prefix by the cluster itself at hook time."""

    def __init__(self):
        self.cmds: dict = {}          # event id -> CmdRecord
        self.transfers: list = []     # (kind, link, tenant, t0, t1,
                                      #  nbytes, ev_id, chunk_arrivals)
        self.nic_spans: list = []     # (label, t0, busy_dur)
        self.placements: list = []    # (t, tenant, name, chosen, policy)
        self.dedups: list = []        # (t, tenant, signed nbytes)
        self.faults: list = []        # (t, kind, target, detail)
        self.slo: list = []           # (t, tenant, ev_id, latency, slo)
        self.admissions: list = []    # (t, tenant, status, predicted_s,
                                      #  requested_slo_s, slo_s, reason)
        self.link_spans: list = []    # (label, wire_t0, wire_busy)
        self.runq: list = []          # (label, t, queued_depth)
        self.links: dict = {}         # label -> (latency_s, bandwidth_Bps)
        self._clusters: list = []

    # ---- wiring ----
    def register_cluster(self, cluster) -> int:
        self._clusters.append(cluster)
        return len(self._clusters) - 1

    # ---- hot-path hooks (called only when the gate saw non-None) ----
    def cmd_queued(self, ev, tenant: str) -> None:
        self.cmds[ev.id] = CmdRecord(ev, tenant)

    def cmd_ready(self, ev, now: float, server: str, device: str,
                  cost: float) -> None:
        r = self.cmds.get(ev.id)
        if r is None:                 # enqueued before tracing attached
            r = self.cmds[ev.id] = CmdRecord(ev, "?")
        r.t_ready = now
        r.server = server
        r.device = device
        r.cost = cost

    def requeue(self, ev, now: float, src: str, reason: str) -> None:
        r = self.cmds.get(ev.id)
        if r is None:
            r = self.cmds[ev.id] = CmdRecord(ev, "?")
        if r.requeues is None:
            r.requeues = []
        r.requeues.append((now, src, reason))

    def cmd_deps(self, ev, dep_ids) -> None:
        """Happens-before edges (DESIGN.md §11): the dependency event
        ids this command waited on, as classified by the client at send
        time — the explicit half of the causal DAG (resource edges come
        from exec slices, link/NIC spans, and run-queue samples)."""
        if not dep_ids:
            return
        r = self.cmds.get(ev.id)
        if r is None:
            r = self.cmds[ev.id] = CmdRecord(ev, "?")
        r.deps = list(dep_ids)

    def exec_slice(self, ev, t0: float, t1: float) -> None:
        """One device slice of a preemptively-scheduled command (llf,
        DESIGN.md §10): the device was occupied by ``ev`` over exactly
        ``[t0, t1)``. Non-preemptive commands occupy
        ``[t_start, t_start + cost)`` and never emit slices."""
        r = self.cmds.get(ev.id)
        if r is None:
            r = self.cmds[ev.id] = CmdRecord(ev, "?")
        if r.slices is None:
            r.slices = []
        r.slices.append((t0, t1))

    def admission(self, tenant: str, decision) -> None:
        """Admission verdict marker (DESIGN.md §10 control plane →
        §9 observability): admit/degrade/reject with the controller's
        predicted latency, so predicted-vs-actual is inspectable next
        to the tenant's own command tracks."""
        self.admissions.append((decision.t, tenant, decision.status,
                                decision.predicted_s,
                                decision.requested_slo_s,
                                decision.slo_s, decision.reason))

    def link_span(self, label: str, t0: float, busy: float) -> None:
        """Wire occupancy of one link: ``busy`` seconds of serialization
        starting at ``t0`` (queueing behind earlier messages excluded —
        that is the gap between the transfer span start and this)."""
        self.link_spans.append((label, t0, busy))

    def run_queue(self, label: str, t: float, depth: int) -> None:
        """Run-queue depth sample from a DeviceScheduler at a push/pop
        boundary (the in-service command is excluded, matching
        ``queued_seconds``). Renders as a Perfetto counter track."""
        self.runq.append((label, t, depth))

    def transfer(self, kind: str, link: str, tenant: str, t0: float,
                 t1: float, nbytes: float, ev_id: Optional[int] = None,
                 chunk_arrivals: Optional[list] = None,
                 link_obj=None) -> None:
        if link_obj is not None and link not in self.links:
            # substrate metadata for what-if re-timing: which part of a
            # recorded transfer duration is bandwidth-proportional
            self.links[link] = (link_obj.latency, link_obj.bandwidth)
        self.transfers.append((kind, link, tenant, t0, t1, nbytes,
                               ev_id, chunk_arrivals))

    def nic_span(self, label: str, t0: float, busy: float) -> None:
        # ``busy`` is the exact float the caller added to
        # ``NIC.busy_time`` — appended in the same order, so a sum over
        # these spans reproduces the counter bit-for-bit.
        self.nic_spans.append((label, t0, busy))

    def placement(self, t: float, tenant: str, name: str, chosen: str,
                  policy: str) -> None:
        self.placements.append((t, tenant, name, chosen, policy))

    def dedup(self, t: float, tenant: str, nbytes: float) -> None:
        self.dedups.append((t, tenant, nbytes))

    def fault(self, t: float, kind: str, target: str,
              detail: str = "") -> None:
        self.faults.append((t, kind, target, detail))

    def slo_violation(self, t: float, tenant: str, ev_id: int,
                      latency: float, slo: float) -> None:
        """Client-ack hook (gated: only violations of a declared SLO
        reach here): command ``ev_id`` finished ``latency`` seconds
        after enqueue against an SLO of ``slo`` seconds."""
        self.slo.append((t, tenant, ev_id, latency, slo))

    # ---- derived views ----
    @staticmethod
    def _cmd_end(ev) -> float:
        return ev.t_client_ack if ev.t_client_ack > 0.0 else ev.t_end

    @staticmethod
    def _stamps(rec) -> list:
        """Forward-filled stamp chain [queued, submitted, ready, start,
        done, end] — six boundaries, one per STAGES interval. A 0.0
        stamp means the command never reached that stage (e.g. a
        WriteBuffer completes inline without a run queue); it inherits
        the previous boundary so its stage contributes exactly zero and
        the telescoping sum stays exact."""
        ev = rec.ev
        raw = [ev.t_queued, ev.t_submitted,
               rec.t_ready if rec.t_ready is not None else 0.0,
               ev.t_start, ev.t_end, Tracer._cmd_end(ev)]
        out = [raw[0]]
        for s in raw[1:]:
            out.append(s if s > out[-1] else out[-1])
        return out

    def finished(self) -> list:
        """CmdRecords whose lifecycle closed (COMPLETE, end stamped)."""
        return [r for r in self.cmds.values()
                if r.ev.status == "complete" and self._cmd_end(r.ev) > 0.0]

    def breakdown(self, exact: bool = False) -> dict:
        """Per-stage decomposition over finished commands.

        Returns ``{stage: [durations...]}`` plus ``"total"`` (end-to-end
        per-command latency, same order). With ``exact=True`` durations
        are ``fractions.Fraction`` — the per-command stage sums then
        equal the end-to-end latency *exactly* (telescoping is exact in
        rational arithmetic), which ``benchmarks/latency_breakdown.py``
        gates on."""
        num = Fraction if exact else float
        out: dict = {s: [] for s in STAGES}
        out["total"] = []
        for rec in self.finished():
            st = self._stamps(rec)
            if exact:
                st = [Fraction(x) for x in st]
            for name, a, b in zip(STAGES, st, st[1:]):
                out[name].append(num(b - a) if not exact else b - a)
            out["total"].append(st[-1] - st[0])
        return out

    def format_breakdown(self, title: str = "") -> str:
        """Terminal table: per-stage count/mean/p50/p95/p99 (µs) and the
        share of total end-to-end latency attributed to each stage."""
        bd = self.breakdown()
        total = sum(bd["total"]) or 1.0
        lines = []
        if title:
            lines.append(f"# {title}")
        lines.append(f"{'stage':<14}{'count':>7}{'mean_us':>10}"
                     f"{'p50_us':>10}{'p95_us':>10}{'p99_us':>10}"
                     f"{'share%':>8}")

        def row(name, vals, share):
            h = Histogram()
            for v in vals:
                h.add(0.0, v * 1e6)
            s = h.summary()
            lines.append(f"{name:<14}{s['count']:>7}{s['mean']:>10.2f}"
                         f"{s['p50']:>10.2f}{s['p95']:>10.2f}"
                         f"{s['p99']:>10.2f}{share:>8.2f}")

        raw = [100.0 * sum(bd[stage]) / total for stage in STAGES]
        for stage, share in zip(STAGES, _round_shares(raw)):
            row(stage, bd[stage], share)
        row("total", bd["total"], 100.0)
        return "\n".join(lines)

    def metrics(self) -> MetricsRegistry:
        """Histograms derived from the spans: end-to-end latency per
        tenant, execute/queue-wait per server/device, wire time and
        bytes per link — then every attached cluster's ``stats()``
        counters flattened alongside."""
        reg = MetricsRegistry()
        for rec in self.finished():
            st = self._stamps(rec)
            reg.observe("cmd_latency", rec.tenant, st[0], st[-1] - st[0])
            if rec.server is not None:
                key = f"{rec.server}/{rec.device}"
                reg.observe("queue_wait", key, st[2], st[3] - st[2])
                reg.observe("execute", key, st[3], rec.cost)
                if rec.slices:
                    # llf preemption slices (DESIGN.md §10): per-slice
                    # device occupancy, plus the count per command
                    for a, b in rec.slices:
                        reg.observe("preempt_slice", key, a, b - a)
                    reg.observe("preempt_slices_per_cmd", key, st[3],
                                len(rec.slices))
        for kind, link, _tenant, t0, t1, nbytes, _e, _c in self.transfers:
            reg.observe("wire_time", link, t0, t1 - t0)
            reg.observe("wire_bytes", link, t0, nbytes)
        for label, t0, busy in self.link_spans:
            reg.observe("link_busy", label, t0, busy)
        for label, t, depth in self.runq:
            reg.observe("run_queue_depth", label, t, depth)
        for t, _tenant, status, predicted, _req, _slo, _why \
                in self.admissions:
            # verdict counts + the controller's predicted latency per
            # verdict class; actuals live in cmd_latency/slo_lateness
            reg.observe("admission_predicted", status, t, predicted)
            name = f"admission.{status}"
            reg.counters[name] = reg.counters.get(name, 0) + 1
        for t, tenant, _eid, latency, slo in self.slo:
            # lateness past the deadline, per tenant: the per-class
            # violation *rates* live on the admission controller; this
            # is the per-violation magnitude view
            reg.observe("slo_lateness", tenant, t, latency - slo)
        for i, cluster in enumerate(self._clusters):
            pfx = f"c{i}" if len(self._clusters) > 1 else ""
            reg.ingest_stats(pfx, cluster.stats())
        return reg

    # ---- Perfetto / Chrome trace_event export ----
    def perfetto_events(self) -> list:
        """Chrome ``trace_event`` list. Layout:

        * one process per tenant; each finished command is an async
          track (``ph: b/e``, ``cat: 'cmd'``, ``id``: event id) whose
          nested child slices are the lifecycle stages;
        * one process per server; device threads carry ``X`` execution
          slices, NIC threads carry ``X`` occupancy slices;
        * a ``net`` process with one thread per link: ``X`` transfer
          slices plus ``i`` chunk-landfall instants;
        * placement decisions as thread-scoped instants, fault markers
          as global instants (``cat: 'fault'``).

        ``ts`` is simulated microseconds. Deterministic: entities are
        sorted, ids are simulation-assigned."""
        ev_list: list = []
        pids: dict = {}
        tids: dict = {}

        def pid(kind, name):
            key = (kind, name)
            if key not in pids:
                pids[key] = len(pids) + 1
                ev_list.append({"ph": "M", "name": "process_name",
                                "pid": pids[key], "tid": 0,
                                "args": {"name": f"{kind}:{name}"}})
            return pids[key]

        def tid(p, name):
            key = (p, name)
            if key not in tids:
                tids[key] = len([1 for (q, _n) in tids if q == p]) + 1
                ev_list.append({"ph": "M", "name": "thread_name",
                                "pid": p, "tid": tids[key],
                                "args": {"name": name}})
            return tids[key]

        us = 1e6
        # command lifecycles, per tenant, deterministic order by id
        for eid in sorted(self.cmds):
            rec = self.cmds[eid]
            ev = rec.ev
            if ev.status != "complete" or self._cmd_end(ev) <= 0.0:
                continue
            p = pid("tenant", rec.tenant)
            st = self._stamps(rec)
            name = getattr(ev.command, "name", None) or \
                type(ev.command).__name__ if ev.command is not None \
                else f"cmd{eid}"
            args = {"server": rec.server or (ev.server or ""),
                    "device": rec.device or ""}
            if rec.requeues:
                args["requeues"] = [
                    {"t_us": t * us, "from": src, "reason": why}
                    for t, src, why in rec.requeues]
            ev_list.append({"ph": "b", "cat": "cmd", "id": str(eid),
                            "name": str(name), "pid": p, "tid": 0,
                            "ts": st[0] * us, "args": args})
            for stage, a, b in zip(STAGES, st, st[1:]):
                if b <= a:
                    continue
                ev_list.append({"ph": "b", "cat": "cmd", "id": str(eid),
                                "name": stage, "pid": p, "tid": 0,
                                "ts": a * us})
                ev_list.append({"ph": "e", "cat": "cmd", "id": str(eid),
                                "name": stage, "pid": p, "tid": 0,
                                "ts": b * us})
            ev_list.append({"ph": "e", "cat": "cmd", "id": str(eid),
                            "name": str(name), "pid": p, "tid": 0,
                            "ts": st[-1] * us})
            # device execution on the server's device thread: one X per
            # llf slice when the command ran preemptively (the wall
            # interval [t_start, t_end] then interleaves with other
            # commands), else a single full-cost X
            if rec.server is not None and ev.t_start > 0.0:
                sp = pid("server", rec.server)
                dt = tid(sp, f"dev:{rec.device}")
                if rec.slices:
                    n_sl = len(rec.slices)
                    for i, (a, b) in enumerate(rec.slices):
                        ev_list.append({"ph": "X", "cat": "exec",
                                        "name": str(name), "pid": sp,
                                        "tid": dt, "ts": a * us,
                                        "dur": (b - a) * us,
                                        "args": {"tenant": rec.tenant,
                                                 "slice": i,
                                                 "slices": n_sl}})
                else:
                    ev_list.append({"ph": "X", "cat": "exec",
                                    "name": str(name), "pid": sp,
                                    "tid": dt,
                                    "ts": ev.t_start * us,
                                    "dur": rec.cost * us,
                                    "args": {"tenant": rec.tenant}})
        # NIC occupancy
        for label, t0, busy in self.nic_spans:
            server = label.split(".", 1)[0]
            p = pid("server", server)
            ev_list.append({"ph": "X", "cat": "nic", "name": "busy",
                            "pid": p, "tid": tid(p, label),
                            "ts": t0 * us, "dur": busy * us})
        # run-queue depth samples as counter tracks on the owning server
        for label, t, depth in self.runq:
            server = label.split(".", 1)[0]
            p = pid("server", server)
            ev_list.append({"ph": "C", "cat": "sched", "name": label,
                            "pid": p, "tid": 0, "ts": t * us,
                            "args": {"queued": depth}})
        # transfers on the net process, one thread per link (wire
        # occupancy gets its own sibling thread so the X slices nest
        # cleanly next to the queue-inclusive transfer spans)
        np_ = pid("net", "links") if (self.transfers or
                                      self.link_spans) else None
        for label, t0, busy in self.link_spans:
            ev_list.append({"ph": "X", "cat": "net", "name": "wire",
                            "pid": np_, "tid": tid(np_, label + ".wire"),
                            "ts": t0 * us, "dur": busy * us})
        for kind, link, tenant, t0, t1, nbytes, eid, chunks \
                in self.transfers:
            t = tid(np_, link)
            ev_list.append({"ph": "X", "cat": "net", "name": kind,
                            "pid": np_, "tid": t, "ts": t0 * us,
                            "dur": max(0.0, (t1 - t0)) * us,
                            "args": {"bytes": nbytes, "tenant": tenant,
                                     "event": eid,
                                     "chunks": len(chunks) if chunks
                                     else 0}})
            for arrive in (chunks or ()):
                ev_list.append({"ph": "i", "cat": "net",
                                "name": "chunk_landfall", "pid": np_,
                                "tid": t, "ts": arrive * us,
                                "s": "t"})
        # placement decisions
        for t, tenant, name, chosen, policy in self.placements:
            p = pid("tenant", tenant)
            ev_list.append({"ph": "i", "cat": "placement",
                            "name": f"{name}->{chosen}", "pid": p,
                            "tid": tid(p, "placement"), "ts": t * us,
                            "s": "t", "args": {"policy": policy}})
        # dedup savings
        for t, tenant, nbytes in self.dedups:
            p = pid("tenant", tenant)
            ev_list.append({"ph": "i", "cat": "dedup",
                            "name": "dedup" if nbytes >= 0
                            else "dedup_undo",
                            "pid": p, "tid": tid(p, "store"),
                            "ts": t * us, "s": "t",
                            "args": {"bytes": nbytes}})
        # admission verdicts: instants on the tenant's process carrying
        # the controller's prediction, so predicted-vs-actual reads off
        # the same screen as the tenant's command latencies
        for t, tenant, status, predicted, req_slo, slo_s, reason \
                in self.admissions:
            p = pid("tenant", tenant)
            ev_list.append({"ph": "i", "cat": "admission",
                            "name": f"admission:{status}", "pid": p,
                            "tid": tid(p, "admission"), "ts": t * us,
                            "s": "t",
                            "args": {"predicted_ms": predicted * 1e3,
                                     "requested_slo_ms":
                                         (req_slo or 0.0) * 1e3,
                                     "granted_slo_ms":
                                         (slo_s or 0.0) * 1e3,
                                     "reason": reason}})
        # SLO violations: instants on the tenant's own process so the
        # breach lines up with the offending command track
        for t, tenant, eid, latency, slo in self.slo:
            p = pid("tenant", tenant)
            ev_list.append({"ph": "i", "cat": "slo",
                            "name": "slo_violation", "pid": p,
                            "tid": tid(p, "slo"), "ts": t * us,
                            "s": "t",
                            "args": {"event": eid,
                                     "latency_ms": latency * 1e3,
                                     "slo_ms": slo * 1e3}})
        # fault markers: global instants so they cut across every track
        for t, kind, target, detail in self.faults:
            p = pid("cluster", "faults")
            ev_list.append({"ph": "i", "cat": "fault",
                            "name": f"{kind}:{target}", "pid": p,
                            "tid": 0, "ts": t * us, "s": "g",
                            "args": {"detail": detail}})
        return ev_list

    def write_perfetto(self, path: str) -> None:
        # a ``.gz`` suffix gzips transparently (1000-UE fleet traces
        # are large; Perfetto's UI loads gzipped JSON directly)
        opener = gzip.open if str(path).endswith(".gz") else open
        with opener(path, "wt") as f:
            json.dump({"traceEvents": self.perfetto_events(),
                       "displayTimeUnit": "ms"}, f, indent=None,
                      separators=(",", ":"))
            f.write("\n")

    # ---- causal critical-path analysis (DESIGN.md §11) ----
    def critical_path(self, exact: bool = False, root=None):
        """Reconstruct the happens-before DAG from the recorded spans
        and walk the binding constraint backward from the last finished
        command (or ``root``): a ``critpath.CriticalPath`` whose
        segments tile the makespan exactly. Post-hoc only — reads the
        span store, never the live simulation."""
        from . import critpath
        return critpath.critical_path(self, exact=exact, root=root)

    def format_blame(self, top: int = 12, title: str = "") -> str:
        """Terminal table ranking the critical path's makespan
        attribution per (resource, stage)."""
        from . import critpath
        return critpath.format_blame(self.critical_path(), top=top,
                                     title=title)

    def whatif(self, **knobs) -> dict:
        """Re-time the recorded DAG under hypothetical substrate changes
        (``nic_bandwidth=2.0``, ``device_speed=2.0``, ``wire=0.0``,
        ``overlap_halo=True``); see ``critpath.whatif`` for the model
        and its assumptions."""
        from . import critpath
        return critpath.whatif(self, **knobs)
