"""Elastic cluster membership: server join / drain / crash (DESIGN.md §7).

Until now the server set was frozen at ``Cluster`` construction and the
only lifecycle verb was ``ClientRuntime.detach()`` — the *client* side
of the paper's §4.3 robustness story. MEC platforms manage the server
side too (ETSI MEP application instantiation / migration / termination;
arXiv:1702.05309 surveys the mobility machinery), so production
credibility requires surviving server loss, not only radio flaps.

Each host carries a lifecycle state:

    JOINING ──▶ ACTIVE ──▶ DRAINING ──▶ DEAD
                   │                     ▲
                   └───── crash ─────────┘

* **join** (``Cluster.join_server``): a new ``ServerHost`` is admitted
  live — peer links and NIC models created on the spot, a session
  handshaken for every attached tenant — and becomes placement-eligible
  (ACTIVE) once every tenant's session is established. A tenant that
  attaches later sees it like any seed host.
* **drain** (``Cluster.drain_server``): graceful decommission. New
  placements stop (every tenant's session flips unavailable and the
  placement engine drops the host from its candidate set), the host's
  scheduled-but-unstarted commands — both run-queue entries and
  dependency waiters — are requeued through the ``PlacementEngine``
  onto survivors with their remaining dependencies intact (command ids
  are preserved, so the §4.3 dedup guarantees exactly-once under
  requeue), and buffers whose ONLY replica lives on the drained host
  are migrated out over the pipelined P2P path (replicas that exist
  elsewhere — another server or the client — are simply dropped). The
  host retires (DEAD) only when every migration landed and its devices,
  NIC, and links have gone idle: zero lost, zero duplicated commands.
* **crash** (``Cluster.crash_server``): abrupt loss. Every link
  touching the host closes (killing mid-flight chunked transfers, see
  ``Link``), live events targeting the host fail fast — dependents on
  survivors observe ERROR through the normal completion routing instead
  of hanging — store replicas and pendings on the host drop (riders
  fall back exactly like the PR 4 ride-death path), and clients are
  expected to retry against re-placed servers with bounded exponential
  backoff (``ClientRuntime.reconnect`` retries; see ``benchmarks/
  chaos.py`` for the closed-loop recovery pattern).

``FaultSchedule`` (netsim) scripts these verbs — plus link-flap windows
— deterministically on the simulated clock, so chaos runs are bit-
reproducible and their sim-time gates portable.

The manager mutates nothing on hosts it is not asked to touch: a
bystander tenant whose traffic never crosses the failed host's links
keeps bit-identical timestamps through a drain or crash (tested).
"""
from __future__ import annotations

from typing import Callable, Optional

# host lifecycle states
JOINING, ACTIVE, DRAINING, DEAD = ("joining", "active", "draining", "dead")


class MembershipManager:
    """Cluster-wide server lifecycle state machine (one per ``Cluster``).

    Holds the authoritative ``state`` per host and orchestrates the
    three verbs; the per-object mechanics (session attach, command
    requeue, event failure) live on ``Cluster`` / ``ClientRuntime`` /
    ``ServerSim`` so this module never needs to import the runtime."""

    def __init__(self, cluster):
        self.cluster = cluster
        self.states: dict = {}        # host name -> lifecycle state
        # scoreboard (Cluster.stats()['membership'])
        self.joins = 0
        self.drains = 0
        self.crashes = 0
        self.requeued_commands = 0    # drain: commands re-placed
        self.replicas_migrated = 0    # drain: sole replicas moved out
        self.replicas_dropped = 0     # drain/crash: redundant replicas
        self.drain_ms: list = []      # per completed drain, sim ms

    # ---- state ----
    def register(self, name: str, state: str = ACTIVE) -> None:
        self.states[name] = state

    def state(self, name: str) -> str:
        return self.states.get(name, DEAD)

    def is_eligible(self, name: str) -> bool:
        """Placement-eligible: new work may land here."""
        return self.states.get(name) == ACTIVE

    def is_alive(self, name: str) -> bool:
        """Reachable at all (ACTIVE or still draining its own work)."""
        return self.states.get(name) in (ACTIVE, DRAINING)

    # ---- join ----
    def join(self, spec, at: Optional[float] = None,
             on_active: Optional[Callable] = None):
        """Admit ``spec`` as a live server. Peer links + NIC models are
        created now; every attached tenant handshakes a session; the
        host turns ACTIVE (placement-eligible) once the last handshake
        lands. Rejoining a DEAD name replaces the corpse with a fresh
        host (fresh sessions, fresh links — nothing resurrects)."""
        clock = self.cluster.clock
        if at is not None:
            clock.schedule_at(at, self.join, spec, None, on_active)
            return
        name = spec.name
        if self.states.get(name) in (JOINING, ACTIVE, DRAINING):
            raise ValueError(f"server {name!r} already in the cluster "
                             f"({self.states[name]})")
        host = self.cluster._admit_host(spec)
        self.states[name] = JOINING
        host.state = JOINING
        self.joins += 1
        tr = self.cluster.trace
        if tr is not None:
            tr.fault(clock.now, "join", self.cluster.trace_prefix + name)

        def activate():
            if self.states.get(name) != JOINING:
                return              # crashed/drained while joining
            self.states[name] = ACTIVE
            host.state = ACTIVE
            if tr is not None:
                tr.fault(clock.now, "join_active",
                         self.cluster.trace_prefix + name)
            if on_active is not None:
                on_active()

        deadlines = [rt._attach_server(host)
                     for rt in list(self.cluster.clients)]
        if deadlines:
            # handshake completions are scheduled at exactly these sim
            # times with earlier heap sequence numbers, so activation
            # observes every session established
            clock.schedule_at(max(deadlines), activate)
        else:
            activate()

    # ---- drain ----
    def drain(self, name: str, at: Optional[float] = None,
              on_complete: Optional[Callable] = None):
        """Gracefully decommission ``name``: stop new placements,
        requeue its scheduled-but-unstarted commands through the
        placement engine, migrate sole-replica buffers to survivors,
        then retire once the host is idle. Exactly-once: requeued
        commands keep their ids and leave the old queues before any
        survivor sees them."""
        clock = self.cluster.clock
        if at is not None:
            clock.schedule_at(at, self.drain, name, None, on_complete)
            return
        if self.states.get(name) != ACTIVE:
            raise ValueError(f"cannot drain server {name!r} in state "
                             f"{self.states.get(name)!r}")
        cluster = self.cluster
        host = cluster.hosts[name]
        self.states[name] = DRAINING
        host.state = DRAINING
        self.drains += 1
        t0 = clock.now
        tr = cluster.trace
        if tr is not None:
            tr.fault(t0, "drain", cluster.trace_prefix + name)
        obligations = {"n": 1}        # sentinel until the sweep finishes

        def done_one(_e=None):
            obligations["n"] -= 1
            if not obligations["n"]:
                self._finalize_drain(name, t0, on_complete)

        # 1. no new placements: the host leaves every tenant's available
        # set (enqueue_kernel raises / the placement engine skips it)
        for rt in list(cluster.clients):
            sess = rt.sessions.get(name)
            if sess is not None:
                sess.available = False

        # 2. requeue scheduled-but-unstarted work. Run-queue entries
        # first (dep-resolved, waiting for the device), then dependency
        # waiters (their remaining deps travel with them). Both leave
        # the draining host's tables BEFORE the re-send, so the command
        # can only ever execute once.
        self.requeued_commands += self._requeue_unstarted(name, host)

        # 3. re-home resident data: buffers whose ONLY replica lives
        # here move to a survivor over the pipelined migration path;
        # replicas that exist elsewhere (another server, or the client
        # holding the canonical copy) are simply dropped.
        for rt in list(cluster.clients):
            for buf in rt._buffers:
                if name not in buf.valid_on:
                    continue
                if buf.valid_on - {name}:
                    buf.valid_on.discard(name)
                    self.replicas_dropped += 1
                    continue
                target = rt._pick_failover_server(exclude=name)
                if target is None:
                    buf.valid_on.discard(name)  # data survives host-side
                    self.replicas_dropped += 1
                    continue
                obligations["n"] += 1
                self.replicas_migrated += 1
                mig = rt.enqueue_migration(buf, target)
                mig.on_complete(done_one)
        done_one()                    # release the sentinel

    def _requeue_unstarted(self, name: str, host) -> int:
        """Requeue every scheduled-but-unstarted command on ``host``:
        run-queue entries (dep-resolved, waiting for the device) first,
        then dependency waiters — whose remaining deps travel with
        them. Both leave the draining host's tables BEFORE the re-send,
        so a command can only ever execute once. Returns the count."""
        n = 0
        for sch in host.schedulers.values():
            for session, tag in sch.drain_queued():
                if tag is None:
                    continue
                ev, dev_name = tag
                session.rt._requeue_after_drain(ev, name, dev_name, [])
                n += 1
        for srv in list(host.sessions.values()):
            for ev, dev_name, dep_ids in srv.drain_waiters():
                srv.rt._requeue_after_drain(ev, name, dev_name, dep_ids)
                n += 1
        return n

    def _finalize_drain(self, name: str, t0: float,
                        on_complete: Optional[Callable]) -> None:
        """Retire the host once it has gone quiet: devices idle, NIC
        drained, peer links drained (a requeue-triggered migration may
        still be pushing FROM the draining host). Re-arms itself at the
        latest busy-until when anything is still in flight."""
        cluster = self.cluster
        clock = cluster.clock
        host = cluster.hosts.get(name)
        if host is None or self.states.get(name) != DRAINING:
            return
        busy = max((dev._busy_until for dev in host.devices.values()),
                   default=0.0)
        if host.nic is not None and host.nic._busy_until > busy:
            busy = host.nic._busy_until
        if host.nic_in is not None and host.nic_in._busy_until > busy:
            busy = host.nic_in._busy_until
        # a link's last message is delivered ``latency`` after its wire
        # leg frees — wait for delivery, not just for the wire
        for (a, b), link in cluster.p_links.items():
            if name in (a, b) and link._busy_until + link.latency > busy:
                busy = link._busy_until + link.latency
        for rt in cluster.clients:
            link = rt.c_links.get(name)
            if link is not None and \
                    link._busy_until + link.latency > busy:
                busy = link._busy_until + link.latency
        if busy > clock.now:
            clock.schedule_at(busy, self._finalize_drain, name, t0,
                              on_complete)
            return
        # late arrivals: commands that were on the wire when the drain
        # began registered after the first sweep — requeue them and
        # re-check (their departure may leave fresh link activity)
        late = self._requeue_unstarted(name, host)
        if late:
            self.requeued_commands += late
            clock.schedule_at(clock.now, self._finalize_drain, name, t0,
                              on_complete)
            return
        self.states[name] = DEAD
        host.state = DEAD
        now = clock.now
        for rt in list(cluster.clients):
            rt._server_retired(name)
        for (a, b), link in cluster.p_links.items():
            if name in (a, b):
                link.close()
        host.sessions.clear()
        if cluster.store is not None:
            self.replicas_dropped += \
                cluster.store.server_retired(name)
        self.drain_ms.append((now - t0) * 1e3)
        tr = cluster.trace
        if tr is not None:
            tr.fault(now, "drain_complete", cluster.trace_prefix + name,
                     detail=f"drain_ms={(now - t0) * 1e3:.3f}")
        if on_complete is not None:
            on_complete()

    # ---- crash ----
    def crash(self, name: str, at: Optional[float] = None):
        """Abrupt server loss: links die (mid-flight chunked transfers
        drop per-chunk), live events on the host fail fast with ERROR
        propagated to dependents on survivors, store replicas and
        pendings vanish (riders fall back), queued commands are gone.
        Recovery is the CLIENT's job: retry / re-place with bounded
        exponential backoff (§4.3 replay dedup keeps it exactly-once)."""
        clock = self.cluster.clock
        if at is not None:
            clock.schedule_at(at, self.crash, name)
            return
        if self.states.get(name) not in (JOINING, ACTIVE, DRAINING):
            raise ValueError(f"cannot crash server {name!r} in state "
                             f"{self.states.get(name)!r}")
        cluster = self.cluster
        host = cluster.hosts[name]
        self.states[name] = DEAD
        host.state = DEAD
        self.crashes += 1
        tr = cluster.trace
        if tr is not None:
            tr.fault(clock.now, "crash", cluster.trace_prefix + name)
        # links first: closing kills mid-flight chunked transfers, whose
        # on_dropped callbacks fire at `now` (after this function) and
        # find their events already failed below — the guards make that
        # a no-op, so ordering is safe either way
        for (a, b), link in cluster.p_links.items():
            if name in (a, b):
                link.close()
        # queued-but-unstarted commands die with the host (their events
        # fail below); drain the policies so nothing dispatches later
        for sch in host.schedulers.values():
            sch.drain_queued()
        for rt in list(cluster.clients):
            rt._server_crashed(name)
        host.sessions.clear()
        if cluster.store is not None:
            self.replicas_dropped += cluster.store.server_retired(name)

    # ---- reporting ----
    def stats(self) -> dict:
        return {
            "states": dict(self.states),
            "joins": self.joins,
            "drains": self.drains,
            "crashes": self.crashes,
            "requeued_commands": self.requeued_commands,
            "replicas_migrated": self.replicas_migrated,
            "replicas_dropped": self.replicas_dropped,
            "drain_ms": list(self.drain_ms),
        }
