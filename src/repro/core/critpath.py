"""Causal critical-path analysis over the span store (DESIGN.md §11).

The tracer (DESIGN.md §9) records *what happened when*; this module
answers *why the workload took as long as it did*. It reconstructs the
happens-before DAG of a finished trace — explicit command dependencies
(``CmdRecord.deps``) plus resource edges (serial device occupancy from
execution intervals and llf slices, per-link transfer ordering, NIC
occupancy chains, per-chunk landfall) — and provides three views:

* ``critical_path``: walk the binding constraint backward from the
  last-finishing command. The result is a gap-free tiling of
  ``[path start, makespan end]`` by segments, each blaming one
  (resource, stage) pair — so the segment sum equals the makespan
  *exactly* (in rational arithmetic under ``exact=True``), which the
  CI gate in ``benchmarks/latency_breakdown.py`` enforces.
* ``whatif``: re-time the recorded DAG under hypothetical substrate
  changes (``nic_bandwidth=2.0``, ``device_speed=2.0``, ``wire=0.0``,
  ``overlap_halo=True``) to bound an optimization's win before
  building it. Uniquely validatable here: the simulator can actually
  re-run with the changed parameter, and the benchmark gates the
  projection within 10% of the ground-truth re-run.
* ``format_blame``: terminal table ranking makespan attribution.

Everything is post-hoc: these functions read the append-only span
store after the simulation drained and never touch the clock, so the
five sim-time baselines stay byte-identical whether or not anyone
calls them.

Walk semantics (the resource edges, DESIGN.md §11):

* ``completion`` — device completion → client ack (completion wire +
  reap), attributed to the tenant's access link.
* ``execute`` — device occupancy of the command itself; under llf the
  recorded slices are tiled and the holes between them (other
  commands' slices) become ``preempt_wait`` on the same device.
* ``transfer`` — a migration/read command whose "execution" is a wire
  leg, attributed to the link that carried it.
* ``queue_wait`` — run-queue time, tiled backward with the device's
  actual occupant intervals: devices are serial and work-conserving,
  so the wait is exactly the predecessors' execution (each sub-segment
  names the occupant).
* ``notify`` — the binding dependency resolved on another server; the
  gap to readiness is its completion-notification leg, and the walk
  jumps *into* that dependency (this is the causal chain: shortening
  anything after it cannot shorten the makespan).
* ``dep_wait`` — dependency wait the walk cannot attribute to a
  recorded command (dep enqueued before tracing attached, or pure
  daemon delivery delay).
* ``submit_wire`` — enqueue → daemon submit stamp on the access link.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Optional

from .trace import Tracer

__all__ = ["Segment", "CriticalPath", "critical_path", "format_blame",
           "whatif"]

_COMPLETE = "complete"


class Segment:
    """One tile of the critical path: ``[t0, t1)`` blamed on
    ``(resource, stage)``; ``ev_id`` names the command occupying the
    resource there (None when idle/unattributed)."""

    __slots__ = ("t0", "t1", "resource", "stage", "ev_id")

    def __init__(self, t0, t1, resource: str, stage: str,
                 ev_id: Optional[int] = None):
        self.t0 = t0
        self.t1 = t1
        self.resource = resource
        self.stage = stage
        self.ev_id = ev_id

    @property
    def dur(self):
        return self.t1 - self.t0

    def __repr__(self):
        return (f"Segment({float(self.t0):.9f}, {float(self.t1):.9f}, "
                f"{self.resource!r}, {self.stage!r}, ev={self.ev_id})")


class CriticalPath:
    """Gap-free tiling of ``[t0, t1]`` (causal order); ``makespan`` is
    ``t1 - t0`` and equals the segment-duration sum exactly — rational
    arithmetic when built with ``exact=True``."""

    __slots__ = ("segments", "t0", "t1", "exact")

    def __init__(self, segments: list, t0, t1, exact: bool):
        self.segments = segments
        self.t0 = t0
        self.t1 = t1
        self.exact = exact

    @property
    def makespan(self):
        return self.t1 - self.t0

    def segment_sum(self):
        total = Fraction(0) if self.exact else 0.0
        for s in self.segments:
            total += s.dur
        return total

    def blame(self) -> list:
        """Ranked attribution: one row per (resource, stage), summed
        over the path, descending by time. Shares are of the makespan
        (they sum to 1 by the tiling identity)."""
        agg: dict = {}
        for s in self.segments:
            key = (s.resource, s.stage)
            tot, cnt = agg.get(key, (0.0, 0))
            agg[key] = (tot + float(s.dur), cnt + 1)
        mk = float(self.makespan) or 1.0
        rows = [{"resource": r, "stage": st, "seconds": tot,
                 "share": tot / mk, "segments": cnt}
                for (r, st), (tot, cnt) in agg.items()]
        rows.sort(key=lambda row: (-row["seconds"], row["resource"],
                                   row["stage"]))
        return rows

    def stage_totals(self) -> dict:
        out: dict = {}
        for s in self.segments:
            out[s.stage] = out.get(s.stage, 0.0) + float(s.dur)
        return out


def _stamp_cache(cmds: dict) -> dict:
    return {eid: Tracer._stamps(rec) for eid, rec in cmds.items()}


def _device_intervals(all_cmds: dict) -> dict:
    """(server, device) -> sorted [(t0, t1, ev_id)] actual occupancy.
    Includes unfinished/failed commands — their device time was real —
    and uses llf slices when the command ran preemptively."""
    by_dev: dict = {}
    for eid, rec in all_cmds.items():
        if rec.server is None or rec.ev.t_start <= 0.0:
            continue
        lst = by_dev.setdefault((rec.server, rec.device), [])
        if rec.slices:
            for a, b in rec.slices:
                lst.append((a, b, eid))
        else:
            lst.append((rec.ev.t_start, rec.ev.t_start + rec.cost, eid))
    for lst in by_dev.values():
        lst.sort()
    return by_dev


def _transfer_maps(tracer: Tracer):
    """ev_id-keyed transfer indexes: payload legs that ARE a command's
    lifecycle stage. ``mig`` covers migration pushes and read returns
    (the command's execute interval is the wire leg), ``upl`` covers
    write uploads (inside the submit leg)."""
    mig: dict = {}
    upl: dict = {}
    for kind, link, _tn, t0, t1, nbytes, eid, chunks in tracer.transfers:
        if eid is None:
            continue
        entry = (link, t0, t1, nbytes, chunks)
        if kind == "upload":
            upl[eid] = entry
        else:                      # migration / read_return
            mig[eid] = entry
    return mig, upl


def critical_path(tracer: Tracer, exact: bool = False,
                  root=None) -> CriticalPath:
    """Extract the critical path ending at ``root`` (default: the
    last-finishing command). See the module docstring for the edge
    semantics; the returned segments tile the window exactly."""
    cmds = {eid: rec for eid, rec in tracer.cmds.items()
            if rec.ev.status == _COMPLETE and
            Tracer._cmd_end(rec.ev) > 0.0}
    if not cmds:
        z = Fraction(0) if exact else 0.0
        return CriticalPath([], z, z, exact)
    stamps = _stamp_cache(cmds)
    mig, upl = _transfer_maps(tracer)
    devs = _device_intervals(tracer.cmds)

    def num(x):
        return Fraction(x) if exact else x

    if root is None:
        root = max(stamps, key=lambda e: (stamps[e][5], e))
    segs: list = []

    def seg(a, b, resource, stage, eid=None):
        if b > a:
            segs.append(Segment(num(a), num(b), resource, stage, eid))

    rec = cmds[root]
    entry = stamps[root][5]
    origin = stamps[root][0]
    # the walk always moves strictly backward in (time, command) — the
    # guard only bounds pathological traces, not correct ones
    for _guard in range(len(cmds) * 8 + 64):
        eid = rec.ev.id
        q, sub, ready, start, end, done = stamps[eid]
        t = entry
        client_res = f"client:{rec.tenant}"
        dev_res = (f"{rec.server}/{rec.device}" if rec.server is not None
                   else "daemon")
        # completion: device end -> client ack
        if t > end:
            seg(end, t, client_res, "completion", eid)
            t = end
        # a join/daemon event that never started anything has no
        # execute/queue interval of its own — its whole window up to
        # the completion stamp is dependency wait (walked below)
        ran = rec.ev.t_start > 0.0 or eid in mig
        # execute: device occupancy, wire leg, or llf slice tiling
        if ran and t > start:
            if eid in mig:
                seg(start, t, mig[eid][0], "transfer", eid)
            elif rec.slices:
                cur = t
                for a, b in reversed(rec.slices):
                    if cur <= start:
                        break
                    if b < cur:
                        # hole between slices: someone else's slice ran
                        lo = b if b > start else start
                        seg(lo, cur, dev_res, "preempt_wait", eid)
                        cur = lo
                        if cur <= start:
                            break
                    lo = a if a > start else start
                    if lo < cur:
                        seg(lo, cur, dev_res, "execute", eid)
                        cur = lo
                if cur > start:
                    seg(start, cur, dev_res, "execute", eid)
            else:
                seg(start, t, dev_res, "execute", eid)
            t = start
        # queue wait: tile with the device's actual occupants. Only
        # commands that entered a device run queue (cmd_ready fired)
        # have one — for a server-less command (migration, daemon
        # write) the [ready, start] gap is dependency wait: the
        # transfer could not start before its producer finished, and
        # the dep-jump below walks into that producer
        if rec.server is not None and t > ready:
            ivs = devs.get((rec.server, rec.device), ())
            cur = t
            for a, b, oid in reversed(ivs):
                if cur <= ready:
                    break
                if oid == eid:
                    continue
                if a >= cur:
                    continue
                if b > cur:
                    b = cur         # clip an interval spanning our start
                if b < cur:
                    # device idle while we were queued (dispatch seam)
                    lo = b if b > ready else ready
                    seg(lo, cur, dev_res, "queue_wait")
                    cur = lo
                    if cur <= ready:
                        break
                lo = a if a > ready else ready
                if lo < cur:
                    seg(lo, cur, dev_res, "queue_wait", oid)
                    cur = lo
            if cur > ready:
                seg(ready, cur, dev_res, "queue_wait")
            t = ready
        # dependency wait: jump into the binding (latest-resolving) dep
        nxt = None
        if t > sub:
            best = None
            best_end = sub
            for d in (rec.deps or ()):
                drec = cmds.get(d)
                if drec is None:
                    continue
                de = stamps[d][4]
                if best_end < de <= t:
                    best_end, best = de, drec
            if best is not None:
                seg(best_end, t, "notify", "notify", best.ev.id)
                nxt = (best, best_end)
            else:
                seg(sub, t, "deps", "dep_wait", eid)
        if nxt is None:
            if sub > q:
                res = upl[eid][0] if eid in upl else client_res
                seg(q, sub, res, "submit_wire", eid)
            origin = q
            break
        rec, entry = nxt
    segs.reverse()
    return CriticalPath(segs, num(origin), num(stamps[root][5]), exact)


def format_blame(path: CriticalPath, top: int = 12,
                 title: str = "") -> str:
    """Terminal blame table for a ``CriticalPath``."""
    lines = []
    if title:
        lines.append(f"# {title}")
    mk = float(path.makespan)
    lines.append(f"critical path: {len(path.segments)} segments, "
                 f"makespan {mk * 1e3:.3f} ms "
                 f"[{float(path.t0) * 1e3:.3f} .. "
                 f"{float(path.t1) * 1e3:.3f}]")
    lines.append(f"{'resource':<28}{'stage':<14}{'ms':>10}{'share%':>8}"
                 f"{'segs':>6}")
    rows = path.blame()
    for row in rows[:top]:
        lines.append(f"{row['resource']:<28}{row['stage']:<14}"
                     f"{row['seconds'] * 1e3:>10.3f}"
                     f"{row['share'] * 100.0:>8.2f}"
                     f"{row['segments']:>6}")
    rest = rows[top:]
    if rest:
        tot = sum(r["seconds"] for r in rest)
        lines.append(f"{'(other)':<28}{'':<14}{tot * 1e3:>10.3f}"
                     f"{tot / (mk or 1.0) * 100.0:>8.2f}"
                     f"{sum(r['segments'] for r in rest):>6}")
    return "\n".join(lines)


def _scaled_wire(dur: float, nbytes: float, link_label: str,
                 links: dict, wire: float, nic_bandwidth: float) -> float:
    """Re-time a recorded wire leg: the bandwidth-proportional part
    (``nbytes / recorded link bandwidth``) scales with the NIC knob,
    the rest (latency, serialization overheads, copy costs) with the
    blanket ``wire`` knob. ``wire == 0`` idealizes communication away
    entirely."""
    if wire == 0.0:
        return 0.0
    lat_bw = links.get(link_label)
    if lat_bw is None or lat_bw[1] <= 0.0 or nbytes <= 0.0:
        return wire * dur
    var = nbytes / lat_bw[1]
    if var > dur:
        var = dur
    return wire * (dur - var) + var / nic_bandwidth


def whatif(tracer: Tracer, nic_bandwidth: float = 1.0,
           device_speed: float = 1.0, wire: float = 1.0,
           overlap_halo: bool = False) -> dict:
    """Forward re-timing of the recorded DAG under hypothetical
    substrate changes. Knobs:

    * ``nic_bandwidth`` — scale every link/NIC bandwidth (2.0 = twice
      as fast); only the bandwidth-proportional share of each recorded
      wire leg moves.
    * ``device_speed`` — scale device compute rate (2.0 = kernels take
      half the device-seconds).
    * ``wire`` — blanket scale on every communication delta (0.0 =
      ideal network: submit/notify/completion/transfers free).
    * ``overlap_halo`` — cut-through into compute: a dependency that is
      a chunked migration resolves at its *first* chunk's landfall
      instead of the last (the ROADMAP "hide the wire" follow-up).

    Model assumptions (DESIGN.md §11): recorded orders are preserved —
    commands dispatch per device in recorded order and payload
    transfers serialize per link in recorded order; preempted commands
    are re-timed as solid ``cost`` blocks; link contention beyond the
    per-resource FIFO (NIC cross-talk between links) is second-order
    and ignored. Projections are therefore estimates — the benchmark
    gate validates them against ground-truth re-runs within 10%.
    """
    nic_bandwidth = float(nic_bandwidth)
    device_speed = float(device_speed)
    wire = float(wire)
    if nic_bandwidth <= 0.0 or device_speed <= 0.0 or wire < 0.0:
        raise ValueError("knobs must be positive (wire may be 0.0)")
    cmds = {eid: rec for eid, rec in tracer.cmds.items()
            if rec.ev.status == _COMPLETE and
            Tracer._cmd_end(rec.ev) > 0.0}
    if not cmds:
        return {"recorded_s": 0.0, "projected_s": 0.0, "speedup": 1.0}
    stamps = _stamp_cache(cmds)
    mig, upl = _transfer_maps(tracer)
    links = tracer.links

    # recorded device dispatch order -> per-command predecessor
    prev_on_dev: dict = {}
    by_dev: dict = {}
    for eid, rec in cmds.items():
        if rec.server is not None and rec.ev.t_start > 0.0:
            by_dev.setdefault((rec.server, rec.device), []).append(eid)
    for lst in by_dev.values():
        lst.sort(key=lambda e: (stamps[e][3], e))
        for prv, nx in zip(lst, lst[1:]):
            prev_on_dev[nx] = prv
    # recorded per-link transfer order (payload legs only)
    prev_on_link: dict = {}
    by_link: dict = {}
    for eid in cmds:
        if eid in mig:
            by_link.setdefault(mig[eid][0], []).append(eid)
    for lst in by_link.values():
        lst.sort(key=lambda e: (mig[e][1], e))
        for prv, nx in zip(lst, lst[1:]):
            prev_on_link[nx] = prv

    # prepass: re-time every upload's wire window, serialized per link
    # in recorded order (uploads depend only on their enqueue time).
    # Commands whose recorded submit landed INSIDE an upload's wire
    # window were queued behind that payload on the shared client link,
    # so their delivery is paced by the upload — it moves
    # proportionally within the upload's re-timed window, not by a
    # blanket scale of the recorded delta.
    new_upl: dict = {}
    paced: dict = {}
    upl_free: dict = {}
    for eid in sorted((e for e in upl if e in cmds),
                      key=lambda e: (upl[e][1], e)):
        lk, t0, t1, nbytes, _ch = upl[eid]
        uq = stamps[eid][0]
        pre = t0 - uq
        if pre < 0.0:
            pre = 0.0
        w0 = uq + wire * pre
        lf = upl_free.get(lk, 0.0)
        if lf > w0:
            w0 = lf
        w1 = w0 + _scaled_wire(t1 - t0, nbytes, lk, links, wire,
                               nic_bandwidth)
        upl_free[lk] = w1
        new_upl[eid] = (w0, w1)
        if t1 > t0:
            paced.setdefault(cmds[eid].tenant, []).append((t0, t1, eid))

    # forward pass in a dependency-safe order: a dep's (filled) start
    # precedes its consumer's, and enqueue ids are monotonic
    order = sorted(cmds, key=lambda e: (stamps[e][3], stamps[e][4], e))
    new_start: dict = {}
    new_end: dict = {}
    new_done: dict = {}
    for eid in order:
        rec = cmds[eid]
        q, sub, ready, start, end, done = stamps[eid]
        if eid in upl:
            _lk, _t0, _t1, _nb, _ch = upl[eid]
            _w0, w1 = new_upl[eid]
            tail = sub - _t1            # post-wire daemon latency
            if tail < 0.0:
                tail = 0.0
            sub_n = w1 + wire * tail
        else:
            sub_n = None
            for t0u, t1u, ueid in paced.get(rec.tenant, ()):
                if t0u < sub <= t1u:
                    f = (sub - t0u) / (t1u - t0u)
                    w0, w1 = new_upl[ueid]
                    sub_n = w0 + f * (w1 - w0)
                    break
            if sub_n is None:
                sub_n = q + wire * (sub - q)
            elif sub_n < q:
                sub_n = q
        constraint = sub_n
        rec_base = sub
        for d in (rec.deps or ()):
            de = stamps[d][4] if d in cmds else None
            if de is None:
                continue
            if de > rec_base:
                rec_base = de
            nde = new_end.get(d)
            if nde is None:
                continue
            if overlap_halo and d in mig and mig[d][4]:
                # resolve at the first chunk's landfall, proportionally
                # re-timed inside the dep's new transfer window
                ds, de_r = stamps[d][3], stamps[d][4]
                first = mig[d][4][0]
                frac = ((first - ds) / (de_r - ds)
                        if de_r > ds else 1.0)
                if frac > 1.0:
                    frac = 1.0
                nde = new_start[d] + frac * (new_end[d] - new_start[d])
            if nde > constraint:
                constraint = nde
        lag = ready - rec_base
        if lag < 0.0:
            lag = 0.0
        ready_n = constraint + wire * lag
        # dispatch under the recorded resource order
        if eid in mig:
            lk, _t0, _t1, nbytes, _ch = mig[eid]
            prv = prev_on_link.get(eid)
            # the wire frees one propagation latency before the
            # previous transfer's ARRIVAL stamp (cut-through): the next
            # payload can be on the link while the last chunk is still
            # in flight
            avail = 0.0
            if prv is not None:
                avail = new_end.get(prv, 0.0) - \
                    wire * links.get(lk, (0.0, 0.0))[0]
            start_n = ready_n if ready_n > avail else avail
            exec_n = _scaled_wire(end - start, nbytes, lk, links, wire,
                                  nic_bandwidth)
        elif rec.server is not None and rec.ev.t_start > 0.0:
            prv = prev_on_dev.get(eid)
            avail = new_end.get(prv, 0.0) if prv is not None else 0.0
            start_n = ready_n if ready_n > avail else avail
            dur = rec.cost if rec.slices else end - start
            exec_n = dur / device_speed
        else:
            # daemon/join event: any part of its [start, end] window
            # that was really waiting on recorded dependencies is
            # modeled by the constraint above, not kept as latency
            start_n = ready_n
            exec_n = end - start
            overlap = (end if end < rec_base else rec_base) - start
            if overlap > 0.0:
                exec_n = exec_n - overlap
                if exec_n < 0.0:
                    exec_n = 0.0
        end_n = start_n + exec_n
        new_start[eid] = start_n
        new_end[eid] = end_n
        new_done[eid] = end_n + wire * (done - end)

    t0_rec = min(st[0] for st in stamps.values())
    rec_mk = max(st[5] for st in stamps.values()) - t0_rec
    prj_mk = max(new_done.values()) - t0_rec
    return {"recorded_s": rec_mk, "projected_s": prj_mk,
            "speedup": (rec_mk / prj_mk) if prj_mk > 0.0 else float("inf"),
            "knobs": {"nic_bandwidth": nic_bandwidth,
                      "device_speed": device_speed, "wire": wire,
                      "overlap_halo": overlap_halo}}
