"""PoCL-R runtime: client driver + server daemons + decentralized
scheduling over a simulated MEC network (paper §4–§5).

Semantics implemented faithfully:

* Commands are pushed to the target server immediately with their event
  dependencies (§5.2); the server dispatches as soon as deps resolve —
  locally-produced events resolve locally, remote ones via peer
  completion notifications, with NO client round-trip (decentralized
  mode). ``scheduling='client'`` routes completions through the client
  instead (the SnuCL-like baseline the paper compares against).
* Buffer migrations go source-server → destination-server directly over
  peer links (§5.1); ``p2p_migration=False`` stages them through the
  client (the naive path: download + upload over the slowest link).
* ``cl_pocl_content_size`` (§5.3): migrations move only the used prefix.
* TCP vs RDMA transports (§5.4) with shadow-buffer staging, registration
  and rkey-exchange costs.
* Connection loss (§4.3): session IDs, command replay on reconnect,
  server-side dedup of already-processed commands, device-unavailable
  status, optional local fallback execution (Fig. 4).

Kernels execute *functionally* (real arrays) in causal simulation order,
so the same runtime that produces latency numbers also produces bit-exact
results for the tests.

Dispatch is O(1) per command (DESIGN.md §1): each server keeps an
indexed waiter table (dep event id → waiting commands, with per-command
remaining-dep counters) and an explicit ready queue instead of rescanning
a pending list; completions are routed only to servers that registered a
dependent on the event (``completion_routing='subscription'``, matching
the paper's direct P2P signaling) instead of broadcast to every peer; and
finished events are retired from all runtime tables once nobody holds a
reference, so long runs stay memory-bounded.
"""
from __future__ import annotations

import dataclasses
import logging
import secrets
from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import commands as C
from repro.core.buffers import Buffer
from repro.core.events import (COMPLETE, ERROR, QUEUED, RUNNING, SUBMITTED,
                               Event)
from repro.core.netsim import DeviceSim, Link, SimClock
from repro.core.transport import (make_transport, wire_scale,
    CLIENT_SUBMIT, CLIENT_REAP, DISPATCH, COMPLETE_WRITE)

log = logging.getLogger(__name__)


@dataclasses.dataclass
class DeviceSpec:
    name: str
    flops: float = 10e12
    mem_bw: float = 500e9


@dataclasses.dataclass
class ServerSpec:
    name: str
    devices: Sequence[DeviceSpec] = (DeviceSpec("gpu0"),)


@dataclasses.dataclass
class LinkSpec:
    latency: float = 61e-6        # one-way; paper LAN ping 0.122 ms RTT
    bandwidth: float = 100e6 / 8  # 100 Mbit Ethernet


class _Waiter:
    """One submitted command waiting on unresolved dependencies."""
    __slots__ = ("ev", "dev_name", "remaining")

    def __init__(self, ev: Event, dev_name: str):
        self.ev = ev
        self.dev_name = dev_name
        self.remaining = 0


class ServerSim:
    """The pocld daemon: reader/writer threads become event-loop actors."""

    def __init__(self, rt: "ClientRuntime", spec: ServerSpec):
        self.rt = rt
        self.name = spec.name
        self.devices = {d.name: DeviceSim(rt.clock, d.name, d.flops, d.mem_bw)
                        for d in spec.devices}
        self.session_id: Optional[bytes] = None
        self.processed: set = set()           # command ids (replay dedup)
        self.resolved_remote: set = set()     # remote event ids seen complete
        # dep event id -> [_Waiter, ...] in command-arrival order
        self._waiters: dict = {}
        self._ready: deque = deque()          # waiters with remaining == 0

    # ---- command arrival ----
    def receive_command(self, ev: Event, dev_name: str, deps: list):
        """``deps`` is [(dep_event_id, is_local_to_this_server), ...] as
        classified by the client at enqueue time."""
        if ev.command.id in self.processed:   # replayed after reconnect
            return
        self.processed.add(ev.command.id)
        ev.status = SUBMITTED
        ev.t_submitted = self.rt.clock.now
        w = _Waiter(ev, dev_name)
        events = self.rt.events
        for dep_id, local in deps:
            dep = events.get(dep_id)
            if dep is None or dep.status == COMPLETE or \
                    (not local and dep_id in self.resolved_remote):
                if dep is not None:
                    dep.release()             # retained at _send_command
                continue
            lst = self._waiters.get(dep_id)
            if lst is None:
                lst = self._waiters[dep_id] = []
                if local:
                    # one callback per dep regardless of waiter count;
                    # fires wherever the event eventually completes
                    dep.on_complete(self._local_dep_complete)
            lst.append(w)
            w.remaining += 1
        if not w.remaining:
            self._ready.append(w)
        self._dispatch_ready()

    def _local_dep_complete(self, dep: Event):
        self._resolve_dep(dep.id)
        self._dispatch_ready()

    def _resolve_dep(self, dep_id: int):
        lst = self._waiters.pop(dep_id, None)
        if not lst:
            return
        dep = self.rt.events.get(dep_id)
        ready = self._ready
        for w in lst:
            w.remaining -= 1
            if not w.remaining:
                ready.append(w)
            if dep is not None:
                dep.release()                 # retained at _send_command
        # caller runs _dispatch_ready (keeps resolve usable mid-dispatch)

    def notify_remote_complete(self, dep_id: int):
        # record only while the event is live: once retired, any command
        # arriving later resolves via the events-table miss, and a stale
        # entry here would never be cleaned (retirement already ran)
        if dep_id in self.rt.events:
            self.resolved_remote.add(dep_id)
        self._resolve_dep(dep_id)
        self._dispatch_ready()

    def _dispatch_ready(self):
        # drain in waves: execution may complete synchronously and
        # re-enter this method; a nested call drains the entries IT made
        # ready before the outer wave continues (matching the recursive
        # semantics of the pre-indexed implementation)
        while self._ready:
            wave = self._ready
            self._ready = deque()
            for w in wave:
                self._execute(w.ev, w.dev_name)

    # ---- execution ----
    def _execute(self, ev: Event, dev_name: str):
        cmd = ev.command
        if isinstance(cmd, C.MigrateBuffer):
            self.rt._start_p2p_push(self, ev)
            return
        if isinstance(cmd, C.ReadBuffer):
            self.rt._start_read_return(self, ev)
            return
        dev = self.devices[dev_name or next(iter(self.devices))]
        if isinstance(cmd, C.WriteBuffer):
            cmd.buffer.set_data(np.asarray(cmd.data), self.name)
            ev.status = RUNNING
            ev.t_start = self.rt.clock.now
            self._complete(ev)
            return
        # NDRangeKernel / BuiltinKernel / Marker
        flops = getattr(cmd, "flops", 0.0)
        bytes_moved = getattr(cmd, "bytes_moved", 0.0)
        duration = getattr(cmd, "duration", None)
        cost = dev.kernel_cost(flops, bytes_moved, duration)
        ev.status = RUNNING

        def done():
            if isinstance(cmd, C.NDRangeKernel) and cmd.fn is not None:
                ins = [b.data for b in cmd.inputs]
                outs = cmd.fn(*ins)
                if not isinstance(outs, (tuple, list)):
                    outs = (outs,)
                for b, arr in zip(cmd.outputs, outs):
                    b.set_data(np.asarray(arr), self.name)
            else:
                for b in getattr(cmd, "outputs", ()):
                    b.invalidate_except(self.name)
                    b.valid_on = {self.name}
            self._complete(ev)

        ev.t_start, _ = dev.execute(cost, done)

    def _complete(self, ev: Event):
        ev.complete(self.rt.clock.now)
        # resolve locally first: dependents on THIS server may have
        # classified the event as remote (e.g. a migration that finishes
        # on the destination) — no wire cost for self-notification
        self.notify_remote_complete(ev.id)
        self.rt._broadcast_completion(self, ev)


class Session:
    """Client-side view of one server connection (paper §4.3)."""

    def __init__(self, name: str):
        self.name = name
        self.session_id = bytes(16)           # all-zeroes until handshake
        self.available = False
        self.replay: deque = deque(maxlen=64)  # last commands (unacked)
        self.lost_unacked = 0                  # overflowed replay slots

    def record(self, item):
        """Append to the replay window, dropping already-finished entries
        first. Overflow means an UNACKED command falls out of the window
        and could not be replayed after a reconnect — that loss used to
        be silent; now it is counted and logged once per session."""
        buf = self.replay
        while buf and buf[0][0].status in (COMPLETE, ERROR):
            buf.popleft()
        if buf.maxlen is not None and len(buf) == buf.maxlen:
            if not self.lost_unacked:
                log.warning(
                    "session %s: replay window full (maxlen=%d); dropping "
                    "oldest unacked command — it cannot be replayed after "
                    "a reconnect", self.name, buf.maxlen)
            self.lost_unacked += 1
        buf.append(item)


class ClientRuntime:
    """The PoCL remote client driver (host side of the OpenCL API)."""

    def __init__(self, servers: Sequence[ServerSpec],
                 client_link: LinkSpec = LinkSpec(),
                 peer_link: LinkSpec = LinkSpec(latency=61e-6,
                                                bandwidth=100e6 / 8),
                 transport: str = "tcp",
                 peer_transport: Optional[str] = None,
                 svm: bool = False,
                 scheduling: str = "decentralized",   # | 'client'
                 p2p_migration: bool = True,
                 completion_routing: str = "subscription",  # | 'broadcast'
                 local_device: Optional[DeviceSpec] = None):
        if completion_routing not in ("subscription", "broadcast"):
            raise ValueError(f"unknown completion_routing "
                             f"{completion_routing!r}")
        self.clock = SimClock()
        self.transport = make_transport(transport, svm)
        self.peer_transport = make_transport(peer_transport or transport, svm)
        self.scheduling = scheduling
        self.p2p_migration = p2p_migration
        self.completion_routing = completion_routing
        self.servers = {s.name: ServerSim(self, s) for s in servers}
        self.events: dict = {}
        # event id -> {server names holding dependents of it}; registered
        # at enqueue time so a completion is signaled "directly to the
        # target server" (§5.2) instead of broadcast to every peer
        self._subs: dict = {}
        self.client_completion_msgs = 0       # server → client completes
        self.peer_completion_msgs = 0         # server → peer notifications
        self.client_routed_completion_msgs = 0  # client → server forwards
        self.sessions = {s: Session(s) for s in self.servers}
        self.local_device = DeviceSim(
            self.clock, "local",
            *( (local_device.flops, local_device.mem_bw)
               if local_device else (1e12, 50e9) ))
        # links
        self.c_links = {s: Link(self.clock, client_link.latency,
                                client_link.bandwidth, f"client<->{s}")
                        for s in self.servers}
        self.p_links = {}
        names = list(self.servers)
        for i, a in enumerate(names):
            for b in names[i + 1:]:
                self.p_links[(a, b)] = Link(self.clock, peer_link.latency,
                                            peer_link.bandwidth, f"{a}<->{b}")
        self._buffers: list[Buffer] = []
        self._mr_registered: set = set()
        # connect (handshake: rtt + session id assignment) — run the
        # clock until all sessions are established, as clCreateContext
        # would block
        for s in self.servers:
            self._handshake(s)
        self.clock.run()

    # ------------------------------------------------------------------
    def peer_link(self, a: str, b: str) -> Link:
        return self.p_links.get((a, b)) or self.p_links[(b, a)]

    def _handshake(self, server: str):
        sess = self.sessions[server]

        def done():
            sess.session_id = secrets.token_bytes(16)
            self.servers[server].session_id = sess.session_id
            sess.available = True

        self.c_links[server].send(64, done)

    # ---- buffers ----
    def create_buffer(self, nbytes: int, content_size_buffer: Buffer = None,
                      name: str = "") -> Buffer:
        b = Buffer(nbytes=nbytes, content_size_buffer=content_size_buffer,
                   name=name)
        b.valid_on = {"client"}
        self._buffers.append(b)
        return b

    # ---- event lifecycle ----
    def _register_event(self, ev: Event) -> Event:
        ev.t_queued = self.clock.now
        ev.retain()                 # client hold until completion observed
        ev.on_retire = self._retire
        self.events[ev.id] = ev
        return ev

    def _new_event(self, cmd, server: str) -> Event:
        return self._register_event(Event(command=cmd, server=server))

    def _retire(self, ev: Event):
        """Last reference dropped on a finished event: remove it from
        every runtime table so long runs stay memory-bounded. The Event
        object itself stays valid for user-held handles."""
        self.events.pop(ev.id, None)
        self._subs.pop(ev.id, None)
        cmd_id = getattr(ev.command, "id", None)
        for srv in self.servers.values():
            srv.resolved_remote.discard(ev.id)
            if cmd_id is not None:
                srv.processed.discard(cmd_id)

    # ---- enqueue API ----
    def enqueue_kernel(self, server: str, device: str = "",
                       fn: Optional[Callable] = None,
                       inputs: Sequence[Buffer] = (),
                       outputs: Sequence[Buffer] = (),
                       flops: float = 0.0, bytes_moved: float = 0.0,
                       duration: Optional[float] = None,
                       wait_for: Sequence[Event] = (),
                       name: str = "kernel") -> Event:
        """Enqueue a kernel; implicit migrations are added for any input
        not valid on the target server (standard OpenCL semantics)."""
        if not self.sessions[server].available:
            raise DeviceUnavailable(server)
        deps = list(wait_for)
        for b in inputs:
            if server not in b.valid_on:
                deps.append(self.enqueue_migration(b, server,
                                                   wait_for=wait_for))
        cmd = C.NDRangeKernel(fn=fn, inputs=tuple(inputs),
                              outputs=tuple(outputs), flops=flops,
                              bytes_moved=bytes_moved, duration=duration,
                              name=name)
        ev = self._new_event(cmd, server)
        self._send_command(ev, server, device, [d.id for d in deps])
        for b in outputs:
            b.valid_on = {server}
        return ev

    def enqueue_write(self, server: str, buf: Buffer, data,
                      wait_for: Sequence[Event] = ()) -> Event:
        cmd = C.WriteBuffer(buffer=buf, data=data,
                            nbytes=np.asarray(data).nbytes)
        ev = self._new_event(cmd, server)
        self._send_command(ev, server, "", [d.id for d in wait_for],
                           payload=cmd.nbytes)
        buf.valid_on = {server, "client"}
        return ev

    def enqueue_read(self, server: str, buf: Buffer,
                     wait_for: Sequence[Event] = ()) -> Event:
        cmd = C.ReadBuffer(buffer=buf)
        ev = self._new_event(cmd, server)
        self._send_command(ev, server, "", [d.id for d in wait_for])
        return ev

    def enqueue_migration(self, buf: Buffer, dst: str,
                          wait_for: Sequence[Event] = ()) -> Event:
        """Migrate to ``dst``. P2P: command goes to the SOURCE server,
        which pushes directly to the destination (paper §5.1)."""
        if dst in buf.valid_on:
            ev = self._new_event(C.Marker(), dst)
            ev.complete(self.clock.now)
            ev.release()            # completed on the client: no ack cycle
            return ev
        srcs = [s for s in buf.valid_on if s != "client"]
        if not srcs:  # client-held data: plain upload
            return self.enqueue_write(dst, buf, buf.data
                                      if buf.data is not None
                                      else np.zeros(buf.nbytes, np.uint8))
        src = srcs[0]
        cmd = C.MigrateBuffer(buffer=buf, dst_server=dst)
        if self.p2p_migration:
            ev = self._new_event(cmd, src)
            self._send_command(ev, src, "", [d.id for d in wait_for])
            return ev
        # naive: read back to client, then write to dst
        rd = self.enqueue_read(src, buf, wait_for=wait_for)
        wr_ev = self._new_event(cmd, dst)

        def after_read(_):
            nb = buf.transfer_bytes()
            cost = self.transport.command_cost(nb)
            self.clock.schedule(CLIENT_SUBMIT + cost.sender_cpu,
                                self._deliver_naive_write, wr_ev, dst,
                                nb, cost)

        rd.on_complete(after_read)
        return wr_ev

    def _deliver_naive_write(self, ev, dst, nbytes, cost):
        def arrived():
            ev.command.buffer.valid_on.add(dst)
            ev.complete(self.clock.now)
            self._broadcast_completion(self.servers[dst], ev)
        link = self.c_links[dst]
        link.send(nbytes * wire_scale(self.transport, link.bandwidth),
                  arrived, serialize_overhead=cost.sender_cpu)

    def marker(self) -> Event:
        ev = self._new_event(C.Marker(), "client")
        ev.complete(self.clock.now)
        ev.release()                # completed on the client: no ack cycle
        return ev

    # ---- wire ----
    def _send_command(self, ev: Event, server: str, device: str,
                      dep_ids: list, payload: float = 0.0):
        # classify deps at enqueue time: already-finished ones are
        # dropped from the wire message; live ones are retained (they
        # must stay resolvable until this command dispatches) and, when
        # remote, the target server subscribes to their completion
        deps = []
        if dep_ids:
            seen = set()
            for dep_id in dep_ids:
                if dep_id in seen:
                    continue
                seen.add(dep_id)
                dep = self.events.get(dep_id)
                if dep is None or dep.status == COMPLETE:
                    continue
                dep.retain()
                local = dep.server == server
                if not local and self.completion_routing == "subscription":
                    self._subs.setdefault(dep_id, set()).add(server)
                deps.append((dep_id, local))
        sess = self.sessions[server]
        sess.record((ev, server, device, deps, payload))
        cost = self.transport.command_cost(payload)
        link = self.c_links[server]

        def deliver():
            self.clock.schedule(
                cost.receiver_cpu + DISPATCH,
                self.servers[server].receive_command, ev, device, deps)

        link.send(cost.wire_bytes * wire_scale(self.transport,
                                               link.bandwidth),
                  deliver,
                  serialize_overhead=CLIENT_SUBMIT + cost.sender_cpu)

    # ---- migration execution (on source server) ----
    def _start_p2p_push(self, src_srv: ServerSim, ev: Event):
        cmd = ev.command
        buf, dst = cmd.buffer, cmd.dst_server
        nbytes = buf.transfer_bytes()
        tr = self.peer_transport
        reg = 0.0
        key = (buf.id, src_srv.name, dst)
        if key not in self._mr_registered:
            reg = tr.register_buffer(nbytes, peers=len(self.servers) - 1)
            self._mr_registered.add(key)
        cost = tr.command_cost(nbytes)
        link = self.peer_link(src_srv.name, dst)
        ev.status = RUNNING
        ev.t_start = self.clock.now

        def arrived():
            def after_cpu():
                buf.valid_on.add(dst)
                ev.server = dst
                self.servers[dst]._complete(ev)
            self.clock.schedule(cost.receiver_cpu, after_cpu)

        link.send(cost.wire_bytes * wire_scale(tr, link.bandwidth),
                  arrived, serialize_overhead=reg + cost.sender_cpu)

    def _start_read_return(self, srv: ServerSim, ev: Event):
        buf = ev.command.buffer
        nbytes = buf.transfer_bytes()
        cost = self.transport.command_cost(nbytes)
        link = self.c_links[srv.name]
        ev.status = RUNNING
        ev.t_start = self.clock.now

        def arrived():
            buf.valid_on.add("client")
            ev.complete(self.clock.now)
            self._route_completion_via_client(ev)
            ev.release()            # client observed completion directly

        link.send(cost.wire_bytes * wire_scale(self.transport,
                                               link.bandwidth),
                  arrived, serialize_overhead=COMPLETE_WRITE + cost.sender_cpu)

    # ---- completion propagation ----
    def _broadcast_completion(self, srv: ServerSim, ev: Event):
        comp = (self.peer_transport if self.scheduling == "decentralized"
                else self.transport).completion_cost()
        # to client (always)
        self.c_links[srv.name].send(
            comp.wire_bytes, lambda: self._client_reap(ev),
            serialize_overhead=COMPLETE_WRITE + comp.sender_cpu)
        self.client_completion_msgs += 1
        if self.scheduling != "decentralized":
            return
        if self.completion_routing == "subscription":
            targets = sorted(self._subs.pop(ev.id, ()))
        else:
            targets = [p for p in self.servers if p != srv.name]
        for name in targets:
            if name == srv.name:
                continue
            link = self.peer_link(srv.name, name)
            link.send(comp.wire_bytes,
                      lambda p=self.servers[name]:
                      p.notify_remote_complete(ev.id),
                      serialize_overhead=comp.sender_cpu)
            self.peer_completion_msgs += 1

    def _route_completion_via_client(self, ev: Event):
        """Events that complete on the client itself (reads, user/race
        events, local fallback) have no server to signal from; notify any
        subscribed servers over their client links."""
        subs = self._subs.pop(ev.id, None)
        if not subs:
            return
        comp = self.transport.completion_cost()
        for name in sorted(subs):
            self.c_links[name].send(
                comp.wire_bytes,
                lambda p=self.servers[name]: p.notify_remote_complete(ev.id),
                serialize_overhead=comp.sender_cpu)
            self.client_routed_completion_msgs += 1

    def _client_reap(self, ev: Event):
        self.clock.schedule(CLIENT_REAP, self._client_reap2, ev)

    def _client_reap2(self, ev: Event):
        ev.t_client_ack = self.clock.now
        if self.scheduling == "client":
            # SnuCL-like: client forwards resolution to the other servers
            if self.completion_routing == "subscription":
                targets = sorted(self._subs.pop(ev.id, ()))
            else:
                targets = [p for p in self.servers if p != ev.server]
            comp = self.transport.completion_cost()
            for name in targets:
                if name == ev.server:
                    continue
                self.c_links[name].send(
                    comp.wire_bytes,
                    lambda p=self.servers[name]:
                    p.notify_remote_complete(ev.id),
                    serialize_overhead=comp.sender_cpu)
                self.client_routed_completion_msgs += 1
        ev.release()                # client hold: completion observed

    # ---- fault injection / sessions (paper §4.3) ----
    def inject_disconnect(self, server: str, at: Optional[float] = None):
        def go():
            self.c_links[server].up = False
            self.sessions[server].available = False
        if at is None:
            go()
        else:
            self.clock.schedule_at(at, go)

    def reconnect(self, server: str, at: Optional[float] = None):
        """Restore the link; replay unacknowledged commands (server dedupes
        by command id). The session ID survives even if the client's
        address changed."""
        def go():
            link = self.c_links[server]
            link.up = True

            def handshook():
                self.sessions[server].available = True
                for (ev, srv, device, deps, payload) in \
                        list(self.sessions[server].replay):
                    if ev.status in (COMPLETE, ERROR):
                        continue
                    cost = self.transport.command_cost(payload)
                    link.send(cost.wire_bytes,
                              lambda e=ev, d=device, dd=deps:
                              self.servers[server].receive_command(e, d, dd),
                              serialize_overhead=cost.sender_cpu)

            link.send(64 + 16, handshook)   # handshake incl. session id
        if at is None:
            go()
        else:
            self.clock.schedule_at(at, go)

    def enqueue_kernel_redundant(self, servers: Sequence[str], **kw) -> Event:
        """Straggler mitigation: dispatch the same kernel to several
        servers; the first completion wins and late copies are ignored
        (the client simply reaps the winner — the OpenCL semantics make
        duplicate side-effect-free kernels safe to race).

        Returns a user event that completes with the winner."""
        race = self._register_event(Event(user=True, server="client"))
        outputs = kw.get("outputs", ())
        fn = kw.pop("fn", None)

        def on_done(ev):
            if race.status != COMPLETE:
                # winner executes the functional payload; losers are void
                if fn is not None:
                    ins = [b.data for b in kw.get("inputs", ())]
                    outs = fn(*ins)
                    if not isinstance(outs, (tuple, list)):
                        outs = (outs,)
                    for b, arr in zip(outputs, outs):
                        b.set_data(np.asarray(arr), ev.server)
                race.server = ev.server
                race.complete(self.clock.now)
                self._route_completion_via_client(race)
                race.release()      # client observed completion directly

        for s in servers:
            if not self.sessions[s].available:
                continue
            ev = self.enqueue_kernel(s, fn=None, **kw)
            ev.on_complete(on_done)
        return race

    def run_local_fallback(self, fn, inputs, outputs, flops=0.0,
                           duration=None) -> Event:
        """Fig. 4: compute locally (reduced model) while remotes are gone."""
        ev = self._new_event(C.NDRangeKernel(fn=fn, inputs=tuple(inputs),
                                             outputs=tuple(outputs),
                                             flops=flops, duration=duration),
                             "client")

        def done():
            cmd = ev.command
            if cmd.fn is not None:
                ins = [b.data for b in cmd.inputs]
                outs = cmd.fn(*ins)
                if not isinstance(outs, (tuple, list)):
                    outs = (outs,)
                for b, arr in zip(cmd.outputs, outs):
                    b.set_data(np.asarray(arr), "client")
            ev.complete(self.clock.now)
            self._route_completion_via_client(ev)
            ev.release()            # client observed completion directly

        cost = self.local_device.kernel_cost(flops, 0.0, duration)
        ev.t_start, _ = self.local_device.execute(cost, done)
        return ev

    # ---- control ----
    def finish(self) -> float:
        """Drain the simulation; returns the final clock time."""
        return self.clock.run()

    def stats(self) -> dict:
        return {
            "time": self.clock.now,
            "client_link_bytes": {s: l.bytes_sent
                                  for s, l in self.c_links.items()},
            "peer_link_bytes": {f"{a}-{b}": l.bytes_sent
                                for (a, b), l in self.p_links.items()},
            "device_busy": {f"{s}/{d}": dev.busy_time
                            for s, srv in self.servers.items()
                            for d, dev in srv.devices.items()},
            "client_completion_msgs": self.client_completion_msgs,
            "peer_completion_msgs": self.peer_completion_msgs,
            "client_routed_completion_msgs":
                self.client_routed_completion_msgs,
            "events_live": len(self.events),
            "replay_overflows": {s: sess.lost_unacked
                                 for s, sess in self.sessions.items()},
        }


class DeviceUnavailable(RuntimeError):
    """CL_DEVICE_NOT_AVAILABLE analogue."""
    def __init__(self, server):
        super().__init__(f"server {server} unavailable")
        self.server = server
