"""PoCL-R runtime: client driver + server daemons + decentralized
scheduling over a simulated MEC network (paper §4–§5).

Semantics implemented faithfully:

* Commands are pushed to the target server immediately with their event
  dependencies (§5.2); the server dispatches as soon as deps resolve —
  locally-produced events resolve locally, remote ones via peer
  completion notifications, with NO client round-trip (decentralized
  mode). ``scheduling='client'`` routes completions through the client
  instead (the SnuCL-like baseline the paper compares against).
* Buffer migrations go source-server → destination-server directly over
  peer links (§5.1); ``p2p_migration=False`` stages them through the
  client (the naive path: download + upload over the slowest link).
* ``cl_pocl_content_size`` (§5.3): migrations move only the used prefix.
* TCP vs RDMA transports (§5.4) with shadow-buffer staging, registration
  and rkey-exchange costs.
* Connection loss (§4.3): session IDs, command replay on reconnect,
  server-side dedup of already-processed commands, device-unavailable
  status, optional local fallback execution (Fig. 4).

Kernels execute *functionally* (real arrays) in causal simulation order,
so the same runtime that produces latency numbers also produces bit-exact
results for the tests.

Dispatch is O(1) per command (DESIGN.md §1): each server keeps an
indexed waiter table (dep event id → waiting commands, with per-command
remaining-dep counters) and an explicit ready queue instead of rescanning
a pending list; completions are routed only to servers that registered a
dependent on the event (``completion_routing='subscription'``, matching
the paper's direct P2P signaling) instead of broadcast to every peer; and
finished events are retired from all runtime tables once nobody holds a
reference, so long runs stay memory-bounded.

The migration data plane is pipelined (DESIGN.md §3): bulk payloads move
as chunked cut-through transfers (sender copy / wire / receiver copy
overlap per chunk, ``Link.send_chunked``); duplicate in-flight requests
for the same ``(buffer, destination)`` coalesce onto the pending
transfer instead of re-sending the payload; and the migration source is
chosen per-replica by estimated delivery time (link queue + bandwidth +
RDMA registration amortization) instead of set order. ``stats()``
exposes the data-plane scoreboard: ``bytes_on_wire``,
``migrations_coalesced``, ``chunks_in_flight``/``peak_chunks_in_flight``.

The server runtime is multi-tenant (DESIGN.md §4, the paper's
server-side scalability claim): a ``Cluster`` owns the shared substrate
— clock, server hosts (devices + per-device run queues + shared egress
NIC) and the peer mesh — and any number of ``ClientRuntime`` instances
(UE sessions) attach to it. Server-side per-session state (replay
dedup, remote-resolution tracking, dependency waiters) lives in a
``ServerSim`` per (client, server), registered in the host's session
table by session id; device time is arbitrated across sessions by a
pluggable scheduler (FIFO baseline or weighted deficit-round-robin —
``src/repro/core/scheduler.py``). Constructing a ``ClientRuntime``
without an explicit cluster builds a private one, preserving the
original single-tenant API.

Kernel placement is a cluster-wide control plane (DESIGN.md §6,
``Cluster(placement=...)``): every ``enqueue_kernel`` passes its
requested server through the ``PlacementEngine``, which may redirect
the kernel (and its implicit migrations) using live telemetry — run-
queue depth in device-seconds, replica locality from the buffer/store
state, and NIC occupancy on both ends. Policies are pluggable
(``pinned`` — the bit-exact default honoring the caller's pick,
``locality``, ``hetmec``) and can be overridden per tenant
(``ClientRuntime(placement=...)``).

Cross-tenant payloads deduplicate through the cluster's opt-in
content-addressed buffer store (DESIGN.md §5, ``Cluster(store=True)``):
identical uploads resolve to one shared physical replica set per server
(command-only writes when resident, gating on in-flight copies when
racing), migrations are served from or deduplicated against any
tenant's valid replica, tenant writes copy-on-write fork shared content
to private buffers, and ``ClientRuntime.detach()`` releases a tenant's
sessions, run-queue entries, and store references so long-lived
clusters shed departed UEs (unreferenced replicas evict LRU under the
store's per-server capacity).
"""
from __future__ import annotations

import dataclasses
import logging
import secrets
from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import commands as C
from repro.core.buffers import Buffer
from repro.core.events import (COMPLETE, ERROR, RUNNING, SUBMITTED,
                               Event)
from repro.core.membership import (ACTIVE, DEAD, JOINING,
                                   MembershipManager)
from repro.core.netsim import NIC, DeviceSim, Link, SimClock
from repro.core.placement import (PinnedPolicy, PlacementEngine,
                                  make_placement_policy)
from repro.core.admission import (AdmissionController, AdmissionRejected,
                                  DEGRADE, REJECT)
from repro.core.scheduler import (DeviceScheduler, make_policy,
                                  validate_scheduler_opts)
from repro.core.store import BufferStore, DIGEST_BYTES, content_digest
from repro.core import trace as trace_mod
from repro.core.transport import (make_transport, wire_scale, scale_chunks,
    CLIENT_SUBMIT, CLIENT_REAP, CMD_BYTES, DISPATCH, COMPLETE_WRITE)

log = logging.getLogger(__name__)

# residual-laxity base for deadline-less commands under a preemptive
# scheduler: never tighter than anything, so they always yield
_INF = float("inf")


@dataclasses.dataclass
class DeviceSpec:
    name: str
    flops: float = 10e12
    mem_bw: float = 500e9


@dataclasses.dataclass
class ServerSpec:
    name: str
    devices: Sequence[DeviceSpec] = (DeviceSpec("gpu0"),)


@dataclasses.dataclass
class LinkSpec:
    latency: float = 61e-6        # one-way; paper LAN ping 0.122 ms RTT
    bandwidth: float = 100e6 / 8  # 100 Mbit Ethernet


class _Waiter:
    """One submitted command waiting on unresolved dependencies.
    ``dev_idx`` is the host's interned device index (resolved once at
    arrival so dispatch never repeats the name lookup); ``dev_name``
    is kept for the drain/requeue API boundary."""
    __slots__ = ("ev", "dev_name", "dev_idx", "remaining")

    def __init__(self, ev: Event, dev_name: str, dev_idx: int = -1):
        self.ev = ev
        self.dev_name = dev_name
        self.dev_idx = dev_idx
        self.remaining = 0


class ServerHost:
    """Cluster-side half of a pocld server: the physical devices, one
    run-queue scheduler per device, the shared egress NIC, and the §4.3
    session table (session id → attached ``ServerSim``). Everything a
    tenant can contend on lives here; everything scoped to one client
    session lives in ``ServerSim``."""

    def __init__(self, cluster: "Cluster", spec: ServerSpec):
        self.cluster = cluster
        self.name = spec.name
        # interned host id (DESIGN.md §8): small int, unique across the
        # cluster's lifetime (rejoins of a reused *name* get a fresh id)
        cluster._sid_seq += 1
        self.sid = cluster._sid_seq
        self.devices = {d.name: DeviceSim(cluster.clock, d.name,
                                          d.flops, d.mem_bw)
                        for d in spec.devices}
        self.schedulers = {
            name: DeviceScheduler(make_policy(cluster.scheduler_policy,
                                              cluster.scheduler_quantum,
                                              cluster.scheduler_opts))
            for name in self.devices}
        # interned device tables: index-aligned lists + name -> index,
        # so the dispatch hot path replaces two string-dict lookups per
        # kernel with two list indexes ('' = default device = index 0)
        self.device_names = list(self.devices)
        self.device_list = list(self.devices.values())
        self.scheduler_list = [self.schedulers[n] for n in self.device_names]
        self.dev_index = {n: i for i, n in enumerate(self.device_names)}
        self.dev_index[""] = 0
        self.nic = (NIC(cluster.nic_bandwidth, f"{self.name}.nic")
                    if cluster.nic_bandwidth else None)
        self.nic_in = (NIC(cluster.nic_ingress_bandwidth,
                           f"{self.name}.nic_in")
                       if cluster.nic_ingress_bandwidth else None)
        # observability (DESIGN.md §9): point the shared ports at the
        # cluster tracer (covers seed hosts and mid-run joins alike);
        # an untraced cluster leaves NIC.trace None — the hooks inside
        # Link.send/send_chunked stay a slot load + branch
        tr = cluster.trace
        if tr is not None:
            for nic in (self.nic, self.nic_in):
                if nic is not None:
                    nic.trace = tr
                    nic.trace_label = cluster.trace_prefix + nic.name
            # run-queue depth samples (DESIGN.md §11): push/pop
            # boundaries become device-ordering resource edges
            for dname, sch in self.schedulers.items():
                sch.trace = tr
                sch.trace_label = (f"{cluster.trace_prefix}{self.name}"
                                   f".{dname}.runq")
                sch.trace_clock = cluster.clock
        self.sessions: dict = {}     # session id (bytes) -> ServerSim
        # membership lifecycle (DESIGN.md §7); the MembershipManager is
        # authoritative, this mirror makes hot-path checks a plain load
        self.state = ACTIVE


class Cluster:
    """A shared simulated MEC cluster: one logical clock, the server
    hosts, and the peer-link mesh. Any number of ``ClientRuntime``
    instances attach to it — each brings its own client links, event
    tables, and per-server sessions, while devices, run queues, peer
    links, and NICs are contended across all of them.

    ``scheduler`` picks the cross-session device policy (``'fifo'`` |
    ``'drr'`` | ``'edf'`` | ``'llf'``, DESIGN.md §4/§10) and
    ``scheduler_opts`` its validated per-policy knobs ({'quantum'} for
    drr, {'chunk'} for llf; ``scheduler_quantum`` is the legacy spelling
    of the drr knob); ``admission`` enables SLO admission control
    (True for defaults, a dict of ``AdmissionController`` knobs, or a
    prebuilt controller — None/False keeps every tenant unscreened);
    ``nic_bandwidth`` (B/s) enables the shared-NIC egress
    model for every host and ``nic_ingress_bandwidth`` its receive-side
    mirror (None keeps the pre-NIC independent-link behavior on that
    side); ``placement`` picks the cluster-wide kernel placement policy
    (``'pinned'`` | ``'locality'`` | ``'hetmec'``, DESIGN.md §6 — a
    tenant can override it per ``ClientRuntime``). A ``ClientRuntime``
    built without an explicit cluster creates a private one, so the
    single-tenant API is unchanged.
    """

    def __init__(self, servers: Sequence[ServerSpec],
                 peer_link: LinkSpec = LinkSpec(),
                 peer_transport: str = "tcp",
                 svm: bool = False,
                 scheduler: str = "fifo",
                 scheduler_quantum: Optional[float] = None,
                 scheduler_opts: Optional[dict] = None,
                 nic_bandwidth: Optional[float] = None,
                 nic_ingress_bandwidth: Optional[float] = None,
                 store: bool = False,
                 store_capacity: Optional[float] = None,
                 placement: str = "pinned",
                 admission=None,
                 trace=None):
        self.clock = SimClock()
        # observability plane (DESIGN.md §9): ``trace`` accepts a Tracer
        # instance, True (build a private one), False (force off even if
        # a module default is set), or None (fall back to the module
        # default, which ``benchmarks/run.py --trace`` sets so every
        # cluster a benchmark builds is traced without plumbing).
        # ``self.trace`` is None whenever tracing is off — every hook in
        # the runtime gates on that with a single load + branch, the
        # same zero-overhead pattern as PlacementEngine.telemetry_active.
        if trace is None:
            trace = trace_mod.get_default()
        elif trace is True:
            trace = trace_mod.Tracer()
        elif trace is False:
            trace = None
        self.trace = trace
        self.trace_prefix = ""
        if trace is not None:
            idx = trace.register_cluster(self)
            if idx:          # 2nd+ cluster on one tracer: namespace it
                self.trace_prefix = f"c{idx}:"
        self.peer_transport = make_transport(peer_transport, svm)
        self.scheduler_policy = scheduler
        self.scheduler_quantum = scheduler_quantum
        # satellite fix (ISSUE 9): per-policy knobs are constructor
        # arguments, validated eagerly — no more monkeypatching module
        # constants. The legacy scheduler_quantum spelling stays valid
        # but may not conflict with the explicit knob.
        opts = validate_scheduler_opts(scheduler, scheduler_opts)
        if scheduler_quantum is not None and "quantum" in opts:
            raise ValueError(
                "pass either scheduler_quantum or "
                "scheduler_opts['quantum'], not both")
        self.scheduler_opts = opts
        self.nic_bandwidth = nic_bandwidth
        self.nic_ingress_bandwidth = nic_ingress_bandwidth
        # content-addressed cross-tenant buffer store (DESIGN.md §5):
        # opt-in so a store-less cluster keeps private-copy semantics
        # bit-exact (it is also the dedup benchmark's baseline)
        self.store = (BufferStore(self.clock, store_capacity)
                      if store or store_capacity is not None else None)
        # interning counters (DESIGN.md §8): hosts and sessions get
        # small-int ids for the hot-path tables; names stay the API
        self._sid_seq = 0
        self._skey_seq = 0
        self.hosts = {s.name: ServerHost(self, s) for s in servers}
        # cluster-wide placement control plane (DESIGN.md §6); 'pinned'
        # keeps every caller's hard-picked server bit-exactly
        self.placement = PlacementEngine(self, placement)
        self.p_links: dict = {}
        self._tenant_seq = 0      # monotonic: default names never recycle
        # kept for membership joins: a host admitted mid-run gets peer
        # links of the same spec the seed mesh was built with
        self.peer_link_spec = peer_link
        names = list(self.hosts)
        for i, a in enumerate(names):
            for b in names[i + 1:]:
                lk = self.p_links[(a, b)] = Link(self.clock,
                                                 peer_link.latency,
                                                 peer_link.bandwidth,
                                                 f"{a}<->{b}")
                if trace is not None:
                    lk.trace = trace
                    lk.trace_label = self.trace_prefix + lk.name
        self.clients: list = []
        # elastic membership control plane (DESIGN.md §7): seed hosts
        # start ACTIVE; join/drain/crash move them through the lifecycle
        self.membership = MembershipManager(self)
        for name in self.hosts:
            self.membership.register(name)
        # SLO admission control (DESIGN.md §10): screens tenants that
        # declare slo_ms at attach time. Off (None) by default — an
        # admission-less cluster admits everything, bit-exactly as
        # before.
        if admission is None or admission is False:
            self.admission = None
        elif isinstance(admission, AdmissionController):
            self.admission = admission
        else:
            self.admission = AdmissionController(
                self, None if admission is True else admission)

    # ---- membership verbs (delegates to the MembershipManager) ----
    def join_server(self, spec: ServerSpec, at: Optional[float] = None,
                    on_active: Optional[Callable] = None) -> None:
        """Admit a new server into the live cluster (DESIGN.md §7)."""
        self.membership.join(spec, at, on_active)

    def drain_server(self, name: str, at: Optional[float] = None,
                     on_complete: Optional[Callable] = None) -> None:
        """Gracefully decommission ``name``: requeue its unstarted
        commands, re-home its sole replicas, then retire it."""
        self.membership.drain(name, at, on_complete)

    def crash_server(self, name: str, at: Optional[float] = None) -> None:
        """Abruptly kill ``name``: links die, live events fail fast."""
        self.membership.crash(name, at)

    def _admit_host(self, spec: ServerSpec) -> ServerHost:
        """Membership join mechanics: build the host and wire fresh peer
        links to every current member. A rejoin of a DEAD name replaces
        the corpse's closed links — nothing resurrects."""
        name = spec.name
        host = ServerHost(self, spec)
        self.hosts[name] = host
        lat = self.peer_link_spec.latency
        bw = self.peer_link_spec.bandwidth
        for other in self.hosts:
            if other == name:
                continue
            key = ((other, name) if (other, name) in self.p_links
                   else (name, other))
            lk = self.p_links[key] = Link(self.clock, lat, bw,
                                          f"{key[0]}<->{key[1]}")
            if self.trace is not None:
                lk.trace = self.trace
                lk.trace_label = self.trace_prefix + lk.name
        return host

    def peer_link(self, a: str, b: str) -> Link:
        return self.p_links.get((a, b)) or self.p_links[(b, a)]

    def run(self, until: Optional[float] = None) -> float:
        """Drain the shared simulation (all attached tenants)."""
        return self.clock.run(until)

    def stats(self) -> dict:
        return {
            "time": self.clock.now,
            "clients": [c.name for c in self.clients],
            "sessions": {h: len(host.sessions)
                         for h, host in self.hosts.items()},
            "device_busy": {f"{h}/{d}": dev.busy_time
                            for h, host in self.hosts.items()
                            for d, dev in host.devices.items()},
            "scheduler": {f"{h}/{d}": {"policy": sch.policy.name,
                                       "dispatched": sch.dispatched,
                                       "preempted": sch.preempted,
                                       "queue_peak": sch.queue_peak,
                                       "queued_seconds":
                                           sch.queued_seconds()}
                          for h, host in self.hosts.items()
                          for d, sch in host.schedulers.items()},
            "nic_bytes": {h: (host.nic.bytes_sent if host.nic else 0)
                          for h, host in self.hosts.items()},
            "nic_busy": {h: (host.nic.busy_time if host.nic else 0.0)
                         for h, host in self.hosts.items()},
            "nic_in_bytes": {h: (host.nic_in.bytes_sent
                                 if host.nic_in else 0)
                             for h, host in self.hosts.items()},
            "nic_in_busy": {h: (host.nic_in.busy_time
                                if host.nic_in else 0.0)
                            for h, host in self.hosts.items()},
            "peer_link_bytes": {f"{a}-{b}": lk.bytes_sent
                                for (a, b), lk in self.p_links.items()},
            "store": self.store.stats() if self.store is not None else None,
            "placement": self.placement.stats(),
            "membership": self.membership.stats(),
            "admission": (self.admission.stats()
                          if self.admission is not None else None),
        }


class ServerSim:
    """One client session's view of the pocld daemon (the per-session
    half of the server split): replay dedup, remote-resolution tracking,
    and the dependency waiter table are all scoped to this session,
    while devices, run queues, and the NIC are shared on ``host``."""

    def __init__(self, rt: "ClientRuntime", host: ServerHost):
        self.rt = rt
        self.host = host
        self.name = host.name
        # interned session key (DESIGN.md §8): the scheduler run queues
        # key their per-tenant tables by this small int instead of the
        # (tenant name, server name) strings
        host.cluster._skey_seq += 1
        self.skey = host.cluster._skey_seq
        # observability (DESIGN.md §9): prefixed server label, built
        # once so the ready-hook never concatenates on the hot path
        self._tlabel = rt._tp + host.name
        self.session_id: Optional[bytes] = None
        self.processed: set = set()           # command ids (replay dedup)
        self.resolved_remote: set = set()     # remote event ids seen complete
        # dep event id -> [_Waiter, ...] in command-arrival order
        self._waiters: dict = {}
        self._ready: deque = deque()          # waiters with remaining == 0

    @property
    def devices(self) -> dict:
        return self.host.devices

    # ---- command arrival ----
    def receive_command(self, ev: Event, dev_name: str, deps: list):
        """``deps`` is [(dep_event_id, is_local_to_this_server), ...] as
        classified by the client at enqueue time."""
        if self.host.state == DEAD:
            # delivered to a corpse (the host retired or crashed while
            # the command was on the wire): bounce it back through
            # placement instead of executing or silently dropping. The
            # command id is unchanged, so if a copy was already
            # requeued the client-side guard dedups this one.
            events = self.rt.events
            for dep_id, _local in deps:
                dep = events.get(dep_id)
                if dep is not None:
                    dep.release()             # retained at _send_command
            self.rt._requeue_after_drain(ev, self.name, dev_name,
                                         [d for d, _l in deps])
            return
        if ev.command.id in self.processed:   # replayed after reconnect
            return
        if ev.status == ERROR:
            # failed client-side while the command was on the wire
            # (e.g. the tenant detached): never execute a dead command
            return
        self.processed.add(ev.command.id)
        ev.status = SUBMITTED
        ev.t_submitted = self.rt.clock.now
        w = _Waiter(ev, dev_name, self.host.dev_index.get(dev_name, -1))
        events = self.rt.events
        waiters = self._waiters
        resolved = self.resolved_remote
        remaining = 0
        for dep_id, local in deps:
            dep = events.get(dep_id)
            # ERROR counts as finished (the runtime's loose error-
            # dependency semantics, like _join_events): a dep that
            # failed while this command was on the wire must not leave
            # the waiter registered on an event whose callbacks already
            # flushed — that command would hang forever
            if dep is None or dep.status == COMPLETE \
                    or dep.status == ERROR \
                    or (not local and dep_id in resolved):
                if dep is not None:
                    dep.release()             # retained at _send_command
                continue
            lst = waiters.get(dep_id)
            if lst is None:
                lst = waiters[dep_id] = []
                if local:
                    # one callback per dep regardless of waiter count;
                    # fires wherever the event eventually completes
                    dep.on_complete(self._local_dep_complete)
            lst.append(w)
            remaining += 1
        if remaining:
            w.remaining = remaining
        else:
            self._ready.append(w)
        self._dispatch_ready()

    def _local_dep_complete(self, dep: Event):
        self._resolve_dep(dep.id)
        self._dispatch_ready()

    def _resolve_dep(self, dep_id: int):
        lst = self._waiters.pop(dep_id, None)
        if not lst:
            return
        dep = self.rt.events.get(dep_id)
        ready = self._ready
        for w in lst:
            w.remaining -= 1
            if not w.remaining:
                ready.append(w)
            if dep is not None:
                dep.release()                 # retained at _send_command
        # caller runs _dispatch_ready (keeps resolve usable mid-dispatch)

    def drain_waiters(self) -> list:
        """Server drain (DESIGN.md §7): empty the dependency waiter
        table, returning ``(ev, dev_name, pending_dep_ids)`` per
        distinct waiting command so the client can requeue each one on
        a survivor with its unresolved deps intact. The retained dep
        references are released here (the requeue's ``_send_command``
        re-retains what is still live); the old ``processed`` entry is
        dropped so nothing on this host claims the command anymore."""
        events = self.rt.events
        by_waiter: dict = {}          # id(w) -> (w, [dep ids])
        order: list = []
        for dep_id, lst in self._waiters.items():
            for w in lst:
                rec = by_waiter.get(id(w))
                if rec is None:
                    by_waiter[id(w)] = rec = (w, [])
                    order.append(rec)
                rec[1].append(dep_id)
                dep = events.get(dep_id)
                if dep is not None:
                    dep.release()             # retained at _send_command
        self._waiters.clear()
        out = []
        for w, dep_ids in order:
            self.processed.discard(w.ev.command.id)
            out.append((w.ev, w.dev_name, dep_ids))
        return out

    def notify_remote_complete(self, dep_id: int):
        # record only while the event is live: once retired, any command
        # arriving later resolves via the events-table miss, and a stale
        # entry here would never be cleaned (retirement already ran)
        if dep_id in self.rt.events:
            self.resolved_remote.add(dep_id)
        self._resolve_dep(dep_id)
        self._dispatch_ready()

    def _dispatch_ready(self):
        # drain in waves: execution may complete synchronously and
        # re-enter this method; a nested call drains the entries IT made
        # ready before the outer wave continues (matching the recursive
        # semantics of the pre-indexed implementation)
        while self._ready:
            wave = self._ready
            self._ready = deque()
            for w in wave:
                self._execute(w.ev, w.dev_name, w.dev_idx)

    # ---- execution ----
    def _execute(self, ev: Event, dev_name: str, dev_idx: int = -1):
        cmd = ev.command
        if type(cmd) is C.NDRangeKernel:
            # hot path: plain kernels skip the command-union isinstance
            # chain entirely and read cost fields as direct slots
            host = self.host
            if dev_idx < 0:
                dev_idx = host.dev_index[dev_name]
            dev = host.device_list[dev_idx]
            duration = cmd.duration
            cost = duration if duration is not None else \
                dev.kernel_cost(cmd.flops, cmd.bytes_moved, None)
        else:
            if isinstance(cmd, C.MigrateBuffer):
                self.rt._start_p2p_push(self, ev)
                return
            if isinstance(cmd, C.ReadBuffer):
                self.rt._start_read_return(self, ev)
                return
            host = self.host
            if dev_idx < 0:
                dev_idx = host.dev_index[dev_name]
            dev = host.device_list[dev_idx]
            if isinstance(cmd, C.WriteBuffer):
                cmd.buffer.set_data(np.asarray(cmd.data), self.name)
                ev.status = RUNNING
                ev.t_start = self.rt.clock.now
                self._complete(ev)
                return
            # BuiltinKernel / Marker / foreign commands: device time is
            # arbitrated across sessions by the host's per-device
            # scheduler — a ready command queues until the policy
            # dispatches it
            cost = dev.kernel_cost(getattr(cmd, "flops", 0.0),
                                   getattr(cmd, "bytes_moved", 0.0),
                                   getattr(cmd, "duration", None))
        dname = host.device_names[dev_idx]
        tr = self.rt._trace
        if tr is not None:
            # deps resolved, entering the device run queue: the one
            # lifecycle stamp the Event itself does not carry
            tr.cmd_ready(ev, self.rt.clock.now, self._tlabel, dname, cost)
        sch = host.scheduler_list[dev_idx]
        if sch.preempt_chunk is not None:
            # preemptive policy (llf, DESIGN.md §10): dispatch in
            # chunk-sized slices with preemption checks at the seams
            self._execute_preemptible(ev, dev, dname, sch, cost)
            return

        def run(release):
            if ev.status == ERROR:
                # failed while queued (crash fail-fast, detach) but the
                # entry outlived the sweep: never run a dead command —
                # and never let RUNNING overwrite a terminal status
                release()
                return
            ev.status = RUNNING

            def done():
                if ev.status == ERROR:
                    # failed while on the device (the host crashed or
                    # the tenant detached): the outputs must not be
                    # written — completion is void
                    release()
                    return
                if isinstance(cmd, C.NDRangeKernel):
                    if cmd.fn is not None:
                        ins = [b.data for b in cmd.inputs]
                        outs = cmd.fn(*ins)
                        if not isinstance(outs, (tuple, list)):
                            outs = (outs,)
                        for b, arr in zip(cmd.outputs, outs):
                            b.set_data(np.asarray(arr), self.name)
                    else:
                        for b in cmd.outputs:
                            b.invalidate_except(self.name)
                            b.valid_on = {self.name}
                else:
                    for b in getattr(cmd, "outputs", ()):
                        b.invalidate_except(self.name)
                        b.valid_on = {self.name}
                self._complete(ev)
                release()       # device freed: policy picks the next cmd

            ev.t_start, _ = dev.execute(cost, done)

        # the (event, device) tag lets a drain requeue scheduled-but-
        # unstarted commands without ever firing their run closures
        sch.submit(self, self.rt.weight, cost, run, (ev, dname),
                   ev.deadline)

    def _execute_preemptible(self, ev: Event, dev, dname: str, sch,
                             cost: float):
        """Chunked dispatch for preemptive policies (DESIGN.md §10).

        The kernel runs in ``preempt_chunk``-sized device slices; after
        each slice the scheduler is asked whether a queued command's
        laxity beats the running command's residual laxity
        (``deadline − remaining``). On preemption the remainder is
        requeued at its residual cost *before* the device is released,
        so the dispatcher's next pop compares remainder and preemptor
        head-to-head. The ``run`` closure may therefore be dispatched
        several times — once per resumption — but the outputs are
        written and the event completed exactly once, on the final
        slice; a drain that sweeps a preempted remainder requeues the
        whole command elsewhere via its (event, device) tag, same as
        any queued entry."""
        cmd = ev.command
        deadline = ev.deadline
        # residual-laxity base: a deadline-less command preempts never
        # and yields always (key inf), matching its queue priority
        key_base = deadline if deadline is not None else _INF
        chunk = sch.preempt_chunk
        weight = self.rt.weight
        state = [cost]                # remaining device-seconds

        def run(release):
            if ev.status == ERROR:
                release()
                return
            ev.status = RUNNING
            slice_next(release)

        def slice_next(release):
            remaining = state[0]
            this = remaining if remaining <= chunk else chunk

            def slice_done():
                if ev.status == ERROR:
                    # crashed/detached mid-kernel: outputs unwritten,
                    # completion void, device freed
                    release()
                    return
                left = state[0] - this
                state[0] = left
                if left <= 0.0:
                    self._finish_exec(ev)
                    release()
                    return
                if sch.should_preempt(key_base - left):
                    sch.requeue_preempted(self, weight, left, run,
                                          (ev, dname), deadline)
                    release()
                    return
                slice_next(release)

            t0, _ = dev.execute(this, slice_done)
            tr = self.rt._trace
            if tr is not None:
                # actual device occupancy: under preemption the wall
                # interval [t_start, t_end] interleaves with other
                # commands; the slices are the ground truth the
                # critical-path analyzer tiles with (DESIGN.md §11)
                tr.exec_slice(ev, t0, t0 + this)
            if ev.t_start == 0.0:
                ev.t_start = t0   # first slice only; resumes keep it

        sch.submit(self, weight, cost, run, (ev, dname), deadline)

    def _finish_exec(self, ev: Event):
        """Final-slice completion for the preemptible path: write the
        outputs and complete the event (the non-preemptive path keeps
        this logic inline in its ``done`` closure)."""
        cmd = ev.command
        if isinstance(cmd, C.NDRangeKernel):
            if cmd.fn is not None:
                ins = [b.data for b in cmd.inputs]
                outs = cmd.fn(*ins)
                if not isinstance(outs, (tuple, list)):
                    outs = (outs,)
                for b, arr in zip(cmd.outputs, outs):
                    b.set_data(np.asarray(arr), self.name)
            else:
                for b in cmd.outputs:
                    b.invalidate_except(self.name)
                    b.valid_on = {self.name}
        else:
            for b in getattr(cmd, "outputs", ()):
                b.invalidate_except(self.name)
                b.valid_on = {self.name}
        self._complete(ev)

    def _complete(self, ev: Event):
        if ev.status == ERROR:
            # failed while executing or queued (tenant detach fails all
            # live events; the non-preemptive in-service command still
            # runs to completion) — completion is void, but the caller's
            # device release must still run
            return
        ev.complete(self.rt.clock.now)
        # resolve locally first: dependents on THIS server may have
        # classified the event as remote (e.g. a migration that finishes
        # on the destination) — no wire cost for self-notification
        self.notify_remote_complete(ev.id)
        self.rt._broadcast_completion(self, ev)


class Session:
    """Client-side view of one server connection (paper §4.3).

    ``replay_window`` bounds the unacked-command replay buffer; it is a
    runtime knob (``ClientRuntime(replay_window=...)``) rather than a
    hard-coded 64, and ``stats()['replay_window']`` surfaces the
    configured size next to the overflow counter."""

    def __init__(self, name: str, replay_window: int = 64):
        self.name = name
        self.session_id = bytes(16)           # all-zeroes until handshake
        self.available = False
        self.replay: deque = deque(maxlen=replay_window)  # unacked cmds
        self.lost_unacked = 0                  # overflowed replay slots

    def record(self, item):
        """Append to the replay window, dropping already-finished entries
        first. Overflow means an UNACKED command falls out of the window
        and could not be replayed after a reconnect — that loss used to
        be silent; now it is counted and logged once per session."""
        buf = self.replay
        while buf:
            s = buf[0][0].status
            if s != COMPLETE and s != ERROR:
                break
            buf.popleft()
        if buf.maxlen is not None and len(buf) == buf.maxlen:
            if not self.lost_unacked:
                log.warning(
                    "session %s: replay window full (maxlen=%d); dropping "
                    "oldest unacked command — it cannot be replayed after "
                    "a reconnect", self.name, buf.maxlen)
            self.lost_unacked += 1
        buf.append(item)


class ClientRuntime:
    """The PoCL remote client driver (host side of the OpenCL API)."""

    def __init__(self, servers: Optional[Sequence[ServerSpec]] = None,
                 client_link: LinkSpec = LinkSpec(),
                 peer_link: Optional[LinkSpec] = None,
                 transport: str = "tcp",
                 peer_transport: Optional[str] = None,
                 svm: bool = False,
                 scheduling: str = "decentralized",   # | 'client'
                 p2p_migration: bool = True,
                 completion_routing: str = "subscription",  # | 'broadcast'
                 local_device: Optional[DeviceSpec] = None,
                 cluster: Optional[Cluster] = None,
                 name: Optional[str] = None,
                 weight: float = 1.0,
                 slo_ms: Optional[float] = None,
                 slo_probe: Optional[dict] = None,
                 replay_window: int = 64,
                 reconnect_retries: int = 4,
                 reconnect_backoff: float = 2e-3,
                 scheduler: Optional[str] = None,
                 scheduler_quantum: Optional[float] = None,
                 scheduler_opts: Optional[dict] = None,
                 nic_bandwidth: Optional[float] = None,
                 nic_ingress_bandwidth: Optional[float] = None,
                 store: Optional[bool] = None,
                 store_capacity: Optional[float] = None,
                 placement: Optional[str] = None,
                 admission=None,
                 trace=None):
        if completion_routing not in ("subscription", "broadcast"):
            raise ValueError(f"unknown completion_routing "
                             f"{completion_routing!r}")
        if not weight > 0.0:
            raise ValueError(f"weight must be positive, got {weight!r}")
        # per-tenant latency target (DESIGN.md §10): every command this
        # tenant enqueues carries the absolute deadline
        # ``t_queued + slo_ms``; deadline-aware schedulers order by it,
        # admission control screens against it, and the client-ack path
        # scores violations against it. None = no target (bit-exact
        # pre-SLO behavior).
        if slo_ms is not None and not slo_ms > 0.0:
            raise ValueError(f"slo_ms must be positive, got {slo_ms!r}")
        if slo_probe is not None:
            if slo_ms is None:
                raise ValueError("slo_probe requires slo_ms")
            unknown = sorted(set(slo_probe) - {"cost_s", "nbytes"})
            if unknown:
                raise ValueError(f"unknown slo_probe keys: {unknown} "
                                 f"(allowed: ['cost_s', 'nbytes'])")
            for k, v in slo_probe.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)) \
                        or v < 0:
                    raise ValueError(
                        f"slo_probe[{k!r}] must be a non-negative "
                        f"number, got {v!r}")
        if cluster is None:
            if servers is None:
                raise ValueError("pass server specs or an existing cluster")
            cluster = Cluster(servers,
                              peer_link=peer_link if peer_link is not None
                              else LinkSpec(latency=61e-6,
                                            bandwidth=100e6 / 8),
                              peer_transport=peer_transport or transport,
                              svm=svm, scheduler=scheduler or "fifo",
                              scheduler_quantum=scheduler_quantum,
                              scheduler_opts=scheduler_opts,
                              nic_bandwidth=nic_bandwidth,
                              nic_ingress_bandwidth=nic_ingress_bandwidth,
                              store=bool(store),
                              store_capacity=store_capacity,
                              placement=placement or "pinned",
                              admission=admission,
                              trace=trace)
            self._placement_policy = None   # cluster default covers it
        else:
            if servers is not None:
                raise ValueError("pass either servers or cluster, not both")
            ignored = {"peer_link": peer_link,
                       "peer_transport": peer_transport,
                       "scheduler": scheduler,
                       "scheduler_quantum": scheduler_quantum,
                       "scheduler_opts": scheduler_opts,
                       "nic_bandwidth": nic_bandwidth,
                       "nic_ingress_bandwidth": nic_ingress_bandwidth,
                       "store": store,
                       "store_capacity": store_capacity,
                       "admission": admission,
                       "trace": trace}
            bad = [k for k, v in ignored.items() if v is not None]
            if bad:
                # these configure the shared substrate — accepting them
                # here would silently measure a different cluster than
                # the caller asked for
                raise ValueError(
                    f"{', '.join(sorted(bad))} are cluster-level settings; "
                    f"pass them to Cluster(), not to a ClientRuntime "
                    f"attaching to an existing one")
            # placement, by contrast, is legitimately per-tenant when
            # attaching: it decides where THIS tenant's kernels run,
            # reading the same shared telemetry (DESIGN.md §6)
            self._placement_policy = (make_placement_policy(placement)
                                      if placement is not None else None)
            if self._placement_policy is not None and \
                    type(self._placement_policy) is not PinnedPolicy:
                # someone will read the telemetry now: start keeping
                # the engine's outstanding tally (stays on for good)
                cluster.placement.telemetry_active = True
        self.cluster = cluster
        self.clock = cluster.clock
        # default names come from a monotonic counter, not the live
        # client list — detach() shrinks the list, and a recycled "ue2"
        # would alias a departed tenant in stats and error messages
        self.name = name if name is not None else f"ue{cluster._tenant_seq}"
        cluster._tenant_seq += 1
        # observability (DESIGN.md §9): the hot-path gate is one slot
        # load + None check; labels are precomputed (cluster-namespace
        # prefix + tenant name) so hooks never build strings
        self._trace = cluster.trace
        self._tp = cluster.trace_prefix
        self._tlabel = self._tp + self.name
        self.weight = weight                  # fair-scheduler share
        self.transport = make_transport(transport, svm)
        self.peer_transport = cluster.peer_transport
        self.scheduling = scheduling
        self.p2p_migration = p2p_migration
        self.completion_routing = completion_routing
        # dispatch hot-path constants (DESIGN.md §8): the zero-payload
        # command cost and the completion cost are per-transport
        # constants, and the scheduling mode is fixed at construction —
        # the per-send ternaries and cost calls fold to these reads.
        # Each derived float is computed with the exact operand pair the
        # per-send expression used, so timestamps are bit-identical.
        _c0 = self.transport.command_cost(0.0)
        self._cmd_cost0 = _c0
        self._submit_overhead0 = CLIENT_SUBMIT + _c0.sender_cpu
        self._recv_delay0 = _c0.receiver_cpu + DISPATCH
        self._comp_cost = (self.peer_transport
                           if scheduling == "decentralized"
                           else self.transport).completion_cost()
        self._complete_overhead = COMPLETE_WRITE + self._comp_cost.sender_cpu
        # every client link (seed and joined alike) is built from
        # `client_link`, so the client-side wire inflation factor is a
        # per-runtime constant too
        self._cscale0 = wire_scale(self.transport, client_link.bandwidth)
        self.servers = {h.name: ServerSim(self, h)
                        for h in cluster.hosts.values()}
        self.events: dict = {}
        # event id -> {server names holding dependents of it}; registered
        # at enqueue time so a completion is signaled "directly to the
        # target server" (§5.2) instead of broadcast to every peer
        self._subs: dict = {}
        self.client_completion_msgs = 0       # server → client completes
        self.peer_completion_msgs = 0         # server → peer notifications
        self.client_routed_completion_msgs = 0  # client → server forwards
        self.sessions = {s: Session(s, replay_window)
                         for s in self.servers}
        # kept for membership joins: a server admitted mid-run gets a
        # session and access link of the same spec the seed set did
        self._replay_window = replay_window
        self._client_link_spec = client_link
        # bounded reconnect (DESIGN.md §7): retries with exponential
        # backoff instead of hanging on a server that never comes back
        if reconnect_retries < 0:
            raise ValueError(f"reconnect_retries must be >= 0, "
                             f"got {reconnect_retries!r}")
        if not reconnect_backoff > 0.0:
            raise ValueError(f"reconnect_backoff must be positive, "
                             f"got {reconnect_backoff!r}")
        self.reconnect_retries = reconnect_retries
        self.reconnect_backoff = reconnect_backoff
        self.reconnect_attempts: dict = {s: 0 for s in self.servers}
        self.reconnect_failures: dict = {}    # server -> surfaced reason
        # drain requeue dedup (DESIGN.md §7): a command bounced off a
        # draining/dead host is re-placed at most once — a replayed or
        # in-flight duplicate arriving later finds the id here
        self._requeued: set = set()
        self.local_device = DeviceSim(
            self.clock, "local",
            *( (local_device.flops, local_device.mem_bw)
               if local_device else (1e12, 50e9) ))
        # links: client links are per tenant (each UE brings its own
        # radio/access link); the peer mesh is the cluster's, shared
        self.c_links = {s: Link(self.clock, client_link.latency,
                                client_link.bandwidth,
                                f"{self.name}<->{s}")
                        for s in self.servers}
        tr = self._trace
        if tr is not None:
            for lk in self.c_links.values():
                lk.trace = tr
                lk.trace_label = self._tp + lk.name
        self.p_links = cluster.p_links
        cluster.clients.append(self)
        self._buffers: list[Buffer] = []
        self._mr_registered: set = set()
        # (buf.id, dst server) -> (migration Event, buf.version snapshot);
        # lets back-to-back requests for the same payload coalesce onto
        # the transfer already in flight (entries drop on completion, and
        # a version mismatch — the buffer was written since — makes the
        # entry stale so a fresh transfer is started instead)
        self._inflight_migrations: dict = {}
        # data-plane scoreboard (stats())
        self.bytes_on_wire = 0.0              # migration payload wire bytes
        self.upload_bytes_on_wire = 0.0       # write payload wire bytes
        self.migrations_coalesced = 0         # requests served by in-flight
        self.chunks_in_flight = 0             # gauge: chunks on any link
        self.peak_chunks_in_flight = 0
        # content-addressed store scoreboard (this tenant's share of the
        # cluster counters in BufferStore.stats())
        self.dedup_hits = 0                   # transfers served by a replica
        self.dedup_bytes_saved = 0.0          # payload bytes never sent
        self.detached = False                 # tenant lifecycle (detach())
        # SLO plumbing (DESIGN.md §10). ``_slo_s`` is the effective
        # per-command budget in seconds (None = no target: the deadline
        # stamp, the reap-time scoring, and the admission feedback are
        # all skipped behind one load + branch). Admission screening
        # happens here — after the links/sessions exist (the probe math
        # reads them) but before the handshake spends simulated time —
        # and may degrade the budget or reject the tenant outright.
        self.slo_ms = slo_ms                  # requested target (ms)
        self._slo_s = slo_ms * 1e-3 if slo_ms is not None else None
        self._slo_probe = dict(slo_probe) if slo_probe else None
        self._slo_class = (f"{slo_ms:g}ms" if slo_ms is not None
                           else None)
        self.admission = None                 # AdmissionDecision or None
        self.slo_commands = 0                 # completions scored
        self.slo_violations = 0               # ... that missed deadline
        ctrl = cluster.admission
        if ctrl is not None and self._slo_s is not None:
            decision = ctrl.request(self)
            self.admission = decision
            tr = self._trace
            if tr is not None:
                # verdict marker (admit/degrade/reject + predicted
                # latency) lands in the trace even for rejects — the
                # tenant then leaves before spending simulated time
                tr.admission(self._tlabel, decision)
            if decision.status == REJECT:
                # leave no residue on the shared cluster: the sessions
                # and links built above were never handshaken and spend
                # no simulated time; only the client list saw us
                cluster.clients.remove(self)
                self.detached = True
                raise AdmissionRejected(self.name, decision)
            if decision.status == DEGRADE:
                self._slo_s = decision.slo_s
                self._slo_class = f"{decision.slo_s * 1e3:g}ms"
        # connect (handshake: rtt + session id assignment) — run the
        # clock just far enough that all of THIS client's sessions are
        # established, as clCreateContext would block. A full drain here
        # would fast-forward every other tenant's in-flight work on a
        # shared cluster, so a dynamically-arriving UE could never
        # contend with work already queued. Hosts that are not live
        # (DEAD/DRAINING members of an elastic cluster) return None —
        # their sessions simply stay unavailable.
        deadlines = [d for d in (self._handshake(s) for s in self.servers)
                     if d is not None]
        if deadlines:
            self.clock.run(until=max(deadlines))

    # ------------------------------------------------------------------
    def peer_link(self, a: str, b: str) -> Link:
        return self.cluster.peer_link(a, b)

    def _nic_in(self, server: str) -> Optional[NIC]:
        """The receiving host's shared ingress port (None when the
        cluster models no ingress NIC). Every send that terminates at a
        server passes through it; sends to the client do not — the UE
        side has no modeled port."""
        return self.cluster.hosts[server].nic_in

    def _handshake(self, server: str) -> Optional[float]:
        """Returns the sim time at which the session becomes available,
        or None when no session can be established (host not live, or
        the access link is down)."""
        if self.cluster.hosts[server].state not in (ACTIVE, JOINING):
            return None
        sess = self.sessions[server]

        def done():
            sess.session_id = secrets.token_bytes(16)
            srv = self.servers[server]
            srv.session_id = sess.session_id
            # §4.3: the daemon's session table is keyed by session id —
            # the id (not the transport address) is what a reconnect
            # from a new IP presents to resume this session's state
            srv.host.sessions[sess.session_id] = srv
            sess.available = True

        return self.c_links[server].send(64, done,
                                         ingress=self._nic_in(server))

    # ---- elastic membership hooks (DESIGN.md §7) ----
    def _attach_server(self, host: ServerHost) -> float:
        """A server joined the live cluster: build this tenant's session
        state and access link to it and handshake, exactly as the
        constructor does for the seed set. Returns the sim time the
        session becomes available (now, if the handshake cannot start).
        A rejoin of a previously-dead name replaces the corpse's
        session wholesale — nothing resurrects."""
        name = host.name
        self.servers[name] = ServerSim(self, host)
        self.sessions[name] = Session(name, self._replay_window)
        lk = self.c_links[name] = Link(self.clock,
                                       self._client_link_spec.latency,
                                       self._client_link_spec.bandwidth,
                                       f"{self.name}<->{name}")
        if self._trace is not None:
            lk.trace = self._trace
            lk.trace_label = self._tp + lk.name
        self.reconnect_attempts.setdefault(name, 0)
        self.reconnect_failures.pop(name, None)
        d = self._handshake(name)
        return d if d is not None else self.clock.now

    def _server_retired(self, name: str) -> None:
        """A drain finished: the host leaves cleanly — every command
        was executed or requeued and every sole replica re-homed, so
        this is bookkeeping: close the session and link, drop replica
        validity (the canonical bytes live on the ``Buffer``), and
        defensively fail anything that still targets the host."""
        sess = self.sessions.get(name)
        if sess is not None:
            sess.available = False
            sess.replay.clear()
            sess.session_id = bytes(16)
        srv = self.servers.get(name)
        if srv is not None:
            srv.processed.clear()
            srv.resolved_remote.clear()
            srv._waiters.clear()      # drained: empty unless raced
            srv._ready.clear()
            srv.session_id = None
        link = self.c_links.get(name)
        if link is not None:
            link.close()
        for b in self._buffers:
            b.valid_on.discard(name)
        self._fail_events_on(name, f"server {name} retired")

    def _server_crashed(self, name: str) -> None:
        """Abrupt server loss: every live event targeting the host
        fails fast — dependents on survivors observe ERROR through the
        normal completion routing instead of hanging — the session is
        destroyed (a rejoin is a FRESH server), and replica validity
        drops. Recovery (retry, re-place, reconnect with backoff) is
        the client application's move, §4.3-style."""
        sess = self.sessions.get(name)
        if sess is not None:
            sess.available = False
            sess.replay.clear()
            sess.session_id = bytes(16)
        srv = self.servers.get(name)
        if srv is not None:
            # commands waiting on deps die with the host; release the
            # dep references they retained or those events never retire
            for dep_id, lst in list(srv._waiters.items()):
                dep = self.events.get(dep_id)
                if dep is not None:
                    for _w in lst:
                        dep.release()
            srv._waiters.clear()
            srv._ready.clear()
            srv.processed.clear()
            srv.resolved_remote.clear()
            srv.session_id = None
        link = self.c_links.get(name)
        if link is not None:
            link.close()              # kills mid-flight chunked uploads
        for b in self._buffers:
            b.valid_on.discard(name)
        self._fail_events_on(name, f"server {name} crashed")

    def _fail_events_on(self, name: str, reason: str) -> None:
        """Fail-fast every live event executing on ``name`` or moving
        data into it. The in-flight migration table self-cleans: fail()
        fires the entry's drop callback."""
        now = self.clock.now
        for ev in list(self.events.values()):
            if ev.status in (COMPLETE, ERROR):
                continue
            if ev.server == name or \
                    getattr(ev.command, "dst_server", None) == name:
                ev.fail(now, reason)
                self._route_completion_via_client(ev)
                ev.release()          # no completion ack will ever come

    def _pick_failover_server(self, exclude: Optional[str] = None) \
            -> Optional[str]:
        """Least-loaded survivor this tenant can use (drain/crash
        failover): an available session on an ACTIVE host, by (queue
        depth, name) so the choice is deterministic."""
        engine = self.cluster.placement
        eligible = self.cluster.membership.is_eligible
        best = None
        best_key = None
        for s in sorted(self.sessions):
            if s == exclude or not eligible(s):
                continue
            if not self.sessions[s].available:
                continue
            key = (engine.queue_depth(s), s)
            if best_key is None or key < best_key:
                best, best_key = s, key
        return best

    def _requeue_after_drain(self, ev: Event, old_server: str,
                             dev_name: str, dep_ids: list) -> None:
        """A draining (or just-dead) server handed back a scheduled-
        but-unstarted command: re-place it on a survivor. The command
        id is unchanged, so the §4.3 dedup guarantees exactly-once —
        the old host's tables dropped the command before this runs, and
        ``_requeued`` stops a replayed or in-flight duplicate from
        bouncing a second time."""
        if self.detached or ev.status in (COMPLETE, ERROR):
            return
        if ev.id in self._requeued:
            return                    # already re-placed: this copy is
        self._requeued.add(ev.id)     # the §4.3 duplicate — drop it
        tr = self._trace
        if tr is not None:
            tr.requeue(ev, self.clock.now, self._tp + old_server, "drain")
        cmd = ev.command
        if isinstance(cmd, C.MigrateBuffer):
            self._requeue_migration(ev, cmd)
            return
        target = self._pick_failover_server(exclude=old_server)
        if target is None:
            ev.fail(self.clock.now,
                    f"server {old_server} left and no failover target")
            self._route_completion_via_client(ev)
            ev.release()              # no completion ack will ever come
            return
        dep_ids = list(dep_ids)
        payload = 0.0
        if isinstance(cmd, C.NDRangeKernel):
            # the kernel's implicit input migrations targeted the old
            # host; re-derive them for the new one
            for b in cmd.inputs:
                if target not in b.valid_on:
                    dep_ids.append(self.enqueue_migration(b, target).id)
        elif isinstance(cmd, C.WriteBuffer):
            payload = cmd.nbytes      # the bytes go to the new host now
            cmd.buffer.valid_on.discard(old_server)
            cmd.buffer.valid_on.add(target)
        if dev_name and \
                dev_name not in self.cluster.hosts[target].devices:
            dev_name = ""             # heterogeneous fleet: default dev
        ev.server = target
        self._send_command(ev, target, dev_name, dep_ids, payload=payload)

    def _requeue_migration(self, ev: Event, cmd) -> None:
        """Re-drive a migration whose source host left: a fresh
        enqueue picks a surviving replica (or falls back to a client
        upload) and the result is mirrored onto the original handle."""
        buf, dst = cmd.buffer, cmd.dst_server
        # the handle must leave the coalescing table first: the fresh
        # migration would otherwise coalesce onto the very event it is
        # meant to complete
        self._drop_inflight((buf.id, dst), ev)
        retry = self.enqueue_migration(buf, dst)

        def mirror(r):
            if ev.status in (COMPLETE, ERROR):
                return
            if r.status == ERROR:
                ev.fail(self.clock.now, r.error or "migration failed")
            else:
                ev.complete(self.clock.now)
            self._route_completion_via_client(ev)
            ev.release()              # client observed completion directly

        retry.on_complete(mirror)

    # ---- buffers ----
    def create_buffer(self, nbytes: int, content_size_buffer: Buffer = None,
                      name: str = "") -> Buffer:
        b = Buffer(nbytes=nbytes, content_size_buffer=content_size_buffer,
                   name=name)
        b.valid_on = {"client"}
        self._buffers.append(b)
        return b

    # ---- event lifecycle ----
    def _register_event(self, ev: Event) -> Event:
        ev.t_queued = self.clock.now
        slo = self._slo_s
        if slo is not None:         # deadline stamp (DESIGN.md §10)
            ev.deadline = ev.t_queued + slo
        ev.retain()                 # client hold until completion observed
        ev.on_retire = self._retire
        self.events[ev.id] = ev
        tr = self._trace
        if tr is not None:
            tr.cmd_queued(ev, self._tlabel)
        return ev

    def _new_event(self, cmd, server: str) -> Event:
        # _register_event, inlined (one enqueue-path call per command)
        ev = Event(command=cmd, server=server)
        ev.t_queued = self.clock.now
        slo = self._slo_s
        if slo is not None:         # deadline stamp (DESIGN.md §10)
            ev.deadline = ev.t_queued + slo
        ev._refs += 1               # client hold until completion observed
        ev.on_retire = self._retire
        self.events[ev.id] = ev
        tr = self._trace
        if tr is not None:
            tr.cmd_queued(ev, self._tlabel)
        return ev

    def _retire(self, ev: Event):
        """Last reference dropped on a finished event: remove it from
        every runtime table so long runs stay memory-bounded. The Event
        object itself stays valid for user-held handles."""
        self.events.pop(ev.id, None)
        self._subs.pop(ev.id, None)
        cmd_id = getattr(ev.command, "id", None)
        for srv in self.servers.values():
            srv.resolved_remote.discard(ev.id)
            if cmd_id is not None:
                srv.processed.discard(cmd_id)

    # ---- enqueue API ----
    def enqueue_kernel(self, server: str, device: str = "",
                       fn: Optional[Callable] = None,
                       inputs: Sequence[Buffer] = (),
                       outputs: Sequence[Buffer] = (),
                       flops: float = 0.0, bytes_moved: float = 0.0,
                       duration: Optional[float] = None,
                       wait_for: Sequence[Event] = (),
                       name: str = "kernel",
                       pin: bool = False) -> Event:
        """Enqueue a kernel; implicit migrations are added for any input
        not valid on the target server (standard OpenCL semantics).

        ``server`` is the *requested* placement: the cluster's placement
        engine (DESIGN.md §6) may redirect the kernel — and therefore
        its implicit migrations — to a better host. The default
        ``pinned`` policy always honors the request, preserving the
        hard-picked behavior bit-exactly; ``pin=True`` bypasses the
        engine for this one kernel regardless of policy (used by the
        redundant-dispatch race, whose whole point is landing each copy
        on a DIFFERENT explicitly-chosen server)."""
        self._check_live()
        engine = self.cluster.placement
        if not pin:
            server = engine.place(self, server, device, inputs, flops,
                                  bytes_moved, duration)
        if not self.sessions[server].available:
            raise DeviceUnavailable(server)
        deps = list(wait_for)
        for b in inputs:
            if server not in b.valid_on:
                deps.append(self.enqueue_migration(b, server,
                                                   wait_for=wait_for))
        # copy-on-write (DESIGN.md §5): writing an output that holds
        # shared content forks it to a private buffer first — the shared
        # replicas stay intact for the other holders, and the fork's
        # device-side copy (read + write of the buffer) is charged to
        # this kernel's memory traffic (a ``duration`` override absorbs
        # it, like every other analytic cost term)
        store = self.cluster.store
        if store is not None:
            for b in outputs:
                if store.cow_fork(b):
                    bytes_moved += 2.0 * b.nbytes
        cmd = C.NDRangeKernel(fn=fn, inputs=tuple(inputs),
                              outputs=tuple(outputs), flops=flops,
                              bytes_moved=bytes_moved, duration=duration,
                              name=name)
        ev = self._new_event(cmd, server)
        if engine.telemetry_active:
            engine.record(server,
                          engine.kernel_cost(server, device, flops,
                                             bytes_moved, duration), ev)
        self._send_command(ev, server, device, [d.id for d in deps])
        for b in outputs:
            # eager client-side clobber: later enqueues must neither read
            # stale replicas nor coalesce onto migrations of the old
            # contents, so the version bumps at enqueue time too
            b.invalidate_except(server)
        return ev

    def enqueue_many(self, server: str, kernels: Sequence[dict],
                     device: str = "", pin: bool = False) -> list:
        """Batched ``enqueue_kernel``: one call, many kernels, identical
        schedule (DESIGN.md §8).

        ``kernels`` is a sequence of dicts carrying ``enqueue_kernel``'s
        keyword arguments (``fn``, ``inputs``, ``outputs``, ``flops``,
        ``bytes_moved``, ``duration``, ``wait_for``, ``name``; optional
        per-kernel ``server``/``device``/``pin`` overriding the
        call-level defaults). ``wait_for`` entries may be Event objects
        or **integer indices** into this batch, referencing an earlier
        kernel's event — the natural way to express a dependency chain
        built in one call. Returns the Events in batch order.

        Produces the *exact* sequence of clock-schedule calls the
        equivalent ``enqueue_kernel`` loop would (same timestamps, same
        seq numbers — bit-exact), because no simulated time passes
        between batch entries: the liveness check, placement policy
        resolution, placement candidate lists (per named device), and
        table lookups are hoisted out of the loop, while everything
        observable — placement decisions and counters, implicit
        migrations, CoW forks, telemetry records, wire sends, eager
        invalidation — runs per kernel in the loop's order."""
        self._check_live()
        engine = self.cluster.placement
        policy = self._placement_policy or engine.default_policy
        pinned_policy = type(policy) is PinnedPolicy
        telemetry = engine.telemetry_active
        sessions = self.sessions
        store = self.cluster.store
        new_event = self._new_event
        send = self._send_command
        cand_cache: dict = {}          # device -> hoisted candidate list
        results: list = []
        for spec in kernels:
            get = spec.get
            srv = get("server", server)
            dev = get("device", device)
            inputs = get("inputs", ())
            outputs = get("outputs", ())
            flops = get("flops", 0.0)
            bytes_moved = get("bytes_moved", 0.0)
            duration = get("duration")
            wait_for = [results[w] if type(w) is int else w
                        for w in get("wait_for", ())]
            if not (pin or get("pin", False)):
                if pinned_policy:
                    # inlined PlacementEngine.place fast path: counters
                    # only, the requested server stands
                    engine.decisions += 1
                    engine.placed_local += 1
                else:
                    cands = cand_cache.get(dev)
                    if cands is None:
                        cands = cand_cache[dev] = \
                            engine.candidates_for(self, dev)
                    srv = engine.place(self, srv, dev, inputs, flops,
                                       bytes_moved, duration,
                                       candidates=cands)
            if not sessions[srv].available:
                raise DeviceUnavailable(srv)
            if inputs:
                deps = list(wait_for)
                for b in inputs:
                    if srv not in b.valid_on:
                        deps.append(self.enqueue_migration(
                            b, srv, wait_for=wait_for))
            else:
                deps = wait_for     # fresh private list: no copy needed
            if store is not None:
                for b in outputs:
                    if store.cow_fork(b):
                        bytes_moved += 2.0 * b.nbytes
            cmd = C.NDRangeKernel(get("fn"), tuple(inputs),
                                  tuple(outputs), flops, bytes_moved,
                                  duration, get("name", "kernel"))
            ev = new_event(cmd, srv)
            if telemetry:
                engine.record(srv,
                              engine.kernel_cost(srv, dev, flops,
                                                 bytes_moved, duration),
                              ev)
            send(ev, srv, dev, [d.id for d in deps])
            for b in outputs:
                b.invalidate_except(srv)
            results.append(ev)
        return results

    def enqueue_write(self, server: str, buf: Buffer, data,
                      wait_for: Sequence[Event] = ()) -> Event:
        self._check_live()
        cmd = C.WriteBuffer(buffer=buf, data=data,
                            nbytes=np.asarray(data).nbytes)
        ev = self._new_event(cmd, server)
        dep_ids = [d.id for d in wait_for]
        store = self.cluster.store
        if store is not None and cmd.nbytes > 0:
            self._send_write_via_store(ev, server, buf, cmd, dep_ids,
                                       store)
        else:
            self._send_command(ev, server, "", dep_ids,
                               payload=cmd.nbytes)
        buf.valid_on = {server, "client"}
        buf.version += 1        # eager: new contents are on their way
        return ev

    def _record_dedup(self, store: BufferStore, entry, nbytes: float):
        store.record_dedup(entry, nbytes)
        self.dedup_hits += 1
        self.dedup_bytes_saved += nbytes
        tr = self._trace
        if tr is not None:
            tr.dedup(self.clock.now, self._tlabel, nbytes)

    def _unrecord_dedup(self, store: BufferStore, nbytes: float):
        store.unrecord_dedup(nbytes)
        self.dedup_hits -= 1
        self.dedup_bytes_saved -= nbytes
        tr = self._trace
        if tr is not None:
            tr.dedup(self.clock.now, self._tlabel, -nbytes)

    def _send_write_via_store(self, ev: Event, server: str, buf: Buffer,
                              cmd, dep_ids: list,
                              store: BufferStore) -> None:
        """Content-addressed upload (DESIGN.md §5). The payload digest is
        computed at enqueue, like the command struct: if an identical
        replica — any tenant's — is already resident on the target
        server, only the command struct + digest cross the wire; if one
        is in flight there, the command gates on its arrival instead of
        re-sending the bytes; otherwise the payload is paid once and the
        landed replica registers with the store for everyone after."""
        key = content_digest(cmd.data)
        entry = store.attach(buf, key, cmd.nbytes)
        # +1 because enqueue_write bumps AFTER this resolution: the
        # snapshot must equal the version this write itself installs,
        # so only a LATER write of the buffer invalidates a gate
        self._resolve_store_write(ev, server, buf, cmd, dep_ids, store,
                                  entry, buf.version + 1)

    def _resolve_store_write(self, ev: Event, server: str, buf: Buffer,
                             cmd, dep_ids: list, store: BufferStore,
                             entry, snap: int) -> None:
        """Resolve a store-attached write against the entry's CURRENT
        replica state (re-entered when a ride dies, so a fresh check —
        a surviving rider may have restarted the upload we can gate
        on instead of each rider paying its own copy). ``snap`` is the
        buffer version this write installs: a later write bumping past
        it supersedes this one while it gates."""
        if server in entry.valid_on:
            self._record_dedup(store, entry, cmd.nbytes)
            self._send_command(ev, server, "", dep_ids,
                               extra_wire=DIGEST_BYTES)
            return
        pend = entry.pending.get(server)
        if pend is not None and pend.status not in (COMPLETE, ERROR):
            self._record_dedup(store, entry, cmd.nbytes)

            def after(_p):
                if self.detached or ev.status in (COMPLETE, ERROR):
                    # we left (detach failed our events) before ever
                    # sending the dedup'd command: no write happened,
                    # so no bytes were saved — take the claim back
                    self._unrecord_dedup(store, cmd.nbytes)
                    return
                if buf.version != snap:
                    # a newer write of this buffer was sent while we
                    # gated: shipping the stale command now would invert
                    # write-after-write order on the server (store-less
                    # clusters send writes FIFO). The content this write
                    # carried is superseded — complete as a no-op
                    ev.complete(self.clock.now)
                    self._route_completion_via_client(ev)
                    ev.release()    # client observed completion directly
                    return
                if server in entry.valid_on:
                    self._send_command(ev, server, "", dep_ids,
                                       extra_wire=DIGEST_BYTES)
                else:
                    # the transfer we gated on never landed (dropped
                    # link or stale payload): the claimed saving did not
                    # materialize — take it back and resolve again
                    self._unrecord_dedup(store, cmd.nbytes)
                    self._resolve_store_write(ev, server, buf, cmd,
                                              dep_ids, store, entry,
                                              snap)

            pend.on_complete(after)
            return
        self._send_upload(ev, server, cmd, dep_ids, store, entry)

    def _send_upload(self, ev: Event, server: str, cmd, dep_ids: list,
                     store: BufferStore, entry) -> None:
        def landed(_e):
            if _e.status == COMPLETE:
                store.replica_landed(entry, server)

        # landed BEFORE add_pending: its clear-callback garbage-collects
        # entries with no refs/replicas/pendings, and if the buffer was
        # rewritten mid-upload (refs empty) the replica must register
        # first — otherwise replica_landed resurrects a popped entry and
        # its resident bytes leak forever
        ev.on_complete(landed)
        store.add_pending(entry, server, ev)
        self._send_command(ev, server, "", dep_ids, payload=cmd.nbytes)

    def enqueue_read(self, server: str, buf: Buffer,
                     wait_for: Sequence[Event] = ()) -> Event:
        self._check_live()
        cmd = C.ReadBuffer(buffer=buf)
        ev = self._new_event(cmd, server)
        self._send_command(ev, server, "", [d.id for d in wait_for])
        return ev

    def enqueue_migration(self, buf: Buffer, dst: str,
                          wait_for: Sequence[Event] = ()) -> Event:
        """Migrate to ``dst``. P2P: command goes to the SOURCE server,
        which pushes directly to the destination (paper §5.1).

        Duplicate requests coalesce: if a migration of the same buffer
        contents to the same destination is already in flight, its event
        is returned instead of pushing the payload a second time. The
        coalesced transfer's contents are identical by construction (a
        write or output clobber bumps ``buf.version``, which makes the
        in-flight entry stale), so a dependent waiting on the returned
        event sees exactly the bytes it asked for. When several replicas
        exist, the source is the server with the cheapest estimated
        delivery (``_pick_migration_source``), not set order."""
        self._check_live()
        if dst in buf.valid_on:
            ev = self._new_event(C.Marker(), dst)
            ev.complete(self.clock.now)
            ev.release()            # completed on the client: no ack cycle
            return ev
        store = self.cluster.store
        sentry = store.entry_for(buf) if store is not None else None
        key = (buf.id, dst)
        entry = self._inflight_migrations.get(key)
        if entry is not None:
            # our OWN transfer of these bytes is already on the wire:
            # coalesce (store-less semantics) BEFORE the store's
            # resident-dedup check — claiming a saving here would
            # double-book bytes this tenant is simultaneously paying
            pending, version = entry
            if version == buf.version and \
                    pending.status not in (COMPLETE, ERROR):
                self.migrations_coalesced += 1
                live = [d for d in wait_for
                        if d.status not in (COMPLETE, ERROR)]
                if not live:
                    return pending
                # the payload still crosses the wire once, but the
                # returned handle must honor the caller's wait list like
                # a non-coalesced migration would
                return self._join_events([pending, *live])
        if sentry is not None and dst in sentry.valid_on:
            # identical content is already resident on dst — uploaded or
            # migrated there by ANY tenant — so nothing needs to move;
            # the §5 content-addressed analogue of `dst in buf.valid_on`
            self._record_dedup(store, sentry, buf.transfer_bytes())
            buf.valid_on.add(dst)
            ev = self._new_event(C.Marker(), dst)
            ev.complete(self.clock.now)
            ev.release()            # completed on the client: no ack cycle
            return ev
        if sentry is not None:
            pend = sentry.pending.get(dst)
            if pend is not None and pend.status not in (COMPLETE, ERROR):
                # identical content is already on the wire to dst —
                # another tenant's upload or migration (our own transfers
                # were caught by the per-tenant table above): ride it
                # instead of pushing the payload again
                self._record_dedup(store, sentry, buf.transfer_bytes())
                ride = self._ride_pending_replica(sentry, pend, buf, dst)
                # the ride joins the per-tenant in-flight table like a
                # real migration: a back-to-back request for the same
                # (buf, dst) coalesces onto it (counted under
                # migrations_coalesced) instead of opening a second
                # ride and double-claiming the dedup saving
                self._track_inflight(key, ride, buf.version)
                live = [d for d in wait_for
                        if d.status not in (COMPLETE, ERROR)]
                if not live:
                    return ride
                return self._join_events([ride, *live])
        # membership (DESIGN.md §7): a DEAD host's replicas are gone —
        # never source from one (DRAINING hosts still serve: the drain's
        # own re-homing pushes FROM the draining host)
        alive = self.cluster.membership.is_alive
        srcs = [s for s in buf.valid_on if s != "client" and alive(s)]
        if sentry is not None and sentry.valid_on:
            # §5 replica-aware sourcing across tenants: any server
            # holding a valid replica of this content can serve the
            # push, not just the ones this tenant put it on
            srcs = sorted({*srcs, *(s for s in sentry.valid_on
                                    if alive(s))})
        if not srcs:  # client-held data: plain upload
            return self.enqueue_write(dst, buf, buf.data
                                      if buf.data is not None
                                      else np.zeros(buf.nbytes, np.uint8))
        src = self._pick_migration_source(buf, srcs, dst)
        cmd = C.MigrateBuffer(buffer=buf, dst_server=dst)
        if self.p2p_migration:
            ev = self._new_event(cmd, src)
            self._track_inflight(key, ev, buf.version)
            if sentry is not None:
                store.add_pending(sentry, dst, ev)
            self._send_command(ev, src, "", [d.id for d in wait_for])
            return ev
        # naive: read back to client, then write to dst
        rd = self.enqueue_read(src, buf, wait_for=wait_for)
        wr_ev = self._new_event(cmd, dst)
        trc = self._trace
        if trc is not None:             # write leg waits on the read leg
            trc.cmd_deps(wr_ev, [rd.id])
        self._track_inflight(key, wr_ev, buf.version)
        if sentry is not None:
            store.add_pending(sentry, dst, wr_ev)

        def after_read(rd_ev):
            if rd_ev.status == ERROR:
                # the read leg was lost on a dead link: release the
                # in-flight entry so a retry starts a fresh transfer,
                # and propagate the failure to the migration handle
                self._drop_inflight(key, wr_ev)
                wr_ev.fail(self.clock.now, rd_ev.error)
                self._route_completion_via_client(wr_ev)
                wr_ev.release()     # no completion ack will ever come
                return
            cur = self._inflight_migrations.get(key)
            if cur is not None and cur[0] is wr_ev:
                # refresh the coalescing snapshot to the generation the
                # read actually captured: a producer that executed after
                # enqueue (bumping the version) no longer blocks requests
                # from riding the long client→dst upload leg (mirrors the
                # push-time refresh on the P2P path; requests arriving
                # during the read leg itself still conservatively miss)
                self._inflight_migrations[key] = (wr_ev, rd_ev.data_version)
            self.clock.schedule(CLIENT_SUBMIT, self._deliver_naive_write,
                                wr_ev, dst, buf.transfer_bytes(),
                                rd_ev.data_version)

        rd.on_complete(after_read)
        return wr_ev

    def _pick_migration_source(self, buf: Buffer, srcs: Sequence[str],
                               dst: str) -> str:
        """Cheapest replica by estimated delivery time at enqueue: data
        link queue (``_busy_until``) + serialization at the link's
        effective bandwidth + propagation, plus — on the P2P path — the
        one-time MR registration/rkey-exchange cost when the RDMA
        transport has not yet registered this (buffer, src, dst), so an
        already-registered replica is preferred even over a slightly
        busier link. P2P scores the src↔dst peer link; naive mode scores
        the read leg over the source's client link (the client→dst leg
        is common to every candidate). The payload-free client→source
        command leg is deliberately ignored: it is near-uniform across
        sources. Under the shared-NIC egress model the source host's NIC
        queue counts toward the estimate too — a server mid-push to one
        peer is a poor source for another even over an idle link. Sorted
        iteration makes the choice deterministic (set order is not)."""
        if len(srcs) == 1:
            return srcs[0]
        nbytes = buf.transfer_bytes()
        p2p = self.p2p_migration
        tr = self.peer_transport if p2p else self.transport
        now = self.clock.now
        best = None
        best_t = None
        for s in sorted(srcs):
            if p2p:
                link = self.p_links.get((s, dst)) \
                    or self.p_links.get((dst, s))
            else:
                link = self.c_links.get(s)
            if link is None or not link.up:
                continue
            busy = link._busy_until
            nic = self.cluster.hosts[s].nic    # both legs leave server s
            if nic is not None and nic._busy_until > busy:
                busy = nic._busy_until         # shared egress is the queue
            queue = busy - now
            if queue < 0.0:
                queue = 0.0
            bw = link.bandwidth
            t = queue + link.latency + (
                (CMD_BYTES + nbytes) * wire_scale(tr, bw) / bw if bw else 0.0)
            if p2p and (buf.id, s, dst) not in self._mr_registered:
                t += tr.register_buffer(nbytes, peers=len(self.servers) - 1)
            if best_t is None or t < best_t:
                best, best_t = s, t
        return best if best is not None else sorted(srcs)[0]

    def _join_events(self, events: Sequence[Event]) -> Event:
        """Client-side user event completing once every input has
        finished (error counts as finished, matching the runtime's loose
        error-dependency semantics); subscribers are notified over the
        client links like any other client-completing event."""
        join = self._register_event(Event(user=True, server="client"))
        trc = self._trace
        if trc is not None:             # the join's causal inputs
            trc.cmd_deps(join, [e.id for e in events])
        state = {"remaining": len(events)}

        def one_done(_e):
            state["remaining"] -= 1
            if not state["remaining"]:
                join.complete(self.clock.now)
                self._route_completion_via_client(join)
                join.release()  # client observed completion directly

        for e in events:
            e.on_complete(one_done)     # fires now if already finished
        return join

    def _check_live(self):
        if self.detached:
            raise DeviceUnavailable(
                f"{self.name} (tenant detached from cluster)")

    def _ride_pending_replica(self, sentry, pending: Event, buf: Buffer,
                              dst: str) -> Event:
        """Identical content is already in flight to ``dst`` on another
        tenant's transfer: return a tenant-local event that completes
        when it lands (cross-tenant coalescing, DESIGN.md §5). The
        foreign event cannot be returned directly — dependency
        classification and completion routing resolve through THIS
        tenant's event table. If the ride dies under us (dropped link,
        payload gone stale) a real migration runs as fallback."""
        ev = self._register_event(Event(user=True, server="client"))
        trc = self._trace
        if trc is not None:             # the ride's causal input
            trc.cmd_deps(ev, [pending.id])
        snap = buf.version
        saved = buf.transfer_bytes()    # what the caller counted as saved

        def settle(_p):
            if self.detached or ev.status in (COMPLETE, ERROR):
                # we left (detach failed our events) before the ride
                # resolved: the claimed saving never materialized —
                # no migration of ours completed
                self._unrecord_dedup(self.cluster.store, saved)
                return
            now = self.clock.now
            landed = dst in sentry.valid_on
            if landed and buf.version == snap:
                buf.valid_on.add(dst)
            if landed or buf.version != snap:
                # delivered — or our buffer was rewritten while riding,
                # which voids the ordering contract exactly like the
                # eager clobber does on a private migration
                if not landed:
                    # ride died after our buffer moved on: nothing was
                    # transferred or avoided — take the credit back
                    self._unrecord_dedup(self.cluster.store, saved)
                ev.complete(now)
                self._route_completion_via_client(ev)
                ev.release()        # client observed completion directly
                return
            # the ride died: the claimed saving did not materialize —
            # take it back before the real migration (which re-counts
            # only if it genuinely dedups). The ride must leave the
            # per-tenant in-flight table first: the retry would
            # otherwise coalesce onto the ride itself (same key, same
            # version) and wait on an event only IT can complete
            self._unrecord_dedup(self.cluster.store, saved)
            self._drop_inflight((buf.id, dst), ev)
            retry = self.enqueue_migration(buf, dst)

            def mirror(r):
                if ev.status in (COMPLETE, ERROR):
                    return
                if r.status == ERROR:
                    ev.fail(self.clock.now, r.error or "migration failed")
                else:
                    ev.complete(self.clock.now)
                self._route_completion_via_client(ev)
                ev.release()        # client observed completion directly

            retry.on_complete(mirror)

        pending.on_complete(settle)
        return ev

    def _fail_dropped_migration(self, ev: Event, dst: str):
        """A migration payload dropped on a dead link can never be
        re-sent (the daemon already marked the command processed, so a
        replay is deduped): fail fast like the read-return leg does —
        the in-flight entry releases via the failure callbacks, so a
        retry after reconnect starts a fresh transfer. Idempotent: a
        crash's fail-fast sweep and the link's mid-flight drop callback
        can both reach the same event — only the first acts."""
        if ev.status in (COMPLETE, ERROR):
            return
        ev.fail(self.clock.now, f"link to {dst} down during migration")
        self._route_completion_via_client(ev)
        ev.release()                # no completion ack will ever come

    def _track_inflight(self, key, ev: Event, version: int):
        self._inflight_migrations[key] = (ev, version)
        ev.on_complete(lambda _e: self._drop_inflight(key, ev))

    def _drop_inflight(self, key, ev: Event):
        cur = self._inflight_migrations.get(key)
        if cur is not None and cur[0] is ev:
            del self._inflight_migrations[key]

    def _send_migration_chunks(self, link: Link, tr, nbytes: float,
                               extra_overhead: float,
                               arrived: Callable,
                               egress: Optional[NIC] = None,
                               ingress: Optional[NIC] = None,
                               on_dropped: Optional[Callable] = None,
                               ev_id: Optional[int] = None) \
            -> bool:
        """Shared bulk-payload leg for both migration paths: build the
        transport's cut-through plan, apply wire inflation, keep the
        scoreboard, and send (``egress`` is the sending host's shared
        NIC when the transfer leaves a server, ``ingress`` the
        receiving host's when it lands on one). ``arrived`` fires after
        the last chunk's receiver-side work. Returns False if the link
        is down at send time (the transfer was dropped); ``on_dropped``
        fires instead of ``arrived`` if the link dies mid-flight — the
        remaining chunks are lost deterministically at fault time."""
        if nbytes > 0:
            fixed, chunks = tr.chunk_plan(nbytes)
        else:   # content-size says empty: command struct only
            cost = tr.command_cost(0.0)
            fixed, chunks = cost.sender_cpu, [(0.0, cost.wire_bytes,
                                               cost.receiver_cpu)]
        scale = wire_scale(tr, link.bandwidth)
        if scale != 1.0:
            chunks = scale_chunks(chunks, scale)
        n_chunks = len(chunks)

        def delivered():
            self.chunks_in_flight -= n_chunks
            arrived()

        def dropped():
            self.chunks_in_flight -= n_chunks
            if on_dropped is not None:
                on_dropped()

        trc = self._trace
        arrivals = [] if trc is not None else None
        t0 = self.clock.now
        rcv = link.send_chunked(chunks, delivered,
                                serialize_overhead=extra_overhead + fixed,
                                egress=egress, ingress=ingress,
                                on_dropped=dropped,
                                chunk_arrivals=arrivals)
        if rcv is None:
            return False
        self.chunks_in_flight += n_chunks
        if self.chunks_in_flight > self.peak_chunks_in_flight:
            self.peak_chunks_in_flight = self.chunks_in_flight
        # computed once, shared by the scoreboard and the trace span, so
        # a span-derived sum reproduces the counter bit-exactly
        wire_total = sum(c[1] for c in chunks)
        self.bytes_on_wire += wire_total
        if trc is not None:
            trc.transfer("migration", self._tp + link.name, self._tlabel,
                         t0, rcv, wire_total, ev_id=ev_id,
                         chunk_arrivals=arrivals, link_obj=link)
        return True

    def _deliver_naive_write(self, ev, dst, nbytes, version):
        """``version`` is the buffer's content generation when the bytes
        left the source (captured by the read leg), NOT now: a write
        landing during the read makes the payload stale even though it
        has not crossed the client→dst link yet."""
        buf = ev.command.buffer

        def arrived():
            if buf.version == version:   # not clobbered while in flight
                buf.valid_on.add(dst)
                self._store_replica_landed(buf, dst)
            # completes on the destination daemon like any other server-
            # side command, sharing the completion-routing logic
            # (subscription vs broadcast) with every other path
            self.servers[dst]._complete(ev)

        if not self._send_migration_chunks(
                self.c_links[dst], self.transport, nbytes, 0.0, arrived,
                ingress=self._nic_in(dst),
                on_dropped=lambda: self._fail_dropped_migration(ev, dst),
                ev_id=ev.id):
            self._fail_dropped_migration(ev, dst)

    def marker(self) -> Event:
        ev = self._new_event(C.Marker(), "client")
        ev.complete(self.clock.now)
        ev.release()                # completed on the client: no ack cycle
        return ev

    # ---- wire ----
    def _send_command(self, ev: Event, server: str, device: str,
                      dep_ids: list, payload: float = 0.0,
                      extra_wire: float = 0.0):
        trc = self._trace
        if trc is not None and dep_ids:
            # happens-before edges for the critical-path DAG
            # (DESIGN.md §11): raw ids, before the wire-message
            # classification below drops already-finished deps
            trc.cmd_deps(ev, dep_ids)
        # classify deps at enqueue time: already-finished ones are
        # dropped from the wire message; live ones are retained (they
        # must stay resolvable until this command dispatches) and, when
        # remote, the target server subscribes to their completion
        deps = []
        if dep_ids:
            events = self.events
            by_sub = self.completion_routing == "subscription"
            if len(dep_ids) == 1:     # common case: skip the dedup set
                dep_id = dep_ids[0]
                dep = events.get(dep_id)
                if dep is not None and dep.status != COMPLETE \
                        and dep.status != ERROR:
                    dep.retain()
                    local = dep.server == server
                    if not local and by_sub:
                        self._subs.setdefault(dep_id, set()).add(server)
                    deps.append((dep_id, local))
            else:
                seen = set()
                for dep_id in dep_ids:
                    if dep_id in seen:
                        continue
                    seen.add(dep_id)
                    dep = events.get(dep_id)
                    if dep is None or dep.status == COMPLETE \
                            or dep.status == ERROR:
                        continue      # finished (error counts): no wire dep
                    dep.retain()
                    local = dep.server == server
                    if not local and by_sub:
                        self._subs.setdefault(dep_id, set()).add(server)
                    deps.append((dep_id, local))
        sess = self.sessions[server]
        sess.record((ev, server, device, deps, payload))
        link = self.c_links[server]
        if payload > 0:
            # bulk upload: cut-through chunks (per-chunk copy totals
            # equal cost.sender_cpu/receiver_cpu, so single-chunk timing
            # on an idle link is unchanged)
            fixed, chunks = self.transport.chunk_plan(payload)
            scale = self._cscale0
            if scale != 1.0:
                chunks = scale_chunks(chunks, scale)

            def deliver_chunked():
                self.clock.schedule(
                    DISPATCH,
                    self.servers[server].receive_command, ev, device, deps)

            arrivals = [] if trc is not None else None
            t0 = self.clock.now
            rcv = link.send_chunked(chunks, deliver_chunked,
                                    serialize_overhead=CLIENT_SUBMIT + fixed,
                                    ingress=self._nic_in(server),
                                    chunk_arrivals=arrivals)
            if rcv is not None:
                # count only bytes that actually went out (a down link
                # drops the send) — mirrors bytes_on_wire's accounting
                self.upload_bytes_on_wire += payload * scale
                if trc is not None:
                    trc.transfer("upload", self._tp + link.name,
                                 self._tlabel, t0, rcv, payload * scale,
                                 ev_id=ev.id, chunk_arrivals=arrivals,
                                 link_obj=link)
            return
        # zero-payload: the cost triple is the transport's cached
        # constant (`_cmd_cost0`) and the derived overhead/delay floats
        # were folded at construction; the delivery callback is a bound
        # method + args instead of a per-send closure
        cost = self._cmd_cost0
        link.send((cost.wire_bytes + extra_wire) * self._cscale0,
                  self._deliver_command,
                  serialize_overhead=self._submit_overhead0,
                  ingress=self.cluster.hosts[server].nic_in,
                  args=(server, ev, device, deps))

    def _deliver_command(self, server: str, ev: Event, device: str,
                         deps: list):
        self.clock.schedule(self._recv_delay0,
                            self.servers[server].receive_command,
                            ev, device, deps)

    # ---- migration execution (on source server) ----
    def _start_p2p_push(self, src_srv: ServerSim, ev: Event):
        cmd = ev.command
        buf, dst = cmd.buffer, cmd.dst_server
        nbytes = buf.transfer_bytes()
        tr = self.peer_transport
        reg = 0.0
        key = (buf.id, src_srv.name, dst)
        if key not in self._mr_registered:
            reg = tr.register_buffer(nbytes, peers=len(self.servers) - 1)
            self._mr_registered.add(key)
        link = self.peer_link(src_srv.name, dst)
        ev.status = RUNNING
        ev.t_start = self.clock.now
        # contents being pushed are the canonical bytes as of now; a
        # write landing while the transfer is in flight makes the copy
        # at dst stale, so validity is only granted on version match
        version = buf.version
        inflight_key = (buf.id, dst)
        entry = self._inflight_migrations.get(inflight_key)
        if entry is not None and entry[0] is ev:
            # refresh the coalescing snapshot: the producer this
            # migration waited on has executed by now, so requests
            # enqueued mid-flight still coalesce
            self._inflight_migrations[inflight_key] = (ev, version)

        def arrived():
            if buf.version == version:   # not clobbered while in flight
                buf.valid_on.add(dst)
                self._store_replica_landed(buf, dst)
            ev.server = dst
            self.servers[dst]._complete(ev)

        if not self._send_migration_chunks(
                link, tr, nbytes, reg, arrived,
                egress=src_srv.host.nic, ingress=self._nic_in(dst),
                on_dropped=lambda: self._fail_dropped_migration(ev, dst),
                ev_id=ev.id):
            self._fail_dropped_migration(ev, dst)

    def _store_replica_landed(self, buf: Buffer, dst: str):
        """A migration payload landed on ``dst`` with its version intact:
        if the buffer shares content through the cluster store, the
        arrival is a new physical replica of that content — register it
        so any tenant's later request resolves there. (The version match
        the callers establish guarantees the buffer is still attached to
        the entry the bytes belong to.)"""
        store = self.cluster.store
        if store is None:
            return
        sentry = store.entry_for(buf)
        if sentry is not None:
            store.replica_landed(sentry, dst)

    def _start_read_return(self, srv: ServerSim, ev: Event):
        buf = ev.command.buffer
        nbytes = buf.transfer_bytes()
        ev.data_version = buf.version   # generation of the returned bytes
        cost = self.transport.command_cost(nbytes)
        link = self.c_links[srv.name]
        ev.status = RUNNING
        ev.t_start = self.clock.now

        def arrived():
            if ev.status in (COMPLETE, ERROR):
                # failed fast while the return leg was in flight (the
                # serving host crashed): the client already observed
                # ERROR — completing now would double-fire callbacks
                return
            if buf.version == ev.data_version:
                # downloaded bytes still match the canonical contents;
                # a write that landed mid-read makes this copy stale
                buf.valid_on.add("client")
            ev.complete(self.clock.now)
            self._route_completion_via_client(ev)
            ev.release()            # client observed completion directly

        trc = self._trace
        t0 = self.clock.now
        ret = link.send(cost.wire_bytes * wire_scale(self.transport,
                                                     link.bandwidth),
                        arrived,
                        serialize_overhead=COMPLETE_WRITE + cost.sender_cpu,
                        egress=srv.host.nic)
        if ret is not None:
            if trc is not None:
                trc.transfer("read_return", self._tp + link.name,
                             self._tlabel, t0, ret,
                             cost.wire_bytes * wire_scale(self.transport,
                                                          link.bandwidth),
                             ev_id=ev.id, link_obj=link)
        else:
            # link died after the command was delivered: the daemon has
            # already marked it processed, so a replay will be deduped
            # and the data can never be re-sent — surface the error
            # instead of hanging the handle (and its consumers) forever
            ev.fail(self.clock.now,
                    f"link to {srv.name} down during read return")
            self._route_completion_via_client(ev)
            ev.release()            # nothing further will arrive

    # ---- completion propagation ----
    def _broadcast_completion(self, srv: ServerSim, ev: Event):
        comp = self._comp_cost          # per-transport constant
        nic = srv.host.nic              # every leg leaves this server
        # to client (always)
        self.c_links[srv.name].send(
            comp.wire_bytes, self._client_reap,
            serialize_overhead=self._complete_overhead,
            egress=nic, args=(ev,))
        self.client_completion_msgs += 1
        if self.scheduling != "decentralized":
            return
        if self.completion_routing == "subscription":
            targets = sorted(self._subs.pop(ev.id, ()))
        else:
            targets = [p for p in self.servers if p != srv.name]
        for name in targets:
            if name == srv.name:
                continue
            link = self.peer_link(srv.name, name)
            link.send(comp.wire_bytes,
                      self.servers[name].notify_remote_complete,
                      serialize_overhead=comp.sender_cpu, egress=nic,
                      ingress=self._nic_in(name), args=(ev.id,))
            self.peer_completion_msgs += 1

    def _route_completion_via_client(self, ev: Event):
        """Events that complete on the client itself (reads, user/race
        events, local fallback) have no server to signal from; notify any
        subscribed servers over their client links."""
        subs = self._subs.pop(ev.id, None)
        if not subs:
            return
        comp = self.transport.completion_cost()
        for name in sorted(subs):
            self.c_links[name].send(
                comp.wire_bytes,
                self.servers[name].notify_remote_complete,
                serialize_overhead=comp.sender_cpu,
                ingress=self._nic_in(name), args=(ev.id,))
            self.client_routed_completion_msgs += 1

    def _client_reap(self, ev: Event):
        self.clock.schedule(CLIENT_REAP, self._client_reap2, ev)

    def _client_reap2(self, ev: Event):
        ev.t_client_ack = self.clock.now
        slo = self._slo_s
        if slo is not None:
            # SLO scoring (DESIGN.md §10): client-observed end-to-end
            # latency vs the tenant's effective budget. Feeds the
            # admission controller's windowed per-class histograms and,
            # when traced, the violation instants.
            latency = ev.t_client_ack - ev.t_queued
            violated = latency > slo
            self.slo_commands += 1
            if violated:
                self.slo_violations += 1
            ctrl = self.cluster.admission
            if ctrl is not None:
                ctrl.observe(self._slo_class, ev.t_client_ack, latency,
                             violated)
            if violated:
                tr = self._trace
                if tr is not None:
                    tr.slo_violation(ev.t_client_ack, self._tlabel,
                                     ev.id, latency, slo)
        if self.scheduling == "client":
            # SnuCL-like: client forwards resolution to the other servers
            if self.completion_routing == "subscription":
                targets = sorted(self._subs.pop(ev.id, ()))
            else:
                targets = [p for p in self.servers if p != ev.server]
            comp = self.transport.completion_cost()
            for name in targets:
                if name == ev.server:
                    continue
                self.c_links[name].send(
                    comp.wire_bytes,
                    self.servers[name].notify_remote_complete,
                    serialize_overhead=comp.sender_cpu,
                    ingress=self._nic_in(name), args=(ev.id,))
                self.client_routed_completion_msgs += 1
        ev.release()                # client hold: completion observed

    # ---- fault injection / sessions (paper §4.3) ----
    def inject_disconnect(self, server: str, at: Optional[float] = None):
        def go():
            self.c_links[server].up = False
            self.sessions[server].available = False
        if at is None:
            go()
        else:
            self.clock.schedule_at(at, go)

    def detach(self) -> None:
        """Tenant lifecycle (DESIGN.md §5): release everything this
        client holds on the shared cluster and leave it.

        * Buffer references drop from the content-addressed store, so
          replicas this tenant pinned become evictable (and dedup'able
          by the tenants that remain).
        * Server-side: the session ids leave every host's §4.3 session
          table, this tenant's queued commands leave the device run
          queues, and the per-session daemon state (replay dedup,
          remote-resolution, waiter tables) is destroyed — a later
          reattach presenting the same session id starts a FRESH
          session; it must not resurrect the dedup'd replay state.
        * Client-side: every live event fails with ``tenant detached``
          (dependents and user callbacks observe ERROR, and other
          tenants gated on this tenant's in-flight transfers fall back
          to their own), the access links close, and the runtime
          refuses further enqueues.

        The in-service command on a device, if any, runs to completion
        (the scheduler is non-preemptive) but completes into a failed
        event, which is a no-op. Bystander tenants only ever shared the
        clock, devices, NICs, and peer mesh — none of which detach
        rewinds — so their timing is unperturbed beyond the freed
        capacity."""
        if self.detached:
            return
        self.detached = True
        now = self.clock.now
        cluster = self.cluster
        if cluster.store is not None:
            for b in self._buffers:
                cluster.store.release(b)
        for srv in self.servers.values():
            host = srv.host
            if srv.session_id is not None:
                host.sessions.pop(srv.session_id, None)
            for sch in host.schedulers.values():
                sch.discard(srv)
            srv.processed.clear()
            srv.resolved_remote.clear()
            srv._waiters.clear()
            srv._ready.clear()
            srv.session_id = None
        for sess in self.sessions.values():
            sess.available = False
            sess.replay.clear()
            sess.session_id = bytes(16)
        for link in self.c_links.values():
            link.close()
        for ev in list(self.events.values()):
            if ev.status not in (COMPLETE, ERROR):
                ev.fail(now, f"tenant {self.name} detached")
        self.events.clear()
        self._subs.clear()
        self._inflight_migrations.clear()
        if self in cluster.clients:
            cluster.clients.remove(self)

    def reconnect(self, server: str, at: Optional[float] = None):
        """Restore the link; replay unacknowledged commands (server dedupes
        by command id). The session ID survives even if the client's
        address changed.

        Bounded (DESIGN.md §7): if the server is gone — crashed,
        retired, or the link stays dead — the handshake is retried with
        exponential backoff (``reconnect_backoff`` doubling, up to
        ``reconnect_retries`` retries beyond the first attempt), then
        the failure is surfaced: the unacked commands still targeting
        the server fail so their dependents observe ERROR instead of
        waiting forever on a session that will never come back. A
        server that rejoins mid-backoff is picked up by the next
        attempt (the fresh link is re-read each try)."""
        self._check_live()

        def attempt(tries_left: int, delay: float):
            self.reconnect_attempts[server] = \
                self.reconnect_attempts.get(server, 0) + 1
            link = self.c_links.get(server)
            if self.cluster.membership.is_alive(server) and \
                    link is not None:
                link.up = True        # a closed (dead-host) link stays down
                if link.up and link.send(
                        64 + 16,      # handshake incl. session id
                        lambda: handshook(link),
                        ingress=self._nic_in(server)) is not None:
                    return
            if tries_left > 0:
                self.clock.schedule(delay, attempt, tries_left - 1,
                                    delay * 2.0)
                return
            self._reconnect_exhausted(server)

        def handshook(link):
            sess = self.sessions[server]
            srv = self.servers[server]
            # present the session id to the daemon's session table
            # (§4.3): the id, not the transport address, resolves
            # the server-side session — its replay-dedup state is
            # what makes the replayed commands below idempotent
            daemon = srv.host.sessions.get(sess.session_id)
            if daemon is None:          # expired/unknown: re-admit
                daemon = srv.host.sessions[sess.session_id] = srv
            sess.available = True
            for (ev, _srv_name, device, deps, payload) in \
                    list(sess.replay):
                if ev.status in (COMPLETE, ERROR):
                    continue
                cost = self.transport.command_cost(payload)
                link.send(cost.wire_bytes,
                          lambda e=ev, d=device, dd=deps:
                          daemon.receive_command(e, d, dd),
                          serialize_overhead=cost.sender_cpu,
                          ingress=self._nic_in(server))

        def go():
            attempt(self.reconnect_retries, self.reconnect_backoff)

        if at is None:
            go()
        else:
            self.clock.schedule_at(at, go)

    def _reconnect_exhausted(self, server: str) -> None:
        """Every reconnect attempt failed: surface it. The commands
        still unacked in the replay buffer can never be replayed —
        fail them (unless a drain already re-placed them elsewhere) so
        nothing upstream hangs on this session."""
        reason = (f"reconnect to {server} failed after "
                  f"{self.reconnect_attempts.get(server, 0)} attempts")
        log.warning("%s: %s", self.name, reason)
        self.reconnect_failures[server] = reason
        now = self.clock.now
        sess = self.sessions.get(server)
        if sess is None:
            return
        for (ev, *_rest) in list(sess.replay):
            # a drain may have requeued the command to a survivor —
            # its event now targets that host and must stay live
            if ev.status in (COMPLETE, ERROR) or ev.server != server:
                continue
            ev.fail(now, reason)
            self._route_completion_via_client(ev)
            ev.release()            # no completion ack will ever come
        sess.replay.clear()

    def enqueue_kernel_redundant(self, servers: Sequence[str], **kw) -> Event:
        """Straggler mitigation: dispatch the same kernel to several
        servers; the first completion wins and late copies are ignored
        (the client simply reaps the winner — the OpenCL semantics make
        duplicate side-effect-free kernels safe to race).

        Returns a user event that completes with the winner."""
        race = self._register_event(Event(user=True, server="client"))
        outputs = kw.get("outputs", ())
        fn = kw.pop("fn", None)

        def on_done(ev):
            if race.status != COMPLETE:
                # winner executes the functional payload; losers are void
                if fn is not None:
                    ins = [b.data for b in kw.get("inputs", ())]
                    outs = fn(*ins)
                    if not isinstance(outs, (tuple, list)):
                        outs = (outs,)
                    for b, arr in zip(outputs, outs):
                        b.set_data(np.asarray(arr), ev.server)
                race.server = ev.server
                race.complete(self.clock.now)
                self._route_completion_via_client(race)
                race.release()      # client observed completion directly

        for s in servers:
            if not self.sessions[s].available:
                continue
            # pin=True: the race's value IS the explicit server spread —
            # a placement policy would happily collapse every copy onto
            # the one telemetry-best host, defeating the mitigation
            ev = self.enqueue_kernel(s, fn=None, pin=True, **kw)
            ev.on_complete(on_done)
        return race

    def run_local_fallback(self, fn, inputs, outputs, flops=0.0,
                           duration=None) -> Event:
        """Fig. 4: compute locally (reduced model) while remotes are gone."""
        self._check_live()
        fork_bytes = 0.0
        if self.cluster.store is not None:
            for b in outputs:       # local writes fork shared content too
                if self.cluster.store.cow_fork(b):
                    # same 2×nbytes device-copy charge as the server-side
                    # kernel path (DESIGN.md §5)
                    fork_bytes += 2.0 * b.nbytes
        ev = self._new_event(C.NDRangeKernel(fn=fn, inputs=tuple(inputs),
                                             outputs=tuple(outputs),
                                             flops=flops, duration=duration),
                             "client")

        def done():
            cmd = ev.command
            if cmd.fn is not None:
                ins = [b.data for b in cmd.inputs]
                outs = cmd.fn(*ins)
                if not isinstance(outs, (tuple, list)):
                    outs = (outs,)
                for b, arr in zip(cmd.outputs, outs):
                    b.set_data(np.asarray(arr), "client")
            ev.complete(self.clock.now)
            self._route_completion_via_client(ev)
            ev.release()            # client observed completion directly

        cost = self.local_device.kernel_cost(flops, fork_bytes, duration)
        ev.t_start, _ = self.local_device.execute(cost, done)
        return ev

    # ---- control ----
    def finish(self) -> float:
        """Drain the simulation; returns the final clock time. The clock
        is the cluster's, so on a shared cluster this drains every
        attached tenant, not just this one."""
        return self.clock.run()

    def stats(self) -> dict:
        # NOTE: peer_link_bytes and device_busy read the cluster-shared
        # substrate — on a shared cluster they are totals across every
        # tenant, not this client's share (Cluster.stats() carries the
        # same numbers); the remaining keys are per-client
        return {
            "time": self.clock.now,
            "client_link_bytes": {s: lk.bytes_sent
                                  for s, lk in self.c_links.items()},
            "peer_link_bytes": {f"{a}-{b}": lk.bytes_sent
                                for (a, b), lk in self.p_links.items()},
            "device_busy": {f"{s}/{d}": dev.busy_time
                            for s, srv in self.servers.items()
                            for d, dev in srv.devices.items()},
            "client_completion_msgs": self.client_completion_msgs,
            "peer_completion_msgs": self.peer_completion_msgs,
            "client_routed_completion_msgs":
                self.client_routed_completion_msgs,
            "events_live": len(self.events),
            "replay_window": {s: sess.replay.maxlen
                              for s, sess in self.sessions.items()},
            "replay_overflows": {s: sess.lost_unacked
                                 for s, sess in self.sessions.items()},
            # bounded reconnect (DESIGN.md §7)
            "reconnect_attempts": dict(self.reconnect_attempts),
            "reconnect_failures": dict(self.reconnect_failures),
            # data-plane scoreboard (DESIGN.md §3)
            "bytes_on_wire": self.bytes_on_wire,
            "upload_bytes_on_wire": self.upload_bytes_on_wire,
            "migrations_coalesced": self.migrations_coalesced,
            "chunks_in_flight": self.chunks_in_flight,
            "peak_chunks_in_flight": self.peak_chunks_in_flight,
            "migrations_inflight": len(self._inflight_migrations),
            # content-addressed store scoreboard (DESIGN.md §5)
            "dedup_hits": self.dedup_hits,
            "dedup_bytes_saved": self.dedup_bytes_saved,
            "detached": self.detached,
            # SLO scoreboard (DESIGN.md §10)
            "slo_ms": self.slo_ms,
            "slo_effective_ms": (self._slo_s * 1e3
                                 if self._slo_s is not None else None),
            "slo_commands": self.slo_commands,
            "slo_violations": self.slo_violations,
            "slo_violation_rate": (self.slo_violations
                                   / self.slo_commands
                                   if self.slo_commands else 0.0),
            "admission": (self.admission.status
                          if self.admission is not None else None),
            # placement scoreboard (DESIGN.md §6) — cluster-wide, like
            # peer_link_bytes: decisions across every attached tenant
            "placement": self.cluster.placement.stats(),
        }


class DeviceUnavailable(RuntimeError):
    """CL_DEVICE_NOT_AVAILABLE analogue."""
    def __init__(self, server):
        super().__init__(f"server {server} unavailable")
        self.server = server
