"""Cluster placement control plane (DESIGN.md §6).

Until now every caller hard-picked the execution server for each
kernel. That is the right default for a single tenant that knows its
own topology, but it throws away exactly the information a MEC cluster
accumulates at runtime: per-device run-queue depth in device-seconds
(``scheduler.DeviceScheduler.queued_seconds``), where content replicas
physically live (``Buffer.valid_on`` plus the content-addressed
store's cross-tenant replica sets, ``BufferStore.replica_servers``),
and how congested each host's NIC ports are on both the send and the
receive side (``NIC.queue_seconds``). HetMEC (Wang et al.,
arXiv:1901.09307) frames the resulting assignment problem:
latency-optimal task placement from heterogeneous server load and link
state.

``PlacementEngine`` is the cluster-wide decision point: every
``enqueue_kernel`` passes its *requested* server through
``engine.place``, which may redirect the kernel (and therefore its
implicit input migrations) to a better host. Policies are pluggable
behind one interface and can differ per tenant
(``ClientRuntime(placement=...)`` overrides the cluster default):

* ``pinned`` — return the requested server unconditionally. This is
  the pre-placement behavior and the default; a pinned cluster is
  bit-exact with a cluster that has no engine at all (the engine only
  keeps counters, never touching the clock).
* ``locality`` — greedy replica affinity: run the kernel on the
  candidate holding the most resident input bytes, so kernels chase
  their content instead of dragging it. Ties break on queue depth,
  then on sorted server name; a kernel with no resident inputs
  anywhere stays on the requested server.
* ``hetmec`` — estimated completion time: for every candidate, the
  transfer cost of the inputs it is missing (cheapest replica over
  current link + egress-NIC + ingress-NIC occupancy, including the
  RDMA registration cost when unregistered) plus the server's queued
  device-seconds plus the kernel's own device cost; the minimum wins,
  ties break on sorted server name. Backlogged-but-near loses to
  idle-but-far exactly when the queue exceeds the transfer.

Queue depth has two sources, and the engine takes the max: the
scheduler probe (dep-resolved commands sitting in the run queue plus
the in-service remainder on the device timeline) and the engine's own
``outstanding`` tally of placed-but-unfinished device-seconds. The
tally is what spreads a batch of kernels enqueued at the same instant
whose dependencies have not resolved into any scheduler queue yet —
the probe alone would see every queue empty and stack the whole batch
on one server.

Decisions are pure bookkeeping at enqueue time: no simulated time is
consumed, no shared state beyond the decision itself is mutated, so
one tenant's placement churn cannot perturb a bystander tenant's
timestamps (tested). The scoreboard (``stats()['placement']``) counts
``placed_local`` (kept the caller's pick), ``placed_remote``
(redirected), and ``placement_bytes_avoided`` (input bytes already
resident on the chosen server that the requested server would have had
to migrate in).
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.membership import ACTIVE
from repro.core.transport import CMD_BYTES, wire_scale

try:                      # vectorized transfer-ETA math (optional)
    import numpy as _np
except ImportError:       # pragma: no cover - numpy ships with the image
    _np = None

# Vectorizing the per-source ETA arithmetic pays only once enough
# replica sources exist to amortize the array round-trip (DESIGN.md §8);
# below the cutoff the scalar loop is faster — zero cost when unused.
_VEC_MIN_SOURCES = 8


class PinnedPolicy:
    """Caller knows best: the requested server, unconditionally."""

    name = "pinned"

    def place(self, engine: "PlacementEngine", rt, requested: str,
              candidates: Sequence[str], device: str, inputs,
              flops: float, bytes_moved: float,
              duration: Optional[float]) -> str:
        return requested


class LocalityPolicy:
    """Greedy replica affinity: most resident input bytes wins; queue
    depth breaks ties, sorted server name breaks those. No resident
    inputs anywhere → the requested server (pinned behavior)."""

    name = "locality"

    def place(self, engine, rt, requested, candidates, device, inputs,
              flops, bytes_moved, duration):
        best = None
        best_key = None
        resident_anywhere = False
        for s in candidates:                    # sorted by the engine
            resident = 0.0
            for b in inputs:
                if s in engine.replica_servers(rt, b):
                    resident += b.transfer_bytes()
            if resident > 0.0:
                resident_anywhere = True
            key = (-resident, engine.queue_depth(s), s)
            if best_key is None or key < best_key:
                best, best_key = s, key
        if not resident_anywhere:
            return requested if requested in candidates else best
        return best


class HetMECPolicy:
    """Estimated completion time per candidate: missing-input transfer
    cost over current link/NIC state + queued device-seconds + kernel
    device cost. Minimum wins; sorted-name tie-break — except for SLO
    tenants, where equal ECT resolves toward the server carrying the
    least deadline-bound backlog (``queued_slo_seconds``), so a tight
    command lands where it competes with the least SLO work
    (DESIGN.md §10). Non-SLO tenants keep the early-break/keep-first
    scan byte-for-byte."""

    name = "hetmec"

    def place(self, engine, rt, requested, candidates, device, inputs,
              flops, bytes_moved, duration):
        # the early break leaves a partial ECT that is only usable for
        # the "already worse" verdict, never for an equality tie-break;
        # SLO tenants need exact ECTs to compare ties, so they skip it
        exact = getattr(rt, "_slo_s", None) is not None
        best = None
        best_ect = None
        for s in candidates:                    # sorted by the engine
            ect = engine.queue_depth(s) \
                + engine.kernel_cost(s, device, flops, bytes_moved,
                                     duration)
            for b in inputs:
                ect += engine.transfer_eta(rt, b, s)
                if not exact and best_ect is not None \
                        and ect >= best_ect:
                    break                       # already worse
            if best_ect is None or ect < best_ect:
                best, best_ect = s, ect
            elif exact and ect == best_ect \
                    and engine.queued_slo_seconds(s) \
                    < engine.queued_slo_seconds(best):
                best = s
        return best


_POLICIES = {p.name: p for p in (PinnedPolicy, LocalityPolicy,
                                 HetMECPolicy)}


def make_placement_policy(kind: str):
    cls = _POLICIES.get(kind)
    if cls is None:
        raise ValueError(f"unknown placement policy {kind!r} "
                         f"(known: {sorted(_POLICIES)})")
    return cls()


class PlacementEngine:
    """Cluster-wide kernel placement from live telemetry (one per
    ``Cluster``; see the module docstring for the decision model)."""

    def __init__(self, cluster, policy: str = "pinned"):
        self.cluster = cluster
        self.default_policy = make_placement_policy(policy)
        # server -> device-seconds placed here and not yet finished;
        # the enqueue-time complement of the scheduler queue probe.
        # Maintained only once a non-pinned policy exists anywhere on
        # the cluster (telemetry_active flips on and stays on): an
        # all-pinned cluster never reads the tally, so the enqueue hot
        # path skips the closure per kernel entirely
        self.outstanding: dict = {}
        self.telemetry_active = type(self.default_policy) \
            is not PinnedPolicy
        # scoreboard (stats()['placement'])
        self.decisions = 0
        self.placed_local = 0
        self.placed_remote = 0
        self.placement_bytes_avoided = 0.0

    # ---- telemetry probes ----
    def queued_device_seconds(self, server: str) -> float:
        """Scheduler view: dep-resolved device-seconds queued on
        ``server`` across its devices, plus each device's in-service
        remainder."""
        host = self.cluster.hosts[server]
        now = self.cluster.clock.now
        total = 0.0
        for dname, dev in host.devices.items():
            total += host.schedulers[dname].queued_seconds()
            rem = dev._busy_until - now
            if rem > 0.0:
                total += rem
        return total

    def queued_slo_seconds(self, server: str) -> float:
        """Deadline-carrying device-seconds queued on ``server`` (0.0
        under deadline-blind scheduler policies): the laxity-aware
        placement tie-break signal (DESIGN.md §10)."""
        host = self.cluster.hosts[server]
        total = 0.0
        for sch in host.schedulers.values():
            total += sch.queued_slo_seconds()
        return total

    def queue_depth(self, server: str) -> float:
        """Effective backlog: max of the scheduler probe and the
        engine's outstanding tally. The probe is exact for work whose
        deps resolved; the tally also sees same-instant enqueues whose
        deps are still in flight (each covers the other's blind spot,
        and everything the tally sees late the probe sees precisely)."""
        q = self.queued_device_seconds(server)
        o = self.outstanding.get(server, 0.0)
        return q if q > o else o

    def replica_servers(self, rt, buf) -> set:
        """Servers holding a valid replica of ``buf``'s bytes: the
        tenant's own copies plus — through the content-addressed store
        — any tenant's replica of identical content."""
        srvs = {s for s in buf.valid_on if s != "client"}
        store = self.cluster.store
        if store is not None:
            srvs |= store.replica_servers(buf)
        return srvs

    def kernel_cost(self, server: str, device: str, flops: float,
                    bytes_moved: float, duration: Optional[float]) -> float:
        host = self.cluster.hosts[server]
        dev = host.devices.get(device) or \
            host.devices[next(iter(host.devices))]
        return dev.kernel_cost(flops, bytes_moved, duration)

    def transfer_eta(self, rt, buf, dst: str) -> float:
        """Estimated time to make ``buf`` resident on ``dst``: zero if
        a replica is already there, else the cheapest source replica's
        peer-link delivery (link queue + egress/ingress NIC occupancy,
        whichever governs + serialization at wire scale + propagation,
        plus the one-time RDMA registration when unregistered), else —
        client-held data — the same estimate over the tenant's access
        link. Mirrors ``_pick_migration_source``'s cost model from the
        placement side."""
        srcs = self.replica_servers(rt, buf)
        if dst in srcs:
            return 0.0
        nbytes = buf.transfer_bytes()
        now = self.cluster.clock.now
        hosts = self.cluster.hosts
        nic_in = hosts[dst].nic_in
        in_queue = nic_in.queue_seconds(now) if nic_in is not None else 0.0
        best = None
        tr = rt.peer_transport
        srcs_sorted = sorted(srcs)
        if _np is not None and len(srcs_sorted) >= _VEC_MIN_SOURCES:
            # Vectorized ETA: the probe gathering (link/NIC occupancy)
            # stays scalar, but the per-source arithmetic runs as four
            # float64 array ops with the exact operand grouping of the
            # scalar loop below — (queue + latency) + num/bw, then
            # + registration — so each lane is the same IEEE operation
            # sequence and the result is bit-identical. argmin returns
            # the FIRST minimal lane, matching the strict-< keep-first
            # scan over the same sorted source order. Sources with
            # bw == 0 carry num = 0, bw = 1 (wire term exactly 0.0, as
            # the scalar conditional yields); registered sources carry
            # reg = 0.0 (t + 0.0 == t for these non-negative ETAs).
            q_rows, lat_rows, num_rows, bw_rows, reg_rows = \
                [], [], [], [], []
            reg_cost = None
            for s in srcs_sorted:
                link = self.cluster.p_links.get((s, dst)) \
                    or self.cluster.p_links.get((dst, s))
                if link is None or not link.up:
                    continue
                queue = link.queue_seconds(now)
                nic = hosts[s].nic
                if nic is not None:
                    nq = nic.queue_seconds(now)
                    if nq > queue:
                        queue = nq
                if in_queue > queue:
                    queue = in_queue
                bw = link.bandwidth
                if bw:
                    num = (CMD_BYTES + nbytes) * wire_scale(tr, bw)
                else:
                    num, bw = 0.0, 1.0
                if (buf.id, s, dst) not in rt._mr_registered:
                    if reg_cost is None:
                        reg_cost = tr.register_buffer(
                            nbytes, peers=len(rt.servers) - 1)
                    reg = reg_cost
                else:
                    reg = 0.0
                q_rows.append(queue)
                lat_rows.append(link.latency)
                num_rows.append(num)
                bw_rows.append(bw)
                reg_rows.append(reg)
            if q_rows:
                t = (_np.array(q_rows) + _np.array(lat_rows)
                     + _np.array(num_rows) / _np.array(bw_rows))
                t = t + _np.array(reg_rows)
                best = float(t[int(t.argmin())])
        else:
            for s in srcs_sorted:
                link = self.cluster.p_links.get((s, dst)) \
                    or self.cluster.p_links.get((dst, s))
                if link is None or not link.up:
                    continue
                queue = link.queue_seconds(now)
                nic = hosts[s].nic
                if nic is not None:
                    nq = nic.queue_seconds(now)
                    if nq > queue:
                        queue = nq
                if in_queue > queue:
                    queue = in_queue
                bw = link.bandwidth
                t = queue + link.latency + (
                    (CMD_BYTES + nbytes) * wire_scale(tr, bw) / bw
                    if bw else 0.0)
                if (buf.id, s, dst) not in rt._mr_registered:
                    t += tr.register_buffer(nbytes,
                                            peers=len(rt.servers) - 1)
                if best is None or t < best:
                    best = t
        if best is not None:
            return best
        # client-held only: an upload over this tenant's access link
        link = rt.c_links.get(dst)
        if link is None or not link.up:
            return float("inf")
        queue = link.queue_seconds(now)
        if in_queue > queue:
            queue = in_queue
        bw = link.bandwidth
        return queue + link.latency + (
            (CMD_BYTES + nbytes) * wire_scale(rt.transport, bw) / bw
            if bw else 0.0)

    # ---- the enqueue hook ----
    def candidates_for(self, rt, device: str) -> list:
        """Eligible placement candidates for ``rt``'s kernels naming
        ``device`` (sorted; see ``place``). Pure read — safe to hoist
        across a batch of same-instant enqueues (``enqueue_many``):
        availability, membership state, and device inventories only
        change when simulated time advances or an explicit lifecycle
        call runs, neither of which can happen mid-batch. Eligibility
        reads the host's own ``state`` slot (mirrored by
        ``MembershipManager`` on every transition) instead of the
        name-keyed membership table — one attribute load per candidate
        on the every-enqueue path."""
        hosts = self.cluster.hosts
        return [s for s in sorted(rt.servers)
                if rt.sessions[s].available
                and hosts[s].state == ACTIVE
                and (not device or device in hosts[s].devices)]

    def place(self, rt, requested: str, device: str, inputs,
              flops: float, bytes_moved: float,
              duration: Optional[float],
              candidates: Optional[list] = None) -> str:
        """Pick the execution server for one kernel. Pure bookkeeping:
        consumes no simulated time, mutates nothing shared. Candidates
        are the tenant's available sessions in sorted order (the
        deterministic tie-break every policy inherits); with none, the
        requested server is returned and the caller raises its usual
        ``DeviceUnavailable``."""
        policy = rt._placement_policy or self.default_policy
        if type(policy) is PinnedPolicy:
            # fast path, and bit-exactness by construction: no
            # telemetry is read, nothing but the counter moves
            self.decisions += 1
            self.placed_local += 1
            return requested
        # an explicitly-named device restricts candidates to hosts that
        # actually have it — redirecting a 'gpu0' kernel to a TPU-only
        # host would KeyError at dispatch, long after the decision.
        # Membership (DESIGN.md §7) gates eligibility the same way:
        # only ACTIVE hosts take new placements — joining hosts are not
        # established everywhere yet, draining ones are being emptied
        if candidates is None:
            candidates = self.candidates_for(rt, device)
        if not candidates:
            return requested
        chosen = policy.place(self, rt, requested, candidates, device,
                              inputs, flops, bytes_moved, duration)
        self.decisions += 1
        tr = self.cluster.trace
        if tr is not None:
            # decision instant (DESIGN.md §9): pure observation of a
            # choice already made — the pinned fast path above is left
            # untouched (nothing to attribute: requested == chosen)
            tr.placement(self.cluster.clock.now, rt._tlabel,
                         self.cluster.trace_prefix + requested,
                         self.cluster.trace_prefix + chosen, policy.name)
        if chosen == requested:
            self.placed_local += 1
        else:
            self.placed_remote += 1
            for b in inputs:
                srvs = self.replica_servers(rt, b)
                if chosen in srvs and requested not in srvs:
                    self.placement_bytes_avoided += b.transfer_bytes()
        return chosen

    def record(self, server: str, cost: float, ev) -> None:
        """Track a placed kernel's device-seconds on ``server`` until
        its event finishes (complete or error — both callbacks fire),
        feeding ``queue_depth``'s outstanding side. A no-op until some
        tenant or the cluster uses a non-pinned policy — nothing would
        ever read the tally."""
        if not self.telemetry_active or cost <= 0.0:
            return
        self.outstanding[server] = \
            self.outstanding.get(server, 0.0) + cost

        def done(_e):
            self.outstanding[server] -= cost

        ev.on_complete(done)

    # ---- reporting ----
    def stats(self) -> dict:
        return {
            "policy": self.default_policy.name,
            "decisions": self.decisions,
            "placed_local": self.placed_local,
            "placed_remote": self.placed_remote,
            "placement_bytes_avoided": self.placement_bytes_avoided,
        }
