"""PoCL-R offloading runtime (the paper's core contribution, adapted to a
deterministic event-loop + JAX execution model — see DESIGN.md §2)."""
from repro.core.admission import (ADMIT, DEGRADE, REJECT,  # noqa: F401
                                  AdmissionController, AdmissionDecision,
                                  AdmissionRejected)
from repro.core.buffers import Buffer  # noqa: F401
from repro.core.commands import (BuiltinKernel, Marker, MigrateBuffer,  # noqa: F401
                                 NDRangeKernel, ReadBuffer, WriteBuffer)
from repro.core.events import (COMPLETE, ERROR, QUEUED, RUNNING,  # noqa: F401
                               SUBMITTED, Event)
from repro.core.membership import (ACTIVE, DEAD, DRAINING,  # noqa: F401
                                   JOINING, MembershipManager)
from repro.core.netsim import (NIC, DeviceSim, FaultSchedule,  # noqa: F401
                               HeapSimClock, Link, SimClock)
from repro.core.placement import (HetMECPolicy, LocalityPolicy,  # noqa: F401
                                  PinnedPolicy, PlacementEngine,
                                  make_placement_policy)
from repro.core.runtime import (ClientRuntime, Cluster,  # noqa: F401
                                DeviceSpec, DeviceUnavailable, LinkSpec,
                                ServerHost, ServerSpec)
from repro.core.scheduler import (DeviceScheduler, DRRPolicy,  # noqa: F401
                                  EDFPolicy, FIFOPolicy, LLFPolicy,
                                  make_policy, validate_scheduler_opts)
from repro.core.store import (BufferStore, StoreEntry,  # noqa: F401
                              content_digest)
from repro.core.trace import (Histogram, MetricsRegistry,  # noqa: F401
                              Tracer)
from repro.core.transport import (RDMATransport, TCPTransport,  # noqa: F401
                                  make_transport)
