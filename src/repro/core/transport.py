"""Transport cost models: tuned-TCP streams vs RDMA verbs (paper §5.4).

The paper's TCP scheme sends a standalone size field, then the command
struct, then any bulk payload — ≥2 write() syscalls per command, ≥3 for
buffer transfers, plus one more write per send-buffer split (9 MiB) for
large payloads. Each write is a syscall + a kernel-space copy.

RDMA chains an RDMA_WRITE (payload, zero-copy) with an RDMA_SEND (command
struct) in a single post; the HCA handles fragmentation with no further
syscalls. Without SVM, a shadow-buffer staging copy is paid on both sides
(paper §5.4); with SVM it is skipped (the ``svm`` flag — the paper's
compile-time option).

Constants are calibrated so the synthetic benchmarks land on the paper's
measurements: ~60 µs command overhead on top of ping (Fig. 8), RDMA ~30 %
faster from 32 B and plateauing ~65 % above 134 MiB with the knee at the
9 MiB send buffer (Fig. 11).
"""
from __future__ import annotations

try:                      # vectorized chunk-plan math (optional)
    import numpy as _np
except ImportError:       # pragma: no cover - numpy ships with the image
    _np = None

KiB = 1024
MiB = 1024 * 1024

# protocol constants (seconds) — calibrated so a no-op command lands at
# the paper's ~60 µs over ping (Fig. 8) and RDMA at ~30 % for small /
# ~65 % for ≥134 MiB migrations (Fig. 11)
SYSCALL = 3e-6            # one write()/read() syscall + kernel bookkeeping
THREAD_WAKE = 9e-6        # reader/writer thread wakeup per TCP message
DISPATCH = 5e-6           # daemon: decode + enqueue to native OpenCL runtime
COMPLETE_WRITE = 4e-6     # completion serialization (writer side)
CLIENT_SUBMIT = 5e-6      # client driver: command build + queue bookkeeping
CLIENT_REAP = 4e-6        # client driver: completion processing
RDMA_POST = 2e-6          # one chained work-request post (no syscall path)
RDMA_COMPLETE = 5e-6      # completion-queue poll + event signal
MR_REGISTER = 45e-6       # per-buffer one-time memory-region registration
MR_KEY_EXCHANGE = 20e-6   # per-buffer per-peer rkey exchange
COPY_BW = 11e9            # host memcpy bandwidth (shadow buffers, TCP copies)
TCP_SNDBUF = 9 * MiB      # paper: 9 MiB kernel send/receive buffers
HCA_FRAG = 4 * MiB        # RDMA staging-pipeline granularity: the HCA
                          # fragments at MTU on the wire, but the shadow-
                          # buffer copy overlaps the wire at this coarser
                          # doorbell/fragment granularity
CMD_BYTES = 96            # wire size of a command struct (size-prefixed)
COMPLETION_BYTES = 48
# single-stream TCP on ≥40 Gb links achieves well under line rate
# (segmentation, ACK clocking, window limits); RDMA reaches ~wire speed.
# 0.60 calibrates the Fig. 11 plateau (~65 % RDMA speedup ≥134 MiB) now
# that the chunked cut-through path overlaps both transports' host
# copies with the wire (pre-pipeline the constant was 0.45: TCP paid its
# two extra copies serially, so less wire-level inflation was needed to
# land the same measured plateau).
# Slow links (≤10 Gb) are easily saturated → efficiency 1.
TCP_WIRE_EFFICIENCY = 0.60
TCP_EFFICIENCY_BW_THRESHOLD = 1.5e9   # B/s (~12 Gb/s)


def wire_scale(transport, link_bandwidth: float) -> float:
    """Inflation factor for payload bytes on the wire (protocol
    inefficiency). RDMA ≈ line rate everywhere; single-stream TCP only
    below ~12 Gb/s."""
    if getattr(transport, "name", "") == "tcp" \
            and link_bandwidth > TCP_EFFICIENCY_BW_THRESHOLD:
        return 1.0 / TCP_WIRE_EFFICIENCY
    return 1.0


class TransferCost:
    """Per-message cost triple. A ``__slots__`` value object (one is
    built per command send on the dispatch hot path); the zero-payload
    instances are cached per transport and shared — holders only read
    the fields."""

    __slots__ = ("sender_cpu", "wire_bytes", "receiver_cpu")

    def __init__(self, sender_cpu: float, wire_bytes: float,
                 receiver_cpu: float):
        self.sender_cpu = sender_cpu      # sending-side time before the wire
        self.wire_bytes = wire_bytes      # bytes that cross the link
        self.receiver_cpu = receiver_cpu  # receiving-side time after delivery

    def __repr__(self):
        return (f"TransferCost(sender_cpu={self.sender_cpu!r}, "
                f"wire_bytes={self.wire_bytes!r}, "
                f"receiver_cpu={self.receiver_cpu!r})")


# Vectorizing the per-chunk wire-scale multiply pays only once a plan is
# big enough to amortize the array round-trip (DESIGN.md §8); below the
# cutoff the plain list comprehension is faster — zero cost when unused.
_VEC_MIN_CHUNKS = 64


def scale_chunks(chunks: list, scale: float) -> list:
    """Apply a wire inflation factor to a chunk plan's wire-bytes
    column. Elementwise multiply only — each output float is the same
    single IEEE operation the scalar path performs, so results are
    bit-exact either way."""
    if _np is not None and len(chunks) >= _VEC_MIN_CHUNKS:
        arr = _np.array(chunks, dtype=_np.float64)
        arr[:, 1] *= scale
        return [tuple(row) for row in arr.tolist()]
    return [(s, wb * scale, r) for s, wb, r in chunks]


def _chunk_sizes(payload: float, chunk_bytes: float) -> list:
    """``floor(payload / chunk_bytes) + 1`` equal-sized chunks — ceil,
    except that an exact multiple of the granularity also rounds up.
    Equal sizing (instead of full-size chunks plus a remainder) keeps
    the cut-through pipeline's per-chunk cadence uniform, and rounding
    up at exact multiples makes the chunk count continuous from the
    right at every split boundary: a payload that *fills* the send
    buffer already overlaps its copy with the wire drain (the kernel
    transmits while the application's write completes), so modeling it
    as a single store-and-forward chunk bolted a full serial
    copy+wire+copy onto exactly the boundary sizes — the Fig. 11 9 MiB
    knee overshot the ~66 % plateau at ~85 % from that cliff."""
    n = int(payload // chunk_bytes) + 1
    return [payload / n] * n


class TCPTransport:
    """Size-prefixed command stream over tuned TCP sockets."""
    name = "tcp"

    def __init__(self):
        # The dispatch hot path asks for these two costs once per
        # command/completion; both are payload-independent constants, so
        # build them once and share (holders never mutate TransferCost).
        self._cost_zero = TransferCost(
            THREAD_WAKE + 2 * SYSCALL, CMD_BYTES + 0.0,
            THREAD_WAKE + SYSCALL)
        self._cost_completion = TransferCost(
            THREAD_WAKE + SYSCALL, COMPLETION_BYTES, THREAD_WAKE + SYSCALL)

    def command_cost(self, payload: float = 0.0) -> TransferCost:
        if not payload:
            return self._cost_zero
        writes = 3
        if payload > TCP_SNDBUF:
            writes += int(payload // TCP_SNDBUF)
        # every byte is copied into the kernel send buffer, and out again;
        # each message wakes the writer (sender) and reader (receiver)
        copy = payload / COPY_BW
        return TransferCost(
            sender_cpu=THREAD_WAKE + writes * SYSCALL + copy,
            wire_bytes=CMD_BYTES + payload,
            receiver_cpu=THREAD_WAKE + SYSCALL + copy,
        )

    def completion_cost(self) -> TransferCost:
        return self._cost_completion

    def chunk_plan(self, payload: float):
        """Split a bulk payload at the kernel send-buffer granularity for
        the cut-through pipeline (``Link.send_chunked``). Returns
        ``(fixed_sender_cpu, [(sender_cpu, wire_bytes, receiver_cpu)])``
        whose totals equal ``command_cost(payload)`` exactly, so a
        single-chunk transfer on an idle link is time-identical to the
        store-and-forward path (Fig. 8/Fig. 11 small-size calibration).
        Requires ``payload > 0``."""
        sizes = _chunk_sizes(payload, TCP_SNDBUF)
        # writes: size prefix + command struct up front, then one
        # write() per send-buffer worth of payload (mirroring
        # command_cost, which adds split writes only when the payload
        # strictly exceeds the send buffer)
        chunk_writes = 1 + (int(payload // TCP_SNDBUF)
                            if payload > TCP_SNDBUF else 0)
        n = len(sizes)
        if n >= 3:
            # Chunks are equal-sized by construction, so every interior
            # chunk is the *same* cost tuple — build the plan by
            # replication instead of re-deriving n identical rows. The
            # first/middle/last tuples go through the exact arithmetic
            # of the general loop below, so the plan is bit-identical.
            c = sizes[0]
            copy = c / COPY_BW
            head = (SYSCALL + copy, CMD_BYTES + c, copy)
            mid = (SYSCALL + copy, c, copy)
            tail = ((1 + chunk_writes - n) * SYSCALL + copy, c,
                    copy + THREAD_WAKE + SYSCALL)
            chunks = [head] + [mid] * (n - 2) + [tail]
            return THREAD_WAKE + 2 * SYSCALL, chunks
        chunks = []
        last = n - 1
        for i, c in enumerate(sizes):
            writes = 1 + (chunk_writes - n if i == last else 0)
            chunks.append((
                writes * SYSCALL + c / COPY_BW,
                (CMD_BYTES if i == 0 else 0.0) + c,
                c / COPY_BW + (THREAD_WAKE + SYSCALL if i == last else 0.0),
            ))
        return THREAD_WAKE + 2 * SYSCALL, chunks

    def register_buffer(self, nbytes: float, peers: int) -> float:
        return 0.0


class RDMATransport:
    """Chained RDMA_WRITE + RDMA_SEND; optional SVM (no shadow copies)."""
    name = "rdma"

    def __init__(self, svm: bool = False):
        self.svm = svm
        self._cost_zero = TransferCost(RDMA_POST, CMD_BYTES + 0.0,
                                       RDMA_COMPLETE)
        self._cost_completion = TransferCost(
            RDMA_POST, COMPLETION_BYTES, RDMA_COMPLETE)

    def command_cost(self, payload: float = 0.0) -> TransferCost:
        if not payload:
            return self._cost_zero
        stage = 0.0 if self.svm else payload / COPY_BW
        return TransferCost(
            sender_cpu=RDMA_POST + stage,
            wire_bytes=CMD_BYTES + payload,
            receiver_cpu=RDMA_COMPLETE + stage,
        )

    def completion_cost(self) -> TransferCost:
        return self._cost_completion

    def chunk_plan(self, payload: float):
        """Split at the HCA staging-fragment granularity; the shadow-
        buffer copies (absent with SVM) pipeline against the wire.
        Totals equal ``command_cost(payload)``. Requires ``payload >
        0``."""
        if self.svm:
            # zero-copy: nothing to overlap, one fragment is exact
            return RDMA_POST, [(0.0, CMD_BYTES + payload, RDMA_COMPLETE)]
        sizes = _chunk_sizes(payload, HCA_FRAG)
        last = len(sizes) - 1
        chunks = [(c / COPY_BW,
                   (CMD_BYTES if i == 0 else 0.0) + c,
                   c / COPY_BW + (RDMA_COMPLETE if i == last else 0.0))
                  for i, c in enumerate(sizes)]
        return RDMA_POST, chunks

    def register_buffer(self, nbytes: float, peers: int) -> float:
        # registration + rkey exchange with every peer (paper Fig. 13:
        # a net NEGATIVE for small work on many servers)
        return MR_REGISTER + peers * MR_KEY_EXCHANGE


def make_transport(kind: str, svm: bool = False):
    if kind == "tcp":
        return TCPTransport()
    if kind == "rdma":
        return RDMATransport(svm=svm)
    raise ValueError(f"unknown transport {kind!r}")
