"""Per-device run queues with pluggable cross-session fairness policies
(DESIGN.md §4).

The single-tenant runtime stacked ready commands straight onto the
device's busy-until timeline, i.e. global FIFO in ready order. With many
client sessions sharing one server that policy lets any tenant with a
deep backlog capture the device for its whole burst. Each
``DeviceScheduler`` owns one device's run queue and dispatches exactly
one command at a time; *which* command is a policy decision:

* ``fifo`` — one queue in arrival order, across all sessions. This is
  the pre-multi-tenant behavior and the baseline the fairness
  benchmarks compare against (a straggler tenant's backlog head-of-line
  blocks everyone else).
* ``drr`` — deficit round robin (Shreedhar & Varghese) over per-session
  FIFO queues, with the deficit measured in device-seconds. Visiting a
  session grants it ``quantum * weight`` of credit; its queued commands
  run while their cost fits the remaining credit, then the scheduler
  moves on, carrying the unspent deficit. Sessions that go idle forfeit
  their deficit (no banking credit while absent). Weighted shares fall
  out of the per-visit grant, and the wait for a newly-arrived light
  tenant is bounded by one rotation plus the in-service command's
  remainder instead of the straggler's whole backlog.

The scheduler is non-preemptive — a dispatched kernel always runs to
completion (matching OpenCL command semantics); fairness is decided at
dispatch boundaries.

HetMEC (arXiv:1901.09307) frames the cross-tenant assignment problem
this policy layer plugs into; DRR is the classic O(1)-per-decision
answer for latency-bounded fair sharing of one serial resource.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Callable, Optional

# Default DRR quantum (device-seconds per visit). Roughly one "frame
# slice" of GPU time: large enough that millisecond kernels run on their
# first visit, small enough that a tenant queueing tens-of-millisecond
# kernels cannot hold the device for more than ~one of them per round.
DEFAULT_QUANTUM = 2e-3


def _intern(tenant):
    """Run-queue key for a tenant: the session's interned small int
    (``ServerSim.skey``, DESIGN.md §8) when it has one, else the object
    itself (unit tests push plain strings). Int keys hash to themselves
    and compare with one machine op — the queues never touch session
    *names* on the hot path; names stay at the API boundary
    (``drain_queued`` returns the tenant objects, stats render names)."""
    return getattr(tenant, "skey", tenant)


class FIFOPolicy:
    """Single arrival-order queue across every session (baseline)."""

    name = "fifo"
    __slots__ = ("_q", "_cost")

    def __init__(self):
        # (skey, tenant, cost, run, tag) in arrival order; ``tag``
        # identifies the command for drain-time requeue (the Event, in
        # the runtime) and ``skey`` is the interned session id used for
        # tenant-match scans (``remove``)
        self._q: deque = deque()
        self._cost = 0.0              # queued device-seconds

    def push(self, tenant, weight: float, cost: float, run: Callable,
             tag=None):
        self._q.append((_intern(tenant), tenant, cost, run, tag))
        self._cost += cost

    def pop(self) -> Optional[Callable]:
        if not self._q:
            return None
        _k, _t, cost, run, _g = self._q.popleft()
        self._cost -= cost
        return run

    def queued_seconds(self) -> float:
        return self._cost

    def remove(self, tenant) -> int:
        """Drop every queued command of ``tenant`` (detach); returns the
        number removed. The in-service command, if any, was already
        popped and runs to completion (non-preemptive)."""
        key = _intern(tenant)
        kept = [e for e in self._q if e[0] != key]
        removed = len(self._q) - len(kept)
        self._q = deque(kept)
        self._cost = sum(e[2] for e in kept)
        return removed

    def drain_queued(self) -> list:
        """Empty the queue, returning ``(tenant, tag)`` per entry in
        arrival order (server drain: the commands are requeued on a
        survivor, so their ``run`` closures must never fire here)."""
        out = [(t, g) for _k, t, _c, _r, g in self._q]
        self._q.clear()
        self._cost = 0.0
        return out

    def __len__(self):
        return len(self._q)


class DRRPolicy:
    """Deficit round robin over per-tenant FIFO queues, in device-seconds.

    ``_ring`` holds exactly the tenants with queued work, in round-robin
    order. The head tenant is granted ``quantum * weight`` once per
    visit (``_granted`` latches the grant so repeated ``pop`` calls
    while it stays at the head do not re-grant); when no tenant in a
    full rotation can afford its head command, the rotation deficit is
    advanced several rounds at once (``skip-ahead``) so a command
    costing many quanta needs O(ring) work, not O(cost/quantum).
    """

    name = "drr"
    __slots__ = ("quantum", "_queues", "_weights", "_deficit", "_ring",
                 "_granted", "_cost", "_tenants")

    def __init__(self, quantum: float = DEFAULT_QUANTUM):
        if not quantum > 0.0:
            # a zero quantum never grants credit (skip-ahead divides by
            # it); a negative one shrinks deficits forever
            raise ValueError(f"quantum must be positive, got {quantum!r}")
        self.quantum = quantum
        # every per-tenant table is keyed by the interned session key
        # (``_intern``); ``_tenants`` maps it back to the tenant object
        # for the drain-time API boundary
        self._queues: dict = {}       # skey -> deque[(cost, run, tag)]
        self._weights: dict = {}
        self._deficit: dict = {}      # only tenants currently in the ring
        self._ring: deque = deque()   # skeys with queued work
        self._granted = False
        self._cost = 0.0              # queued device-seconds
        self._tenants: dict = {}      # skey -> tenant object

    def push(self, tenant, weight: float, cost: float, run: Callable,
             tag=None):
        key = _intern(tenant)
        self._tenants[key] = tenant
        self._weights[key] = weight
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = deque()
        if not q:
            # going active: join the rotation with zero credit (idle
            # periods bank nothing)
            self._deficit[key] = 0.0
            self._ring.append(key)
            if len(self._ring) == 1:
                self._granted = False
        q.append((cost, run, tag))
        self._cost += cost

    def queued_seconds(self) -> float:
        return self._cost

    def pop(self) -> Optional[Callable]:
        ring = self._ring
        if not ring:
            return None
        visited = 0
        while True:
            t = ring[0]
            q = self._queues[t]
            if not self._granted:
                self._deficit[t] += self.quantum * self._weights[t]
                self._granted = True
            cost, run, _g = q[0]
            if cost <= self._deficit[t]:
                q.popleft()
                self._deficit[t] -= cost
                self._cost -= cost
                if not q:
                    del self._deficit[t]    # forfeit on going idle
                    ring.popleft()
                    self._granted = False
                return run
            # head unaffordable: keep the carried deficit, move on
            ring.rotate(-1)
            self._granted = False
            visited += 1
            if visited >= len(ring):
                # a full rotation granted everyone a quantum and nobody
                # could run: advance whole rotations at once. Grant
                # ``rounds - 1`` here and let the resumed loop's normal
                # per-visit grant supply each tenant's final quantum, so
                # the deficits match the unoptimized rotation exactly
                # (pre-granting all ``rounds`` would leak one extra
                # quantum to tenants visited before the dispatching one)
                rounds = min(
                    math.ceil((self._queues[x][0][0] - self._deficit[x])
                              / (self.quantum * self._weights[x]))
                    for x in ring)
                for x in ring:
                    self._deficit[x] += \
                        (rounds - 1) * self.quantum * self._weights[x]
                visited = 0

    def remove(self, tenant) -> int:
        """Drop ``tenant``'s queue, deficit, and ring slot (detach);
        returns the number of queued commands removed. If the tenant was
        at the ring head its latched grant is discarded with it."""
        key = _intern(tenant)
        q = self._queues.pop(key, None)
        self._weights.pop(key, None)
        self._tenants.pop(key, None)
        removed = len(q) if q else 0
        if q:
            self._cost -= sum(c for c, _r, _g in q)
        if self._deficit.pop(key, None) is not None:
            if self._ring and self._ring[0] == key:
                self._granted = False
            try:
                self._ring.remove(key)
            except ValueError:
                pass
        return removed

    def drain_queued(self) -> list:
        """Empty every queue, returning ``(tenant, tag)`` per entry in
        ring order (server drain: the commands are requeued elsewhere,
        so their ``run`` closures must never fire here). Tenant objects
        — not interned keys — cross this boundary."""
        out = []
        order = list(self._ring) + [k for k in self._queues
                                    if k not in self._deficit]
        tenants = self._tenants
        for k in order:
            t = tenants.get(k, k)
            for _c, _r, g in self._queues.get(k, ()):
                out.append((t, g))
        self._queues.clear()
        self._deficit.clear()
        self._ring.clear()
        self._tenants.clear()
        self._granted = False
        self._cost = 0.0
        return out

    def __len__(self):
        return sum(len(q) for q in self._queues.values())


def make_policy(kind: str, quantum: Optional[float] = None):
    if kind == "fifo":
        return FIFOPolicy()
    if kind == "drr":
        return DRRPolicy(quantum if quantum is not None
                         else DEFAULT_QUANTUM)
    raise ValueError(f"unknown scheduler policy {kind!r}")


class DeviceScheduler:
    """One device's run queue: ready commands from every attached session
    funnel through ``submit`` and run one at a time in policy order.

    ``run(release)`` performs the actual dispatch (setting timestamps,
    calling ``DeviceSim.execute``) and must invoke ``release`` exactly
    once, when the device finishes the command — that hands the device
    to the next queued command. Dispatch is work-conserving: the device
    only idles when no session has queued work.
    """

    __slots__ = ("policy", "_busy", "dispatched", "queue_peak")

    def __init__(self, policy):
        self.policy = policy
        self._busy = False
        self.dispatched = 0          # commands run through this queue
        self.queue_peak = 0          # max commands ever waiting

    def submit(self, tenant, weight: float, cost: float, run: Callable,
               tag=None):
        policy = self.policy
        if not self._busy and type(policy) is FIFOPolicy and \
                not policy._q and policy._cost == 0.0:
            # Uncontended fast path: an idle device with an empty FIFO
            # queue would push this entry and immediately pop it back —
            # skip the queue round-trip. Observable state transitions
            # exactly as the general path: backlog peaked at 1,
            # dispatched counted, device marked busy. FIFO only: a
            # DRR push/pop mutates deficits, and a nonzero residual
            # ``_cost`` (float cancellation dust) must keep flowing
            # through the same += / -= sequence to stay bit-exact.
            if self.queue_peak < 1:
                self.queue_peak = 1
            self._busy = True
            self.dispatched += 1
            run(self._release)
            return
        policy.push(tenant, weight, cost, run, tag)
        backlog = len(policy)
        if backlog > self.queue_peak:
            self.queue_peak = backlog
        if not self._busy:
            self._dispatch()

    def discard(self, tenant) -> int:
        """Tenant lifecycle (detach): drop every command ``tenant`` still
        has queued. The in-service command — already dispatched — runs to
        completion; its events were failed by the caller, so completion
        is a no-op there."""
        return self.policy.remove(tenant)

    def drain_queued(self) -> list:
        """Server lifecycle (drain/crash): empty the run queue, returning
        ``(tenant, tag)`` per queued command so the caller can requeue
        (drain) or fail (crash) each one. The in-service command — if
        any — runs to completion; its ``_release`` finds the queue
        empty."""
        return self.policy.drain_queued()

    def queued_seconds(self) -> float:
        """Queue-depth probe (DESIGN.md §6): device-seconds of work
        sitting in this run queue, policy-independent. The in-service
        command is NOT included — its remainder shows on the device's
        own busy-until timeline, which the placement engine reads
        alongside this probe."""
        return self.policy.queued_seconds()

    def _dispatch(self):
        run = self.policy.pop()
        if run is None:
            return
        self._busy = True
        self.dispatched += 1
        run(self._release)

    def _release(self):
        self._busy = False
        self._dispatch()
