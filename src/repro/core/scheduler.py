"""Per-device run queues with pluggable cross-session fairness policies
(DESIGN.md §4).

The single-tenant runtime stacked ready commands straight onto the
device's busy-until timeline, i.e. global FIFO in ready order. With many
client sessions sharing one server that policy lets any tenant with a
deep backlog capture the device for its whole burst. Each
``DeviceScheduler`` owns one device's run queue and dispatches exactly
one command at a time; *which* command is a policy decision:

* ``fifo`` — one queue in arrival order, across all sessions. This is
  the pre-multi-tenant behavior and the baseline the fairness
  benchmarks compare against (a straggler tenant's backlog head-of-line
  blocks everyone else).
* ``drr`` — deficit round robin (Shreedhar & Varghese) over per-session
  FIFO queues, with the deficit measured in device-seconds. Visiting a
  session grants it ``quantum * weight`` of credit; its queued commands
  run while their cost fits the remaining credit, then the scheduler
  moves on, carrying the unspent deficit. Sessions that go idle forfeit
  their deficit (no banking credit while absent). Weighted shares fall
  out of the per-visit grant, and the wait for a newly-arrived light
  tenant is bounded by one rotation plus the in-service command's
  remainder instead of the straggler's whole backlog.

Two deadline-aware policies ride the same interface (DESIGN.md §10).
Commands enqueued by a tenant with a latency target
(``ClientRuntime(slo_ms=)``) carry an absolute deadline; commands
without one sort after every deadline-carrying command, FIFO among
themselves:

* ``edf`` — earliest deadline first. Non-preemptive like fifo/drr:
  fairness-free, purely deadline-ordered dispatch.
* ``llf`` — least laxity first, *with chunk-granularity preemption*.
  The queue orders by laxity (deadline − now − remaining cost); since
  ``now`` is common to every comparison the key is the static
  ``deadline − cost``. A dispatched kernel runs in ``chunk``-sized
  slices, and at each chunk boundary the runtime asks
  ``should_preempt``: if a queued command's laxity is strictly tighter
  than the running command's residual laxity, the remainder is requeued
  at its residual cost and the tighter command takes the device.

fifo/drr are and stay non-preemptive — a dispatched kernel always runs
to completion (matching OpenCL command semantics); fairness is decided
at dispatch boundaries, and their timestamp streams are bit-identical
to the pre-SLO runtime when no tenant declares an SLO.

HetMEC (arXiv:1901.09307) frames the cross-tenant assignment problem
this policy layer plugs into; DRR is the classic O(1)-per-decision
answer for latency-bounded fair sharing of one serial resource, and
"Latency and Reliability-Aware Task Offloading" (arXiv:1710.00590)
motivates the deadline/tail-constraint framing EDF/LLF serve.
"""
from __future__ import annotations

import math
from collections import deque
from heapq import heapify, heappop, heappush
from typing import Callable, Optional

# Default DRR quantum (device-seconds per visit). Roughly one "frame
# slice" of GPU time: large enough that millisecond kernels run on their
# first visit, small enough that a tenant queueing tens-of-millisecond
# kernels cannot hold the device for more than ~one of them per round.
# Overridable per cluster via Cluster(scheduler_opts={"quantum": ...}).
DEFAULT_QUANTUM = 2e-3

# Default LLF preemption chunk (device-seconds between preemption
# checks). A quarter of the quantum: fine enough that a millisecond-SLO
# command waits at most ~0.5 ms behind a bulk kernel, coarse enough
# that a 10 ms kernel costs only ~20 slice callbacks. Overridable via
# Cluster(scheduler_opts={"chunk": ...}).
DEFAULT_PREEMPT_CHUNK = 5e-4

_INF = float("inf")


def _intern(tenant):
    """Run-queue key for a tenant: the session's interned small int
    (``ServerSim.skey``, DESIGN.md §8) when it has one, else the object
    itself (unit tests push plain strings). Int keys hash to themselves
    and compare with one machine op — the queues never touch session
    *names* on the hot path; names stay at the API boundary
    (``drain_queued`` returns the tenant objects, stats render names)."""
    return getattr(tenant, "skey", tenant)


class FIFOPolicy:
    """Single arrival-order queue across every session (baseline)."""

    name = "fifo"
    preempt_chunk = None             # deadline-blind: never preempts
    __slots__ = ("_q", "_cost")

    def __init__(self):
        # (skey, tenant, cost, run, tag) in arrival order; ``tag``
        # identifies the command for drain-time requeue (the Event, in
        # the runtime) and ``skey`` is the interned session id used for
        # tenant-match scans (``remove``)
        self._q: deque = deque()
        self._cost = 0.0              # queued device-seconds

    def push(self, tenant, weight: float, cost: float, run: Callable,
             tag=None, deadline=None):
        self._q.append((_intern(tenant), tenant, cost, run, tag))
        self._cost += cost

    def pop(self) -> Optional[Callable]:
        if not self._q:
            return None
        _k, _t, cost, run, _g = self._q.popleft()
        self._cost -= cost
        return run

    def queued_seconds(self) -> float:
        return self._cost

    def queued_slo_seconds(self) -> float:
        return 0.0                   # deadline-blind: nothing tracked

    def remove(self, tenant) -> int:
        """Drop every queued command of ``tenant`` (detach); returns the
        number removed. The in-service command, if any, was already
        popped and runs to completion (non-preemptive)."""
        key = _intern(tenant)
        kept = [e for e in self._q if e[0] != key]
        removed = len(self._q) - len(kept)
        self._q = deque(kept)
        self._cost = sum(e[2] for e in kept)
        return removed

    def drain_queued(self) -> list:
        """Empty the queue, returning ``(tenant, tag)`` per entry in
        arrival order (server drain: the commands are requeued on a
        survivor, so their ``run`` closures must never fire here)."""
        out = [(t, g) for _k, t, _c, _r, g in self._q]
        self._q.clear()
        self._cost = 0.0
        return out

    def __len__(self):
        return len(self._q)


class DRRPolicy:
    """Deficit round robin over per-tenant FIFO queues, in device-seconds.

    ``_ring`` holds exactly the tenants with queued work, in round-robin
    order. The head tenant is granted ``quantum * weight`` once per
    visit (``_granted`` latches the grant so repeated ``pop`` calls
    while it stays at the head do not re-grant); when no tenant in a
    full rotation can afford its head command, the rotation deficit is
    advanced several rounds at once (``skip-ahead``) so a command
    costing many quanta needs O(ring) work, not O(cost/quantum).
    """

    name = "drr"
    preempt_chunk = None             # deadline-blind: never preempts
    __slots__ = ("quantum", "_queues", "_weights", "_deficit", "_ring",
                 "_granted", "_cost", "_tenants")

    def __init__(self, quantum: float = DEFAULT_QUANTUM):
        if not quantum > 0.0:
            # a zero quantum never grants credit (skip-ahead divides by
            # it); a negative one shrinks deficits forever
            raise ValueError(f"quantum must be positive, got {quantum!r}")
        self.quantum = quantum
        # every per-tenant table is keyed by the interned session key
        # (``_intern``); ``_tenants`` maps it back to the tenant object
        # for the drain-time API boundary
        self._queues: dict = {}       # skey -> deque[(cost, run, tag)]
        self._weights: dict = {}
        self._deficit: dict = {}      # only tenants currently in the ring
        self._ring: deque = deque()   # skeys with queued work
        self._granted = False
        self._cost = 0.0              # queued device-seconds
        self._tenants: dict = {}      # skey -> tenant object

    def push(self, tenant, weight: float, cost: float, run: Callable,
             tag=None, deadline=None):
        key = _intern(tenant)
        self._tenants[key] = tenant
        self._weights[key] = weight
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = deque()
        if not q:
            # going active: join the rotation with zero credit (idle
            # periods bank nothing)
            self._deficit[key] = 0.0
            self._ring.append(key)
            if len(self._ring) == 1:
                self._granted = False
        q.append((cost, run, tag))
        self._cost += cost

    def queued_seconds(self) -> float:
        return self._cost

    def queued_slo_seconds(self) -> float:
        return 0.0                   # deadline-blind: nothing tracked

    def pop(self) -> Optional[Callable]:
        ring = self._ring
        if not ring:
            return None
        visited = 0
        while True:
            t = ring[0]
            q = self._queues[t]
            if not self._granted:
                self._deficit[t] += self.quantum * self._weights[t]
                self._granted = True
            cost, run, _g = q[0]
            if cost <= self._deficit[t]:
                q.popleft()
                self._deficit[t] -= cost
                self._cost -= cost
                if not q:
                    del self._deficit[t]    # forfeit on going idle
                    ring.popleft()
                    self._granted = False
                return run
            # head unaffordable: keep the carried deficit, move on
            ring.rotate(-1)
            self._granted = False
            visited += 1
            if visited >= len(ring):
                # a full rotation granted everyone a quantum and nobody
                # could run: advance whole rotations at once. Grant
                # ``rounds - 1`` here and let the resumed loop's normal
                # per-visit grant supply each tenant's final quantum, so
                # the deficits match the unoptimized rotation exactly
                # (pre-granting all ``rounds`` would leak one extra
                # quantum to tenants visited before the dispatching one)
                rounds = min(
                    math.ceil((self._queues[x][0][0] - self._deficit[x])
                              / (self.quantum * self._weights[x]))
                    for x in ring)
                for x in ring:
                    self._deficit[x] += \
                        (rounds - 1) * self.quantum * self._weights[x]
                visited = 0

    def remove(self, tenant) -> int:
        """Drop ``tenant``'s queue, deficit, and ring slot (detach);
        returns the number of queued commands removed. If the tenant was
        at the ring head its latched grant is discarded with it."""
        key = _intern(tenant)
        q = self._queues.pop(key, None)
        self._weights.pop(key, None)
        self._tenants.pop(key, None)
        removed = len(q) if q else 0
        if q:
            self._cost -= sum(c for c, _r, _g in q)
        if self._deficit.pop(key, None) is not None:
            if self._ring and self._ring[0] == key:
                self._granted = False
            try:
                self._ring.remove(key)
            except ValueError:
                pass
        return removed

    def drain_queued(self) -> list:
        """Empty every queue, returning ``(tenant, tag)`` per entry in
        ring order (server drain: the commands are requeued elsewhere,
        so their ``run`` closures must never fire here). Tenant objects
        — not interned keys — cross this boundary."""
        out = []
        order = list(self._ring) + [k for k in self._queues
                                    if k not in self._deficit]
        tenants = self._tenants
        for k in order:
            t = tenants.get(k, k)
            for _c, _r, g in self._queues.get(k, ()):
                out.append((t, g))
        self._queues.clear()
        self._deficit.clear()
        self._ring.clear()
        self._tenants.clear()
        self._granted = False
        self._cost = 0.0
        return out

    def __len__(self):
        return sum(len(q) for q in self._queues.values())


class _DeadlineHeapPolicy:
    """Shared machinery for the deadline-ordered policies (EDF/LLF): a
    binary heap keyed by a per-command priority derived from the
    absolute deadline, with commands lacking a deadline keyed at +inf —
    strictly after every SLO command, FIFO among themselves via the
    monotone sequence number. ``_slo_cost`` tracks the queued
    device-seconds belonging to deadline-carrying commands, the
    laxity-aware placement tie-break probe (DESIGN.md §10)."""

    __slots__ = ("_heap", "_cost", "_slo_cost", "_seq")

    def __init__(self):
        # (key, seq, skey, tenant, cost, run, tag, deadline); seq is
        # unique so tuple comparison never reaches the tenant object
        self._heap: list = []
        self._cost = 0.0             # queued device-seconds, all
        self._slo_cost = 0.0         # queued device-seconds, SLO only
        self._seq = 0

    @staticmethod
    def _key(cost: float, deadline: Optional[float]) -> float:
        raise NotImplementedError

    def push(self, tenant, weight: float, cost: float, run: Callable,
             tag=None, deadline=None):
        self._seq += 1
        heappush(self._heap, (self._key(cost, deadline), self._seq,
                              _intern(tenant), tenant, cost, run, tag,
                              deadline))
        self._cost += cost
        if deadline is not None:
            self._slo_cost += cost

    def pop(self) -> Optional[Callable]:
        if not self._heap:
            return None
        entry = heappop(self._heap)
        cost, run = entry[4], entry[5]
        self._cost -= cost
        if entry[7] is not None:
            self._slo_cost -= cost
        return run

    def min_key(self) -> float:
        """Tightest queued priority key, +inf when empty — the
        preemption comparison point (``DeviceScheduler.should_preempt``).
        """
        heap = self._heap
        return heap[0][0] if heap else _INF

    def queued_seconds(self) -> float:
        return self._cost

    def queued_slo_seconds(self) -> float:
        return self._slo_cost

    def remove(self, tenant) -> int:
        """Drop every queued command of ``tenant`` (detach); returns the
        number removed. O(n) rebuild — detach is cold."""
        key = _intern(tenant)
        kept = [e for e in self._heap if e[2] != key]
        removed = len(self._heap) - len(kept)
        if removed:
            heapify(kept)
            self._heap = kept
            self._cost = sum(e[4] for e in kept)
            self._slo_cost = sum(e[4] for e in kept
                                 if e[7] is not None)
        return removed

    def drain_queued(self) -> list:
        """Empty the heap, returning ``(tenant, tag)`` per entry in
        priority order (server drain: requeued elsewhere, so the ``run``
        closures must never fire here). A preempted remainder that was
        requeued drains like any queued entry — its tag still names the
        original event, so the survivor restarts it from scratch and
        completes it exactly once."""
        out = [(e[3], e[6]) for e in sorted(self._heap)]
        self._heap.clear()
        self._cost = 0.0
        self._slo_cost = 0.0
        return out

    def __len__(self):
        return len(self._heap)


class EDFPolicy(_DeadlineHeapPolicy):
    """Earliest deadline first, non-preemptive: ready commands dispatch
    in absolute-deadline order; a dispatched kernel runs to completion.
    """

    name = "edf"
    preempt_chunk = None
    __slots__ = ()

    @staticmethod
    def _key(cost: float, deadline: Optional[float]) -> float:
        return _INF if deadline is None else deadline


class LLFPolicy(_DeadlineHeapPolicy):
    """Least laxity first with chunk-granularity preemption.

    Laxity of a queued command at time t is ``deadline − t − cost``;
    t is common to every pairwise comparison, so the queue orders by
    the static key ``deadline − cost``. A preempted remainder re-enters
    with its residual cost — i.e. a fresh, *looser* key than the
    preemptor's, exactly the laxity it has left."""

    name = "llf"
    __slots__ = ("preempt_chunk",)

    def __init__(self, chunk: float = DEFAULT_PREEMPT_CHUNK):
        if not chunk > 0.0:
            # zero would slice forever without advancing sim time
            raise ValueError(
                f"preemption chunk must be positive, got {chunk!r}")
        super().__init__()
        self.preempt_chunk = chunk

    @staticmethod
    def _key(cost: float, deadline: Optional[float]) -> float:
        # inf − finite cost is still inf: no-deadline commands sort
        # last, FIFO among themselves (never inf − inf, so never NaN)
        return _INF if deadline is None else deadline - cost


# Per-policy tuning knobs accepted by Cluster(scheduler_opts=); every
# value must be a positive number. make_policy validates eagerly so a
# typo'd knob fails at cluster construction, not first dispatch.
_POLICY_KNOBS = {
    "fifo": frozenset(),
    "drr": frozenset(("quantum",)),
    "edf": frozenset(),
    "llf": frozenset(("chunk",)),
}


def validate_scheduler_opts(kind: str, opts: Optional[dict]) -> dict:
    """Validate ``scheduler_opts`` for policy ``kind`` and return a
    normalized copy. Raises ValueError on an unknown policy, an unknown
    knob, or a non-positive/non-numeric value."""
    if kind not in _POLICY_KNOBS:
        raise ValueError(f"unknown scheduler policy {kind!r}")
    if opts is None:
        return {}
    if not isinstance(opts, dict):
        raise ValueError(
            f"scheduler_opts must be a dict, got {type(opts).__name__}")
    unknown = sorted(set(opts) - _POLICY_KNOBS[kind])
    if unknown:
        raise ValueError(
            f"unknown scheduler_opts for {kind!r}: {unknown} "
            f"(allowed: {sorted(_POLICY_KNOBS[kind])})")
    for k, v in opts.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)) \
                or not v > 0.0:
            raise ValueError(
                f"scheduler_opts[{k!r}] must be a positive number, "
                f"got {v!r}")
    return dict(opts)


def make_policy(kind: str, quantum: Optional[float] = None,
                opts: Optional[dict] = None):
    """Build a policy instance. ``quantum`` is the legacy spelling of
    ``opts['quantum']`` (kept for Cluster(scheduler_quantum=) callers;
    ignored by quantum-less policies, as before)."""
    opts = validate_scheduler_opts(kind, opts)
    if kind == "fifo":
        return FIFOPolicy()
    if kind == "drr":
        q = opts.get("quantum", quantum)
        return DRRPolicy(q if q is not None else DEFAULT_QUANTUM)
    if kind == "edf":
        return EDFPolicy()
    if kind == "llf":
        return LLFPolicy(opts.get("chunk", DEFAULT_PREEMPT_CHUNK))
    raise ValueError(f"unknown scheduler policy {kind!r}")


class DeviceScheduler:
    """One device's run queue: ready commands from every attached session
    funnel through ``submit`` and run one at a time in policy order.

    ``run(release)`` performs the actual dispatch (setting timestamps,
    calling ``DeviceSim.execute``) and must invoke ``release`` exactly
    once, when the device finishes the command — that hands the device
    to the next queued command. Dispatch is work-conserving: the device
    only idles when no session has queued work.

    ``preempt_chunk`` (copied from the policy; None for non-preemptive
    policies) tells the runtime to dispatch kernels in chunk-sized
    slices and poll ``should_preempt`` at each boundary; a preempted
    remainder comes back through ``requeue_preempted`` *before* the
    dispatcher's ``release`` fires, so ``_dispatch`` pops whichever of
    {remainder, preemptor} is tighter — the remainder never skips the
    queue (DESIGN.md §10).
    """

    __slots__ = ("policy", "_busy", "dispatched", "queue_peak",
                 "preempt_chunk", "preempted", "trace", "trace_label",
                 "trace_clock")

    def __init__(self, policy):
        self.policy = policy
        self._busy = False
        self.dispatched = 0          # commands run through this queue
        self.queue_peak = 0          # max commands ever waiting
        self.preempt_chunk = policy.preempt_chunk
        self.preempted = 0           # chunk-boundary preemptions
        # observability (DESIGN.md §9/§11): a traced cluster points
        # these at its Tracer so push/pop boundaries emit run-queue
        # depth samples — the device-ordering resource edge of the
        # critical-path DAG. Untraced: one slot load + branch, same
        # zero-overhead gate as NIC.trace.
        self.trace = None
        self.trace_label = ""
        self.trace_clock = None

    def submit(self, tenant, weight: float, cost: float, run: Callable,
               tag=None, deadline=None):
        policy = self.policy
        if not self._busy and type(policy) is FIFOPolicy and \
                not policy._q and policy._cost == 0.0:
            # Uncontended fast path: an idle device with an empty FIFO
            # queue would push this entry and immediately pop it back —
            # skip the queue round-trip. Observable state transitions
            # exactly as the general path: backlog peaked at 1,
            # dispatched counted, device marked busy. FIFO only: a
            # DRR push/pop mutates deficits, and a nonzero residual
            # ``_cost`` (float cancellation dust) must keep flowing
            # through the same += / -= sequence to stay bit-exact.
            if self.queue_peak < 1:
                self.queue_peak = 1
            self._busy = True
            self.dispatched += 1
            run(self._release)
            return
        policy.push(tenant, weight, cost, run, tag, deadline)
        backlog = len(policy)
        if backlog > self.queue_peak:
            self.queue_peak = backlog
        tr = self.trace
        if tr is not None:
            tr.run_queue(self.trace_label, self.trace_clock.now, backlog)
        if not self._busy:
            self._dispatch()

    def should_preempt(self, running_key: float) -> bool:
        """Chunk-boundary poll: does some queued command hold a strictly
        tighter priority key than the running command's residual key
        (``deadline − remaining``, i.e. its laxity now)? Strict: equal
        laxity never preempts, so a lone command is never preempted by
        its own arrival pattern and ties keep the device (no thrash)."""
        return self.policy.min_key() < running_key

    def requeue_preempted(self, tenant, weight: float, remaining: float,
                          run: Callable, tag=None, deadline=None):
        """Put a preempted remainder back in the queue at its residual
        cost. The caller invokes the dispatcher's ``release`` *after*
        this returns, so the very next pop compares the remainder
        against the preemptor on equal footing."""
        self.preempted += 1
        self.policy.push(tenant, weight, remaining, run, tag, deadline)
        backlog = len(self.policy)
        if backlog > self.queue_peak:
            self.queue_peak = backlog
        tr = self.trace
        if tr is not None:
            tr.run_queue(self.trace_label, self.trace_clock.now, backlog)

    def discard(self, tenant) -> int:
        """Tenant lifecycle (detach): drop every command ``tenant`` still
        has queued. The in-service command — already dispatched — runs to
        completion; its events were failed by the caller, so completion
        is a no-op there."""
        return self.policy.remove(tenant)

    def drain_queued(self) -> list:
        """Server lifecycle (drain/crash): empty the run queue, returning
        ``(tenant, tag)`` per queued command so the caller can requeue
        (drain) or fail (crash) each one. The in-service command — if
        any — runs to completion; its ``_release`` finds the queue
        empty."""
        return self.policy.drain_queued()

    def queued_seconds(self) -> float:
        """Queue-depth probe (DESIGN.md §6): device-seconds of work
        sitting in this run queue, policy-independent. The in-service
        command is NOT included — its remainder shows on the device's
        own busy-until timeline, which the placement engine reads
        alongside this probe."""
        return self.policy.queued_seconds()

    def queued_slo_seconds(self) -> float:
        """Deadline-carrying slice of ``queued_seconds`` (0.0 under
        deadline-blind policies) — the laxity-aware placement tie-break
        signal (DESIGN.md §10)."""
        return self.policy.queued_slo_seconds()

    def _dispatch(self):
        run = self.policy.pop()
        if run is None:
            return
        self._busy = True
        self.dispatched += 1
        tr = self.trace
        if tr is not None:
            tr.run_queue(self.trace_label, self.trace_clock.now,
                         len(self.policy))
        run(self._release)

    def _release(self):
        self._busy = False
        self._dispatch()
