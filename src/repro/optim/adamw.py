"""AdamW with global-norm clipping and optional low-precision moments.

Moments inherit each parameter's sharding (they are ``zeros_like`` the
params), so optimizer state is fully ZeRO-sharded across the mesh for
free. ``moment_dtype='bfloat16'`` halves optimizer HBM for the giant
archs (nemotron-340b, grok-314b) — a standard production trade-off; the
update math always runs in fp32.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import tree_global_norm

Pytree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: Pytree
    v: Pytree


class TrainState(NamedTuple):
    params: Pytree
    opt: AdamWState


class AdamW:
    def __init__(self, lr_schedule: Callable, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0,
                 moment_dtype=jnp.float32):
        self.lr_schedule = lr_schedule
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm
        self.moment_dtype = moment_dtype

    def init(self, params: Pytree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads: Pytree, state: AdamWState, params: Pytree):
        """Returns (new_params, new_state, metrics)."""
        gnorm = tree_global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self.lr_schedule(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            mhat = mf / c1
            vhat = vf / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return (newp.astype(p.dtype), mf.astype(m.dtype),
                    vf.astype(v.dtype))

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.m)
        flat_v = jax.tree.leaves(state.v)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, AdamWState(step, new_m, new_v), metrics
