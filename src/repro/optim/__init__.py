from repro.optim.adamw import AdamW, AdamWState, TrainState  # noqa: F401
from repro.optim.schedules import cosine_schedule, constant_schedule  # noqa: F401
