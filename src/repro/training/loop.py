"""Fault-tolerant training loop: checkpoint/restart, heartbeat failure
detection, step log (the paper's command-replay idea at training scale),
straggler-aware step timing."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.data.pipeline import DataLoader
from repro.optim.adamw import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    keep: int = 3
    # straggler mitigation: steps slower than median×threshold are logged
    # and (on real fleets) trigger hot-spare promotion
    straggler_threshold: float = 2.0


class Trainer:
    def __init__(self, train_step: Callable, state: TrainState,
                 loader: DataLoader, cfg: LoopConfig,
                 failure_hook: Optional[Callable] = None):
        self.train_step = train_step
        self.state = state
        self.loader = loader
        self.cfg = cfg
        self.failure_hook = failure_hook
        self.step = 0
        self.metrics_log: list = []
        self.step_times: list = []
        self.stragglers: list = []

    # ---- checkpoint/restart ----
    def maybe_restore(self):
        d = self.cfg.ckpt_dir
        if d and ckpt_lib.latest_step(d) is not None:
            self.state, extras, self.step = ckpt_lib.restore(d, self.state)
            if "loader" in extras:
                self.loader.restore(extras["loader"])
            return True
        return False

    def save(self):
        if self.cfg.ckpt_dir:
            ckpt_lib.save(self.cfg.ckpt_dir, self.step, self.state,
                          extras={"loader": self.loader.snapshot()},
                          keep=self.cfg.keep)

    # ---- main loop ----
    def run(self) -> dict:
        it = iter(self.loader)
        last_loss = None
        while self.step < self.cfg.total_steps:
            batch = next(it)
            t0 = time.perf_counter()
            try:
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            except Exception:
                # device loss / preemption: persist nothing (the last
                # checkpoint is the recovery point), notify orchestrator
                if self.failure_hook is not None:
                    self.failure_hook(self.step)
                raise
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times[-50:]))
            if len(self.step_times) > 5 and dt > med * self.cfg.straggler_threshold:
                self.stragglers.append((self.step, dt, med))
            self.step += 1
            last_loss = float(metrics["loss"])
            if self.step % self.cfg.log_every == 0 or \
                    self.step == self.cfg.total_steps:
                self.metrics_log.append(
                    {"step": self.step, "loss": last_loss,
                     "grad_norm": float(metrics["grad_norm"]),
                     "lr": float(metrics["lr"]), "sec_per_step": dt})
            if self.cfg.ckpt_every and self.step % self.cfg.ckpt_every == 0:
                self.save()
        self.save()
        return {"final_loss": last_loss, "log": self.metrics_log,
                "stragglers": self.stragglers}
