"""Training step builder: chunked-vocab cross-entropy, gradient
accumulation over microbatches, remat policy, AdamW update.

The loss never materializes the full [B, S, V] logits tensor: a scan over
sequence chunks computes per-chunk logits → CE and discards them (the
backward pass rematerializes). For 256k-vocab archs this is the
difference between ~100 MB and ~4 GB of live activations per device.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW, TrainState
from repro.utils import (grad_cast, jax_shard_map, storage_barrier,
                         tree_add, tree_scale, tree_zeros_like, vma_like)

AUX_LOSS_COEF = 0.01


def chunked_ce_loss(params: dict, cfg: ModelConfig, hidden: jax.Array,
                    labels: jax.Array, chunk: int = 1024):
    """Mean next-token CE over valid labels (label < 0 → masked)."""
    hidden = grad_cast(hidden)
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nch = S // chunk
    table = storage_barrier(
        params.get("lm_head", params["embed"]).astype(jnp.bfloat16))

    def body(carry, i):
        tot, cnt = carry
        h = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        y = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        logits = jnp.einsum("bsd,vd->bsv", h, table,
                            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y, 0)[..., None], axis=-1)[..., 0]
        valid = (y >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - gold) * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (vma_like(jnp.float32(0), hidden),
               vma_like(jnp.float32(0), hidden)),
        jnp.arange(nch, dtype=jnp.int32))
    return tot / jnp.maximum(cnt, 1.0)


def make_loss_fn(cfg: ModelConfig, remat: str = "full",
                 remat_group: int = 1) -> Callable:
    def loss_fn(params, mb):
        hidden, aux = lm.forward(params, cfg, mb, remat=remat,
                                 remat_group=remat_group)
        loss = chunked_ce_loss(params, cfg, hidden, mb["labels"])
        return loss + AUX_LOSS_COEF * aux, loss

    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer: AdamW,
                    microbatches: int = 1, remat: str = "full",
                    remat_group: int = 1) -> Callable:
    """Returns train_step(state, batch) → (state, metrics).

    ``batch`` leaves are microbatch-major: [A, local_batch, ...] with A ==
    ``microbatches`` (A=1 → the extra dim is squeezed away below).
    """
    loss_fn = make_loss_fn(cfg, remat, remat_group)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        if microbatches == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            (total, ce), grads = grad_fn(state.params, mb)
        else:
            def acc(carry, mb):
                gsum, tsum, csum = carry
                (t, c), g = grad_fn(state.params, mb)
                return (tree_add(gsum, g), tsum + t, csum + c), None

            g0 = tree_zeros_like(state.params)
            (grads, total, ce), _ = jax.lax.scan(
                acc, (g0, jnp.float32(0), jnp.float32(0)), batch)
            grads = tree_scale(grads, 1.0 / microbatches)
            total = total / microbatches
            ce = ce / microbatches

        new_params, new_opt, metrics = optimizer.update(
            grads, state.opt, state.params)
        metrics = dict(metrics, loss=ce, total_loss=total)
        return TrainState(new_params, new_opt), metrics

    return train_step

def make_compressed_train_step(cfg: ModelConfig, optimizer: AdamW, mesh,
                               microbatches: int = 1, remat: str = "full",
                               remat_group: int = 1,
                               k_per_block: int = 32,
                               block: int = 1024,
                               compress: bool = True) -> Callable:
    """Cross-pod content-sized gradient sync (paper §5.3 → the DCN link).

    The step runs inside a shard_map that is *manual over 'pod' only*
    (data/model stay compiler-sharded), so XLA does NOT insert the
    automatic cross-pod dense gradient all-reduce; instead each pod
    top-k-packs its gradients (+error feedback) and all-gathers only the
    packed payload over the pod axis — the "content size" crosses DCN,
    not the dense buffer.

    State layout: the TrainState (and error state) carry a leading
    per-pod replica dim sharded P('pod') — each pod owns and updates its
    own numerically-identical replica (plain DP semantics), so no dense
    bytes ever cross pods. Build with ``replicate_state_per_pod``.

    Returns step(state, batch, err) → (state, err, metrics).
    """
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_psum_tree

    loss_fn = make_loss_fn(cfg, remat, remat_group)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    manual = frozenset({"pod"}) & frozenset(mesh.axis_names)
    n_pod = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)

    def pod_body(state, batch, err):
        state = jax.tree.map(lambda a: a[0], state)   # this pod's replica
        err = jax.tree.map(lambda e: e[0], err)
        if microbatches == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            (total, ce), grads = grad_fn(state.params, mb)
        else:
            def acc(carry, mb):
                gsum, tsum, csum = carry
                (t, c), g = grad_fn(state.params, mb)
                return (tree_add(gsum, g), tsum + t, csum + c), None
            tmpl = jax.tree.leaves(batch)[0]
            g0 = vma_like(tree_zeros_like(state.params), tmpl)
            z = vma_like(jnp.float32(0), tmpl)
            (grads, total, ce), _ = jax.lax.scan(acc, (g0, z, z), batch)
            grads = tree_scale(grads, 1.0 / microbatches)
            ce = ce / microbatches
        if compress:
            grads, err = compressed_psum_tree(grads, err, axis="pod",
                                              k_per_block=k_per_block,
                                              block=block)
        else:  # dense DP baseline: full-gradient all-reduce over DCN
            grads = jax.tree.map(
                lambda g: jax.lax.psum(g, "pod") / n_pod, grads)
        new_params, new_opt, metrics = optimizer.update(
            grads, state.opt, state.params)
        state = TrainState(new_params, new_opt)
        metrics = dict(metrics, loss=ce)
        # scalar metrics: cheap exact mean over pods
        metrics = {k: jax.lax.psum(v, "pod") / n_pod
                   for k, v in metrics.items()}
        state = jax.tree.map(lambda a: a[None], state)
        err = jax.tree.map(lambda e: e[None], err)
        return state, err, metrics

    return jax_shard_map(
        pod_body, mesh=mesh,
        in_specs=(P("pod"), P(None, "pod"), P("pod")),
        out_specs=(P("pod"), P("pod"), P()),
        axis_names=manual)


def replicate_state_per_pod(state, n_pod: int):
    """Add the leading per-pod replica dim the compressed step expects."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_pod,) + a.shape), state)
