"""Sharded checkpointing with atomic manifest swap + command replay log.

The paper's reconnect machinery (§4.3: session IDs + replay of the last
unacked commands, server dedup) maps at training scale to
checkpoint/restart: the checkpoint is the session state, and the step log
is the replay buffer — a restarted worker resumes from (checkpoint,
replayed steps) exactly, including the data-loader cursor.

Layout:
  <dir>/step_000100/
    manifest.json         tree structure + per-leaf shape/dtype
    shard_00000.npz       leaf arrays (per-host shard in real deployment)
    extras.json           loader cursor, step log
  <dir>/LATEST            atomic pointer (written last)
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def _flatten_with_names(tree) -> list:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save(directory: str, step: int, state: Pytree,
         extras: Optional[dict] = None, keep: int = 3):
    """Write a checkpoint; the LATEST pointer is flipped atomically last."""
    tag = f"step_{step:08d}"
    final = os.path.join(directory, tag)
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    named = _flatten_with_names(state)
    manifest = {"step": step,
                "leaves": [{"name": n,
                            "shape": list(np.shape(a)),
                            "dtype": str(jnp.asarray(a).dtype)}
                           for n, a in named]}
    # npz can't hold ml_dtypes (bf16/f8): store raw bytes, view on load
    arrays = {f"a{i}": np.frombuffer(
        np.ascontiguousarray(np.asarray(jax.device_get(a))).tobytes(),
        np.uint8)
        for i, (n, a) in enumerate(named)}
    np.savez(os.path.join(tmp, "shard_00000.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "extras.json"), "w") as f:
        json.dump(extras or {}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # atomic LATEST flip
    ptr = os.path.join(directory, "LATEST")
    fd, tmp_ptr = tempfile.mkstemp(dir=directory)
    with os.fdopen(fd, "w") as f:
        f.write(tag)
    os.replace(tmp_ptr, ptr)

    _gc(directory, keep)


def _gc(directory: str, keep: int):
    tags = sorted(t for t in os.listdir(directory) if t.startswith("step_")
                  and not t.endswith(".tmp"))
    for t in tags[:-keep]:
        shutil.rmtree(os.path.join(directory, t), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        return int(f.read().strip().split("_")[1])


def restore(directory: str, like: Pytree, step: Optional[int] = None):
    """Returns (state, extras, step) with leaves shaped/dtyped like ``like``
    (and device_put with matching shardings when leaves carry them)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    tag = f"step_{step:08d}"
    path = os.path.join(directory, tag)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_00000.npz"))
    import ml_dtypes
    arrays = []
    for i, leaf in enumerate(manifest["leaves"]):
        raw = data[f"a{i}"]
        dt = np.dtype(getattr(ml_dtypes, leaf["dtype"], None)
                      or leaf["dtype"])
        arrays.append(np.frombuffer(raw.tobytes(), dt).reshape(leaf["shape"]))

    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(arrays) == len(leaves_like), "checkpoint/state mismatch"
    out = []
    for arr, ref in zip(arrays, leaves_like):
        a = jnp.asarray(arr, dtype=getattr(ref, "dtype", None))
        sh = getattr(ref, "sharding", None)
        if sh is not None and hasattr(sh, "mesh"):
            a = jax.device_put(a, sh)
        out.append(a)
    state = jax.tree_util.tree_unflatten(treedef, out)
    with open(os.path.join(path, "extras.json")) as f:
        extras = json.load(f)
    return state, extras, step
