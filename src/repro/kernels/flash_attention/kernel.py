"""Pallas TPU flash attention (forward) with GQA-native K/V indexing.

TPU adaptation of the paper's offload-kernel layer: HBM→VMEM streaming
with online softmax, MXU-aligned tiles, and *block skipping* for causal
and sliding-window masks (the XLA fallback computes masked rectangles;
this kernel doesn't — see models/attention.py docstring).

Grid: (B·H, nq, nk) with the kv dim 'arbitrary' (sequential) so the
running (m, l, acc) state lives in VMEM scratch across kv steps.
K/V BlockSpecs index the *shared* kv head directly (kv_head = h // G),
so GQA streams each K/V tile once per query-head group, not H times.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.utils import pallas_tpu_compiler_params

NEG_INF = -1e30
LANES = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: Optional[int],
               q_offset: int, kv_len: int, softcap: Optional[float],
               q_chunk: int, kv_chunk: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = q_offset + qi * q_chunk            # first q position of block
    k_lo = ki * kv_chunk

    # block-level skip: entirely-masked tiles do no work
    needed = (k_lo < kv_len)
    if causal:
        needed &= k_lo <= q_lo + q_chunk - 1
    if window is not None:
        needed &= k_lo + kv_chunk - 1 > q_lo - window

    @pl.when(needed)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale     # [qc, hd]
        k = k_ref[...].astype(jnp.float32)             # [kc, hd]
        v = v_ref[...].astype(jnp.float32)             # [kc, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                          # [qc, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)                 # [qc, 1]
        l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-20)
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,                 # [B, Sq, H, hd]
    k: jax.Array,                 # [B, Sk, KV, hd]
    v: jax.Array,                 # [B, Sk, KV, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_len: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    q_chunk: int = 256,
    kv_chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if kv_len is None:
        kv_len = Sk
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0, (Sq, q_chunk, Sk, kv_chunk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk

    # [B, S, H, hd] → [B*H, S, hd]; K/V stay at KV heads (GQA-native)
    qr = jnp.transpose(q, (0, 2, 1, 3)).reshape(B * H, Sq, hd)
    kr = jnp.transpose(k, (0, 2, 1, 3)).reshape(B * KV, Sk, hd)
    vr = jnp.transpose(v, (0, 2, 1, 3)).reshape(B * KV, Sk, hd)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b = bh // H
        kvh = (bh % H) // G
        return (b * KV + kvh, ki, 0)

    kernel = functools.partial(
        _fa_kernel, scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        q_offset=q_offset, kv_len=kv_len, softcap=logit_softcap,
        q_chunk=q_chunk, kv_chunk=kv_chunk, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, q_chunk, hd), q_map),
            pl.BlockSpec((None, kv_chunk, hd), kv_map),
            pl.BlockSpec((None, kv_chunk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((None, q_chunk, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_chunk, LANES), jnp.float32),   # running max
            pltpu.VMEM((q_chunk, LANES), jnp.float32),   # running denom
            pltpu.VMEM((q_chunk, hd), jnp.float32),      # output acc
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention_fwd",
    )(qr, kr, vr)
    return jnp.transpose(out.reshape(B, H, Sq, hd), (0, 2, 1, 3))
