"""Jitted dispatch wrapper: Pallas kernel on TPU, interpret-mode on CPU
(validation), with the blockwise-XLA path as the production fallback."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref  # noqa: F401 (re-export)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "kv_len", "logit_softcap",
    "q_chunk", "kv_chunk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    kv_len: Optional[int] = None,
                    logit_softcap: Optional[float] = None,
                    q_chunk: int = 256, kv_chunk: int = 256,
                    interpret: bool = False):
    """Flash attention forward. On non-TPU backends, ``interpret=True``
    runs the kernel body in the Pallas interpreter for validation."""
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_len=kv_len, logit_softcap=logit_softcap,
        q_chunk=q_chunk, kv_chunk=kv_chunk,
        interpret=interpret or not _on_tpu())


__all__ = ["flash_attention", "attention_ref"]
