"""Pure-jnp oracle for the flash attention kernel: naive dense attention
with explicit masking (materializes the full score matrix — small shapes
only)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,                # [B, Sq, H, hd]
    k: jax.Array,                # [B, Sk, KV, hd]
    v: jax.Array,                # [B, Sk, KV, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_len: Optional[int] = None,
    logit_softcap: Optional[float] = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    k = jnp.repeat(k, H // KV, axis=2)
    v = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None], p, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
