"""Pure-jnp oracle for the SSD scan kernel — per-head chunked SSD,
identical math to repro.models.ssm.ssd_chunked but head-major layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dA, Bm, Cm, chunk: int, initial_state=None):
    """Head-major SSD.

    x:  [BH, S, P]   (pre-scaled by dt)
    dA: [BH, S]      log-decay per step (negative)
    Bm: [BH, S, N]
    Cm: [BH, S, N]
    Returns (y [BH, S, P], final_state [BH, P, N]).
    """
    BH, S, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    f32 = jnp.float32

    xc = x.reshape(BH, nc, Q, P).astype(f32)
    dAc = dA.reshape(BH, nc, Q).astype(f32)
    Bc = Bm.reshape(BH, nc, Q, N).astype(f32)
    Cc = Cm.reshape(BH, nc, Q, N).astype(f32)

    cs = jnp.cumsum(dAc, axis=2)                           # [BH,nc,Q]
    diff = cs[..., :, None] - cs[..., None, :]
    L = jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc) * L
    y_diag = jnp.einsum("bcqk,bckp->bcqp", scores, xc)

    decay_states = jnp.exp(cs[..., -1:] - cs)              # [BH,nc,Q]
    states = jnp.einsum("bcqn,bcq,bcqp->bcpn", Bc, decay_states, xc)

    chunk_decay = jnp.exp(cs[..., -1])                     # [BH,nc]
    h0 = (initial_state.astype(f32) if initial_state is not None
          else jnp.zeros((BH, P, N), f32))

    def step(h, inp):
        dec, st = inp
        return h * dec[:, None, None] + st, h

    final, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(states, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                    # [BH,nc,P,N]

    y_off = jnp.einsum("bcqn,bcpn,bcq->bcqp", Cc, h_prev, jnp.exp(cs))
    y = (y_diag + y_off).reshape(BH, S, P)
    return y.astype(x.dtype), final
