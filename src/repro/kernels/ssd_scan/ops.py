"""Jitted dispatch wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref  # noqa: F401 (re-export)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dA, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """Returns (y, final_state) for the head-major SSD recurrence."""
    return ssd_scan(x, dA, Bm, Cm, chunk=chunk,
                    interpret=interpret or not _on_tpu())


__all__ = ["ssd", "ssd_ref"]
