"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (B·H, n_chunks) with the chunk dim 'arbitrary' (sequential); the
inter-chunk SSM state [P, N] lives in VMEM scratch across chunk steps —
the recurrence never round-trips HBM, which is the TPU-native version of
the paper's "keep the hot loop on-device" offloading principle.

Per chunk the kernel does four small MXU matmuls (Q×N·N×Q, Q×Q·Q×P,
N×Q·Q×P, Q×N·N×P) and VPU cumsum/exp — chunk length and state width are
chosen MXU-aligned (Q, N, P multiples of 64/128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.utils import pallas_tpu_compiler_params


def _ssd_kernel(x_ref, dA_ref, b_ref, c_ref, y_ref, fin_ref, state_ref, *,
                n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)          # [Q, P]
    dA = dA_ref[...].astype(jnp.float32)        # [Q, 1] (lane-padded)
    Bm = b_ref[...].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[...].astype(jnp.float32)         # [Q, N]
    Q = x.shape[0]

    cs = jnp.cumsum(dA[:, 0])                   # [Q]
    # intra-chunk decay matrix L[i,j] = exp(cs_i - cs_j) for i >= j
    diff = cs[:, None] - cs[None, :]
    tril = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    L = jnp.where(tril, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    decay_out = jnp.exp(cs)[:, None]            # [Q, 1]
    y += jax.lax.dot_general(Cm * decay_out, state_ref[...],
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: h = h * exp(sum dA) + Σ_j exp(cs_Q - cs_j) B_j ⊗ x_j
    decay_states = jnp.exp(cs[-1] - cs)[:, None]     # [Q, 1]
    new_state = jax.lax.dot_general(x, Bm * decay_states,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    state_ref[...] = state_ref[...] * jnp.exp(cs[-1]) + new_state

    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_final():
        fin_ref[...] = state_ref[...]


def ssd_scan(x, dA, Bm, Cm, chunk: int = 128, interpret: bool = False):
    """Head-major SSD scan.

    x: [BH, S, P]; dA: [BH, S]; Bm/Cm: [BH, S, N]
    Returns (y [BH, S, P], final_state [BH, P, N]).
    """
    BH, S, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    dA2 = dA[..., None]                         # [BH, S, 1]

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    y, fin = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((None, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, Q, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, Q, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, P, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="ssd_scan",
    )(x, dA2, Bm, Cm)
    return y, fin
