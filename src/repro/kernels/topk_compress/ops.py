"""Jitted wrappers + the full compress/decompress pipeline used by the
cross-pod gradient reducer (repro.distributed.compression)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topk_compress.kernel import topk_pack
from repro.kernels.topk_compress.ref import topk_pack_ref, unpack_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k_per_block", "block",
                                             "interpret", "use_kernel"))
def compress(x, *, k_per_block: int, block: int = 1024,
             interpret: bool = False, use_kernel: bool = True):
    """→ (values [nb,k], idx [nb,k], residual [n], content_bytes scalar).

    ``content_bytes`` is the cl_pocl_content_size analogue: the number of
    meaningful payload bytes a migration of this buffer must move.
    """
    if use_kernel and (_on_tpu() or interpret):
        vals, idx, resid = topk_pack(x, k_per_block, block,
                                     interpret=interpret or not _on_tpu())
    else:
        vals, idx = topk_pack_ref(x, k_per_block, block)
        resid = x - unpack_ref(vals, idx, block, x.shape[0])
    content = jnp.int32(vals.size * vals.dtype.itemsize
                        + idx.size * idx.dtype.itemsize)
    return vals, idx, resid, content


@functools.partial(jax.jit, static_argnames=("block", "n"))
def decompress(vals, idx, *, block: int, n: int):
    return unpack_ref(vals, idx, block, n)


__all__ = ["compress", "decompress", "topk_pack", "topk_pack_ref",
           "unpack_ref"]
