"""Pure-jnp oracle for block-local top-k compression packing."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_pack_ref(x: jax.Array, k_per_block: int, block: int):
    """x: [n] (n % block == 0) → (values [nb, k], local_idx [nb, k] int32).

    Per block of ``block`` elements, select the k largest |x| (ties by
    lower index, matching lax.top_k) and return values + block-local
    indices.
    """
    n = x.shape[0]
    nb = n // block
    xb = x.reshape(nb, block)
    mag = jnp.abs(xb)
    _, idx = jax.lax.top_k(mag, k_per_block)        # [nb, k]
    vals = jnp.take_along_axis(xb, idx, axis=1)
    return vals, idx.astype(jnp.int32)


def unpack_ref(vals: jax.Array, idx: jax.Array, block: int, n: int):
    """Inverse of topk_pack_ref: scatter into a dense [n] array."""
    nb, k = vals.shape
    out = jnp.zeros((nb, block), vals.dtype)
    out = out.at[jnp.arange(nb)[:, None], idx].set(vals)
    return out.reshape(n)
