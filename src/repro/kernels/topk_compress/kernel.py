"""Pallas TPU kernel: block-local top-k gradient compression packing.

This is the paper's ``cl_pocl_content_size`` insight (§5.3) applied to
the slow cross-pod link: a gradient buffer is allocated at full size, but
only the packed (values, indices) prefix — the "content size" — crosses
the wire. The kernel packs each VMEM-resident block with an iterative
argmax (k ≪ block, so k VPU max-reduction sweeps beat a full sort), and
the error-feedback residual (x − unpack(pack(x))) is emitted in the same
pass so the caller never re-reads the dense buffer.

Grid: (n_blocks,) fully parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils import pallas_tpu_compiler_params


def _topk_kernel(x_ref, vals_ref, idx_ref, resid_ref, *, k: int):
    x = x_ref[...]                                  # [1, block]
    block = x.shape[-1]
    mag = jnp.abs(x).astype(jnp.float32)
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)

    def body(j, carry):
        mag_c, resid = carry
        m = jnp.max(mag_c, axis=-1, keepdims=True)           # [1,1]
        # argmax with lowest-index tie-break (matches lax.top_k)
        is_max = mag_c == m
        big = jnp.where(is_max, pos, block)
        sel = jnp.min(big, axis=-1, keepdims=True)           # [1,1]
        hit = pos == sel
        val = jnp.sum(jnp.where(hit, x, 0.0), axis=-1)       # [1]
        vals_ref[:, j] = val.astype(vals_ref.dtype)
        idx_ref[:, j] = sel[:, 0]
        resid = jnp.where(hit, 0.0, resid)
        mag_c = jnp.where(hit, -1.0, mag_c)
        return mag_c, resid

    _, resid = jax.lax.fori_loop(0, k, body,
                                 (mag, x.astype(jnp.float32)))
    resid_ref[...] = resid.astype(resid_ref.dtype)


def topk_pack(x: jax.Array, k_per_block: int, block: int = 1024,
              interpret: bool = False):
    """x: [n] → (values [nb,k], idx [nb,k] int32, residual [n])."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    nb = n // block
    xb = x.reshape(nb, block)

    kernel = functools.partial(_topk_kernel, k=k_per_block)
    vals, idx, resid = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda b: (b, 0))],
        out_specs=[
            pl.BlockSpec((1, k_per_block), lambda b: (b, 0)),
            pl.BlockSpec((1, k_per_block), lambda b: (b, 0)),
            pl.BlockSpec((1, block), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, k_per_block), x.dtype),
            jax.ShapeDtypeStruct((nb, k_per_block), jnp.int32),
            jax.ShapeDtypeStruct((nb, block), x.dtype),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="topk_pack",
    )(xb)
    return vals, idx, resid.reshape(n)
