from repro.models.config import LayerKind, ModelConfig, MoEConfig, SSMConfig  # noqa: F401
from repro.models import lm  # noqa: F401
