"""Residual block application for every layer kind (attn/ssm × mlp/moe,
sequential or parallel residual, optional sandwich norms, cross-attn)."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import kv_pad, shard_act
from repro.utils import storage_barrier
from repro.models import attention as attn_lib
from repro.models.config import LayerKind, ModelConfig
from repro.models.moe import moe_mlp
from repro.models.nn import apply_rope, relu2, rms_norm, swiglu
from repro.models.ssm import init_ssm_cache, mamba_mixer

CACHE_AXES = ("batch", "kv_seq", "kv_heads", None)


class AttnCache(NamedTuple):
    k: jax.Array   # [B, max_len, KV*kv_pad, hd]
    v: jax.Array


class XAttnCache(NamedTuple):
    k: jax.Array   # [B, enc_len, KV, hd]
    v: jax.Array


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> AttnCache:
    r = kv_pad(cfg.n_heads, cfg.n_kv)
    shape = (batch, max_len, cfg.n_kv * r, cfg.hd)
    z = shard_act(jnp.zeros(shape, dtype), CACHE_AXES)
    return AttnCache(z, z)


def _norm(x, p, cfg):
    return rms_norm(x, p, cfg.norm_eps, plus_one=cfg.norm_plus_one)


def cast_params(p, dtype):
    """Mixed precision: cast fp32 weights to the compute dtype at use-site
    (inside remat, so the bf16 copies are rematerialized, not saved)."""
    def f(a):
        if hasattr(a, "dtype") and a.dtype == jnp.float32:
            return a.astype(dtype)
        return a
    return storage_barrier(jax.tree.map(f, p))


def attention_mixer(
    p: dict,
    x: jax.Array,                      # [B, S, d]
    cfg: ModelConfig,
    kind: LayerKind,
    positions: jax.Array,              # rope positions for this slice
    cache: Optional[AttnCache] = None,
    pos=None,                          # scalar write offset into the cache
):
    B, S, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    theta = cfg.rope_theta if kind.global_rope else (cfg.rope_theta_local or cfg.rope_theta)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)

    cap = cfg.attn_logit_softcap
    if cache is None:
        out = attn_lib.attention(q, k, v, causal=kind.causal, window=kind.window,
                                 logit_softcap=cap)
        new_cache = None
    else:
        r = cache.k.shape[2] // cfg.n_kv   # kv_rep padding factor
        if r > 1:
            k = jnp.repeat(k, r, axis=2)
            v = jnp.repeat(v, r, axis=2)
        new_k = shard_act(jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), pos, axis=1), CACHE_AXES)
        new_v = shard_act(jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), pos, axis=1), CACHE_AXES)
        new_cache = AttnCache(new_k, new_v)
        if S == 1:
            out = attn_lib.decode_attention(q, new_k, new_v, pos,
                                            window=kind.window, logit_softcap=cap)
        else:  # chunked prefill
            out = attn_lib.attention(q, new_k, new_v, causal=True,
                                     window=kind.window, q_offset=pos,
                                     kv_len=pos + S, logit_softcap=cap)
    out = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]
    return out, new_cache


def cross_attention_mixer(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    enc_out: Optional[jax.Array] = None,    # [B, S_enc, d] (training)
    cache: Optional[XAttnCache] = None,     # precomputed cross K/V (serving)
):
    B, S, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    if cache is not None:
        k, v = cache.k, cache.v
    else:
        k = (enc_out @ p["wk"]).reshape(B, -1, cfg.n_kv, hd)
        v = (enc_out @ p["wv"]).reshape(B, -1, cfg.n_kv, hd)
    out = attn_lib.attention(q, k, v, causal=False)
    return out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]


def build_xattn_cache(p: dict, cfg: ModelConfig, enc_out: jax.Array) -> XAttnCache:
    B = enc_out.shape[0]
    k = (enc_out @ p["wk"]).reshape(B, -1, cfg.n_kv, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(B, -1, cfg.n_kv, cfg.hd)
    return XAttnCache(k, v)


def mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig, kind: LayerKind):
    """Returns (y, aux_loss)."""
    if kind.mlp == "swiglu":
        h = shard_act(swiglu(x @ p["wg"], x @ p["wu"]),
                      ("batch", None, "act_mlp"))
        return h @ p["wd"], jnp.float32(0)
    if kind.mlp == "relu2":
        h = shard_act(relu2(x @ p["wu"]), ("batch", None, "act_mlp"))
        return h @ p["wd"], jnp.float32(0)
    if kind.mlp == "gelu":
        h = shard_act(jax.nn.gelu(x @ p["wu"]), ("batch", None, "act_mlp"))
        return h @ p["wd"], jnp.float32(0)
    if kind.mlp == "moe":
        out = moe_mlp(p, x, cfg)
        return out.y, out.aux_loss
    raise ValueError(kind.mlp)


def apply_block(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    kind: LayerKind,
    positions: jax.Array,
    cache: Optional[dict] = None,
    pos=None,
    enc_out: Optional[jax.Array] = None,
):
    """One residual block. Returns (x, new_cache_or_None, aux_loss)."""
    p = cast_params(p, x.dtype)
    x = shard_act(x, ("batch", None, None))
    aux = jnp.float32(0)
    h = _norm(x, p["ln1"], cfg)

    if kind.mixer == "ssm":
        mix, new_mixer_cache = mamba_mixer(
            p["ssm"], h, cfg, cache["ssm"] if cache is not None else None)
        cache_key = "ssm"
    else:
        mix, new_mixer_cache = attention_mixer(
            p["attn"], h, cfg, kind, positions,
            cache["attn"] if cache is not None else None, pos)
        cache_key = "attn"

    if cfg.sandwich_norm:
        mix = _norm(mix, p["ln1_post"], cfg)

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache[cache_key] = new_mixer_cache

    if cfg.parallel_block and "mlp" in p:
        mlp_out, aux = mlp_apply(p["mlp"], h, cfg, kind)
        x = x + mix + mlp_out
        return x, new_cache, aux

    x = x + mix

    if cfg.cross_attention and "xattn" in p:
        hx = _norm(x, p["ln_x"], cfg)
        xout = cross_attention_mixer(
            p["xattn"], hx, cfg, enc_out=enc_out,
            cache=cache.get("xattn") if cache is not None else None)
        x = x + xout

    if "mlp" in p:
        h2 = _norm(x, p["ln2"], cfg)
        mlp_out, aux = mlp_apply(p["mlp"], h2, cfg, kind)
        if cfg.sandwich_norm:
            mlp_out = _norm(mlp_out, p["ln2_post"], cfg)
        x = x + mlp_out
    return x, new_cache, aux


def init_block_cache(cfg: ModelConfig, kind: LayerKind, batch: int,
                     max_len: int, dtype, enc_len: int = 0) -> dict:
    c: dict = {}
    if kind.mixer == "ssm":
        c["ssm"] = init_ssm_cache(cfg, batch, dtype)
    else:
        c["attn"] = init_attn_cache(cfg, batch, max_len, dtype)
    if cfg.cross_attention and enc_len:
        shape = (batch, enc_len, cfg.n_kv, cfg.hd)
        c["xattn"] = XAttnCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    return c
