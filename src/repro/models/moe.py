"""Mixture-of-Experts with capacity-bounded scatter dispatch.

Dispatch avoids the GShard-style ``[tokens, experts, capacity]`` one-hot
tensor (which is O(S²) memory per row at long sequence lengths): instead
each token computes its slot index ``expert*C + position_in_expert`` via a
cumsum over the routing one-hot, and a scatter-add packs tokens into the
``[E, C, d]`` expert input buffer. Dropped tokens (over capacity) land in
a discard slot. Memory is O(top_k · capacity_factor) × token bytes.

Routing groups are the leading dim: train/prefill routes per sequence row
(fully local under batch sharding — no collectives in dispatch); decode
reshapes [B,1,d] → [1,B,d] to route across the batch.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_act
from repro.models.config import ModelConfig
from repro.models.nn import swiglu


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array     # load-balancing loss (scalar, fp32)
    dropped_frac: jax.Array  # fraction of assignments dropped (scalar)


def _dispatch_group(x, slot, n_slots):
    """x: [S, d]; slot: [S, k] int32 → buf [n_slots, d] via scatter-add."""
    S, d = x.shape
    k = slot.shape[1]
    flat_slot = slot.reshape(S * k)
    vals = jnp.repeat(x, k, axis=0)  # [S*k, d] (token repeated per assignment)
    buf = jnp.zeros((n_slots, d), x.dtype)
    return buf.at[flat_slot].add(vals, mode="drop")


def moe_mlp(params: dict, x: jax.Array, cfg: ModelConfig) -> MoEOut:
    """x: [B, S, d] → MoEOut. Routing per row of the leading dim."""
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    B, S, d = x.shape

    decode = S == 1
    if decode:                      # route across the batch instead
        x = x.reshape(1, B, d)
        B, S = 1, B

    C = max(1, int(-(-S * k * m.capacity_factor // E)))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                      # [B,S,E]
    top_g, top_i = jax.lax.top_k(gates, k)                       # [B,S,k]
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    # position of each assignment within its expert (cumsum over the row)
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.int32)           # [B,S,k,E]
    per_tok = onehot.sum(2)                                      # [B,S,E]
    cum = jnp.cumsum(per_tok, axis=1)                            # [B,S,E]
    pos = jnp.take_along_axis(cum, top_i, axis=2) - 1            # [B,S,k]
    keep = pos < C
    slot = jnp.where(keep, top_i * C + pos, E * C)               # discard slot

    buf = jax.vmap(lambda xb, sb: _dispatch_group(xb, sb, E * C + 1))(x, slot)
    # the scatter obscures sharding from GSPMD: without these constraints
    # the dispatch buffers replicate across 'data' (observed directly in
    # the dry-run HLO as [E, f/16, B_global, C] per-device tensors)
    buf = shard_act(buf, ("batch", None, None))
    expert_in = shard_act(buf[:, : E * C].reshape(B, E, C, d),
                          ("batch", None, None, None))

    h = swiglu(
        jnp.einsum("becd,edf->becf", expert_in, params["wg"]),
        jnp.einsum("becd,edf->becf", expert_in, params["wu"]),
    )
    h = shard_act(h, ("batch", None, None, "act_mlp"))
    expert_out = shard_act(jnp.einsum("becf,efd->becd", h, params["wd"]),
                           ("batch", None, None, None))
    flat_out = shard_act(expert_out.reshape(B, E * C, d),
                         ("batch", None, None))
    flat_out = jnp.concatenate(
        [flat_out, jnp.zeros((B, 1, d), flat_out.dtype)], axis=1)

    gathered = jnp.take_along_axis(
        flat_out[:, None], slot[..., None], axis=2)              # [B,S,k,d] via broadcast
    # take_along_axis broadcast: flat_out[:,None] is [B,1,EC+1,d]; slot[...,None]
    # is [B,S,k,1] → gathers along axis=2
    y = (gathered * (top_g * keep)[..., None].astype(gathered.dtype)).sum(2)

    if m.shared_expert:
        y = y + jnp.einsum(
            "bsf,fd->bsd",
            swiglu(jnp.einsum("bsd,df->bsf", x, params["shared_wg"]),
                   jnp.einsum("bsd,df->bsf", x, params["shared_wu"])),
            params["shared_wd"])

    # Switch-style load-balancing auxiliary loss
    importance = gates.mean(axis=(0, 1))                         # [E]
    load = (per_tok.astype(jnp.float32) / k).mean(axis=(0, 1))   # [E]
    aux = E * jnp.sum(importance * load)
    dropped = 1.0 - keep.mean().astype(jnp.float32)

    if decode:
        y = y.reshape(-1, 1, d)
    return MoEOut(y, aux, dropped)
