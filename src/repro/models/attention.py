"""Memory-efficient blockwise attention with a flash-style custom VJP.

This is the lowering used on CPU and in the multi-pod dry-run; on TPU the
Pallas flash kernel (``repro.kernels.flash_attention``) replaces the
inner blocks, and this module doubles as its numerical oracle.

Design notes
------------
* Forward: online-softmax over KV chunks inside ``lax.scan`` → peak
  memory O(q_chunk × kv_chunk); only the output O and the row-wise
  logsumexp L are saved for backward.
* Backward: flash-attention recomputation — P is rebuilt per block from
  (q, k, L); dQ/dK/dV accumulate blockwise. Without this, scan-VJP stacks
  every fp32 P block ([nq, nkv, B, H, qc, kc] ≈ 2 GB/layer at 4k) and the
  dry-run showed it dominating HBM traffic 10× over everything else.
* K/V are repeated to the full query-head count up front. Under tensor
  parallelism all head-indexed tensors then shard cleanly on the 'model'
  axis (the repeat is sharded too, so per-device memory is unchanged);
  this mirrors Megatron's KV-head replication for TP > n_kv.
* Sliding-window attention only *visits* the KV chunks inside the window
  (plus one boundary chunk), so local-attention layers (gemma3) pay
  O(S·W) FLOPs, not O(S²).
* Causal full attention visits all chunks up to the query block and masks
  the rest; the FLOP overshoot is bounded by 2× of the attention term,
  <3 % of total step FLOPs for every assigned cell. The Pallas kernel
  skips fully-masked blocks.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_act
from repro.utils import grad_cast, vma_like

NEG_INF = -1e30


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """[B, S, KV, hd] → [B, S, H, hd] by repeating each kv head."""
    kv = k.shape[2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=2)


def _block_mask(q_pos, k_pos, kv_len, causal: bool, window):
    """mask [B, qc, kc] (True = attend)."""
    m = (k_pos[None, None, :] < kv_len[:, None, None])
    if causal:
        m = m & (q_pos[:, None] >= k_pos[None, :])[None]
    if window is not None:
        m = m & (q_pos[:, None] - k_pos[None, :] < window)[None]
    return m


def _apply_softcap(s, cap):
    return cap * jnp.tanh(s / cap) if cap is not None else s


def _softcap_grad(s_raw, cap):
    if cap is None:
        return 1.0
    t = jnp.tanh(s_raw / cap)
    return 1.0 - jnp.square(t)


def _n_inner(Skp, kv_chunk, q_chunk, window):
    if window is not None:
        return min(-(-(window + q_chunk) // kv_chunk) + 1, Skp // kv_chunk)
    return Skp // kv_chunk


def _lo_chunk(q_pos0, kv_chunk, window):
    if window is None:
        return jnp.array(0, jnp.int32)
    return (jnp.maximum(q_pos0 - window + 1, 0) // kv_chunk).astype(jnp.int32)


def _flash_fwd(q, k, v, kv_len, *, causal, window, q_offset, softcap,
               q_chunk, kv_chunk):
    """Returns (out [B,Sq,H,hd], lse [B,H,Sq]) — padded inputs required."""
    B, Sqp, H, hd = q.shape
    Skp = k.shape[1]
    nq = Sqp // q_chunk
    scale = 1.0 / math.sqrt(hd)
    n_inner = _n_inner(Skp, kv_chunk, q_chunk, window)

    qs = jnp.moveaxis(q.reshape(B, nq, q_chunk, H, hd), 1, 0)

    def q_block(qi, qb):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        qb = qb * jnp.asarray(scale, qb.dtype)
        lo = _lo_chunk(q_pos[0], kv_chunk, window)

        m0 = vma_like(jnp.full((B, H, q_chunk), NEG_INF, jnp.float32), qb)
        l0 = vma_like(jnp.zeros((B, H, q_chunk), jnp.float32), qb)
        a0 = vma_like(jnp.zeros((B, H, q_chunk, hd), jnp.float32), qb)

        def kv_step(carry, j):
            m, lsum, acc = carry
            ki = lo + j
            start = jnp.clip(ki * kv_chunk, 0, Skp - kv_chunk)
            kb = jax.lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)

            s = jnp.einsum("bqhd,bchd->bhqc", qb, kb,
                           preferred_element_type=jnp.float32)
            s = _apply_softcap(s, softcap)
            mask = _block_mask(q_pos, k_pos, kv_len, causal, window)
            s = jnp.where(mask[:, None], s, NEG_INF)

            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[:, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = lsum * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqc,bchd->bhqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, lsum, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(n_inner, dtype=jnp.int32))
        out = (acc / jnp.maximum(lsum, 1e-20)[..., None]) \
            .astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(lsum, 1e-20))     # [B,H,qc]
        return jnp.transpose(out, (0, 2, 1, 3)), lse

    outs, lses = jax.lax.map(lambda a: q_block(*a),
                             (jnp.arange(nq, dtype=jnp.int32), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sqp, H, hd)
    lse = jnp.moveaxis(lses, 0, 2).reshape(B, H, Sqp)   # [nq,B,H,qc] → [B,H,S]
    return out, lse


def _flash_bwd(q, k, v, kv_len, out, lse, dout, *, causal, window, q_offset,
               softcap, q_chunk, kv_chunk):
    """Flash backward: recompute P per block. Returns (dq, dk, dv)."""
    B, Sqp, H, hd = q.shape
    Skp = k.shape[1]
    nq = Sqp // q_chunk
    scale = 1.0 / math.sqrt(hd)
    n_inner = _n_inner(Skp, kv_chunk, q_chunk, window)

    # D_i = rowsum(dO * O)  [B,H,Sq]
    delta = jnp.einsum("bqhd,bqhd->bhq", dout.astype(jnp.float32),
                       out.astype(jnp.float32))

    qs = jnp.moveaxis(q.reshape(B, nq, q_chunk, H, hd), 1, 0)
    dos = jnp.moveaxis(dout.reshape(B, nq, q_chunk, H, hd), 1, 0)
    lses = jnp.moveaxis(lse.reshape(B, H, nq, q_chunk), 2, 0)
    deltas = jnp.moveaxis(delta.reshape(B, H, nq, q_chunk), 2, 0)

    dk0 = vma_like(jnp.zeros((B, Skp, H, hd), jnp.float32), k)
    dv0 = vma_like(jnp.zeros((B, Skp, H, hd), jnp.float32), k)

    def q_block(carry, xs):
        dk_acc, dv_acc = carry
        qi, qb, dob, lseb, delb = xs
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        qb_s = qb * jnp.asarray(scale, qb.dtype)
        lo = _lo_chunk(q_pos[0], kv_chunk, window)
        dob = dob.astype(jnp.float32)

        dq0 = vma_like(jnp.zeros((B, q_chunk, H, hd), jnp.float32), qb)

        def kv_step(carry2, j):
            dq, dk_acc, dv_acc = carry2
            ki = lo + j
            start = jnp.clip(ki * kv_chunk, 0, Skp - kv_chunk)
            kb = jax.lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)

            s_raw = jnp.einsum("bqhd,bchd->bhqc", qb_s, kb,
                               preferred_element_type=jnp.float32)
            s = _apply_softcap(s_raw, softcap)
            mask = _block_mask(q_pos, k_pos, kv_len, causal, window)
            s = jnp.where(mask[:, None], s, NEG_INF)
            p = jnp.exp(s - lseb[..., None])                  # [B,H,qc,kc]
            p = jnp.where(mask[:, None], p, 0.0)

            dv_blk = jnp.einsum("bhqc,bqhd->bchd", p, dob)
            dp = jnp.einsum("bqhd,bchd->bhqc", dob,
                            vb.astype(jnp.float32))
            ds = p * (dp - delb[..., None])
            ds = ds * _softcap_grad(s_raw, softcap) * scale

            dq = dq + jnp.einsum("bhqc,bchd->bqhd", ds,
                                 kb.astype(jnp.float32))
            dk_blk = jnp.einsum("bhqc,bqhd->bchd", ds,
                                qb.astype(jnp.float32))
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(
                    dk_acc, start, kv_chunk, 1) + dk_blk, start, axis=1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(
                    dv_acc, start, kv_chunk, 1) + dv_blk, start, axis=1)
            return (dq, dk_acc, dv_acc), None

        (dq, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc),
            jnp.arange(n_inner, dtype=jnp.int32))
        return (dk_acc, dv_acc), dq

    (dk, dv), dqs = jax.lax.scan(
        q_block, (dk0, dv0),
        (jnp.arange(nq, dtype=jnp.int32), qs, dos, lses, deltas))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sqp, H, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _attention_padded(q, k, v, kv_len, *, causal, window, q_offset, softcap,
                      q_chunk, kv_chunk):
    """custom-vjp core on padded [B,S,H,hd] inputs."""
    kw = dict(causal=causal, window=window, q_offset=q_offset,
              softcap=softcap, q_chunk=q_chunk, kv_chunk=kv_chunk)

    @jax.custom_vjp
    def core(q, k, v, kv_len):
        out, _ = _flash_fwd(q, k, v, kv_len, **kw)
        return out

    def fwd(q, k, v, kv_len):
        out, lse = _flash_fwd(q, k, v, kv_len, **kw)
        return out, (q, k, v, kv_len, out, lse)

    def bwd(res, dout):
        q, k, v, kv_len, out, lse = res
        dq, dk, dv = _flash_bwd(q, k, v, kv_len, out, lse, dout, **kw)
        return dq, dk, dv, None

    core.defvjp(fwd, bwd)
    return core(q, k, v, kv_len)


def attention(
    q: jax.Array,                 # [B, Sq, H, hd]
    k: jax.Array,                 # [B, Sk, KV, hd]   (KV | H heads)
    v: jax.Array,                 # [B, Sk, KV, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,                   # global position of q[0] (chunked prefill)
    kv_len=None,                  # valid kv length (int or [B]); None → Sk
    logit_softcap: Optional[float] = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jax.Array:
    """Blockwise flash attention. Returns [B, Sq, H, hd]."""
    assert causal or window is None, \
        "sliding-window attention requires causal=True (a backward-only " \
        "window is ill-defined for bidirectional attention)"
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]

    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    q = grad_cast(shard_act(q, ("batch", None, "heads", None)))
    k = grad_cast(shard_act(k, ("batch", None, "heads", None)))
    v = grad_cast(shard_act(v, ("batch", None, "heads", None)))

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)

    if kv_len is None:
        kv_len = Sk
    kv_len = jnp.asarray(kv_len)
    if kv_len.ndim == 0:
        kv_len = jnp.broadcast_to(kv_len, (B,))

    qp = _pad_to(q, 1, q_chunk)
    kp = _pad_to(k, 1, kv_chunk)
    vp = _pad_to(v, 1, kv_chunk)

    out = _attention_padded(qp, kp, vp, kv_len, causal=causal, window=window,
                            q_offset=q_offset, softcap=logit_softcap,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    return out[:, :Sq]


def decode_attention(
    q: jax.Array,                 # [B, 1, H, hd]
    k_cache: jax.Array,           # [B, S, KV, hd]
    v_cache: jax.Array,           # [B, S, KV, hd]
    pos,                          # scalar or [B]: index of the current token
    *,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against the cache.

    With a sequence-sharded cache ('seq' layout) the softmax reductions
    over S become cross-device collectives — exactly the flash-decoding
    partial-softmax pattern, with XLA inserting the (tiny) combines.
    """
    B, S, KV, hd = k_cache.shape
    H = q.shape[2]
    scale = 1.0 / math.sqrt(hd)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (B,))

    kc = _expand_kv(k_cache, H)
    vc = _expand_kv(v_cache, H)
    qr = q.reshape(B, H, hd) * jnp.asarray(scale, q.dtype)
    s = jnp.einsum("bhd,bshd->bhs", qr, kc,
                   preferred_element_type=jnp.float32)
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)

    idx = jnp.arange(S)
    mask = idx[None, :] <= pos[:, None]
    if window is not None:
        mask = mask & (idx[None, :] > pos[:, None] - window)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p.astype(vc.dtype), vc)
    return out.reshape(B, 1, H, hd).astype(q.dtype)
