"""Primitive NN ops shared by all families: RMSNorm, RoPE, activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float, plus_one: bool = False) -> jax.Array:
    """RMSNorm in fp32 with cast back to input dtype."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    wf = w.astype(jnp.float32)
    scale = (1.0 + wf) if plus_one else wf  # gemma stores w as offset from 1
    return (y * scale).astype(dt)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return theta ** (-jnp.arange(half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, half-split convention.

    x: [..., S, H, hd]; positions: [S] or [B, S] int32.
    """
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [(B,)S, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over head dim: [..., S, 1, hd/2]
    cos = jnp.expand_dims(cos, -2)
    sin = jnp.expand_dims(sin, -2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def relu2(x: jax.Array) -> jax.Array:
    r = jax.nn.relu(x)
    return r * r


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)
