"""Top-level model: embedding → scanned block stack → norm → (un)embed.

One code path serves every assigned architecture family. Layers are
grouped into full pattern *cycles* executed under ``lax.scan`` (HLO size
independent of depth — essential for the 512-device dry-run) plus an
unrolled tail for depths not divisible by the pattern length.

Entry points
------------
``forward``      teacher-forced hidden states (training); loss is computed
                 chunked over the vocab in ``repro.training.step``.
``init_cache``   KV/SSM cache pytree for serving.
``prefill``      (optionally chunked) cache fill; returns last-token logits.
``decode_step``  single-token decode.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.blocks import (apply_block, build_xattn_cache,
                                 init_block_cache)
from repro.models.config import LayerKind, ModelConfig
from repro.models.nn import rms_norm
from repro.utils import storage_barrier, vma_like


class LMCache(NamedTuple):
    blocks: Optional[dict]
    tail: Optional[dict]
    pos: jax.Array                # scalar int32: tokens already in cache


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def embed_tokens(params: dict, cfg: ModelConfig, tokens: jax.Array,
                 dtype=jnp.bfloat16) -> jax.Array:
    x = storage_barrier(jnp.take(params["embed"], tokens, axis=0).astype(dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    return x


def unembed(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    table = params.get("lm_head", params["embed"])
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                      table.astype(jnp.float32))


def final_norm(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return rms_norm(x, params["final_norm"], cfg.norm_eps,
                    plus_one=cfg.norm_plus_one)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # 'full': save only inputs


# --------------------------------------------------------------------------
# block stack
# --------------------------------------------------------------------------

def run_stack(params: dict, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array, cache: Optional[LMCache] = None,
              pos=None, enc_out=None, remat: str = "full",
              remat_group: int = 1):
    """Returns (x, new_cache_or_None, aux_loss_sum).

    ``remat_group`` > 1 enables nested remat for deep models: the outer
    scan saves only every g-th cycle boundary ([n_cycles/g, ...] instead
    of [n_cycles, ...]); the inner g cycles recompute during backward.
    At nemotron-340b scale this is the difference between 27 GiB and
    ~3 GiB of saved residuals per device (one extra forward per group).
    """
    P = len(cfg.pattern)
    n_cycles, tail = cfg.cycles()
    aux = vma_like(jnp.float32(0), x)
    new_blocks = None
    new_tail = None

    if n_cycles > 0:
        def cycle(carry, xs):
            xc, auxc = carry
            cp, cc = xs
            new_cc = {}
            for i in range(P):
                kind = cfg.pattern[i]
                blk_cache = cc[f"p{i}"] if cc is not None else None
                xc, nc, a = apply_block(cp[f"p{i}"], xc, cfg, kind, positions,
                                        blk_cache, pos, enc_out)
                new_cc[f"p{i}"] = nc
            return (xc, auxc + a), (new_cc if cc is not None else None)

        cycle = _remat(cycle, remat)
        cache_blocks = cache.blocks if cache is not None else None
        g = remat_group if (cache is None and remat != "none") else 1
        if g > 1 and n_cycles % g == 0:
            n_outer = n_cycles // g
            gp = jax.tree.map(
                lambda a: a.reshape((n_outer, g) + a.shape[1:]),
                params["blocks"])

            def group_fn(carry, gxs):
                return jax.lax.scan(cycle, carry, (gxs, None))

            group_fn = _remat(group_fn, remat)
            (x, aux), _ = jax.lax.scan(group_fn, (x, aux), gp)
        else:
            (x, aux), new_blocks = jax.lax.scan(
                cycle, (x, aux), (params["blocks"], cache_blocks))

    if tail:
        kinds = cfg.layer_kinds()
        new_tail = {}
        for i in range(tail):
            kind = kinds[n_cycles * P + i]
            blk_cache = cache.tail[f"t{i}"] if cache is not None else None
            blk = _remat(
                lambda p_, x_, c_, k_=kind: apply_block(
                    p_, x_, cfg, k_, positions, c_, pos, enc_out), remat)
            x, nc, a = blk(params["tail"][f"t{i}"], x, blk_cache)
            new_tail[f"t{i}"] = nc
            aux = aux + a

    new_cache = None
    if cache is not None:
        new_cache = LMCache(new_blocks, new_tail, cache.pos)
    return x, new_cache, aux


def encode(params: dict, cfg: ModelConfig, enc_embeds: jax.Array,
           remat: str = "full") -> jax.Array:
    """Whisper-style encoder over precomputed (stub frontend) embeddings."""
    enc_kind = LayerKind(mixer="attn", mlp=cfg.pattern[0].mlp, causal=False)
    x = enc_embeds
    positions = jnp.arange(x.shape[1])

    def layer(carry, bp):
        xc, auxc = carry
        xc, _, a = apply_block(bp, xc, cfg, enc_kind, positions)
        return (xc, auxc + a), None

    layer = _remat(layer, remat)
    (x, _), _ = jax.lax.scan(layer, (x, vma_like(jnp.float32(0), x)),
                             params["encoder"]["blocks"])
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps,
                    plus_one=cfg.norm_plus_one)


# --------------------------------------------------------------------------
# training forward
# --------------------------------------------------------------------------

def forward(params: dict, cfg: ModelConfig, batch: dict,
            remat: str = "full", dtype=jnp.bfloat16, remat_group: int = 1):
    """Teacher-forced forward. Returns (hidden [B,S,d], aux_loss)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["enc_embeds"].astype(dtype), remat)
    if cfg.frontend is not None and "embeds" in batch:
        x = batch["embeds"].astype(dtype)
    else:
        x = embed_tokens(params, cfg, batch["tokens"], dtype)
    positions = jnp.arange(x.shape[1])
    x, _, aux = run_stack(params, cfg, x, positions, enc_out=enc_out,
                          remat=remat, remat_group=remat_group)
    return final_norm(params, cfg, x), aux


def full_logits(params: dict, cfg: ModelConfig, batch: dict,
                remat: str = "none", dtype=jnp.bfloat16) -> jax.Array:
    h, _ = forward(params, cfg, batch, remat=remat, dtype=dtype)
    return unembed(params, cfg, h)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, enc_len: int = 0) -> LMCache:
    P = len(cfg.pattern)
    n_cycles, tail = cfg.cycles()
    kinds = cfg.layer_kinds()
    blocks = None
    if n_cycles > 0:
        blocks = {}
        for i in range(P):
            c = init_block_cache(cfg, cfg.pattern[i], batch, max_len, dtype,
                                 enc_len)
            blocks[f"p{i}"] = jax.tree.map(
                lambda a: jnp.zeros((n_cycles,) + a.shape, a.dtype), c)
    tail_c = None
    if tail:
        tail_c = {f"t{i}": init_block_cache(cfg, kinds[n_cycles * P + i],
                                            batch, max_len, dtype, enc_len)
                  for i in range(tail)}
    return LMCache(blocks, tail_c, jnp.int32(0))


def _fill_xattn(params: dict, cfg: ModelConfig, cache: LMCache,
                enc_out: jax.Array) -> LMCache:
    """Precompute per-decoder-layer cross K/V into the cache."""
    def fill(_, bp):
        return None, build_xattn_cache(bp["xattn"], cfg, enc_out)

    blocks = dict(cache.blocks)
    _, stacked = jax.lax.scan(fill, None, params["blocks"]["p0"])
    blk = dict(blocks["p0"])
    blk["xattn"] = stacked
    blocks["p0"] = blk
    return LMCache(blocks, cache.tail, cache.pos)


def prefill(params: dict, cfg: ModelConfig, cache: LMCache,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            enc_embeds: Optional[jax.Array] = None,
            chunk: Optional[int] = None, dtype=jnp.bfloat16):
    """Fill the cache from position cache.pos. Returns (last_logits, cache)."""
    if cfg.is_encdec:
        enc_out = encode(params, cfg, enc_embeds.astype(dtype), remat="none")
        cache = _fill_xattn(params, cfg, cache, enc_out)

    x = embeds.astype(dtype) if embeds is not None else embed_tokens(
        params, cfg, tokens, dtype)
    B, S, _ = x.shape
    p0 = cache.pos

    if chunk is None or chunk >= S:
        positions = p0 + jnp.arange(S)
        h, cache, _ = run_stack(params, cfg, x, positions, cache, pos=p0,
                                remat="none")
        last = h[:, -1:]
    else:
        assert S % chunk == 0, f"prefill len {S} % chunk {chunk} != 0"
        nch = S // chunk

        def step(carry, i):
            cachec, _ = carry
            xc = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
            posc = p0 + i * chunk
            positions = posc + jnp.arange(chunk)
            h, cachec, _ = run_stack(params, cfg, xc, positions, cachec,
                                     pos=posc, remat="none")
            return (cachec, h[:, -1:]), None

        (cache, last), _ = jax.lax.scan(
            step, (cache, jnp.zeros((B, 1, cfg.d_model), dtype)),
            jnp.arange(nch, dtype=jnp.int32))

    logits = unembed(params, cfg, final_norm(params, cfg, last))[:, 0]
    cache = LMCache(cache.blocks, cache.tail, cache.pos + S)
    return logits, cache


def decode_step(params: dict, cfg: ModelConfig, cache: LMCache,
                token: jax.Array, dtype=jnp.bfloat16):
    """token: [B] int32 → (logits [B,V], new cache)."""
    pos = cache.pos
    x = embed_tokens(params, cfg, token[:, None], dtype)
    positions = pos + jnp.arange(1)
    h, cache, _ = run_stack(params, cfg, x, positions, cache, pos=pos,
                            remat="none")
    logits = unembed(params, cfg, final_norm(params, cfg, h))[:, 0]
    return logits, LMCache(cache.blocks, cache.tail, cache.pos + 1)
