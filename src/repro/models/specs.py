"""Parameter specifications: a single source of truth for shapes, logical
sharding axes and initializers.

Every materialization path derives from the same spec tree:
  * ``init_from_specs``      → real arrays (smoke tests, examples, training)
  * ``abstract_from_specs``  → ShapeDtypeStruct stand-ins (multi-pod dry-run)

Logical axis names (mapped to mesh axes by ``repro.distributed.sharding``):
  stack   scan-cycle dim                    → never sharded
  embed   d_model                           → 'data'   (FSDP / ZeRO-3)
  q       fused q/o head dim (H*hd)         → 'model'  (tensor parallel)
  kvh     fused kv head dim (n_kv*hd)       → 'model' when n_kv divisible
  mlp     d_ff                              → 'model'
  vocab   vocabulary                        → 'model'
  expert  MoE expert dim                    → None (E is small/odd)
  inner   SSM inner dim (expand*d_model)    → 'model'
  hssm    SSM head count                    → 'model' when divisible
  None    anything else                     → replicated
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import LayerKind, ModelConfig


class ParamSpec(NamedTuple):
    shape: tuple
    axes: tuple          # logical axis names, len == len(shape)
    init: str = "normal"  # normal|zeros|ones|ssm_a|ssm_dt|small
    scale: float = 1.0    # multiplier on the fan-in normal stddev


def _proj(d_in: int, d_out: int, ax_in, ax_out, scale: float = 1.0) -> ParamSpec:
    return ParamSpec((d_in, d_out), (ax_in, ax_out), "normal", scale)


def _norm(d: int, ax=None) -> ParamSpec:
    return ParamSpec((d,), (ax,), "ones")


# --------------------------------------------------------------------------
# per-block specs
# --------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    s = {
        "wq": _proj(d, cfg.n_heads * hd, "embed", "q"),
        "wk": _proj(d, cfg.n_kv * hd, "embed", "kvh"),
        "wv": _proj(d, cfg.n_kv * hd, "embed", "kvh"),
        "wo": _proj(cfg.n_heads * hd, d, "q", "embed"),
    }
    if cfg.qk_norm and not cross:
        s["q_norm"] = _norm(hd)
        s["k_norm"] = _norm(hd)
    return s


def mlp_specs(cfg: ModelConfig, kind: LayerKind) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if kind.mlp == "none":
        return {}
    if kind.mlp in ("relu2", "gelu"):
        return {"wu": _proj(d, f, "embed", "mlp"),
                "wd": _proj(f, d, "mlp", "embed")}
    if kind.mlp == "moe":
        m = cfg.moe
        E = m.n_experts
        s = {
            "router": ParamSpec((d, E), ("embed", None), "normal", 1.0),
            "wg": ParamSpec((E, d, f), ("expert", "embed", "mlp"), "normal", 1.0),
            "wu": ParamSpec((E, d, f), ("expert", "embed", "mlp"), "normal", 1.0),
            "wd": ParamSpec((E, f, d), ("expert", "mlp", "embed"), "normal", 1.0),
        }
        if m.shared_expert:
            s["shared_wg"] = _proj(d, f, "embed", "mlp")
            s["shared_wu"] = _proj(d, f, "embed", "mlp")
            s["shared_wd"] = _proj(f, d, "mlp", "embed")
        return s
    # swiglu
    return {"wg": _proj(d, f, "embed", "mlp"),
            "wu": _proj(d, f, "embed", "mlp"),
            "wd": _proj(f, d, "mlp", "embed")}


def ssm_specs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nheads = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    return {
        "wz": _proj(d, d_in, "embed", "inner"),
        "wx": _proj(d, d_in, "embed", "inner"),
        "wB": _proj(d, gn, "embed", None),
        "wC": _proj(d, gn, "embed", None),
        "wdt": _proj(d, nheads, "embed", "hssm"),
        "conv_x": ParamSpec((s.conv_width, d_in), (None, "inner"), "normal", 1.0),
        "conv_B": ParamSpec((s.conv_width, gn), (None, None), "normal", 1.0),
        "conv_C": ParamSpec((s.conv_width, gn), (None, None), "normal", 1.0),
        "A_log": ParamSpec((nheads,), ("hssm",), "ssm_a"),
        "dt_bias": ParamSpec((nheads,), ("hssm",), "ssm_dt"),
        "norm": ParamSpec((d_in,), ("inner",), "ones"),
        "wo": _proj(d_in, d, "inner", "embed"),
    }


def block_specs(cfg: ModelConfig, kind: LayerKind, cross_attention: bool = False) -> dict:
    """Specs for one transformer/ssm block (pre-norm residual)."""
    d = cfg.d_model
    s: dict[str, Any] = {"ln1": _norm(d)}
    if kind.mixer == "ssm":
        s["ssm"] = ssm_specs(cfg)
    else:
        s["attn"] = attn_specs(cfg)
    if cfg.sandwich_norm:
        s["ln1_post"] = _norm(d)
    mlp = mlp_specs(cfg, kind)
    if mlp:
        s["ln2"] = _norm(d)
        s["mlp"] = mlp
        if cfg.sandwich_norm:
            s["ln2_post"] = _norm(d)
    if cross_attention:
        s["ln_x"] = _norm(d)
        s["xattn"] = attn_specs(cfg, cross=True)
    return s


def _stack(tree, n: int):
    """Add a leading 'stack' dim of length n to every spec in the tree."""
    def f(p: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + p.shape, ("stack",) + p.axes, p.init, p.scale)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# --------------------------------------------------------------------------
# whole-model specs
# --------------------------------------------------------------------------

def model_param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    n_cycles, tail = cfg.cycles()
    kinds = cfg.layer_kinds()
    p = len(cfg.pattern)

    specs: dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), "normal", 1.0),
        "final_norm": _norm(d),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.vocab, d), ("vocab", "embed"), "normal", 1.0)

    cross = cfg.cross_attention
    if n_cycles > 0:
        specs["blocks"] = {
            f"p{i}": _stack(block_specs(cfg, cfg.pattern[i], cross), n_cycles)
            for i in range(p)
        }
    if tail:
        specs["tail"] = {
            f"t{i}": block_specs(cfg, kinds[n_cycles * p + i], cross)
            for i in range(tail)
        }
    if cfg.is_encdec:
        enc_kind = LayerKind(mixer="attn", mlp=cfg.pattern[0].mlp)
        specs["encoder"] = {
            "blocks": _stack(block_specs(cfg, enc_kind), cfg.encoder_layers),
            "final_norm": _norm(d),
        }
    return specs


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


# --------------------------------------------------------------------------
# materialization
# --------------------------------------------------------------------------

def _init_one(key, p: ParamSpec, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "ssm_a":
        # A = -exp(A_log); init A_log ~ log(U[1, 16])
        u = jax.random.uniform(key, p.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if p.init == "ssm_dt":
        # inverse-softplus of U[1e-3, 1e-1]
        dt = jax.random.uniform(key, p.shape, jnp.float32, 1e-3, 1e-1)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    # fan-in scaled normal over the second-to-last meaningful dim
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    std = p.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)


def init_from_specs(rng, specs, dtype=jnp.float32) -> dict:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_one(k, p, dtype) for k, p in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_from_specs(specs, dtype=jnp.float32, sharding_fn=None) -> dict:
    """ShapeDtypeStruct tree; ``sharding_fn(axes, shape) -> Sharding|None``."""
    def f(p: ParamSpec):
        sh = sharding_fn(p.axes, p.shape) if sharding_fn is not None else None
        if sh is not None:
            return jax.ShapeDtypeStruct(p.shape, dtype, sharding=sh)
        return jax.ShapeDtypeStruct(p.shape, dtype)
    return jax.tree.map(f, specs, is_leaf=is_spec)


def spec_param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(p.shape)) for p in leaves)
