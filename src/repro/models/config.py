"""Model configuration dataclasses + derived quantities (param counts, FLOPs).

A single ``ModelConfig`` describes every assigned architecture family:
dense / MoE / SSM / hybrid / enc-dec / VLM. Heterogeneous layer stacks
(gemma3's 5:1 local:global, jamba's 1:7 mamba:attn with alternating MoE)
are expressed as a repeating ``pattern`` of ``LayerKind``s; the model is
executed as ``lax.scan`` over full pattern cycles plus an unrolled tail,
which keeps the HLO size independent of depth.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class LayerKind:
    """Static description of one layer position inside the pattern."""
    mixer: str = "attn"          # 'attn' | 'ssm'
    window: Optional[int] = None  # sliding-window size; None = full causal
    mlp: str = "swiglu"          # 'swiglu' | 'relu2' | 'moe' | 'none'
    global_rope: bool = True      # use rope_theta (True) or rope_theta_local
    causal: bool = True           # False only for encoder stacks


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    shared_expert: bool = False   # llama4-style always-on shared expert
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    pattern: tuple = (LayerKind(),)
    head_dim: Optional[int] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # numerics / architectural variants
    norm_eps: float = 1e-5
    rope_theta: float = 1e4
    rope_theta_local: Optional[float] = None
    qk_norm: bool = False
    sandwich_norm: bool = False   # gemma3: post-attn/post-mlp norms
    parallel_block: bool = False  # command-r: attn & mlp in parallel
    tie_embeddings: bool = True
    embed_scale: bool = False     # multiply embeds by sqrt(d_model)
    norm_plus_one: bool = False   # gemma-style (1 + w) RMSNorm
    attn_logit_softcap: Optional[float] = None
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    cross_attention: bool = False
    # modality frontend stub: None → token ids; 'patches'/'audio' → embeds
    frontend: Optional[str] = None
    max_seq: int = 131072

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def layer_kinds(self) -> list:
        """LayerKind per decoder layer (pattern repeated, truncated)."""
        reps = -(-self.n_layers // len(self.pattern))
        return list(self.pattern * reps)[: self.n_layers]

    def cycles(self) -> tuple[int, int]:
        """(n_full_pattern_cycles, tail_layers)."""
        p = len(self.pattern)
        return self.n_layers // p, self.n_layers % p

    # ---------------- parameter counting ----------------
    def _mixer_params(self, kind: LayerKind) -> int:
        d = self.d_model
        if kind.mixer == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            return (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
                + conv_dim * s.conv_width                             # conv1d
                + 2 * nheads                                          # A_log, dt_bias
                + d_in                                                # gated norm
                + d_in * d                                            # out_proj
            )
        hd = self.hd
        qk_extra = 2 * hd if self.qk_norm else 0
        return d * (self.n_heads * hd) * 2 + d * (self.n_kv * hd) * 2 + qk_extra

    def _mlp_params(self, kind: LayerKind) -> int:
        d, f = self.d_model, self.d_ff
        if kind.mlp == "none":
            return 0
        if kind.mlp in ("relu2", "gelu"):
            return 2 * d * f
        if kind.mlp == "moe":
            m = self.moe
            per = 3 * d * f
            total = m.n_experts * per + d * m.n_experts  # experts + router
            if m.shared_expert:
                total += per
            return total
        return 3 * d * f  # swiglu

    def _mlp_active_params(self, kind: LayerKind) -> int:
        if kind.mlp == "moe":
            m = self.moe
            per = 3 * self.d_model * self.d_ff
            act = m.top_k * per + self.d_model * m.n_experts
            if m.shared_expert:
                act += per
            return act
        return self._mlp_params(kind)

    def _norm_params(self, kind: LayerKind) -> int:
        n = 0 if kind.mixer == "ssm" and kind.mlp == "none" else 2
        if kind.mixer == "ssm" and kind.mlp == "none":
            n = 1
        if self.sandwich_norm:
            n *= 2
        return n * self.d_model

    def param_count(self, active_only: bool = False) -> int:
        total = self.vocab * self.d_model  # embeddings
        if not self.tie_embeddings:
            total += self.vocab * self.d_model
        for kind in self.layer_kinds():
            total += self._mixer_params(kind) + self._norm_params(kind)
            total += (self._mlp_active_params(kind) if active_only
                      else self._mlp_params(kind))
        # encoder stack (whisper): same width, full attention, swiglu → we
        # count with the same block structure plus cross-attention in decoder
        if self.is_encdec:
            enc_kind = LayerKind(mixer="attn", mlp=self.pattern[0].mlp)
            per_enc = self._mixer_params(enc_kind) + self._mlp_params(enc_kind) + 2 * self.d_model
            total += self.encoder_layers * per_enc + self.d_model  # + enc final norm
            # decoder cross-attention blocks
            total += self.n_layers * (self._mixer_params(enc_kind) + self.d_model)
        total += self.d_model  # final norm
        return total

    def model_flops_per_token(self, seq_len: int, mode: str = "train") -> float:
        """'Useful' FLOPs per token: {6,2,2}·N_active + attention term.

        MODEL_FLOPS for the roofline table uses 6·N·D (dense) or
        6·N_active·D (MoE) per the assignment (2·N for forward-only
        serving); the attention score/value term is added so long-context
        cells stay honest. mode ∈ {'train', 'prefill', 'decode'}.
        """
        n_active = self.param_count(active_only=True)
        matmul_factor = 6.0 if mode == "train" else 2.0
        flops = matmul_factor * n_active
        attn_factor = 12.0 if mode == "train" else 4.0
        for kind in self.layer_kinds():
            if kind.mixer != "attn":
                continue
            if mode == "decode":
                eff = seq_len if kind.window is None else min(kind.window, seq_len)
            else:
                eff = (seq_len if kind.window is None
                       else min(kind.window, seq_len)) / 2.0  # causal avg
            flops += attn_factor * self.n_heads * self.hd * eff
        return flops
