"""Mamba2 state-space duality (SSD) blocks: chunked training scan,
single-token decode recurrence, and the surrounding gated block.

The chunked SSD follows the minimal discrete formulation of the Mamba2
paper (arXiv:2405.21060): intra-chunk quadratic term + inter-chunk state
recurrence. The pure-jnp implementation here is the oracle for the
``repro.kernels.ssd_scan`` Pallas kernel and the lowering used on CPU.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_act
from repro.models.config import ModelConfig
from repro.models.nn import rms_norm


def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., Q] → [..., Q, Q] lower-triangular pairwise cumsums.

    out[i, j] = sum(a[j+1 .. i]) for i >= j, -inf elsewhere.
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # [..., i, j]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,      # [B, S, H, P]  (pre-scaled by dt)
    dA: jax.Array,     # [B, S, H]     log-decay per step (negative)
    Bm: jax.Array,     # [B, S, G, N]
    Cm: jax.Array,     # [B, S, G, N]
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # [B, H, P, N]
):
    """Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} must divide chunk {Q}"
    nc = S // Q

    f32 = jnp.float32
    xc = x.reshape(B_, nc, Q, H, P).astype(f32)
    dAc = dA.reshape(B_, nc, Q, H).astype(f32)
    Bc = Bm.reshape(B_, nc, Q, G, N).astype(f32)
    Cc = Cm.reshape(B_, nc, Q, G, N).astype(f32)

    # expand groups → heads once (G is tiny; N,P are small)
    Bh = jnp.repeat(Bc, rep, axis=3)                    # [B,nc,Q,H,N]
    Ch = jnp.repeat(Cc, rep, axis=3)

    # ---- intra-chunk (diagonal blocks) ----
    L = jnp.exp(_segsum(jnp.moveaxis(dAc, -1, -2)))     # [B,nc,H,Q,Q]
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)   # [B,nc,H,Q,Q]
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp",
                        scores, L, xc)

    # ---- per-chunk states ----
    cums = jnp.cumsum(dAc, axis=2)                      # [B,nc,Q,H]
    decay_states = jnp.exp(cums[:, :, -1:, :] - cums)   # [B,nc,Q,H]
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                        Bh, decay_states, xc)           # [B,nc,H,P,N]

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(cums[:, :, -1, :])            # [B,nc,H]
    from repro.utils import vma_like
    h0 = (initial_state.astype(f32) if initial_state is not None
          else vma_like(jnp.zeros((B_, H, P, N), f32), x))

    def step(h, inp):
        dec, st = inp                                   # dec [B,H], st [B,H,P,N]
        h_new = h * dec[:, :, None, None] + st
        return h_new, h                                  # emit state *entering* the chunk

    final, h_prev = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                 # [B,nc,H,P,N]

    # ---- inter-chunk contribution to outputs ----
    decay_out = jnp.exp(cums)                           # [B,nc,Q,H]
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       Ch, h_prev, decay_out)

    y = (y_diag + y_off).reshape(B_, S, H, P)
    return y.astype(x.dtype), final


def ssd_decode_step(
    state: jax.Array,  # [B, H, P, N]
    x_t: jax.Array,    # [B, H, P] (pre-scaled by dt)
    dA_t: jax.Array,   # [B, H] log-decay
    B_t: jax.Array,    # [B, G, N]
    C_t: jax.Array,    # [B, G, N]
):
    """One recurrence step. Returns (y [B,H,P], new_state)."""
    H = state.shape[1]
    G = B_t.shape[1]
    rep = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(B_t.astype(f32), rep, axis=1)       # [B,H,N]
    Ch = jnp.repeat(C_t.astype(f32), rep, axis=1)
    dec = jnp.exp(dA_t.astype(f32))                     # [B,H]
    new_state = (state.astype(f32) * dec[:, :, None, None]
                 + jnp.einsum("bhp,bhn->bhpn", x_t.astype(f32), Bh))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x_t.dtype), new_state.astype(state.dtype)


# --------------------------------------------------------------------------
# full mamba block
# --------------------------------------------------------------------------

class SSMCache(NamedTuple):
    conv_x: jax.Array   # [B, W-1, d_inner]
    conv_B: jax.Array   # [B, W-1, G*N]
    conv_C: jax.Array   # [B, W-1, G*N]
    state: jax.Array    # [B, H, P, N]


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    w = s.conv_width - 1
    return SSMCache(
        conv_x=jnp.zeros((batch, w, d_in), dtype),
        conv_B=jnp.zeros((batch, w, gn), dtype),
        conv_C=jnp.zeros((batch, w, gn), dtype),
        state=jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    )


def _causal_conv(x: jax.Array, w: jax.Array, hist: Optional[jax.Array] = None):
    """Depthwise causal conv. x [B,S,D], w [W,D], hist [B,W-1,D] → (y, new_hist)."""
    W = w.shape[0]
    if hist is None:
        hist = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)             # [B, S+W-1, D]
    S = x.shape[1]
    y = sum(xp[:, i : i + S] * w[i] for i in range(W))
    new_hist = xp[:, -(W - 1):] if W > 1 else hist
    return y, new_hist


def mamba_mixer(
    p: dict,
    x: jax.Array,                   # [B, S, d_model]
    cfg: ModelConfig,
    cache: Optional[SSMCache] = None,
):
    """Full mamba2 mixer: projections → conv → SSD → gated norm → out.

    Works for training (cache=None), chunked prefill and decode (S=1) —
    the recurrence path is picked automatically for S == 1 with a cache.
    """
    s = cfg.ssm
    B_, S, _ = x.shape
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    P = s.head_dim
    G, N = s.n_groups, s.d_state

    z = x @ p["wz"]
    xs = x @ p["wx"]
    Bm = x @ p["wB"]
    Cm = x @ p["wC"]
    dt = jax.nn.softplus(
        (x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    hx = hB = hC = None
    if cache is not None:
        hx, hB, hC = cache.conv_x, cache.conv_B, cache.conv_C
    xs, hx = _causal_conv(xs, p["conv_x"], hx)
    Bm, hB = _causal_conv(Bm, p["conv_B"], hB)
    Cm, hC = _causal_conv(Cm, p["conv_C"], hC)
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    xs = shard_act(xs, ("batch", None, "act_inner"))

    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # [H]
    dA = dt * A                                          # [B,S,H]
    xh = xs.reshape(B_, S, H, P) * dt[..., None].astype(xs.dtype)
    Bm = Bm.reshape(B_, S, G, N)
    Cm = Cm.reshape(B_, S, G, N)

    if cache is not None and S == 1:
        y, new_state = ssd_decode_step(
            cache.state, xh[:, 0], dA[:, 0], Bm[:, 0], Cm[:, 0])
        y = y[:, None]                                  # [B,1,H,P]
    else:
        init = cache.state if cache is not None else None
        y, new_state = ssd_chunked(xh, dA, Bm, Cm, s.chunk, init)

    y = y.reshape(B_, S, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["wo"]

    new_cache = None
    if cache is not None:
        new_cache = SSMCache(hx, hB, hC, new_state.astype(cache.state.dtype))
    return out, new_cache
