"""HLO-text cost model with loop trip-count awareness.

``compiled.cost_analysis()`` counts every computation ONCE — a scan over
48 layer-cycles reports 1/48th of the real FLOPs. This module parses the
optimized (post-SPMD) HLO text and walks the call graph from ENTRY,
multiplying ``while`` bodies by their ``known_trip_count``, so the roofline
terms reflect what a device actually executes.

Cost conventions (documented in EXPERIMENTS.md §Roofline):
* FLOPs: 2·result_elems·contraction for every ``dot`` (including dots
  inside fusions); elementwise FLOPs are ignored (dots dominate ≫10³×).
* HBM bytes: per op, operands + result; fusions count only their external
  operands/result (internals live in registers/VMEM — the right model for
  TPU). In-place dynamic-update-slice is counted as 2×update bytes, not a
  full read+write of the target buffer (critical for KV caches).
* Collective wire bytes per device, ring model over group size s:
    all-gather: result·(s-1)/s      reduce-scatter: operand·(s-1)/s
    all-reduce: 2·operand·(s-1)/s   all-to-all:  operand·(s-1)/s
    collective-permute: result
  The raw Σ(operand bytes) figure (assignment spec) is reported alongside.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0, "u1": 1, "s1": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(type_str: str) -> float:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str
    is_root: bool = False
    args_raw: str = ""

    @property
    def param_index(self) -> Optional[int]:
        if self.opcode != "parameter":
            return None
        m = re.match(r"\s*(\d+)", self.args_raw)
        return int(m.group(1)) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict            # name -> Op
    order: list          # op names in order


_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _split_type_op(rest: str):
    """'f32[2,3]{1,0} dot(%a, %b), attrs' → (type, opcode, args, attrs)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest2 = rest[: i + 1], rest[i + 1:].strip()
    else:
        sp = rest.index(" ")
        type_str, rest2 = rest[:sp], rest[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\((.*)$", rest2, re.S)
    if not m:
        return type_str, None, "", ""
    opcode = m.group(1)
    tail = m.group(2)
    depth = 1
    for i, ch in enumerate(tail):
        depth += ch == "("
        depth -= ch == ")"
        if depth == 0:
            break
    args = tail[:i]
    attrs = tail[i + 1:]
    return type_str, opcode, args, attrs


def parse_module(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEAD.match(line.strip())
            if m:
                cur = Computation(m.group(1), {}, [])
                if line.strip().startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        is_root = bool(m.group(1))
        name = m.group(2)
        type_str, opcode, args, attrs = _split_type_op(m.group(3))
        if opcode is None:
            continue
        operands = re.findall(r"%([\w.\-]+)", args)
        cur.ops[name] = Op(name, type_str, opcode, operands, attrs, is_root,
                           args_raw=args)
        cur.order.append(name)
    comps["__entry__"] = comps[entry]
    return comps


def _operand_bytes(comp: Computation, op: Op, comps: dict) -> float:
    total = 0.0
    for o in op.operands:
        if o in comp.ops:
            total += shape_bytes(comp.ops[o].type_str)
    return total


_SLICING = {"dynamic-slice", "gather", "slice"}


def _fusion_param_access(callee: Computation, param_idx: int) -> Optional[float]:
    """Bytes a fusion actually reads of parameter `param_idx`, if every use
    is a slicing op (dynamic-slice/gather/slice): the slice result size per
    use. Returns None when any use reads the full operand.

    This matters enormously inside scan loops: a fused dynamic-slice of a
    [S, ...] buffer reads one block per iteration, not the whole buffer.
    """
    pname = None
    for name in callee.order:
        o = callee.ops[name]
        if o.opcode == "parameter" and o.param_index == param_idx:
            pname = name
            break
    if pname is None:
        return None
    total = 0.0
    used = False
    for name in callee.order:
        o = callee.ops[name]
        if pname in o.operands:
            used = True
            if o.opcode in _SLICING and o.operands[0] == pname:
                total += shape_bytes(o.type_str)
            elif o.opcode == "dynamic-update-slice" and o.operands[0] == pname:
                # reads only the region it overwrites
                upd = callee.ops.get(o.operands[1])
                total += shape_bytes(upd.type_str) if upd else 0.0
            else:
                return None
    return total if used else 0.0


def _itemsize(type_str: str) -> float:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 4.0
    return _DTYPE_BYTES.get(m.group(1), 4.0)


def _elems(type_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        for d in (m.group(2).split(",") if m.group(2) else []):
            n *= int(d)
        total += n
    return total


_UNARY_PASS = {"convert", "bitcast", "copy", "transpose", "reshape",
               "broadcast", "negate", "abs", "exponential", "tanh", "log",
               "logistic", "sqrt", "rsqrt", "floor", "ceil",
               "round-nearest-afz", "sign", "expm1", "log1p", "sine",
               "cosine", "not"}
_NARY_PASS = {"add", "multiply", "subtract", "divide", "maximum", "minimum",
              "power", "select", "clamp", "and", "or", "xor",
              "dynamic-slice", "slice", "concatenate", "pad",
              "dynamic-update-slice", "fusion"}


def _internal_convert_min(callee: Computation) -> float:
    """Narrowest convert target inside a fused computation.

    With REPRO_DTYPE_BARRIER, mixed-precision down-casts survive CPU
    legalization as f32→bf16→f32 convert pairs *inside* fusions (e.g.
    ``convert_convert_fusion``): the value passes through bf16, which is
    what a TPU compilation would keep end-to-end."""
    best = 8.0
    for name in callee.order:
        o = callee.ops[name]
        if o.opcode == "convert":
            best = min(best, _itemsize(o.type_str))
    return best


def _effective_itemsize(comp: Computation, name: str,
                        memo: dict, depth: int = 12, comps: dict = None) -> float:
    """TPU-honest dtype of a value, in bytes per element.

    XLA-CPU legalizes ALL bf16 compute to f32 (converts at storage
    boundaries) and emits bf16×bf16 dots with f32 outputs; TPU keeps bf16
    end-to-end. Recursively take the narrowest dtype consistent with the
    producer chain: at a ``dot``, the TPU output dtype is the widest
    operand dtype; elementwise ops inherit the widest (effective) operand;
    parameters/constants are authoritative storage dtypes; fusions that
    squeeze through an internal bf16 convert count as bf16."""
    if name in memo:
        return memo[name]
    op = comp.ops.get(name)
    if op is None:
        return 4.0
    own = _itemsize(op.type_str)
    memo[name] = own  # cycle guard
    if depth <= 0 or op.opcode in ("parameter", "constant", "iota"):
        return own
    if op.opcode == "fusion" and comps is not None:
        m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
        callee = comps.get(m.group(1)) if m else None
        if callee is not None:
            own = min(own, max(_internal_convert_min(callee),
                               _MIN_TRACKED_ITEMSIZE))
    if op.opcode == "dot" or op.opcode in _NARY_PASS or op.opcode in _UNARY_PASS:
        effs = [_effective_itemsize(comp, o, memo, depth - 1, comps)
                for o in op.operands if o in comp.ops]
        effs = [e for e in effs if e > 0]
        if effs:
            own = min(own, max(effs))
    memo[name] = own
    return own


# never squeeze below bf16 via the convert heuristic (int8 masks etc. are
# not evidence that the main value path is int8)
_MIN_TRACKED_ITEMSIZE = 2.0


def _eff_bytes(comp: Computation, name: str, memo: dict,
               comps: dict = None) -> float:
    op = comp.ops.get(name)
    if op is None:
        return 0.0
    return _elems(op.type_str) * _effective_itemsize(comp, name, memo,
                                                     comps=comps)


def _collective_operand_bytes(comp: Computation, op: Op, memo: dict,
                              comps: dict = None) -> float:
    """Wire bytes entering a collective, with TPU-effective dtypes."""
    return sum(_eff_bytes(comp, o, memo, comps) for o in op.operands
               if o in comp.ops)


def _fusion_operand_bytes(comp: Computation, op: Op, comps: dict,
                          memo: dict) -> float:
    """Operand bytes for a fusion op, slice-aware per parameter and with
    TPU-effective dtypes."""
    m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
    callee = comps.get(m.group(1)) if m else None
    total = 0.0
    for idx, o in enumerate(op.operands):
        if o not in comp.ops:
            continue
        full = _eff_bytes(comp, o, memo, comps)
        if callee is not None:
            acc = _fusion_param_access(callee, idx)
            if acc is not None:
                eff = _effective_itemsize(comp, o, memo, comps=comps)
                its = _itemsize(comp.ops[o].type_str)
                total += min(full, acc * eff / max(its, 1e-9))
                continue
        total += full
    return total


def _dot_flops(comp: Computation, op: Op) -> float:
    result_elems = 1
    for d in shape_dims(op.type_str):
        result_elems *= d
    # contraction size from lhs shape + lhs_contracting_dims
    lhs = comp.ops.get(op.operands[0]) if op.operands else None
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    contract = 1
    if lhs is not None and m and m.group(1):
        ldims = shape_dims(lhs.type_str)
        for idx in m.group(1).split(","):
            contract *= ldims[int(idx)]
    return 2.0 * result_elems * contract


def _group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    return 1


def _trip_count(attrs: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
    return int(m.group(1)) if m else 1


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id",
               "while", "conditional", "call"}


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_operand_bytes: float = 0.0
    coll_by_type: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    dus_bytes: float = 0.0
    unknown_while: int = 0
    custom_calls: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))


def _fusion_dot_flops(comp: Computation, comps: dict) -> float:
    total = 0.0
    for name in comp.order:
        op = comp.ops[name]
        if op.opcode == "dot":
            total += _dot_flops(comp, op)
        elif op.opcode == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            if m and m.group(1) in comps:
                total += _fusion_dot_flops(comps[m.group(1)], comps)
    return total


def _fused_root_is_dus(comp: Computation) -> Optional[Op]:
    for name in comp.order:
        op = comp.ops[name]
        if op.is_root and op.opcode == "dynamic-update-slice":
            return op
    return None


def walk(comps: dict, comp: Computation, mult: float, tot: CostTotals,
         memos: dict):
    memo = memos.setdefault(comp.name, {})
    for name in comp.order:
        op = comp.ops[name]
        oc = op.opcode
        if oc == "while":
            trips = _trip_count(op.attrs)
            if trips == 1 and '"known_trip_count"' not in op.attrs:
                tot.unknown_while += 1
            m = re.search(r"body=%?([\w.\-]+)", op.attrs)
            if m and m.group(1) in comps:
                walk(comps, comps[m.group(1)], mult * trips, tot, memos)
            continue
        if oc in ("call", "conditional", "async-start"):
            for m in re.finditer(r"(?:calls|branch_computations)=\{?%?([\w.\-]+)", op.attrs):
                if m.group(1) in comps:
                    walk(comps, comps[m.group(1)], mult, tot, memos)
            continue
        if oc == "custom-call":
            m = re.search(r'custom_call_target="([^"]+)"', op.attrs)
            tot.custom_calls[m.group(1) if m else "?"] += 1

        base = oc.replace("-start", "")
        if any(base == c for c in COLLECTIVES):
            ob = _collective_operand_bytes(comp, op, memo, comps)
            s = max(_group_size(op.attrs), 1)
            ring = {
                "all-gather": ob * (s - 1),
                "all-reduce": 2.0 * ob * (s - 1) / s,
                "reduce-scatter": ob * (s - 1) / s,
                "all-to-all": ob * (s - 1) / s,
                "collective-permute": ob,
            }[base]
            tot.coll_wire_bytes += ring * mult
            tot.coll_operand_bytes += ob * mult
            tot.coll_by_type[base] += ring * mult
            tot.hbm_bytes += (_eff_bytes(comp, name, memo, comps) + ob) * mult
            continue
        if oc.endswith("-done"):
            continue

        if oc == "dot":
            tot.flops += _dot_flops(comp, op) * mult
        elif oc == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            callee = comps.get(m.group(1)) if m else None
            if callee is not None:
                tot.flops += _fusion_dot_flops(callee, comps) * mult
                dus = _fused_root_is_dus(callee)
                result_b = _eff_bytes(comp, name, memo, comps)
                if dus is not None:
                    # in-place cache update: write only the update region
                    upd = callee.ops.get(dus.operands[1])
                    if upd is not None:
                        result_b = min(result_b, shape_bytes(upd.type_str))
                    tot.dus_bytes += result_b * mult
                b = result_b + _fusion_operand_bytes(comp, op, comps, memo)
                tot.hbm_bytes += b * mult
                continue

        if oc in _SKIP_BYTES:
            continue
        if oc == "dynamic-update-slice":
            upd = comp.ops.get(op.operands[1])
            ub = (_eff_bytes(comp, op.operands[1], memo, comps) if upd
                  else _eff_bytes(comp, name, memo, comps))
            tot.hbm_bytes += 2.0 * ub * mult
            tot.dus_bytes += 2.0 * ub * mult
            continue
        if oc in _SLICING:
            tot.hbm_bytes += 2.0 * _eff_bytes(comp, name, memo, comps) * mult
            continue
        tot.hbm_bytes += (_eff_bytes(comp, name, memo, comps)
                          + sum(_eff_bytes(comp, o, memo, comps)
                                for o in op.operands if o in comp.ops)) * mult


def analyze_hlo(text: str) -> CostTotals:
    comps = parse_module(text)
    tot = CostTotals()
    walk(comps, comps["__entry__"], 1.0, tot, {})
    tot.coll_by_type = dict(tot.coll_by_type)
    tot.custom_calls = dict(tot.custom_calls)
    return tot
