"""Roofline terms from analyzed HLO + TPU v5e hardware constants."""
from __future__ import annotations

import dataclasses

from repro.roofline.hlo_cost import CostTotals

# TPU v5e (per chip) — constants from the assignment
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link
DCN_BW = 25e9                # B/s cross-pod (assumed; pod axis collectives)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities (the SPMD module is a per-device program)
    hlo_flops: float
    hbm_bytes: float
    coll_wire_bytes: float
    coll_operand_bytes: float
    coll_by_type: dict
    # useful work
    model_flops_global: float
    # memory_analysis
    arg_bytes: float = 0.0
    temp_bytes: float = 0.0
    # xla raw (per-invocation of each computation once; reference only)
    xla_flops_raw: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_wire_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def model_flops_per_dev(self) -> float:
        return self.model_flops_global / self.chips

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops_per_dev / max(self.hlo_flops, 1.0)

    @property
    def roofline_frac(self) -> float:
        """Useful-FLOPs time vs the binding roofline term (≈ achievable MFU)."""
        t_useful = self.model_flops_per_dev / PEAK_FLOPS_BF16
        return t_useful / max(self.t_bound, 1e-30)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_flops_per_dev": self.hlo_flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_wire_bytes_per_dev": self.coll_wire_bytes,
            "coll_operand_bytes_per_dev": self.coll_operand_bytes,
            "model_flops_global": self.model_flops_global,
            "useful_ratio": self.useful_ratio,
            "roofline_frac": self.roofline_frac,
            "arg_bytes": self.arg_bytes, "temp_bytes": self.temp_bytes,
        }


def from_totals(arch: str, shape: str, mesh_desc: str, chips: int,
                tot: CostTotals, model_flops_global: float,
                arg_bytes: float = 0.0, temp_bytes: float = 0.0,
                xla_flops_raw: float = 0.0) -> Roofline:
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        hlo_flops=tot.flops, hbm_bytes=tot.hbm_bytes,
        coll_wire_bytes=tot.coll_wire_bytes,
        coll_operand_bytes=tot.coll_operand_bytes,
        coll_by_type=dict(tot.coll_by_type),
        model_flops_global=model_flops_global,
        arg_bytes=arg_bytes, temp_bytes=temp_bytes,
        xla_flops_raw=xla_flops_raw)
