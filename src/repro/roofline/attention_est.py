"""Estimate attention-interior HBM traffic in an analyzed module.

The XLA attention path materializes per-block score/probability tensors
(shape [..., q_chunk, kv_chunk]) at fusion boundaries; the Pallas flash
kernel keeps them in VMEM. This helper sums the bytes of exactly those
tensors so the §Perf log can report a 'with-Pallas-kernel' memory term
for TPU, which the CPU dry-run cannot lower directly.
"""
from __future__ import annotations

import re

from repro.roofline import hlo_cost as hc


def attention_interior_bytes(text: str, q_chunk: int = 512,
                             kv_chunk: int = 512) -> float:
    comps = hc.parse_module(text)
    memos: dict = {}
    total = 0.0

    def is_score_shape(type_str: str) -> bool:
        dims = hc.shape_dims(type_str)
        return (len(dims) >= 2 and dims[-1] in (q_chunk, kv_chunk)
                and dims[-2] in (q_chunk, kv_chunk))

    def walk(comp, mult):
        nonlocal total
        memo = memos.setdefault(comp.name, {})
        for name in comp.order:
            op = comp.ops[name]
            oc = op.opcode
            if oc == "while":
                t = hc._trip_count(op.attrs)
                m = re.search(r"body=%?([\w.\-]+)", op.attrs)
                if m and m.group(1) in comps:
                    walk(comps[m.group(1)], mult * t)
                continue
            if oc in ("call", "conditional"):
                for m in re.finditer(r"calls=\{?%?([\w.\-]+)", op.attrs):
                    if m.group(1) in comps:
                        walk(comps[m.group(1)], mult)
                continue
            if oc in hc._SKIP_BYTES:
                continue
            if is_score_shape(op.type_str):
                total += hc._eff_bytes(comp, name, memo, comps) * mult
            # operand side: score-shaped inputs read by this op
            for o in op.operands:
                if o in comp.ops and is_score_shape(comp.ops[o].type_str):
                    total += hc._eff_bytes(comp, o, memo, comps) * mult

    walk(comps["__entry__"], 1.0)
    return total
