from repro.roofline.hlo_cost import analyze_hlo, CostTotals  # noqa: F401
from repro.roofline.terms import (Roofline, from_totals,  # noqa: F401
                                  PEAK_FLOPS_BF16, HBM_BW, LINK_BW)
