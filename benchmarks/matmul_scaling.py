"""Paper Fig. 12: 8192×8192 matmul distributed over 1..16 GPUs (4 per
server), result-merge included in the timing. Paper: ~6× at 16 GPUs, no
SnuCL-style regression past 8 devices.

Functional correctness is checked at a reduced size through the same
code path; the scaling numbers use the analytic device model (P100/V100
fp32) on the simulated 56 Gb LAN.
"""
from __future__ import annotations

from benchmarks.common import ETH_56G, GPU_P100, GPU_V100, Row, emit
from repro.core import ClientRuntime, ServerSpec


def _cluster(n_gpus: int):
    servers = []
    specs = [GPU_P100] * 12 + [GPU_V100] * 4
    for s in range((n_gpus + 3) // 4):
        devs = []
        for g in range(min(4, n_gpus - 4 * s)):
            d = specs[4 * s + g]
            devs.append(type(d)(f"gpu{g}", d.flops, d.mem_bw))
        servers.append(ServerSpec(f"s{s}", devs))
    return servers


def _matmul_time(n_gpus: int, N: int = 8192) -> float:
    servers = _cluster(n_gpus)
    rt = ClientRuntime(servers=servers, client_link=ETH_56G,
                       peer_link=ETH_56G, transport="tcp")
    rows_per = N // n_gpus
    # "the full input data is uploaded to each device" BEFORE the timed
    # section (paper §6.4); only multiply + result merge are timed
    ins = []
    for s in servers:
        for _d in s.devices:
            a = rt.create_buffer(rows_per * N * 4)
            b = rt.create_buffer(N * N * 4)
            a.valid_on = {s.name}
            b.valid_on = {s.name}
            ins.append((s, _d, a, b))
    rt.finish()
    t0 = rt.clock.now
    for s, d, a, b in ins:
        o = rt.create_buffer(rows_per * N * 4)
        ek = rt.enqueue_kernel(
            s.name, d.name, fn=None, inputs=[a, b], outputs=[o],
            flops=2.0 * rows_per * N * N,
            bytes_moved=(rows_per * N + N * N + rows_per * N) * 4)
        # merge: read each partial result back to the host (included)
        rt.enqueue_read(s.name, o, wait_for=[ek])
    rt.finish()
    return rt.clock.now - t0


def run():
    t1 = _matmul_time(1)
    rows = []
    prev = None
    for n in (1, 2, 4, 8, 12, 16):
        t = _matmul_time(n)
        sp = t1 / t
        regression = prev is not None and sp < prev - 0.05
        rows.append(Row(f"fig12_matmul_{n}gpu", t * 1e6,
                        f"speedup={sp:.2f};regression={regression}"))
        prev = sp
    return emit(rows)


if __name__ == "__main__":
    run()
