"""CFD halo-exchange scaling under the placement control plane
(paper §VII Fig. 14 shape; DESIGN.md §6).

The workload is an iterative Jacobi/stencil solve whose domain is
sharded row-wise across servers: every step each partition runs one
stencil kernel and publishes its two boundary rows as halo buffers,
which the neighbors consume next step — so each step triggers P2P
halo migrations between neighboring servers, the paper's CFD traffic
pattern.

The client is deliberately *placement-oblivious*: partitions are born
on the server whose sensors produced them (pre-sharded ingest writes),
but every step kernel is requested on ``s0`` — the only endpoint the
client knows. Placement policy decides what actually happens:

* ``pinned`` (the ``naive`` rows): every kernel lands on s0, dragging
  the whole domain to one server — the 1-server serial time plus the
  drag. This is placement OFF, the locality-blind comparator.
* ``locality``: kernels chase their partition's replica, so partitions
  stay put and halos move P2P — near-ideal spread.
* ``hetmec``: estimated-completion-time placement — same spread, and
  under contention (a background tenant flooding s0 with a deep
  backlog) it *evacuates* s0's partition to the queue-cheapest
  neighbor, where locality keeps it pinned behind the backlog.

``eff`` is strong-scaling efficiency ``T1 / (n × Tn)`` against the
1-server monolithic run (same transport); drain is measured to the
last step kernel's completion, so the contended rows are not masked by
the background tenant's own backlog draining.

A functional check runs a REAL (small) Jacobi grid through the
runtime under ``hetmec`` placement and compares bit-exactly against
the monolithic solver — placement must never change results, only
timing.

  PYTHONPATH=src python -m benchmarks.cfd_halo \
      [--baseline benchmarks/BENCH_cfd.json] [--write-baseline P]

With ``--baseline``, exits non-zero if any row's simulated drain time
regresses more than 20%, the 8-server hetmec efficiency drops below
0.75, hetmec fails to beat the locality-off (naive) placement by at
least 20% on drain sim-ms, or contended hetmec fails to beat contended
locality by at least 20% (used by scripts/ci.sh).

A SEPARATE traced 8-server hetmec run (so the five baseline rows above
stay byte-identical — tracing attaches at cluster construction) feeds
the causal critical-path analyzer (core/critpath.py): how much of the
drain sits in halo communication (transfer + dependency/notify wait on
the critical path), and what the scaling efficiency would be if the
halo wire were hidden behind compute (``whatif(overlap_halo=True,
nic_bandwidth=...)``) — the quantified case for the ROADMAP's
"hide the wire" follow-up. ``--critpath-baseline`` gates those rows
against ``BENCH_critpath.json``; ``--trace FILE[.gz]`` additionally
exports the traced run as Perfetto JSON (CI artifact + trace-diff
forensics input).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks import common
from benchmarks.common import ETH_1G, ETH_40G, GPU_A6000, MiB, Row, emit
from repro.core import ClientRuntime, Cluster, ServerSpec, Tracer

STEPS = 30
TOTAL_STEP_S = 80e-3          # whole-domain step on one GPU
PART_BYTES = 16 * MiB         # per-partition field slab (8-server shard)
HALO_BYTES = 1 * MiB          # one boundary face of the sharded domain
NIC_BW = 25e9 / 8             # per-host port, both directions modeled
BG_KERNELS = 80               # contended rows: backlog flooding s0
BG_KERNEL_S = 10e-3
REGRESSION_TOLERANCE = 0.20
EFFICIENCY_FLOOR = 0.75       # CI floor (measured ~0.80 at 8 servers;
                              # the sim is deterministic, so the
                              # acceptance bar gates directly)
IMPROVEMENT_FLOOR = 0.20      # hetmec vs locality-off placement
REGENERATE = ("python -m benchmarks.cfd_halo "
              "--write-baseline benchmarks/BENCH_cfd.json")


def _mk(n_srv: int, policy: str, peer_transport: str, trace=None):
    cluster = Cluster([ServerSpec(f"s{i}", [GPU_A6000])
                       for i in range(n_srv)],
                      peer_link=ETH_40G, peer_transport=peer_transport,
                      nic_bandwidth=NIC_BW, nic_ingress_bandwidth=NIC_BW,
                      placement=policy, trace=trace)
    rt = ClientRuntime(cluster=cluster, client_link=ETH_1G,
                       transport="tcp", name="cfd",
                       replay_window=4096)  # whole schedule is in flight
    return cluster, rt


def _ingest(rt, n_srv: int, part_bytes: int, halo_bytes: int):
    """Partition i is born on server i (its sensors' edge server): the
    client never has to know the topology — placement reads it back out
    of replica locality."""
    parts, lo, hi = [], [], []
    for i in range(n_srv):
        p = rt.create_buffer(part_bytes, name=f"part{i}")
        blo = rt.create_buffer(halo_bytes, name=f"halo_lo{i}")
        h = rt.create_buffer(halo_bytes, name=f"halo_hi{i}")
        rt.enqueue_write(f"s{i}", p, np.zeros(part_bytes // 4, np.uint32))
        rt.enqueue_write(f"s{i}", blo,
                         np.zeros(halo_bytes // 4, np.uint32))
        rt.enqueue_write(f"s{i}", h, np.zeros(halo_bytes // 4, np.uint32))
        parts.append(p)
        lo.append(blo)
        hi.append(h)
    return parts, lo, hi


def _run_steps(rt, n_srv: int, parts, lo, hi) -> list:
    """Enqueue the full stencil schedule (every kernel requested on s0)
    and return the last step's kernel events."""
    per_step = TOTAL_STEP_S / n_srv
    step_evs: list = [None] * n_srv
    for k in range(STEPS):
        prev = step_evs[:]
        for i in range(n_srv):
            ins = [parts[i]]
            deps = [prev[i]]
            if i > 0:
                ins.append(hi[i - 1])
                deps.append(prev[i - 1])
            if i < n_srv - 1:
                ins.append(lo[i + 1])
                deps.append(prev[i + 1])
            step_evs[i] = rt.enqueue_kernel(
                "s0", fn=None, inputs=ins,
                outputs=[parts[i], lo[i], hi[i]],
                duration=per_step,
                wait_for=[d for d in deps if d is not None],
                name=f"step{k}_p{i}")
    return step_evs


def _measure(n_srv: int, policy: str, peer_transport: str = "tcp",
             contended: bool = False, trace=None) -> dict:
    cluster, rt = _mk(n_srv, policy, peer_transport, trace=trace)
    bg = None
    if contended:
        # the background tenant hard-pins its flood to s0 regardless of
        # the cluster's default policy (per-tenant override)
        bg = ClientRuntime(cluster=cluster, client_link=ETH_1G,
                           transport="tcp", name="bg",
                           placement="pinned",
                           replay_window=2 * BG_KERNELS)
    parts, lo, hi = _ingest(rt, n_srv, PART_BYTES, HALO_BYTES)
    cluster.run()                         # ingest drained
    if bg is not None:
        for j in range(BG_KERNELS):
            bg.enqueue_kernel("s0", fn=None, duration=BG_KERNEL_S,
                              name=f"bg{j}")
    t0 = cluster.clock.now
    step_evs = _run_steps(rt, n_srv, parts, lo, hi)
    cluster.run()
    done = max(e.t_end for e in step_evs)  # drain to the LAST stencil:
    # the contended rows must not be masked by the backlog's own tail
    elapsed = done - t0
    st = cluster.stats()
    return {
        "sim_ms": elapsed * 1e3,
        "steps_per_sec": STEPS / elapsed,
        "placed_remote": st["placement"]["placed_remote"],
        "bytes_avoided": st["placement"]["placement_bytes_avoided"],
        "peer_mb": sum(st["peer_link_bytes"].values()) / 1e6,
        "nic_in_busy_ms": sum(st["nic_in_busy"].values()) * 1e3,
    }


# ---- functional check: placement must never change results ----

def _make_step(is_top: bool, is_bot: bool):
    def step(slab, up, down):
        g = np.vstack([up, slab, down])
        new = g.copy()
        new[1:-1, 1:-1] = 0.25 * (g[:-2, 1:-1] + g[2:, 1:-1]
                                  + g[1:-1, :-2] + g[1:-1, 2:])
        if is_top:
            new[1] = g[1]          # global boundary row stays fixed
        if is_bot:
            new[-2] = g[-2]
        out = new[1:-1]
        return out, out[:1].copy(), out[-1:].copy()
    return step


def functional_check(n_srv: int = 4, rows: int = 32, cols: int = 64,
                     steps: int = 12, policy: str = "hetmec") -> float:
    """Real Jacobi through the runtime under placement vs the
    monolithic solver; returns the max abs error (0.0 = bit-exact)."""
    grid = np.add.outer(np.arange(rows, dtype=np.float64),
                        np.arange(cols, dtype=np.float64))
    grid[0] = 100.0                       # hot top edge
    cluster, rt = _mk(n_srv, policy, "tcp")
    rs = rows // n_srv
    slabs = [grid[i * rs:(i + 1) * rs] for i in range(n_srv)]
    # halo buffers are DOUBLE-buffered by step parity (the standard CFD
    # exchange scheme): step k writes parity k%2 and reads the
    # neighbors' parity (k-1)%2, so a fast neighbor's step k+1 can
    # never overwrite a halo its slower peer has not consumed — the
    # dependency edges only order producer→consumer, not the reverse
    parts = []
    lo = [[None, None] for _ in range(n_srv)]
    hi = [[None, None] for _ in range(n_srv)]
    for i, s in enumerate(slabs):
        p = rt.create_buffer(int(s.nbytes), name=f"fpart{i}")
        rt.enqueue_write(f"s{i}", p, s)
        parts.append(p)
        for par in (0, 1):
            lo[i][par] = rt.create_buffer(int(s[:1].nbytes))
            hi[i][par] = rt.create_buffer(int(s[:1].nbytes))
        # ingest halos act as "step -1" output: parity (-1) % 2 == 1
        rt.enqueue_write(f"s{i}", lo[i][1], s[:1].copy())
        rt.enqueue_write(f"s{i}", hi[i][1], s[-1:].copy())
    ghost = rt.create_buffer(int(slabs[0][:1].nbytes))
    rt.enqueue_write("s0", ghost, np.zeros((1, cols)))  # unused rows
    cluster.run()
    step_evs: list = [None] * n_srv
    for k in range(steps):
        prev = step_evs[:]
        rd, wr = (k - 1) % 2, k % 2
        for i in range(n_srv):
            up = hi[i - 1][rd] if i > 0 else ghost
            down = lo[i + 1][rd] if i < n_srv - 1 else ghost
            deps = [prev[i]]
            if i > 0:
                deps.append(prev[i - 1])
            if i < n_srv - 1:
                deps.append(prev[i + 1])
            deps = [d for d in deps if d is not None]
            step_evs[i] = rt.enqueue_kernel(
                "s0", fn=_make_step(i == 0, i == n_srv - 1),
                inputs=[parts[i], up, down],
                outputs=[parts[i], lo[i][wr], hi[i][wr]],
                duration=1e-4, wait_for=deps, name=f"fstep_p{i}")
    cluster.run()
    got = np.vstack([p.data for p in parts])
    ref = grid.copy()
    for _ in range(steps):
        new = ref.copy()
        new[1:-1, 1:-1] = 0.25 * (ref[:-2, 1:-1] + ref[2:, 1:-1]
                                  + ref[1:-1, :-2] + ref[1:-1, 2:])
        ref = new
    return float(np.max(np.abs(got - ref)))


HALO_STAGES = ("transfer", "dep_wait", "notify")


def _critpath_rows(base_ms: float, trace_path=None) -> list:
    """Separate traced 8-server hetmec run -> critical-path halo-wait
    attribution and the hidden-halo efficiency projection. ``base_ms``
    is the 1-server tcp drain the efficiency is computed against."""
    tr = Tracer()
    r = _measure(8, "hetmec", "tcp", trace=tr)
    cp = tr.critical_path(exact=True)
    ident = bool(cp.segments) and cp.segment_sum() == cp.makespan
    mk = float(cp.makespan)
    halo_ms = sum(float(s.dur) for s in cp.segments
                  if s.stage in HALO_STAGES) * 1e3
    share = halo_ms / (mk * 1e3) if mk else 0.0
    print(tr.format_blame(top=10, title="critical path: cfd 8srv hetmec"),
          file=sys.stderr)
    rows = [Row("critpath_cfd8_halo_wait_share", share,
                f"halo_ms={halo_ms:.3f};makespan_ms={mk * 1e3:.3f};"
                f"segments={len(cp.segments)};"
                f"identity={1 if ident else 0}")]
    # what the scaling curve looks like with the halo wire hidden
    # behind compute (first-chunk cut-through): the savings come out of
    # the stepping drain — halo traffic only exists during stepping.
    # Savings are projection-vs-projection (no-knob model baseline
    # minus the overlap projection) so the re-timing model's ~1% bias
    # on this two-phase workload cancels out instead of swamping the
    # few-ms effect being measured.
    w0 = tr.whatif()
    w = tr.whatif(overlap_halo=True)
    saved_ms = (w0["projected_s"] - w["projected_s"]) * 1e3
    proj_ms = r["sim_ms"] - saved_ms
    if proj_ms < 1e-9:
        proj_ms = 1e-9
    base_eff = base_ms / (8 * r["sim_ms"])
    eff = base_ms / (8 * proj_ms)
    rows.append(Row(
        "critpath_cfd8_halo_hidden_ms", proj_ms * 1e3,
        f"eff={eff:.3f};base_eff={base_eff:.3f};"
        f"saved_ms={saved_ms:.3f};sim_ms={proj_ms:.3f}"))
    print(f"# halo-wait share of 8srv critical path: {share:.3f} "
          f"({halo_ms:.1f} of {mk * 1e3:.1f} ms); halo hidden -> "
          f"eff {base_eff:.3f} => {eff:.3f}", file=sys.stderr)
    if trace_path:
        tr.write_perfetto(trace_path)
        errs = common.validate_perfetto(trace_path)
        for e in errs:
            print(f"# trace: {e}", file=sys.stderr)
        print(f"# trace: {len(tr.cmds)} commands -> {trace_path} "
              f"({'INVALID' if errs else 'schema ok'})", file=sys.stderr)
        if errs:
            raise SystemExit(1)
    return rows


def run(trace_path=None):
    err = functional_check()
    rows = [Row("cfd_functional_err", 0.0, f"max_abs_err={err:.2e}")]
    base = {}
    for tr in ("tcp", "rdma"):
        base[tr] = _measure(1, "hetmec", tr)
        rows.append(Row(f"cfd_1srv_{tr}", base[tr]["sim_ms"] * 1e3,
                        f"sim_ms={base[tr]['sim_ms']:.3f};"
                        f"steps_per_sec={base[tr]['steps_per_sec']:.1f}"))

    def scaled(n, policy, tr):
        r = _measure(n, policy, tr)
        eff = base[tr]["sim_ms"] / (n * r["sim_ms"])
        rows.append(Row(
            f"cfd_{n}srv_{policy}_{tr}", r["sim_ms"] * 1e3,
            f"sim_ms={r['sim_ms']:.3f};eff={eff:.3f};"
            f"steps_per_sec={r['steps_per_sec']:.1f};"
            f"placed_remote={r['placed_remote']};"
            f"bytes_avoided={r['bytes_avoided']:.0f};"
            f"peer_mb={r['peer_mb']:.1f};"
            f"nic_in_busy_ms={r['nic_in_busy_ms']:.3f}"))

    for n in (2, 4, 8):
        scaled(n, "hetmec", "tcp")
    scaled(8, "hetmec", "rdma")
    scaled(8, "locality", "tcp")
    scaled(8, "pinned", "tcp")          # placement OFF: the naive drag
    for policy in ("locality", "hetmec"):
        r = _measure(8, policy, "tcp", contended=True)
        rows.append(Row(
            f"cfd_8srv_contended_{policy}_tcp", r["sim_ms"] * 1e3,
            f"sim_ms={r['sim_ms']:.3f};"
            f"placed_remote={r['placed_remote']};"
            f"bytes_avoided={r['bytes_avoided']:.0f}"))
    rows.extend(_critpath_rows(base["tcp"]["sim_ms"],
                               trace_path=trace_path))
    return emit(rows)


def _sim_ms(row: Row) -> float:
    return common.derived(row, "sim_ms")


def check_baseline(rows, baseline_path: str) -> bool:
    """Simulated drain time gates tightly (deterministic); on top of
    the per-row regression ceilings, the acceptance floors: 8-server
    hetmec efficiency, hetmec ≥20% under locality-off (naive pinned)
    drain, contended hetmec ≥20% under contended locality drain, and
    the functional check bit-exact."""
    gated = [r for r in rows if r.name != "cfd_functional_err"]
    ok = common.check_rows(gated, baseline_path, extract=_sim_ms,
                           tolerance=REGRESSION_TOLERANCE,
                           direction="lower_is_better", unit=" sim_ms",
                           benchmark="cfd_halo")
    by_name = {r.name: r for r in rows}
    err = common.derived(by_name["cfd_functional_err"], "max_abs_err")
    if err > 1e-12:
        print(f"# cfd_functional_err: {err:.2e} — placement changed "
              f"the Jacobi RESULT", file=sys.stderr)
        ok = False
    eff = common.derived(by_name["cfd_8srv_hetmec_tcp"], "eff")
    if eff < EFFICIENCY_FLOOR:
        print(f"# cfd_8srv_hetmec_tcp: efficiency {eff:.3f} < "
              f"{EFFICIENCY_FLOOR} FLOOR", file=sys.stderr)
        ok = False
    else:
        print(f"# cfd_8srv_hetmec_tcp: efficiency {eff:.3f} "
              f"(floor {EFFICIENCY_FLOOR}) ok", file=sys.stderr)
    for fast, slow, what in (
            ("cfd_8srv_hetmec_tcp", "cfd_8srv_pinned_tcp",
             "hetmec vs locality-off (naive)"),
            ("cfd_8srv_contended_hetmec_tcp",
             "cfd_8srv_contended_locality_tcp",
             "contended hetmec vs locality")):
        f, s = _sim_ms(by_name[fast]), _sim_ms(by_name[slow])
        gain = 1.0 - f / s
        if gain < IMPROVEMENT_FLOOR:
            print(f"# {what}: {f:.1f} vs {s:.1f} sim_ms — gain "
                  f"{gain:.3f} < {IMPROVEMENT_FLOOR} FLOOR",
                  file=sys.stderr)
            ok = False
        else:
            print(f"# {what}: {f:.1f} vs {s:.1f} sim_ms — gain "
                  f"{gain:.3f} (floor {IMPROVEMENT_FLOOR}) ok",
                  file=sys.stderr)
    return ok


def check_critpath(rows, baseline_path: str) -> bool:
    """Gate the critical-path rows: the tiling identity must hold, and
    the halo-wait share / hidden-halo projection must not drift beyond
    the shared BENCH_critpath.json tolerances."""
    from benchmarks.latency_breakdown import CRITPATH_TOLERANCE

    by_name = {r.name: r for r in rows}
    share_row = by_name["critpath_cfd8_halo_wait_share"]
    ident = common.derived(share_row, "identity")
    ok = ident == 1
    print(f"# critpath_cfd8 identity={ident:.0f} "
          f"{'ok' if ok else 'FAILED'}", file=sys.stderr)
    gated = [r for r in rows if r.name.startswith("critpath_")]
    return common.check_rows(
        gated, baseline_path, extract=lambda r: r.us_per_call,
        tolerance=CRITPATH_TOLERANCE, direction="lower_is_better",
        benchmark="critpath") and ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=None,
                    help="BENCH_cfd.json; fail on >20%% sim-time "
                         "regression or acceptance-floor violation")
    ap.add_argument("--write-baseline", default=None,
                    help="write measured sim_ms to this JSON path")
    ap.add_argument("--critpath-baseline", default=None,
                    help="BENCH_critpath.json; gate the halo-wait share "
                         "and hidden-halo projection rows")
    ap.add_argument("--write-critpath-baseline", default=None,
                    help="merge this module's critpath_* rows into the "
                         "shared BENCH_critpath.json at this path")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="export the traced 8-server hetmec run as "
                         "Perfetto trace_event JSON (.gz gzips it)")
    ap.add_argument("--json-out", default=None,
                    help="write the result rows to this JSON path")
    args = ap.parse_args()
    rows = run(trace_path=args.trace)
    if args.json_out:
        common.dump_rows(rows, args.json_out)
    if args.write_baseline:
        common.write_baseline(
            args.write_baseline,
            {r.name: _sim_ms(r) for r in rows
             if r.name != "cfd_functional_err"
             and not r.name.startswith("critpath_")},
            benchmark="cfd_halo", metric="sim_ms",
            direction="lower_is_better", tolerance=REGRESSION_TOLERANCE,
            regenerate=REGENERATE)
    if args.write_critpath_baseline:
        from benchmarks.latency_breakdown import write_critpath_baseline
        write_critpath_baseline(
            args.write_critpath_baseline,
            {r.name: r.us_per_call for r in rows
             if r.name.startswith("critpath_")})
    ok = True
    if args.baseline:
        ok = check_baseline(rows, args.baseline)
    if args.critpath_baseline:
        ok = check_critpath(rows, args.critpath_baseline) and ok
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
