"""Migration data-plane throughput: multi-kernel DAG with shared large
inputs over 4/8 servers, TCP and RDMA peer transports.

The workload is migration-bound by construction: two large weight
buffers are written to one server, then every other server runs
back-to-back kernel pairs that consume them. Back-to-back kernels on the
same destination exercise in-flight migration coalescing (one payload on
the wire instead of one per kernel); a second wave of servers starts
after the first drains, so replicas exist on several peers, and each
wave-2 server enqueues its second buffer's kernels while the first
buffer's push already occupies the s0 link — replica-aware source
selection then pulls the second buffer from a wave-1 replica holder over
an idle link; the payload sizes (several TCP send buffers) exercise the
chunked cut-through pipeline.

Reported per row: simulated drain time (``sim_ms`` — deterministic, so it
gates tightly), effective migration throughput (useful replicated bytes /
sim time), and the data-plane scoreboard counters
(``bytes_on_wire``/``migrations_coalesced``/``peak_chunks_in_flight``
when the runtime provides them).

  PYTHONPATH=src python -m benchmarks.migration_pipeline \
      [--baseline benchmarks/BENCH_migration.json] [--write-baseline P]

With ``--baseline``, exits non-zero if any row's simulated time regresses
more than 20% above the checked-in baseline (used by scripts/ci.sh).
"""
from __future__ import annotations

import argparse

from benchmarks import common
from benchmarks.common import ETH_1G, ETH_40G, GPU_2080TI, MiB, Row, emit
from repro.core import ClientRuntime, ServerSpec

import numpy as np

BIG = 32 * MiB            # shared weight buffer (≫ TCP_SNDBUF → chunked)
KERNELS_PER_SERVER = 2    # back-to-back consumers → coalescing candidates
REGRESSION_TOLERANCE = 0.20
REGENERATE = ("python -m benchmarks.migration_pipeline "
              "--write-baseline benchmarks/BENCH_migration.json")


def _measure(n_srv: int, peer_transport: str) -> Row:
    rt = ClientRuntime(
        servers=[ServerSpec(f"s{i}", [GPU_2080TI]) for i in range(n_srv)],
        client_link=ETH_1G, peer_link=ETH_40G,
        transport="tcp", peer_transport=peer_transport)
    weights = []
    for k in range(2):
        w = rt.create_buffer(BIG, name=f"weights{k}")
        rt.enqueue_write("s0", w, np.zeros(BIG // 4, np.uint32))
        weights.append(w)
    rt.finish()
    t0 = rt.clock.now
    outs = []

    def consume(server, w, tag):
        # back-to-back kernel pair on one buffer: the second kernel's
        # implicit migration coalesces onto the first's
        for j in range(KERNELS_PER_SERVER):
            out = rt.create_buffer(4096)
            outs.append(out)
            rt.enqueue_kernel(server, fn=None, inputs=[w], outputs=[out],
                              duration=1e-5, name=f"{server}_{tag}{j}")

    # wave 1: the first half of the peers pull both buffers from s0
    wave1 = [f"s{i}" for i in range(1, 1 + max(1, (n_srv - 1) // 2))]
    wave2 = [f"s{i}" for i in range(len(wave1) + 1, n_srv)]
    for s in wave1:
        for k, w in enumerate(weights):
            consume(s, w, f"w{k}")
    rt.finish()   # replicas of both buffers now exist on every wave-1 peer
    # wave 2: per server, start the first buffer's pull, give the push
    # time to occupy the s0 link, then enqueue the second buffer's
    # kernels — replica-aware source selection pulls it from a wave-1
    # holder over an idle link instead of queueing behind the first pull
    for s in wave2:
        consume(s, weights[0], "w0")
        rt.clock.run(until=rt.clock.now + 3e-4)   # w0 push starts at s0
        consume(s, weights[1], "w1")
    rt.finish()
    elapsed = rt.clock.now - t0
    st = rt.stats()
    useful = 2 * BIG * (n_srv - 1)        # each peer needs both buffers
    mbps = useful / elapsed / 1e6
    peer_bytes = sum(st["peer_link_bytes"].values())
    return Row(
        f"migpipe_{n_srv}srv_{peer_transport}", elapsed * 1e6,
        f"sim_ms={elapsed * 1e3:.3f};mig_mbytes_per_sec={mbps:.1f};"
        f"peer_link_bytes={peer_bytes:.0f};"
        f"bytes_on_wire={st.get('bytes_on_wire', 0.0):.0f};"
        f"migrations_coalesced={st.get('migrations_coalesced', 0)};"
        f"peak_chunks_in_flight={st.get('peak_chunks_in_flight', 0)}")


def run():
    rows = []
    for n_srv in (4, 8):
        for peer_transport in ("tcp", "rdma"):
            rows.append(_measure(n_srv, peer_transport))
    return emit(rows)


def _sim_ms(row: Row) -> float:
    return common.derived(row, "sim_ms")


def check_baseline(rows, baseline_path: str) -> bool:
    """Simulated time is deterministic, so any slowdown is a real model
    regression (lower is better — the inverse of the dispatch gate)."""
    return common.check_rows(rows, baseline_path, extract=_sim_ms,
                             tolerance=REGRESSION_TOLERANCE,
                             direction="lower_is_better", unit=" sim_ms",
                             benchmark="migration_pipeline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=None,
                    help="JSON {row_name: sim_ms}; fail on >20%% regression")
    ap.add_argument("--write-baseline", default=None,
                    help="write measured sim_ms to this JSON path")
    ap.add_argument("--json-out", default=None,
                    help="write the result rows to this JSON path")
    args = ap.parse_args()
    rows = run()
    if args.json_out:
        common.dump_rows(rows, args.json_out)
    if args.write_baseline:
        common.write_baseline(
            args.write_baseline, {r.name: _sim_ms(r) for r in rows},
            benchmark="migration_pipeline", metric="sim_ms",
            direction="lower_is_better", tolerance=REGRESSION_TOLERANCE,
            regenerate=REGENERATE)
    if args.baseline and not check_baseline(rows, args.baseline):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
