"""SLO-aware scheduling + admission control under a Poisson burst
(DESIGN.md §10; paper §6 server-side scalability under load).

A 2-server MEC cluster carries three steady closed-loop UE populations:

* **tight** — AR-style sessions with a hard 4 ms frame target
  (``ClientRuntime(slo_ms=4)``), short kernels, long think time;
* **loose** — analytics-style sessions at a relaxed 30 ms target;
* **best-effort** — no SLO at all: saturators that soak every idle
  device-second and keep the run queues warm.

At ``BURST_AT`` a Poisson burst of ``N_BURST`` extra tight-class UEs
slams the cluster (mean inter-arrival ``BURST_GAP``), each constructed
*mid-run* through the reentrant sim clock — exactly how a real MEC site
sees a flash crowd. Five scenarios share the identical workload:

* ``slo_drr`` — the PR 5 fair scheduler, deadline-blind: every tight
  frame waits out the best-effort ring rotation, so the tight class
  blows its SLO almost every frame. The control row.
* ``slo_edf`` / ``slo_llf`` — earliest-deadline-first and
  least-laxity-first (chunk-granularity preemption): steady state holds
  the SLO, but the unscreened burst overloads the class anyway.
* ``slo_edf_admit`` / ``slo_llf_admit`` — the same schedulers behind
  the probe-driven admission controller: burst arrivals that fit are
  admitted, marginal ones are degraded to a 2x target, the rest are
  rejected — the classes the cluster *did* promise stay within SLO.

Violation accounting is the runtime's own (client-ack latency vs the
tenant's *effective* target), cross-checked here against the per-event
ledger: every issued frame must complete exactly once (``lost=0``,
``dup=0``) even under llf preemption churn.

  PYTHONPATH=src python -m benchmarks.slo_burst \
      [--baseline benchmarks/BENCH_slo.json] [--write-baseline P]

With ``--baseline``, exits non-zero if any row's simulated drain time
regresses more than 20% above the checked-in baseline, or if the
acceptance floors fail: the DRR control row must violate ≥ 25% of tight
frames (else the comparison is vacuous); under EDF/LLF + admission the
tight class's violation rate must be ≤ 20% of DRR's and every admitted
class (tight, degraded, loose) must hold its contract — p95 within its
effective SLO and ≤ 5% of frames over it; llf rows must actually
preempt; every row's completion ledger must balance. Simulated time
is deterministic, so the baseline is portable (used by
scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import random
import sys

import numpy as np

from benchmarks import common
from benchmarks.common import ETH_40G, GPU_2080TI, Row, emit
from repro.core import (AdmissionRejected, COMPLETE, ClientRuntime,
                        Cluster, LinkSpec, ServerSpec)

N_SERVERS = 2
RADIO_5G = LinkSpec(latency=150e-6, bandwidth=1e9 / 8)  # uRLLC access

N_TIGHT = 40
SLO_TIGHT_MS = 4.0
T_TIGHT = 0.8e-3                # tight-class kernel
THINK_TIGHT = 40e-3
FRAMES_TIGHT = 40

N_LOOSE = 16
SLO_LOOSE_MS = 30.0
T_LOOSE = 2e-3
THINK_LOOSE = 60e-3
FRAMES_LOOSE = 25

N_BE = 14                       # best-effort saturators (no SLO)
T_BE = 1.2e-3
THINK_BE = 1.5e-3
FRAMES_BE = 30

N_BURST = 120                   # flash crowd, all tight-class
BURST_AT = 0.4                  # sim-seconds after steady state starts
BURST_GAP = 0.8e-3             # Poisson mean inter-arrival
FRAMES_BURST = 10

QUANTUM = 2e-3                  # drr
CHUNK = 0.5e-3                  # llf preemption grain
STAGGER = 0.9e-3                # steady-UE start offsets
GRACE = 0.5e-3                  # handshake-to-first-frame gap
SEED = 7

ADMISSION_OPTS = {"window_s": 0.04, "headroom": 0.25, "degrade_factor": 2.0}

REGRESSION_TOLERANCE = 0.20
DRR_VIOL_FLOOR = 0.25           # control row must actually hurt
RATIO_CEILING = 0.20            # admit rows vs the DRR control row
ADMITTED_VIOL_CEILING = 0.05    # per admitted class, in admit rows
REGENERATE = ("python -m benchmarks.slo_burst "
              "--write-baseline benchmarks/BENCH_slo.json")

SCENARIOS = [
    ("slo_drr", "drr", False),
    ("slo_edf", "edf", False),
    ("slo_llf", "llf", False),
    ("slo_edf_admit", "edf", True),
    ("slo_llf_admit", "llf", True),
]


def _mk_cluster(scheduler: str, admit: bool) -> Cluster:
    opts = None
    if scheduler == "drr":
        opts = {"quantum": QUANTUM}
    elif scheduler == "llf":
        opts = {"chunk": CHUNK}
    return Cluster([ServerSpec(f"s{i}", [GPU_2080TI])
                    for i in range(N_SERVERS)],
                   peer_link=ETH_40G, scheduler=scheduler,
                   scheduler_opts=opts,
                   admission=dict(ADMISSION_OPTS) if admit else None)


class SloUE:
    """One closed-loop session: issue a frame kernel, think, repeat.
    Latency/violation scoring uses the runtime's own client-ack
    accounting (``ev.t_client_ack``), read back after the run."""

    def __init__(self, cluster: Cluster, name: str, server: str,
                 slo_ms, t_kernel: float, think: float, frames: int,
                 rng: random.Random):
        self.rt = ClientRuntime(
            cluster=cluster, client_link=RADIO_5G, transport="tcp",
            name=name, slo_ms=slo_ms,
            slo_probe={"cost_s": t_kernel} if slo_ms is not None
            else None)
        self.server = server
        self.t_kernel = t_kernel
        self.frames = frames
        # pre-drawn think jitter: consumed at construction so frame
        # pacing never depends on cross-scenario event interleaving
        self._thinks = [think * (0.7 + 0.6 * rng.random())
                        for _ in range(frames)]
        self.events: list = []
        self.completions = 0
        self._frame_no = 0

    def start(self, delay: float):
        self.rt.clock.schedule(delay, self._next_frame)

    def _next_frame(self):
        i = self._frame_no
        if i >= self.frames:
            return
        self._frame_no += 1
        ev = self.rt.enqueue_kernel(self.server, fn=None,
                                    duration=self.t_kernel,
                                    name=f"f{i}")
        self.events.append(ev)

        def done(_ev, i=i):
            self.completions += 1
            self.rt.clock.schedule(self._thinks[i], self._next_frame)

        ev.on_complete(done)


def _class_rollup(ues) -> dict:
    """Aggregate per *effective* SLO class (degraded tenants land in the
    relaxed class they actually got): runtime violation counters plus
    pooled client-ack latencies."""
    by: dict = {}
    for ue in ues:
        rt = ue.rt
        if rt._slo_s is None:
            continue
        d = by.setdefault(rt._slo_class,
                          {"cmds": 0, "viol": 0, "lat": []})
        d["cmds"] += rt.slo_commands
        d["viol"] += rt.slo_violations
        d["lat"].extend(ev.t_client_ack - ev.t_queued
                        for ev in ue.events)
    return by


def _ledger(ues) -> tuple:
    """Exactly-once check: every issued frame completed once — no frame
    lost (missing/errored completion, short issue count) and none
    double-fired, even under llf preempt/requeue churn."""
    lost = dup = 0
    for ue in ues:
        issued = len(ue.events)
        bad = sum(1 for ev in ue.events if ev.status != COMPLETE)
        lost += bad + (ue.frames - issued)
        if ue.completions > issued:
            dup += ue.completions - issued
        elif ue.completions < issued - bad:
            lost += (issued - bad) - ue.completions
        if ue.rt.slo_ms is not None and ue.rt.slo_commands != issued:
            lost += abs(ue.rt.slo_commands - issued)
    return lost, dup


def _cls(by: dict, key: str) -> tuple:
    d = by.get(key)
    if d is None or not d["cmds"]:
        return 0, 0.0, 0.0, 0.0
    lat = np.asarray(d["lat"]) * 1e3
    return (d["cmds"], d["viol"] / d["cmds"],
            float(np.percentile(lat, 95)), float(np.percentile(lat, 99)))


def _run_scenario(scheduler: str, admit: bool) -> dict:
    cluster = _mk_cluster(scheduler, admit)
    rng = random.Random(SEED)
    ues = []
    for i in range(N_TIGHT):
        ues.append(SloUE(cluster, f"t{i}", f"s{i % N_SERVERS}",
                         SLO_TIGHT_MS, T_TIGHT, THINK_TIGHT,
                         FRAMES_TIGHT, rng))
    for i in range(N_LOOSE):
        ues.append(SloUE(cluster, f"l{i}", f"s{i % N_SERVERS}",
                         SLO_LOOSE_MS, T_LOOSE, THINK_LOOSE,
                         FRAMES_LOOSE, rng))
    for i in range(N_BE):
        ues.append(SloUE(cluster, f"e{i}", f"s{i % N_SERVERS}",
                         None, T_BE, THINK_BE, FRAMES_BE, rng))
    cluster.run()                           # handshakes drained
    t0 = cluster.clock.now
    for i, ue in enumerate(ues):
        ue.start(delay=GRACE + i * STAGGER)

    # the flash crowd: tight-class arrivals constructed mid-run (the
    # sim clock is reentrant), screened by admission where enabled
    rejected = [0]
    arrival = t0 + BURST_AT
    for k in range(N_BURST):
        arrival += rng.expovariate(1.0 / BURST_GAP)

        def spawn(k=k):
            try:
                ue = SloUE(cluster, f"b{k}", f"s{k % N_SERVERS}",
                           SLO_TIGHT_MS, T_TIGHT, THINK_TIGHT,
                           FRAMES_BURST, rng)
            except AdmissionRejected:
                rejected[0] += 1
                return
            ues.append(ue)
            ue.start(delay=GRACE)

        cluster.clock.schedule_at(arrival, spawn)
    cluster.run()
    elapsed = cluster.clock.now - t0

    by = _class_rollup(ues)
    tcmds, tviol, tp95, tp99 = _cls(by, f"{SLO_TIGHT_MS:g}ms")
    _, lviol, lp95, lp99 = _cls(by, f"{SLO_LOOSE_MS:g}ms")
    deg_ms = SLO_TIGHT_MS * ADMISSION_OPTS["degrade_factor"]
    dcmds, dviol, dp95, dp99 = _cls(by, f"{deg_ms:g}ms")
    lost, dup = _ledger(ues)
    adm = cluster.admission
    preempted = sum(s.preempted for h in cluster.hosts.values()
                    for s in h.schedulers.values())
    return {
        "sim_ms": elapsed * 1e3,
        "tviol": tviol, "tp95": tp95, "tp99": tp99, "tcmds": tcmds,
        "lviol": lviol, "lp95": lp95, "lp99": lp99,
        "dviol": dviol, "dp95": dp95, "dp99": dp99, "dcmds": dcmds,
        "admitted": adm.counts["admit"] if adm else 0,
        "degraded": adm.counts["degrade"] if adm else 0,
        "rejected": rejected[0],
        "preempted": preempted,
        "lost": lost, "dup": dup,
    }


def run():
    rows = []
    for name, scheduler, admit in SCENARIOS:
        r = _run_scenario(scheduler, admit)
        rows.append(Row(
            name, r["sim_ms"],
            f"sim_ms={r['sim_ms']:.3f};"
            f"tviol={r['tviol']:.4f};tp95={r['tp95']:.3f};"
            f"tp99={r['tp99']:.3f};tcmds={r['tcmds']};"
            f"lviol={r['lviol']:.4f};lp95={r['lp95']:.3f};"
            f"lp99={r['lp99']:.3f};"
            f"dviol={r['dviol']:.4f};dp95={r['dp95']:.3f};"
            f"dp99={r['dp99']:.3f};dcmds={r['dcmds']};"
            f"admitted={r['admitted']};degraded={r['degraded']};"
            f"rejected={r['rejected']};preempted={r['preempted']};"
            f"lost={r['lost']};dup={r['dup']}"))
    return emit(rows)


def check_baseline(rows, baseline_path: str) -> bool:
    by_name = {r.name: r for r in rows}
    ok = common.check_rows(rows, baseline_path,
                           extract=lambda r: common.derived(r, "sim_ms"),
                           tolerance=REGRESSION_TOLERANCE,
                           direction="lower_is_better", unit=" sim_ms",
                           benchmark="slo_burst")
    d = common.derived
    drr_viol = d(by_name["slo_drr"], "tviol")
    if drr_viol < DRR_VIOL_FLOOR:
        print(f"# slo_drr: tight violation rate {drr_viol:.4f} < "
              f"{DRR_VIOL_FLOOR} FLOOR (control row is vacuous)",
              file=sys.stderr)
        ok = False
    deg_ms = SLO_TIGHT_MS * ADMISSION_OPTS["degrade_factor"]
    for name in ("slo_edf_admit", "slo_llf_admit"):
        row = by_name[name]
        viol = d(row, "tviol")
        ceiling = RATIO_CEILING * drr_viol
        if viol > ceiling:
            print(f"# {name}: tight violation rate {viol:.4f} > "
                  f"{RATIO_CEILING} x drr ({ceiling:.4f}) CEILING",
                  file=sys.stderr)
            ok = False
        else:
            print(f"# {name}: tight violation rate {viol:.4f} <= "
                  f"{RATIO_CEILING} x drr ({ceiling:.4f}) ok",
                  file=sys.stderr)
        # every class the controller admitted must hold its contract:
        # p95 within the effective SLO and ≤ 5% of frames over it (the
        # sim is deterministic — these margins absorb legitimate timing
        # shifts, not noise)
        for label, key, slo in (
                ("tight", "t", SLO_TIGHT_MS),
                ("loose", "l", SLO_LOOSE_MS),
                ("degraded", "d", deg_ms)):
            if label == "degraded" and d(row, "dcmds") == 0:
                continue
            p95 = d(row, key + "p95")
            vr = d(row, key + "viol")
            if p95 > slo:
                print(f"# {name}: {label} p95 {p95:.3f} ms > "
                      f"{slo:g} ms SLO", file=sys.stderr)
                ok = False
            if vr > ADMITTED_VIOL_CEILING:
                print(f"# {name}: {label} violation rate {vr:.4f} > "
                      f"{ADMITTED_VIOL_CEILING} CEILING",
                      file=sys.stderr)
                ok = False
        if d(row, "rejected") == 0:
            print(f"# {name}: admission rejected nothing under a "
                  f"{N_BURST}-UE burst", file=sys.stderr)
            ok = False
    for name in ("slo_llf", "slo_llf_admit"):
        if d(by_name[name], "preempted") == 0:
            print(f"# {name}: llf never preempted", file=sys.stderr)
            ok = False
    for r in rows:
        lost, dup = d(r, "lost"), d(r, "dup")
        if lost or dup:
            print(f"# {r.name}: completion ledger broken "
                  f"(lost={lost:.0f} dup={dup:.0f})", file=sys.stderr)
            ok = False
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=None,
                    help="BENCH_slo.json; fail on >20%% sim-time "
                         "regression or acceptance-floor violation")
    ap.add_argument("--write-baseline", default=None,
                    help="write measured sim_ms to this JSON path")
    ap.add_argument("--json-out", default=None,
                    help="write the result rows to this JSON path")
    args = ap.parse_args()
    rows = run()
    if args.json_out:
        common.dump_rows(rows, args.json_out)
    if args.write_baseline:
        common.write_baseline(
            args.write_baseline,
            {r.name: common.derived(r, "sim_ms") for r in rows},
            benchmark="slo_burst", metric="sim_ms",
            direction="lower_is_better", tolerance=REGRESSION_TOLERANCE,
            regenerate=REGENERATE)
    if args.baseline and not check_baseline(rows, args.baseline):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
