"""Multi-tenant server scalability: many AR-style UE sessions sharing
one MEC cluster (paper §5–§6 server-side scalability; DESIGN.md §4).

Each UE runs a closed-loop AR frame pipeline against its primary server
— upload the depth map, run the point sort, read the index buffer back
— and every 8 frames hands the second half of the window to a secondary
server, dragging its 2 MiB model buffer across the peer mesh (the
kernel updates the model, so each hand-off is a fresh migration, not a
cached replica). All UEs share the cluster's devices (arbitrated by the
fair scheduler), peer links, and per-server egress NICs; each brings
its own radio link.

Rows:

* ``mt_1ue_*`` / ``mt_32ue_*`` (TCP + RDMA peers, DRR scheduler): the
  scaling story. ``eff`` is aggregate scaling efficiency — aggregate
  frame throughput at 32 UEs over 32× the single-UE throughput —
  and ``p95_spread`` the cross-tenant fairness spread
  ``(max p95 − min p95) / mean p95``.
* ``mt_straggler_fifo`` / ``mt_straggler_drr``: one tenant floods a
  server with a deep backlog of 8 ms kernels while 8 light UEs run
  frames. FIFO head-of-line blocks the collocated tenants for the whole
  backlog; DRR bounds their p95 to ~one straggler kernel.
* ``mt_dedup_private`` / ``mt_dedup_shared`` (DESIGN.md §5): 32 UEs load
  ONE identical 2 MiB model (read-only inference weights) and roam.
  Private copies push the same bytes through every radio and across the
  peer mesh once per UE; the content-addressed store collapses them to
  one upload per server and zero roam migrations. ``reduction`` is the
  relative cut in payload wire bytes (uploads + migrations), gated ≥ 40%
  against ``benchmarks/BENCH_dedup.json`` alongside the sim-time rows.

  PYTHONPATH=src python -m benchmarks.multi_tenant \
      [--baseline benchmarks/BENCH_multitenant.json] \
      [--dedup-baseline benchmarks/BENCH_dedup.json] [--write-baseline P]

With ``--baseline``, exits non-zero if any row's simulated drain time
regresses more than 20% above the checked-in baseline, or if the
acceptance floors fail (efficiency ≥ 0.70, p95 spread ≤ 0.25, DRR
straggler p95 below half the FIFO one, dedup payload reduction ≥ 40%).
Simulated time is deterministic, so the baseline is portable (used by
scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks import common
from benchmarks.common import ETH_40G, GPU_2080TI, MiB, Row, WIFI6, emit
from repro.core import ClientRuntime, Cluster, ServerSpec

N_SERVERS = 4
FRAMES = 24
DEPTH_BYTES = 96 * 1024         # per-frame upload (and index readback)
MODEL_BYTES = 2 * MiB           # per-UE model dragged on server hand-off
T_KERNEL = 1e-3                 # point sort on the server GPU
NIC_BW = 25e9 / 8               # per-server egress port: slower than the
                                # 40G peer links, so peer pushes and all
                                # client egress share one binding budget
QUANTUM = 2e-3
STAGGER = 1.3e-3                # UE start offset (decorrelates frames)
STRAGGLER_KERNELS = 100
STRAGGLER_WINDOW = 6            # heavy kernels kept in flight
STRAGGLER_FRAMES = 12           # light-UE frames in the straggler rows
T_STRAGGLER = 8e-3
DEDUP_UES = 32
DEDUP_FRAMES = 8
REGRESSION_TOLERANCE = 0.20
EFFICIENCY_FLOOR = 0.70
SPREAD_CEILING = 0.25
DEDUP_REDUCTION_FLOOR = 0.40
REGENERATE = ("python -m benchmarks.multi_tenant "
              "--write-baseline benchmarks/BENCH_multitenant.json")
REGENERATE_DEDUP = ("python -m benchmarks.multi_tenant "
                    "--write-dedup-baseline benchmarks/BENCH_dedup.json")


def _mk_cluster(peer_transport: str, scheduler: str,
                store: bool = False) -> Cluster:
    return Cluster([ServerSpec(f"s{i}", [GPU_2080TI])
                    for i in range(N_SERVERS)],
                   peer_link=ETH_40G, peer_transport=peer_transport,
                   scheduler=scheduler, scheduler_quantum=QUANTUM,
                   nic_bandwidth=NIC_BW, store=store)


class UE:
    """One AR client session: closed-loop frames, next frame enqueued
    when the previous read lands (self-paced under contention)."""

    def __init__(self, cluster: Cluster, idx: int, frames: int = FRAMES,
                 roam: bool = True, shared_model: bool = False):
        self.rt = ClientRuntime(cluster=cluster, client_link=WIFI6,
                                transport="tcp", name=f"ue{idx}")
        self.idx = idx
        self.primary = f"s{idx % N_SERVERS}"
        self.secondary = f"s{(idx + 1) % N_SERVERS}"
        self.frames = frames
        self.roam = roam and N_SERVERS > 1
        # shared_model: the 2 MiB model is read-only inference weights,
        # bit-identical across every UE (the §5 dedup scenario) — the
        # kernel no longer clobbers it, and each frame's depth map is
        # unique so only the model is cross-tenant redundant
        self.shared_model = shared_model
        self.latencies: list = []
        self.depth = self.rt.create_buffer(DEPTH_BYTES)
        self.index = self.rt.create_buffer(DEPTH_BYTES)
        self.model = self.rt.create_buffer(MODEL_BYTES)
        self._depth_data = np.zeros(DEPTH_BYTES // 4, np.uint32)
        self._frame_no = 0
        self._phase = idx % 8           # desynchronizes roam hand-offs
        self.commands = 0               # every command incl. migrations

    def start(self, delay: float = 0.0):
        """Begin the frame loop after ``delay`` sim-seconds: staggered
        starts keep identically-timed UEs from convoying on the device
        run queues (real UEs are never phase-locked)."""
        def go():
            seed = self.rt.enqueue_write(self.primary, self.model,
                                         np.zeros(MODEL_BYTES // 4,
                                                  np.uint32))
            self.commands += 1
            # frames begin once the model is resident server-side (the
            # app's load phase) — frame latency measures steady state,
            # not the one-time 2 MiB upload crawling up the radio
            seed.on_complete(lambda _e: self._next_frame())
        self.rt.clock.schedule(delay, go)

    def _next_frame(self):
        i = self._frame_no
        if i >= self.frames:
            return
        self._frame_no += 1
        srv = (self.secondary
               if (self.roam and (i + self._phase) % 8 >= 4)
               else self.primary)
        rt = self.rt
        t0 = rt.clock.now
        # a hand-off finds the model invalid on srv (the kernel clobbers
        # it every frame), so enqueue_kernel adds an implicit migration
        self.commands += 3 + (srv not in self.model.valid_on)
        if self.shared_model:
            # unique per (UE, frame): depth maps are real sensor data
            # and must never dedup — only the model is redundant
            depth_data = np.full(DEPTH_BYTES // 4,
                                 self.idx * 65536 + i, np.uint32)
            outputs = [self.index]
        else:
            depth_data = self._depth_data
            outputs = [self.index, self.model]
        e1 = rt.enqueue_write(srv, self.depth, depth_data)
        # the sort consumes the depth map + model and refreshes the
        # index buffer — and, unless the model is shared read-only
        # weights, the model too, so a server hand-off re-migrates
        e2 = rt.enqueue_kernel(srv, fn=None,
                               inputs=[self.depth, self.model],
                               outputs=outputs,
                               duration=T_KERNEL, wait_for=[e1],
                               name=f"sort{i}")
        e3 = rt.enqueue_read(srv, self.index, wait_for=[e2])

        def frame_done(_ev, t0=t0):
            self.latencies.append(rt.clock.now - t0)
            self._next_frame()

        e3.on_complete(frame_done)


def _percentiles(lat):
    arr = np.asarray(lat) * 1e3             # ms
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 95))


def _run_scaling(n_ue: int, peer_transport: str):
    cluster = _mk_cluster(peer_transport, "drr")
    ues = [UE(cluster, i) for i in range(n_ue)]
    cluster.run()                           # handshakes drained
    t0 = cluster.clock.now
    for i, ue in enumerate(ues):
        ue.start(delay=i * STAGGER)
    cluster.run()
    elapsed = cluster.clock.now - t0
    agg_fps = n_ue * FRAMES / elapsed
    p50s, p95s = zip(*(_percentiles(u.latencies) for u in ues))
    cmds = sum(u.commands for u in ues)     # incl. hand-off migrations
    return {
        "sim_ms": elapsed * 1e3,
        "agg_fps": agg_fps,
        "cmds_per_sec": cmds / elapsed,
        "p50_ms": float(np.mean(p50s)),
        "p95_ms": float(np.max(p95s)),
        "p95_spread": (max(p95s) - min(p95s)) / float(np.mean(p95s))
        if n_ue > 1 else 0.0,
    }


class Straggler:
    """A misbehaving tenant keeping a deep backlog of heavy kernels in
    flight on one server for the whole run (windowed closed loop, so the
    queue stays ~``window`` kernels deep instead of draining once)."""

    def __init__(self, cluster: Cluster, server: str = "s0",
                 total: int = STRAGGLER_KERNELS,
                 window: int = STRAGGLER_WINDOW):
        self.rt = ClientRuntime(cluster=cluster, client_link=WIFI6,
                                transport="tcp", name="straggler")
        self.server = server
        self.remaining = total
        self.window = window

    def start(self):
        for _ in range(self.window):
            self._launch()

    def _launch(self):
        if self.remaining <= 0:
            return
        self.remaining -= 1
        ev = self.rt.enqueue_kernel(self.server, fn=None,
                                    duration=T_STRAGGLER)
        ev.on_complete(lambda _e: self._launch())


def _run_straggler(scheduler: str):
    cluster = _mk_cluster("tcp", scheduler)
    lights = [UE(cluster, i, frames=STRAGGLER_FRAMES, roam=False)
              for i in range(8)]
    straggler = Straggler(cluster)
    cluster.run()
    t0 = cluster.clock.now
    straggler.start()
    cluster.run(until=cluster.clock.now + 5e-3)   # backlog lands first
    for i, ue in enumerate(lights):
        ue.start(delay=i * STAGGER)
    cluster.run()
    elapsed = cluster.clock.now - t0
    p95s = [_percentiles(u.latencies)[1] for u in lights]
    return {"sim_ms": elapsed * 1e3, "light_p95_ms": max(p95s),
            "light_p95_min_ms": min(p95s)}


def _run_shared_weights(dedup: bool) -> dict:
    """32 UEs, ONE 2 MiB model (read-only weights): private copies vs
    the content-addressed store (DESIGN.md §5). Reported payload bytes
    are everything that crossed a wire as bulk data — radio uploads plus
    peer-mesh migrations — and ``nic_busy`` is the shared egress ports'
    cumulative occupancy."""
    cluster = _mk_cluster("tcp", "drr", store=dedup)
    ues = [UE(cluster, i, frames=DEDUP_FRAMES, shared_model=True)
           for i in range(DEDUP_UES)]
    cluster.run()                           # handshakes drained
    t0 = cluster.clock.now
    for i, ue in enumerate(ues):
        ue.start(delay=i * STAGGER)
    cluster.run()
    elapsed = cluster.clock.now - t0
    payload = 0.0
    dedup_hits = 0
    for u in ues:
        st = u.rt.stats()
        payload += st["bytes_on_wire"] + st["upload_bytes_on_wire"]
        dedup_hits += st["dedup_hits"]
    cst = cluster.stats()
    return {
        "sim_ms": elapsed * 1e3,
        "payload_mb": payload / 1e6,
        "nic_busy_ms": sum(cst["nic_busy"].values()) * 1e3,
        "dedup_hits": dedup_hits,
        "p95_ms": max(_percentiles(u.latencies)[1] for u in ues),
    }


def run():
    rows = []
    eff = {}
    for tr in ("tcp", "rdma"):
        one = _run_scaling(1, tr)
        many = _run_scaling(32, tr)
        eff[tr] = many["agg_fps"] / (32 * one["agg_fps"])
        rows.append(Row(
            f"mt_1ue_{tr}", one["p50_ms"] * 1e3,
            f"sim_ms={one['sim_ms']:.3f};agg_fps={one['agg_fps']:.1f};"
            f"cmds_per_sec={one['cmds_per_sec']:.0f};"
            f"p50_ms={one['p50_ms']:.3f};p95_ms={one['p95_ms']:.3f}"))
        rows.append(Row(
            f"mt_32ue_{tr}", many["p50_ms"] * 1e3,
            f"sim_ms={many['sim_ms']:.3f};agg_fps={many['agg_fps']:.1f};"
            f"cmds_per_sec={many['cmds_per_sec']:.0f};"
            f"p50_ms={many['p50_ms']:.3f};p95_ms={many['p95_ms']:.3f};"
            f"p95_spread={many['p95_spread']:.3f};eff={eff[tr]:.3f}"))
    for scheduler in ("fifo", "drr"):
        r = _run_straggler(scheduler)
        rows.append(Row(
            f"mt_straggler_{scheduler}", r["light_p95_ms"] * 1e3,
            f"sim_ms={r['sim_ms']:.3f};"
            f"light_p95_ms={r['light_p95_ms']:.3f};"
            f"light_p95_min_ms={r['light_p95_min_ms']:.3f}"))
    private = _run_shared_weights(dedup=False)
    shared = _run_shared_weights(dedup=True)
    reduction = 1.0 - shared["payload_mb"] / private["payload_mb"]
    nic_reduction = 1.0 - shared["nic_busy_ms"] / private["nic_busy_ms"]
    rows.append(Row(
        "mt_dedup_private", private["p95_ms"] * 1e3,
        f"sim_ms={private['sim_ms']:.3f};"
        f"payload_mb={private['payload_mb']:.1f};"
        f"nic_busy_ms={private['nic_busy_ms']:.3f};"
        f"p95_ms={private['p95_ms']:.3f}"))
    rows.append(Row(
        "mt_dedup_shared", shared["p95_ms"] * 1e3,
        f"sim_ms={shared['sim_ms']:.3f};"
        f"payload_mb={shared['payload_mb']:.1f};"
        f"nic_busy_ms={shared['nic_busy_ms']:.3f};"
        f"p95_ms={shared['p95_ms']:.3f};"
        f"dedup_hits={shared['dedup_hits']};"
        f"reduction={reduction:.3f};nic_reduction={nic_reduction:.3f}"))
    return emit(rows)


_derived = common.derived     # back-compat alias (tests, older callers)


def check_baseline(rows, baseline_path: str) -> bool:
    by_name = {r.name: r for r in rows}
    ok = common.check_rows(rows, baseline_path,
                           extract=lambda r: common.derived(r, "sim_ms"),
                           tolerance=REGRESSION_TOLERANCE,
                           direction="lower_is_better", unit=" sim_ms",
                           benchmark="multi_tenant")
    # acceptance floors (ISSUE 3): scaling efficiency, fairness spread,
    # and the fair policy actually bounding the straggler tail
    for tr in ("tcp", "rdma"):
        row = by_name[f"mt_32ue_{tr}"]
        eff = common.derived(row, "eff")
        spread = common.derived(row, "p95_spread")
        if eff < EFFICIENCY_FLOOR:
            print(f"# {row.name}: efficiency {eff:.3f} < "
                  f"{EFFICIENCY_FLOOR} FLOOR", file=sys.stderr)
            ok = False
        if spread > SPREAD_CEILING:
            print(f"# {row.name}: p95 spread {spread:.3f} > "
                  f"{SPREAD_CEILING} CEILING", file=sys.stderr)
            ok = False
    fifo = common.derived(by_name["mt_straggler_fifo"], "light_p95_ms")
    drr = common.derived(by_name["mt_straggler_drr"], "light_p95_ms")
    if not drr < 0.5 * fifo:
        print(f"# straggler: drr p95 {drr:.3f} ms not < half of fifo "
              f"{fifo:.3f} ms", file=sys.stderr)
        ok = False
    return ok


def check_dedup_baseline(rows, baseline_path: str) -> bool:
    """Gate the shared-weights scenario (ISSUE 4): sim-time regressions
    on both rows, plus the acceptance floor — the store must cut payload
    wire bytes by ≥ 40% vs private copies."""
    ok = common.check_rows(rows, baseline_path,
                           extract=lambda r: common.derived(r, "sim_ms"),
                           tolerance=REGRESSION_TOLERANCE,
                           direction="lower_is_better", unit=" sim_ms",
                           benchmark="multi_tenant (shared-weights dedup)")
    shared = next(r for r in rows if r.name == "mt_dedup_shared")
    reduction = common.derived(shared, "reduction")
    if reduction < DEDUP_REDUCTION_FLOOR:
        print(f"# mt_dedup_shared: payload reduction {reduction:.3f} < "
              f"{DEDUP_REDUCTION_FLOOR} FLOOR", file=sys.stderr)
        ok = False
    else:
        print(f"# mt_dedup_shared: payload reduction {reduction:.3f} "
              f"(floor {DEDUP_REDUCTION_FLOOR}) ok", file=sys.stderr)
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=None,
                    help="JSON {row_name: sim_ms}; fail on >20%% "
                         "regression or acceptance-floor violation")
    ap.add_argument("--dedup-baseline", default=None,
                    help="BENCH_dedup.json; also gates the ≥40%% payload "
                         "reduction floor")
    ap.add_argument("--write-baseline", default=None,
                    help="write measured sim_ms to this JSON path")
    ap.add_argument("--write-dedup-baseline", default=None,
                    help="write the dedup rows' sim_ms to this JSON path")
    ap.add_argument("--json-out", default=None,
                    help="write the result rows to this JSON path")
    args = ap.parse_args()
    rows = run()
    dedup_rows = [r for r in rows if r.name.startswith("mt_dedup_")]
    main_rows = [r for r in rows if not r.name.startswith("mt_dedup_")]
    if args.json_out:
        common.dump_rows(rows, args.json_out)
    if args.write_baseline:
        common.write_baseline(
            args.write_baseline,
            {r.name: common.derived(r, "sim_ms") for r in main_rows},
            benchmark="multi_tenant", metric="sim_ms",
            direction="lower_is_better", tolerance=REGRESSION_TOLERANCE,
            regenerate=REGENERATE)
    if args.write_dedup_baseline:
        common.write_baseline(
            args.write_dedup_baseline,
            {r.name: common.derived(r, "sim_ms") for r in dedup_rows},
            benchmark="multi_tenant (shared-weights dedup)",
            metric="sim_ms", direction="lower_is_better",
            tolerance=REGRESSION_TOLERANCE, regenerate=REGENERATE_DEDUP)
    ok = True
    if args.baseline:
        ok = check_baseline(main_rows, args.baseline) and ok
    if args.dedup_baseline:
        ok = check_dedup_baseline(dedup_rows, args.dedup_baseline) and ok
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
