"""Multi-tenant server scalability: many AR-style UE sessions sharing
one MEC cluster (paper §5–§6 server-side scalability; DESIGN.md §4).

Each UE runs a closed-loop AR frame pipeline against its primary server
— upload the depth map, run the point sort, read the index buffer back
— and every 8 frames hands the second half of the window to a secondary
server, dragging its 2 MiB model buffer across the peer mesh (the
kernel updates the model, so each hand-off is a fresh migration, not a
cached replica). All UEs share the cluster's devices (arbitrated by the
fair scheduler), peer links, and per-server egress NICs; each brings
its own radio link.

Rows:

* ``mt_1ue_*`` / ``mt_32ue_*`` (TCP + RDMA peers, DRR scheduler): the
  scaling story. ``eff`` is aggregate scaling efficiency — aggregate
  frame throughput at 32 UEs over 32× the single-UE throughput —
  and ``p95_spread`` the cross-tenant fairness spread
  ``(max p95 − min p95) / mean p95``.
* ``mt_straggler_fifo`` / ``mt_straggler_drr``: one tenant floods a
  server with a deep backlog of 8 ms kernels while 8 light UEs run
  frames. FIFO head-of-line blocks the collocated tenants for the whole
  backlog; DRR bounds their p95 to ~one straggler kernel.

  PYTHONPATH=src python -m benchmarks.multi_tenant \
      [--baseline benchmarks/BENCH_multitenant.json] [--write-baseline P]

With ``--baseline``, exits non-zero if any row's simulated drain time
regresses more than 20% above the checked-in baseline, or if the
acceptance floors fail (efficiency ≥ 0.70, p95 spread ≤ 0.25, DRR
straggler p95 below half the FIFO one). Simulated time is deterministic,
so the baseline is portable (used by scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from benchmarks.common import ETH_40G, GPU_2080TI, MiB, Row, WIFI6, emit
from repro.core import ClientRuntime, Cluster, ServerSpec

N_SERVERS = 4
FRAMES = 24
DEPTH_BYTES = 96 * 1024         # per-frame upload (and index readback)
MODEL_BYTES = 2 * MiB           # per-UE model dragged on server hand-off
T_KERNEL = 1e-3                 # point sort on the server GPU
NIC_BW = 25e9 / 8               # per-server egress port: slower than the
                                # 40G peer links, so peer pushes and all
                                # client egress share one binding budget
QUANTUM = 2e-3
STAGGER = 1.3e-3                # UE start offset (decorrelates frames)
STRAGGLER_KERNELS = 100
STRAGGLER_WINDOW = 6            # heavy kernels kept in flight
STRAGGLER_FRAMES = 12           # light-UE frames in the straggler rows
T_STRAGGLER = 8e-3
REGRESSION_TOLERANCE = 0.20
EFFICIENCY_FLOOR = 0.70
SPREAD_CEILING = 0.25


def _mk_cluster(peer_transport: str, scheduler: str) -> Cluster:
    return Cluster([ServerSpec(f"s{i}", [GPU_2080TI])
                    for i in range(N_SERVERS)],
                   peer_link=ETH_40G, peer_transport=peer_transport,
                   scheduler=scheduler, scheduler_quantum=QUANTUM,
                   nic_bandwidth=NIC_BW)


class UE:
    """One AR client session: closed-loop frames, next frame enqueued
    when the previous read lands (self-paced under contention)."""

    def __init__(self, cluster: Cluster, idx: int, frames: int = FRAMES,
                 roam: bool = True):
        self.rt = ClientRuntime(cluster=cluster, client_link=WIFI6,
                                transport="tcp", name=f"ue{idx}")
        self.primary = f"s{idx % N_SERVERS}"
        self.secondary = f"s{(idx + 1) % N_SERVERS}"
        self.frames = frames
        self.roam = roam and N_SERVERS > 1
        self.latencies: list = []
        self.depth = self.rt.create_buffer(DEPTH_BYTES)
        self.index = self.rt.create_buffer(DEPTH_BYTES)
        self.model = self.rt.create_buffer(MODEL_BYTES)
        self._depth_data = np.zeros(DEPTH_BYTES // 4, np.uint32)
        self._frame_no = 0
        self._phase = idx % 8           # desynchronizes roam hand-offs
        self.commands = 0               # every command incl. migrations

    def start(self, delay: float = 0.0):
        """Begin the frame loop after ``delay`` sim-seconds: staggered
        starts keep identically-timed UEs from convoying on the device
        run queues (real UEs are never phase-locked)."""
        def go():
            seed = self.rt.enqueue_write(self.primary, self.model,
                                         np.zeros(MODEL_BYTES // 4,
                                                  np.uint32))
            self.commands += 1
            # frames begin once the model is resident server-side (the
            # app's load phase) — frame latency measures steady state,
            # not the one-time 2 MiB upload crawling up the radio
            seed.on_complete(lambda _e: self._next_frame())
        self.rt.clock.schedule(delay, go)

    def _next_frame(self):
        i = self._frame_no
        if i >= self.frames:
            return
        self._frame_no += 1
        srv = (self.secondary
               if (self.roam and (i + self._phase) % 8 >= 4)
               else self.primary)
        rt = self.rt
        t0 = rt.clock.now
        # a hand-off finds the model invalid on srv (the kernel clobbers
        # it every frame), so enqueue_kernel adds an implicit migration
        self.commands += 3 + (srv not in self.model.valid_on)
        e1 = rt.enqueue_write(srv, self.depth, self._depth_data)
        # the sort consumes the depth map + model and refreshes both the
        # index buffer and the model, so a server hand-off re-migrates
        e2 = rt.enqueue_kernel(srv, fn=None,
                               inputs=[self.depth, self.model],
                               outputs=[self.index, self.model],
                               duration=T_KERNEL, wait_for=[e1],
                               name=f"sort{i}")
        e3 = rt.enqueue_read(srv, self.index, wait_for=[e2])

        def frame_done(_ev, t0=t0):
            self.latencies.append(rt.clock.now - t0)
            self._next_frame()

        e3.on_complete(frame_done)


def _percentiles(lat):
    arr = np.asarray(lat) * 1e3             # ms
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 95))


def _run_scaling(n_ue: int, peer_transport: str):
    cluster = _mk_cluster(peer_transport, "drr")
    ues = [UE(cluster, i) for i in range(n_ue)]
    cluster.run()                           # handshakes drained
    t0 = cluster.clock.now
    for i, ue in enumerate(ues):
        ue.start(delay=i * STAGGER)
    cluster.run()
    elapsed = cluster.clock.now - t0
    agg_fps = n_ue * FRAMES / elapsed
    p50s, p95s = zip(*(_percentiles(u.latencies) for u in ues))
    cmds = sum(u.commands for u in ues)     # incl. hand-off migrations
    return {
        "sim_ms": elapsed * 1e3,
        "agg_fps": agg_fps,
        "cmds_per_sec": cmds / elapsed,
        "p50_ms": float(np.mean(p50s)),
        "p95_ms": float(np.max(p95s)),
        "p95_spread": (max(p95s) - min(p95s)) / float(np.mean(p95s))
        if n_ue > 1 else 0.0,
    }


class Straggler:
    """A misbehaving tenant keeping a deep backlog of heavy kernels in
    flight on one server for the whole run (windowed closed loop, so the
    queue stays ~``window`` kernels deep instead of draining once)."""

    def __init__(self, cluster: Cluster, server: str = "s0",
                 total: int = STRAGGLER_KERNELS,
                 window: int = STRAGGLER_WINDOW):
        self.rt = ClientRuntime(cluster=cluster, client_link=WIFI6,
                                transport="tcp", name="straggler")
        self.server = server
        self.remaining = total
        self.window = window

    def start(self):
        for _ in range(self.window):
            self._launch()

    def _launch(self):
        if self.remaining <= 0:
            return
        self.remaining -= 1
        ev = self.rt.enqueue_kernel(self.server, fn=None,
                                    duration=T_STRAGGLER)
        ev.on_complete(lambda _e: self._launch())


def _run_straggler(scheduler: str):
    cluster = _mk_cluster("tcp", scheduler)
    lights = [UE(cluster, i, frames=STRAGGLER_FRAMES, roam=False)
              for i in range(8)]
    straggler = Straggler(cluster)
    cluster.run()
    t0 = cluster.clock.now
    straggler.start()
    cluster.run(until=cluster.clock.now + 5e-3)   # backlog lands first
    for i, ue in enumerate(lights):
        ue.start(delay=i * STAGGER)
    cluster.run()
    elapsed = cluster.clock.now - t0
    p95s = [_percentiles(u.latencies)[1] for u in lights]
    return {"sim_ms": elapsed * 1e3, "light_p95_ms": max(p95s),
            "light_p95_min_ms": min(p95s)}


def run():
    rows = []
    eff = {}
    for tr in ("tcp", "rdma"):
        one = _run_scaling(1, tr)
        many = _run_scaling(32, tr)
        eff[tr] = many["agg_fps"] / (32 * one["agg_fps"])
        rows.append(Row(
            f"mt_1ue_{tr}", one["p50_ms"] * 1e3,
            f"sim_ms={one['sim_ms']:.3f};agg_fps={one['agg_fps']:.1f};"
            f"cmds_per_sec={one['cmds_per_sec']:.0f};"
            f"p50_ms={one['p50_ms']:.3f};p95_ms={one['p95_ms']:.3f}"))
        rows.append(Row(
            f"mt_32ue_{tr}", many["p50_ms"] * 1e3,
            f"sim_ms={many['sim_ms']:.3f};agg_fps={many['agg_fps']:.1f};"
            f"cmds_per_sec={many['cmds_per_sec']:.0f};"
            f"p50_ms={many['p50_ms']:.3f};p95_ms={many['p95_ms']:.3f};"
            f"p95_spread={many['p95_spread']:.3f};eff={eff[tr]:.3f}"))
    for scheduler in ("fifo", "drr"):
        r = _run_straggler(scheduler)
        rows.append(Row(
            f"mt_straggler_{scheduler}", r["light_p95_ms"] * 1e3,
            f"sim_ms={r['sim_ms']:.3f};"
            f"light_p95_ms={r['light_p95_ms']:.3f};"
            f"light_p95_min_ms={r['light_p95_min_ms']:.3f}"))
    return emit(rows)


def _derived(row: Row, key: str) -> float:
    for part in row.derived.split(";"):
        if part.startswith(key + "="):
            return float(part.split("=")[1])
    raise ValueError(f"no {key} in {row.derived!r}")


def check_baseline(rows, baseline_path: str) -> bool:
    with open(baseline_path) as f:
        baseline = json.load(f)
    by_name = {r.name: r for r in rows}
    ok = True
    for row in rows:
        want = baseline.get(row.name)
        if want is None:
            continue
        got = _derived(row, "sim_ms")
        ceil = want * (1.0 + REGRESSION_TOLERANCE)
        status = "ok" if got <= ceil else "REGRESSION"
        print(f"# {row.name}: {got:.3f} sim_ms vs baseline {want:.3f} "
              f"(ceiling {ceil:.3f}) {status}", file=sys.stderr)
        if got > ceil:
            ok = False
    # acceptance floors (ISSUE 3): scaling efficiency, fairness spread,
    # and the fair policy actually bounding the straggler tail
    for tr in ("tcp", "rdma"):
        row = by_name[f"mt_32ue_{tr}"]
        eff = _derived(row, "eff")
        spread = _derived(row, "p95_spread")
        if eff < EFFICIENCY_FLOOR:
            print(f"# {row.name}: efficiency {eff:.3f} < "
                  f"{EFFICIENCY_FLOOR} FLOOR", file=sys.stderr)
            ok = False
        if spread > SPREAD_CEILING:
            print(f"# {row.name}: p95 spread {spread:.3f} > "
                  f"{SPREAD_CEILING} CEILING", file=sys.stderr)
            ok = False
    fifo = _derived(by_name["mt_straggler_fifo"], "light_p95_ms")
    drr = _derived(by_name["mt_straggler_drr"], "light_p95_ms")
    if not drr < 0.5 * fifo:
        print(f"# straggler: drr p95 {drr:.3f} ms not < half of fifo "
              f"{fifo:.3f} ms", file=sys.stderr)
        ok = False
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=None,
                    help="JSON {row_name: sim_ms}; fail on >20%% "
                         "regression or acceptance-floor violation")
    ap.add_argument("--write-baseline", default=None,
                    help="write measured sim_ms to this JSON path")
    args = ap.parse_args()
    rows = run()
    if args.write_baseline:
        with open(args.write_baseline, "w") as f:
            json.dump({r.name: _derived(r, "sim_ms") for r in rows}, f,
                      indent=1)
        print(f"# baseline written to {args.write_baseline}",
              file=sys.stderr)
    if args.baseline and not check_baseline(rows, args.baseline):
        sys.exit(1)


if __name__ == "__main__":
    main()
