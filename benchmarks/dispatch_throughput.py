"""Dispatch/completion hot-path throughput: deep random command DAGs
over 1/4/8 servers, subscription routing vs all-peers broadcast, plus
batched-enqueue rows (``ClientRuntime.enqueue_many``).

Reports wall-clock commands/sec (the Python runtime's own dispatch cost,
not simulated time), peer completion-message counts, and the live-event
count after the drain (0 ⇒ retirement keeps tables bounded). The
``_subscription``/``_broadcast`` rows time enqueue + drain including the
DAG construction RNG (the historical definition); the ``_batched`` rows
pre-build the same seeded DAG as a spec list outside the timed region
and time only ``enqueue_many`` + drain — the runtime's raw dispatch
rate, which is what the calendar-queue engine work (DESIGN.md §8)
optimizes.

  PYTHONPATH=src python -m benchmarks.dispatch_throughput \
      [--n 10000] [--smoke] [--baseline benchmarks/BENCH_dispatch.json]

With ``--baseline``, exits non-zero if any measured cmds_per_sec
regresses more than 20% below the checked-in baseline (used by
scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import logging
import random
import sys
import time

from benchmarks import common
from benchmarks.common import LOOPBACK, Row, build_dag, emit
from repro.core import ClientRuntime, DeviceSpec, ServerSpec

SERVER_COUNTS = (1, 4, 8)
ROUTINGS = ("subscription", "broadcast")
BATCHED_SERVER_COUNTS = (1, 4)
REGRESSION_TOLERANCE = 0.20
# tracing OFF must be free (DESIGN.md §9): the traced_off row repeats
# the 4srv subscription sweep with tracing force-disabled and is gated
# at 2% against that row's baseline value — the disabled-hook slot
# loads must cost nothing measurable on the dispatch hot path
OVERHEAD_TOLERANCE = 0.02
OVERHEAD_BASELINE_ROW = "dispatch_4srv_subscription"
REGENERATE = ("python -m benchmarks.dispatch_throughput --smoke "
              "--write-baseline benchmarks/BENCH_dispatch.json")


def build_specs(n_cmds: int, n_srv: int, seed: int = 42, fanin: int = 3,
                window: int = 50, duration: float = 1e-7) -> list:
    """The ``common.build_dag`` DAG as an ``enqueue_many`` spec list:
    same seeded server choices and same dependency structure, with
    in-batch deps expressed as integer indices."""
    rng = random.Random(seed)
    specs = []
    for i in range(n_cmds):
        srv = f"s{rng.randrange(n_srv)}"
        deps = []
        if specs:
            lo = max(0, len(specs) - window)
            for _ in range(rng.randint(1, fanin)):
                deps.append(rng.randrange(lo, len(specs)))
        specs.append({"server": srv, "duration": duration,
                      "wait_for": deps, "name": f"k{i}"})
    return specs


def _make_rt(n_srv: int, routing: str, trace=None) -> ClientRuntime:
    return ClientRuntime(
        servers=[ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                 for i in range(n_srv)],
        client_link=LOOPBACK, peer_link=LOOPBACK,
        completion_routing=routing, trace=trace)


def _measure(n_cmds: int, n_srv: int, routing: str) -> Row:
    rt = _make_rt(n_srv, routing)
    t0 = time.perf_counter()
    build_dag(rt, n_cmds, n_srv, seed=42)
    rt.finish()
    wall = time.perf_counter() - t0
    st = rt.stats()
    return Row(f"dispatch_{n_srv}srv_{routing}", wall / n_cmds * 1e6,
               f"cmds_per_sec={n_cmds / wall:.0f};"
               f"peer_completion_msgs={st['peer_completion_msgs']};"
               f"events_live={st['events_live']}")


def _measure_batched(n_cmds: int, n_srv: int) -> Row:
    rt = _make_rt(n_srv, "subscription")
    specs = build_specs(n_cmds, n_srv, seed=42)   # untimed workload gen
    t0 = time.perf_counter()
    rt.enqueue_many("s0", specs)
    rt.finish()
    wall = time.perf_counter() - t0
    st = rt.stats()
    return Row(f"dispatch_{n_srv}srv_batched", wall / n_cmds * 1e6,
               f"cmds_per_sec={n_cmds / wall:.0f};"
               f"peer_completion_msgs={st['peer_completion_msgs']};"
               f"events_live={st['events_live']}")


def _measure_overhead(n_cmds: int) -> list:
    """The 4srv subscription workload twice more: once with tracing
    force-disabled (gated at 2% vs the untouched-code baseline row) and
    once with a live tracer (informational — tracing ON is allowed to
    cost wall-clock, it just must never move simulated time)."""
    from repro.core import Tracer
    rows = []
    for tag, trace in (("traced_off", False), ("traced_on", Tracer())):
        rt = _make_rt(4, "subscription", trace=trace)
        t0 = time.perf_counter()
        build_dag(rt, n_cmds, 4, seed=42)
        rt.finish()
        wall = time.perf_counter() - t0
        traced = len(trace.cmds) if trace is not False else 0
        rows.append(Row(f"dispatch_4srv_{tag}", wall / n_cmds * 1e6,
                        f"cmds_per_sec={n_cmds / wall:.0f};"
                        f"traced_cmds={traced}"))
    return rows


def run(n_cmds: int = 10000):
    # deep enqueue-ahead DAGs overflow the replay window by design; the
    # (expected) once-per-session warning would drown the CSV output —
    # silence it for the sweep only (run.py shares this process with
    # benchmarks that should keep the warning)
    rt_log = logging.getLogger("repro.core.runtime")
    prev_level = rt_log.level
    rt_log.setLevel(logging.ERROR)
    try:
        rows = []
        for n_srv in SERVER_COUNTS:
            for routing in ROUTINGS:
                rows.append(_measure(n_cmds, n_srv, routing))
        for n_srv in BATCHED_SERVER_COUNTS:
            rows.append(_measure_batched(n_cmds, n_srv))
        rows.extend(_measure_overhead(n_cmds))
    finally:
        rt_log.setLevel(prev_level)
    return emit(rows)


def _cmds_per_sec(row: Row) -> float:
    return common.derived(row, "cmds_per_sec")


def check_baseline(rows, baseline_path: str) -> bool:
    """Gate the subscription and batched rows — those are the shipped
    dispatch paths; the broadcast rows exist as a comparison baseline
    and their absolute wall-clock speed is not a product property.

    The tracing-overhead gate rides along: the ``traced_off`` row must
    land within ``OVERHEAD_TOLERANCE`` (2%) of the baseline value for
    the same workload (``dispatch_4srv_subscription``) — the baseline
    predates the tracing hooks, so this measures what the disabled
    instrumentation costs the hot path against pre-hook code."""
    ok = common.check_rows(
        rows, baseline_path, extract=_cmds_per_sec,
        tolerance=REGRESSION_TOLERANCE, direction="higher_is_better",
        unit=" cmds/s", benchmark="dispatch_throughput",
        gated=lambda row: row.name.endswith(("_subscription",
                                             "_batched")))
    _, baseline = common.load_baseline(baseline_path)
    want = baseline.get(OVERHEAD_BASELINE_ROW)
    off = [r for r in rows if r.name == "dispatch_4srv_traced_off"]
    if want is None or not off:
        print(f"# tracing overhead: missing {OVERHEAD_BASELINE_ROW} "
              "baseline or traced_off row — nothing gated",
              file=sys.stderr)
        return False
    got = _cmds_per_sec(off[0])
    floor = want * (1.0 - OVERHEAD_TOLERANCE)
    bad = got < floor
    print(f"# dispatch_4srv_traced_off: {got:.0f} cmds/s vs "
          f"{OVERHEAD_BASELINE_ROW} baseline {want:.0f} "
          f"(2% floor {floor:.0f}) "
          f"{'TRACING OVERHEAD REGRESSION' if bad else 'ok'}",
          file=sys.stderr)
    return ok and not bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--smoke", action="store_true",
                    help="small command count for CI")
    ap.add_argument("--baseline", default=None,
                    help="JSON {row_name: cmds_per_sec}; fail on >20%% "
                         "regression")
    ap.add_argument("--write-baseline", default=None,
                    help="write measured cmds/sec to this JSON path")
    ap.add_argument("--json-out", default=None,
                    help="write the result rows to this JSON path")
    ap.add_argument("--trials", type=int, default=1,
                    help="repeat the sweep N times and keep the best "
                         "cmds/sec per row (damps wall-clock noise when "
                         "gating)")
    args = ap.parse_args()
    n = 2000 if args.smoke else args.n
    rows = run(n)
    for _ in range(args.trials - 1):
        best = {r.name: r for r in rows}
        for r in run(n):
            if _cmds_per_sec(r) > _cmds_per_sec(best[r.name]):
                best[r.name] = r
        rows = [best[r.name] for r in rows]
    if args.json_out:
        common.dump_rows(rows, args.json_out)
    if args.write_baseline:
        common.write_baseline(
            args.write_baseline,
            {r.name: _cmds_per_sec(r) for r in rows},
            benchmark="dispatch_throughput", metric="cmds_per_sec",
            direction="higher_is_better", tolerance=REGRESSION_TOLERANCE,
            regenerate=REGENERATE)
    if args.baseline and not check_baseline(rows, args.baseline):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
