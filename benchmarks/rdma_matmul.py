"""Paper Fig. 13: RDMA speedup for the distributed matmul, N servers ×
matrix size. Expected: ~60 % once the per-server result buffer exceeds
the ~23 MB tipping point; no meaningful gain below it; registration +
rkey exchange makes many-server small-work cases a net negative.
"""
from __future__ import annotations

from benchmarks.common import ETH_56G, GPU_P100, Row, emit
from repro.core import ClientRuntime, ServerSpec


def _dist_matmul(transport: str, n_servers: int, N: int) -> float:
    servers = [ServerSpec(f"s{i}", [GPU_P100]) for i in range(n_servers)]
    rt = ClientRuntime(servers=servers, client_link=ETH_56G,
                       peer_link=ETH_56G, transport="tcp",
                       peer_transport=transport)
    rows_per = N // n_servers
    # weights resident everywhere; partials produced per server then
    # migrated P2P to server 0 for the merge (the paper's "combining the
    # intermediate results" step)
    parts = []
    evs = []
    for s in servers:
        o = rt.create_buffer(rows_per * N * 4)
        ek = rt.enqueue_kernel(s.name, fn=None, inputs=[], outputs=[o],
                               flops=2.0 * rows_per * N * N,
                               bytes_moved=3.0 * rows_per * N * 4)
        parts.append(o)
        evs.append(ek)
    rt.finish()
    t0 = rt.clock.now
    merge_deps = []
    for o, ek in zip(parts[1:], evs[1:]):
        merge_deps.append(rt.enqueue_migration(o, "s0", wait_for=[ek]))
    rt.enqueue_kernel("s0", fn=None, inputs=parts, outputs=[],
                      duration=1e-4, wait_for=evs[:1] + merge_deps,
                      name="merge")
    rt.finish()
    return rt.clock.now - t0


def run():
    rows = []
    for N in (2048, 4096, 8192, 16384):
        for n_srv in (4, 8, 12):
            t_tcp = _dist_matmul("tcp", n_srv, N)
            t_rdma = _dist_matmul("rdma", n_srv, N)
            sp = (t_tcp / t_rdma - 1.0) * 100.0
            per_server_mb = (N // n_srv) * N * 4 / 1e6
            rows.append(Row(f"fig13_rdma_matmul_N{N}_s{n_srv}",
                            t_rdma * 1e6,
                            f"per_server_MB={per_server_mb:.0f};"
                            f"speedup_pct={sp:.1f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
