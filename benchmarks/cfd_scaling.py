"""Paper Fig. 16/17: FluidX3D multi-node scaling (MLUPs/s) and GPU
utilization, 1–3 A6000 servers on 100 Gb fiber.

The benchmark drives the REAL JAX D2Q9 kernel (validated bit-exact
against the monolithic solver) through the PoCL-R runtime at reduced
size for functional correctness, while the timing model uses FluidX3D's
published per-GPU throughput with the paper's 514³ per-GPU domain and
5.2 MB boundary buffers exchanged P2P per step.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ETH_1G, ETH_100G, GPU_A6000, Row, emit
from repro.apps import lbm
from repro.core import ClientRuntime, ServerSpec

import jax.numpy as jnp

CELLS_PER_GPU = 514 ** 3
GLUPS_PER_GPU = 4.6e9                 # FluidX3D single-A6000 throughput
STEP_S = CELLS_PER_GPU / GLUPS_PER_GPU
HALO_BYTES = 5.2e6                    # paper §7.2
STEPS = 40


def _functional_check() -> float:
    """Run the real kernel through the runtime on 2 simulated servers."""
    f0 = lbm.init_shear(16, 32)
    slabs = lbm.split_domain(f0, 2)
    rt = ClientRuntime(servers=[ServerSpec(f"s{i}", [GPU_A6000])
                                for i in range(2)],
                       client_link=ETH_1G, peer_link=ETH_100G,
                       transport="tcp")
    bufs = []
    evs = []
    for i, s in enumerate(slabs):
        b = rt.create_buffer(int(np.asarray(s).nbytes))
        evs.append(rt.enqueue_write(f"s{i}", b, np.asarray(s)))
        bufs.append(b)
    for step in range(10):
        new_evs = []
        for i in range(2):
            e = rt.enqueue_kernel(
                f"s{i}", fn=lambda x: np.asarray(lbm.slab_step(jnp.asarray(x))),
                inputs=[bufs[i]], outputs=[bufs[i]],
                duration=1e-4, wait_for=evs)
            new_evs.append(e)
        # halo exchange via host-side reconstruction (functional path)
        for i in range(2):
            rt.enqueue_read(f"s{i}", bufs[i], wait_for=new_evs)
        rt.finish()
        slabs = [jnp.asarray(bufs[i].data) for i in range(2)]
        slabs = lbm.exchange_halos(slabs)
        evs = [rt.enqueue_write(f"s{i}", bufs[i], np.asarray(slabs[i]))
               for i in range(2)]
    rt.finish()
    got = jnp.concatenate([s[:, :, 1:-1] for s in slabs], axis=2)
    ref = f0
    for _ in range(10):
        ref = lbm.lbm_step(ref)
    return float(jnp.max(jnp.abs(got - ref)))


def _scaling(n_servers: int):
    rt = ClientRuntime(servers=[ServerSpec(f"s{i}", [GPU_A6000])
                                for i in range(n_servers)],
                       client_link=ETH_1G, peer_link=ETH_100G,
                       transport="tcp")
    halos = {i: rt.create_buffer(int(HALO_BYTES)) for i in range(n_servers)}
    for i, b in halos.items():
        b.valid_on = {f"s{i}"}
    t0 = rt.clock.now
    prev = {i: None for i in range(n_servers)}
    for step in range(STEPS):
        ks = {}
        for i in range(n_servers):
            deps = [e for e in (prev[i],) if e]
            ks[i] = rt.enqueue_kernel(f"s{i}", fn=None, outputs=[halos[i]],
                                      duration=STEP_S, wait_for=deps,
                                      name="lbm_step")
        if n_servers > 1:
            for i in range(n_servers):
                j = (i + 1) % n_servers
                mig = rt.enqueue_migration(halos[i], f"s{j}",
                                           wait_for=[ks[i]])
                prev[j] = mig
        else:
            prev = {0: ks[0]}
    rt.finish()
    wall = rt.clock.now - t0
    mlups = n_servers * CELLS_PER_GPU * STEPS / wall / 1e6
    util = (STEPS * STEP_S) / wall
    return mlups, util


def run():
    err = _functional_check()
    rows = [Row("fig16_lbm_functional_err", 0.0, f"max_abs_err={err:.2e}")]
    base = None
    for n in (1, 2, 3):
        mlups, util = _scaling(n)
        if base is None:
            base = mlups
        eff = mlups / (base * n)
        rows.append(Row(f"fig16_cfd_{n}node", 0.0,
                        f"mlups={mlups:.0f};scaling_eff={eff:.2f};"
                        f"gpu_util={util:.2f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
