"""Paper Fig. 8: duration of a no-op command (client CPU timers).

Measures (a) simulated client-observed no-op latency on the paper's
testbed links vs the ICMP RTT baseline, (b) the real wall-clock Python
dispatch overhead of this runtime implementation.
"""
from __future__ import annotations

import time

from benchmarks.common import ETH_100M, LOOPBACK, Row, emit
from repro.core import ClientRuntime, ServerSpec, DeviceSpec


def _noop_latency(link, n=1000) -> float:
    rt = ClientRuntime(servers=[ServerSpec("s0", [DeviceSpec("gpu0")])],
                       client_link=link, peer_link=link, transport="tcp")
    total = 0.0
    for _ in range(n):
        t0 = rt.clock.now
        ev = rt.enqueue_kernel("s0", fn=None, duration=0.0, name="noop")
        rt.finish()
        total += ev.t_client_ack - t0
    return total / n


def run():
    rows = []
    for name, link in [("lan_100M", ETH_100M), ("loopback", LOOPBACK)]:
        lat = _noop_latency(link)
        rtt = 2 * link.latency
        rows.append(Row(f"fig8_noop_{name}", lat * 1e6,
                        f"rtt_us={rtt*1e6:.1f};overhead_us={(lat-rtt)*1e6:.1f}"))
    # real wall-clock dispatch overhead of this runtime implementation
    rt = ClientRuntime(servers=[ServerSpec("s0", [DeviceSpec("gpu0")])],
                       client_link=LOOPBACK, peer_link=LOOPBACK)
    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        rt.enqueue_kernel("s0", fn=None, duration=0.0)
    rt.finish()
    wall = (time.perf_counter() - t0) / n
    rows.append(Row("fig8_runtime_python_dispatch", wall * 1e6,
                    f"cmds_per_sec={1/wall:.0f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
