"""Paper Fig. 8: duration of a no-op command (client CPU timers).

Measures (a) simulated client-observed no-op latency on the paper's
testbed links vs the ICMP RTT baseline, (b) the real wall-clock Python
dispatch overhead of this runtime implementation.
"""
from __future__ import annotations

import logging
import time

from benchmarks.common import ETH_100M, LOOPBACK, Row, build_dag, emit
from repro.core import ClientRuntime, ServerSpec, DeviceSpec


def _noop_latency(link, n=1000) -> float:
    rt = ClientRuntime(servers=[ServerSpec("s0", [DeviceSpec("gpu0")])],
                       client_link=link, peer_link=link, transport="tcp")
    total = 0.0
    for _ in range(n):
        t0 = rt.clock.now
        ev = rt.enqueue_kernel("s0", fn=None, duration=0.0, name="noop")
        rt.finish()
        total += ev.t_client_ack - t0
    return total / n


def run():
    rows = []
    for name, link in [("lan_100M", ETH_100M), ("loopback", LOOPBACK)]:
        lat = _noop_latency(link)
        rtt = 2 * link.latency
        rows.append(Row(f"fig8_noop_{name}", lat * 1e6,
                        f"rtt_us={rtt*1e6:.1f};overhead_us={(lat-rtt)*1e6:.1f}"))
    # real wall-clock dispatch overhead of this runtime implementation:
    # a deep 10k-command DAG over 4 servers, enqueued up-front so the
    # dependency tracker carries thousands of in-flight commands (the
    # replay window overflows by design — silence the expected warning
    # for this section only)
    rt_log = logging.getLogger("repro.core.runtime")
    prev_level = rt_log.level
    rt_log.setLevel(logging.ERROR)
    try:
        n = 10000
        rt = ClientRuntime(servers=[ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                                    for i in range(4)],
                           client_link=LOOPBACK, peer_link=LOOPBACK)
        t0 = time.perf_counter()
        build_dag(rt, n, 4, seed=1)
        rt.finish()
        wall = (time.perf_counter() - t0) / n
    finally:
        rt_log.setLevel(prev_level)
    st = rt.stats()
    rows.append(Row("fig8_runtime_python_dispatch", wall * 1e6,
                    f"cmds_per_sec={1/wall:.0f};"
                    f"peer_completion_msgs={st['peer_completion_msgs']}"))
    # single-server no-op stream (one command in flight at a time)
    rt = ClientRuntime(servers=[ServerSpec("s0", [DeviceSpec("gpu0")])],
                       client_link=LOOPBACK, peer_link=LOOPBACK)
    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        rt.enqueue_kernel("s0", fn=None, duration=0.0)
    rt.finish()
    wall = (time.perf_counter() - t0) / n
    rows.append(Row("fig8_runtime_python_dispatch_noop_stream", wall * 1e6,
                    f"cmds_per_sec={1/wall:.0f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
