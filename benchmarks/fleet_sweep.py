"""1000-UE fleet sweep: the calendar-queue engine's headline workload
(DESIGN.md §8).

A whole fleet of thin UE sessions shares one MEC cluster. Each UE
batches a short dependent kernel chain onto its home server with
``ClientRuntime.enqueue_many`` at a staggered start time, so the event
engine sees what a city-scale sweep produces: thousands of sessions'
worth of commands interleaved across the calendar queue's buckets, with
far-future staggered starts exercising the overflow heap and bucket
rotation, and the drain exercising the dispatch/completion hot path at
fleet density.

Two things are measured per row:

* ``sim_ms`` — simulated drain time. Deterministic, portable, and gated
  against ``benchmarks/BENCH_fleet.json`` (the calendar queue must stay
  bit-exact with the reference heap, so this number never moves unless
  the model itself changes).
* ``wall_s`` / ``cmds_per_sec`` — the Python runtime's real dispatch
  cost. Host-specific; ``--max-wall-s`` turns it into a smoke ceiling
  (scripts/ci.sh skips the ceiling under ``CI_SKIP_WALLCLOCK=1``).

  PYTHONPATH=src python -m benchmarks.fleet_sweep \
      [--baseline benchmarks/BENCH_fleet.json] [--max-wall-s 30]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import common
from benchmarks.common import LOOPBACK, Row, emit
from repro.core import ClientRuntime, Cluster, DeviceSpec, ServerSpec

N_SERVERS = 4
FLEET_SIZES = (250, 1000)
KERNELS_PER_UE = 6
T_KERNEL = 2e-4                 # short AR-style kernel on the server GPU
STAGGER = 5e-5                  # UE batch-submit offset (sim seconds)
REGRESSION_TOLERANCE = 0.20
REGENERATE = ("python -m benchmarks.fleet_sweep "
              "--write-baseline benchmarks/BENCH_fleet.json")


def _mk_cluster() -> Cluster:
    return Cluster([ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                    for i in range(N_SERVERS)],
                   peer_link=LOOPBACK)


def _chain_specs(ue: int) -> list:
    """One UE's batch: a dependent chain of short kernels (each waits on
    the previous one by in-batch index)."""
    return [{"duration": T_KERNEL, "name": f"u{ue}k{j}",
             "wait_for": [j - 1] if j else []}
            for j in range(KERNELS_PER_UE)]


def _measure(n_ues: int) -> Row:
    cluster = _mk_cluster()
    rts = [ClientRuntime(cluster=cluster, client_link=LOOPBACK,
                         transport="tcp", name=f"ue{i}")
           for i in range(n_ues)]
    cluster.run()                       # handshakes drained
    sim0 = cluster.clock.now
    t0 = time.perf_counter()
    for i, rt in enumerate(rts):
        rt.clock.schedule(
            i * STAGGER,
            lambda rt=rt, i=i: rt.enqueue_many(f"s{i % N_SERVERS}",
                                               _chain_specs(i)))
    cluster.run()
    wall = time.perf_counter() - t0
    sim_ms = (cluster.clock.now - sim0) * 1e3
    n_cmds = n_ues * KERNELS_PER_UE
    live = sum(rt.stats()["events_live"] for rt in rts)
    return Row(f"fleet_{n_ues}ue", sim_ms,
               f"sim_ms={sim_ms:.3f};wall_s={wall:.3f};"
               f"cmds_per_sec={n_cmds / wall:.0f};"
               f"events_live={live}")


def run():
    return emit([_measure(n) for n in FLEET_SIZES])


def check_baseline(rows, baseline_path: str) -> bool:
    return common.check_rows(rows, baseline_path,
                             extract=lambda r: common.derived(r, "sim_ms"),
                             tolerance=REGRESSION_TOLERANCE,
                             direction="lower_is_better", unit=" sim_ms",
                             benchmark="fleet_sweep")


def check_wallclock(rows, ceiling_s: float) -> bool:
    """Smoke ceiling: the whole fleet must dispatch within ``ceiling_s``
    of real time per row (generous — catches order-of-magnitude
    dispatch regressions, not noise)."""
    ok = True
    for row in rows:
        wall = common.derived(row, "wall_s")
        if wall > ceiling_s:
            print(f"# {row.name}: wall {wall:.1f}s > ceiling "
                  f"{ceiling_s:.1f}s CEILING", file=sys.stderr)
            ok = False
        else:
            print(f"# {row.name}: wall {wall:.1f}s (ceiling "
                  f"{ceiling_s:.1f}s) ok", file=sys.stderr)
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=None,
                    help="JSON {row_name: sim_ms}; fail on >20%% "
                         "regression (deterministic, portable)")
    ap.add_argument("--max-wall-s", type=float, default=None,
                    help="fail if any row's wall-clock drain exceeds "
                         "this many seconds (host-specific smoke)")
    ap.add_argument("--write-baseline", default=None,
                    help="write measured sim_ms to this JSON path")
    ap.add_argument("--json-out", default=None,
                    help="write the result rows to this JSON path")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="trace the whole sweep (one tracer shared by "
                         "both fleet-size clusters) and write Perfetto "
                         "trace_event JSON to FILE; the sim_ms gates "
                         "then double as proof tracing never moves "
                         "simulated time")
    args = ap.parse_args()
    tracer = None
    if args.trace:
        from repro.core import trace as trace_mod
        tracer = trace_mod.Tracer()
        trace_mod.set_default(tracer)
    try:
        rows = run()
    finally:
        if tracer is not None:
            trace_mod.set_default(None)
    if args.json_out:
        common.dump_rows(rows, args.json_out)
    if args.write_baseline:
        common.write_baseline(
            args.write_baseline,
            {r.name: common.derived(r, "sim_ms") for r in rows},
            benchmark="fleet_sweep", metric="sim_ms",
            direction="lower_is_better", tolerance=REGRESSION_TOLERANCE,
            regenerate=REGENERATE)
    ok = True
    if tracer is not None:
        tracer.write_perfetto(args.trace)
        errs = common.validate_perfetto(args.trace)
        for e in errs:
            print(f"# trace: {e}", file=sys.stderr)
        print(f"# trace: {len(tracer.cmds)} commands across "
              f"{len(tracer._clusters)} clusters -> {args.trace} "
              f"({'INVALID' if errs else 'schema ok'})", file=sys.stderr)
        ok = ok and not errs
    if args.baseline:
        ok = check_baseline(rows, args.baseline) and ok
    if args.max_wall_s is not None:
        ok = check_wallclock(rows, args.max_wall_s) and ok
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
