"""Paper Fig. 11: relative speedup of RDMA vs TCP for a server→server
uint32 buffer migration, swept over buffer sizes 4 B → 134 MiB.

Expected shape (calibrated): positive from 32 B (fixed-cost regime; our
model lands ~13-15 % vs the paper's ~30 % — the client command legs carry
relatively more fixed cost here, noted in EXPERIMENTS.md), a knee at the
9 MiB TCP send-buffer split point (~59 %, the last size before TCP's
copy/wire overlap fully amortizes), plateau ≈65-69 % ≥134 MiB. The knee
used to overshoot the plateau (~85 % at exactly 9 MiB): a payload equal
to the send buffer was modeled as one store-and-forward chunk — fully
serial copy+wire+copy for TCP while RDMA already pipelined at
HCA-fragment granularity. Equal-sized chunks with the count rounding up
at exact multiples (``transport._chunk_sizes``) removed that
discrete-split cliff.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ETH_100M, ETH_40G, GPU_2080TI, MiB, Row, emit
from repro.core import ClientRuntime, ServerSpec


def _one(transport: str, nbytes: int, n=24) -> float:
    rt = ClientRuntime(servers=[ServerSpec("s0", [GPU_2080TI]),
                                ServerSpec("s1", [GPU_2080TI])],
                       client_link=ETH_100M, peer_link=ETH_40G,
                       transport="tcp", peer_transport=transport)
    buf = rt.create_buffer(nbytes)
    rt.enqueue_write("s0", buf, np.zeros(max(nbytes // 4, 1), np.uint32))
    rt.finish()
    total = 0.0
    here, there = "s0", "s1"
    for _ in range(n):
        t0 = rt.clock.now
        mig = rt.enqueue_migration(buf, there)
        rt.finish()
        total += rt.clock.now - t0
        rt.enqueue_kernel(there, fn=None, inputs=[buf], outputs=[buf],
                          duration=2e-6, wait_for=[mig])
        rt.finish()
        here, there = there, here
    return total / n


SIZES = [4, 32, 256, 4096, 64 * 1024, 1 * MiB, 9 * MiB, 23 * MiB,
         64 * MiB, 134 * MiB, 256 * MiB]


def run():
    rows = []
    for nbytes in SIZES:
        t_tcp = _one("tcp", nbytes)
        t_rdma = _one("rdma", nbytes)
        speedup = (t_tcp / t_rdma - 1.0) * 100.0
        rows.append(Row(f"fig11_rdma_speedup_{nbytes}B", t_rdma * 1e6,
                        f"tcp_us={t_tcp*1e6:.1f};speedup_pct={speedup:.1f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
