"""Shared benchmark scaffolding + testbed constants from the paper."""
from __future__ import annotations

import dataclasses
import random

from repro.core import DeviceSpec, LinkSpec

MiB = 1024 * 1024

# GPUs appearing in the paper's testbeds (fp32 TFLOP/s, HBM GB/s)
GPU_2080TI = DeviceSpec("2080ti", flops=13.4e12, mem_bw=616e9)
GPU_P100 = DeviceSpec("p100", flops=9.3e12, mem_bw=732e9)
GPU_V100 = DeviceSpec("v100", flops=14.0e12, mem_bw=900e9)
GPU_A6000 = DeviceSpec("a6000", flops=38.7e12, mem_bw=768e9)
GPU_1060 = DeviceSpec("gtx1060", flops=3.9e12, mem_bw=192e9)
SOC_ADRENO = DeviceSpec("adreno640", flops=0.9e12, mem_bw=34e9)

# links (one-way latency, B/s)
ETH_100M = LinkSpec(latency=61e-6, bandwidth=100e6 / 8)      # paper LAN
ETH_1G = LinkSpec(latency=50e-6, bandwidth=1e9 / 8)
ETH_40G = LinkSpec(latency=15e-6, bandwidth=40e9 / 8)        # direct link
ETH_56G = LinkSpec(latency=15e-6, bandwidth=56e9 / 8)
ETH_100G = LinkSpec(latency=10e-6, bandwidth=100e9 / 8)
WIFI6 = LinkSpec(latency=1.5e-3, bandwidth=300e6 / 8)        # effective
LOOPBACK = LinkSpec(latency=10e-6, bandwidth=50e9 / 8)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def emit(rows):
    for r in rows:
        print(r.csv())
    return rows


def build_dag(rt, n_cmds: int, n_srv: int, seed: int = 0, fanin: int = 3,
              window: int = 50, duration: float = 1e-7):
    """Enqueue a deterministic random command DAG: pure dispatch load
    (fn=None, no buffers). Command i runs on a seeded-random server and
    waits on 1..``fanin`` events drawn from the last ``window`` commands,
    so the graph stays deep and cross-server the whole run."""
    rng = random.Random(seed)
    events = []
    for i in range(n_cmds):
        srv = f"s{rng.randrange(n_srv)}"
        deps = []
        if events:
            lo = max(0, len(events) - window)
            for _ in range(rng.randint(1, fanin)):
                deps.append(events[rng.randrange(lo, len(events))])
        events.append(rt.enqueue_kernel(srv, fn=None, duration=duration,
                                        wait_for=deps, name=f"k{i}"))
    return events
