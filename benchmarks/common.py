"""Shared benchmark scaffolding + testbed constants from the paper.

Also the shared baseline machinery: every ``benchmarks/BENCH_*.json``
regression baseline carries a ``_meta`` stamp (schema version, which
benchmark owns it, the gated metric and direction, the tolerance, and
the regeneration command) next to its ``rows``; ``load_baseline`` /
``write_baseline`` / ``check_rows`` replace the three per-benchmark
copies of the load/compare/write code, and ``validate_baseline`` is the
schema check behind ``benchmarks/run.py --check-baselines`` (wired into
scripts/ci.sh so a drifted or hand-mangled baseline fails CI before any
benchmark runs)."""
from __future__ import annotations

import dataclasses
import gzip
import json
import math
import os
import random
import sys
import time

from repro.core import DeviceSpec, LinkSpec

MiB = 1024 * 1024

# GPUs appearing in the paper's testbeds (fp32 TFLOP/s, HBM GB/s)
GPU_2080TI = DeviceSpec("2080ti", flops=13.4e12, mem_bw=616e9)
GPU_P100 = DeviceSpec("p100", flops=9.3e12, mem_bw=732e9)
GPU_V100 = DeviceSpec("v100", flops=14.0e12, mem_bw=900e9)
GPU_A6000 = DeviceSpec("a6000", flops=38.7e12, mem_bw=768e9)
GPU_1060 = DeviceSpec("gtx1060", flops=3.9e12, mem_bw=192e9)
SOC_ADRENO = DeviceSpec("adreno640", flops=0.9e12, mem_bw=34e9)

# links (one-way latency, B/s)
ETH_100M = LinkSpec(latency=61e-6, bandwidth=100e6 / 8)      # paper LAN
ETH_1G = LinkSpec(latency=50e-6, bandwidth=1e9 / 8)
ETH_40G = LinkSpec(latency=15e-6, bandwidth=40e9 / 8)        # direct link
ETH_56G = LinkSpec(latency=15e-6, bandwidth=56e9 / 8)
ETH_100G = LinkSpec(latency=10e-6, bandwidth=100e9 / 8)
WIFI6 = LinkSpec(latency=1.5e-3, bandwidth=300e6 / 8)        # effective
LOOPBACK = LinkSpec(latency=10e-6, bandwidth=50e9 / 8)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def emit(rows):
    for r in rows:
        print(r.csv())
    return rows


def dump_rows(rows, path: str) -> None:
    """Write the row list as JSON (CI artifact upload)."""
    with open(path, "w") as f:
        json.dump([{"name": r.name, "us_per_call": r.us_per_call,
                    "derived": r.derived} for r in rows], f, indent=1)


def derived(row: Row, key: str) -> float:
    """Pull ``key=value`` out of a row's derived-metrics string."""
    for part in row.derived.split(";"):
        if part.startswith(key + "="):
            return float(part.split("=")[1])
    raise ValueError(f"no {key} in {row.derived!r}")


# ---- regression baselines (BENCH_*.json) ----

BASELINE_SCHEMA = 1
_DIRECTIONS = ("lower_is_better", "higher_is_better")


def load_baseline(path: str) -> tuple[dict, dict]:
    """Returns ``(meta, rows)``. Legacy flat ``{row: value}`` files load
    with an empty meta so an old checkout still gates."""
    with open(path) as f:
        data = json.load(f)
    if "_meta" in data:
        return data["_meta"], data.get("rows", {})
    return {}, data


def write_baseline(path: str, values: dict, *, benchmark: str,
                   metric: str, direction: str, tolerance: float,
                   regenerate: str) -> None:
    assert direction in _DIRECTIONS, direction
    with open(path, "w") as f:
        json.dump({
            "_meta": {
                "schema": BASELINE_SCHEMA,
                "benchmark": benchmark,
                "metric": metric,
                "direction": direction,
                "tolerance": tolerance,
                "regenerate": regenerate,
                # full UTC timestamp: the --check-baselines drift guard
                # compares this stamp across git revisions, and a
                # date-only stamp would false-positive on same-day
                # regenerations
                "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                              time.gmtime()),
            },
            "rows": values,
        }, f, indent=1)
    print(f"# baseline written to {path}", file=sys.stderr)


def check_rows(rows, baseline_path: str, *, extract, tolerance: float,
               direction: str = "lower_is_better", unit: str = "",
               gated=None, benchmark: str = None) -> bool:
    """Compare each row's ``extract(row)`` against the baseline entry of
    the same name (rows absent from the baseline are skipped — but ZERO
    matches is a failure: a wrong baseline file or a row rename must not
    green-light CI having compared nothing). ``direction`` picks the
    regression side: ``lower_is_better`` gates a ceiling of
    ``want * (1 + tolerance)`` (simulated times), ``higher_is_better`` a
    floor of ``want * (1 - tolerance)`` (throughputs). ``gated``
    optionally restricts which rows can fail the check — ungated rows
    are still printed for the log. ``benchmark`` cross-checks the
    file's ``_meta.benchmark`` stamp when both are present."""
    assert direction in _DIRECTIONS, direction
    meta, baseline = load_baseline(baseline_path)
    ok = True
    if benchmark is not None and meta.get("benchmark") not in (
            None, benchmark):
        print(f"# {baseline_path}: baseline belongs to "
              f"{meta.get('benchmark')!r}, not {benchmark!r} — "
              f"wrong file?", file=sys.stderr)
        ok = False
    matched = 0
    for row in rows:
        want = baseline.get(row.name)
        if want is None:
            continue
        matched += 1
        got = extract(row)
        is_gated = gated is None or gated(row)
        if direction == "lower_is_better":
            bound = want * (1.0 + tolerance)
            bad = got > bound
            kind = "ceiling"
        else:
            bound = want * (1.0 - tolerance)
            bad = got < bound
            kind = "floor"
        status = ("ok" if not bad
                  else "REGRESSION" if is_gated else "slow (ungated)")
        print(f"# {row.name}: {got:.3f}{unit} vs baseline {want:.3f} "
              f"({kind} {bound:.3f}) {status}", file=sys.stderr)
        _emit_margin(benchmark or meta.get("benchmark"), row.name, got,
                     want, bound, direction, unit, status)
        if bad and is_gated:
            ok = False
    if not matched:
        print(f"# {baseline_path}: NO rows matched the baseline — "
              f"nothing was gated (renamed rows? wrong file?)",
              file=sys.stderr)
        ok = False
    return ok


def _emit_margin(benchmark, row: str, got: float, want: float,
                 bound: float, direction: str, unit: str,
                 status: str) -> None:
    """Append one gate comparison to ``$CI_GATE_MARGINS`` (JSONL) for
    the scripts/ci_summary.py step summary — how much headroom each
    gate had left, not just pass/fail. No-op unless scripts/ci.sh set
    the env var. ``margin`` is the remaining fraction of the bound
    (negative = breached)."""
    path = os.environ.get("CI_GATE_MARGINS")
    if not path or not bound:
        return
    if direction == "lower_is_better":
        margin = (bound - got) / bound
    else:
        margin = (got - bound) / bound
    try:
        with open(path, "a") as f:
            f.write(json.dumps({
                "benchmark": benchmark or "?", "row": row,
                "got": got, "baseline": want, "bound": bound,
                "unit": unit.strip(), "direction": direction,
                "margin": margin, "status": status}) + "\n")
    except OSError:
        pass


def validate_baseline(path: str) -> list:
    """Schema check for one BENCH_*.json; returns human-readable error
    strings (empty = valid). Required: a ``_meta`` stamp with schema
    version, owning benchmark, metric name, gate direction, tolerance in
    (0, 1), regeneration command, and generation date; ``rows`` must be
    a non-empty map of row name → positive finite number."""
    errs = []
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        return [f"unreadable: {e}"]
    if not isinstance(data, dict):
        return ["top level must be an object"]
    meta = data.get("_meta")
    if not isinstance(meta, dict):
        errs.append("missing _meta stamp (regenerate with the module's "
                    "--write-baseline)")
        meta = {}
    if meta.get("schema") != BASELINE_SCHEMA:
        errs.append(f"_meta.schema must be {BASELINE_SCHEMA}, "
                    f"got {meta.get('schema')!r}")
    for key in ("benchmark", "metric", "regenerate", "generated_at"):
        if not isinstance(meta.get(key), str) or not meta.get(key):
            errs.append(f"_meta.{key} must be a non-empty string")
    if meta.get("direction") not in _DIRECTIONS:
        errs.append(f"_meta.direction must be one of {_DIRECTIONS}")
    tol = meta.get("tolerance")
    if not isinstance(tol, (int, float)) or not 0.0 < tol < 1.0:
        errs.append("_meta.tolerance must be a number in (0, 1)")
    rows = data.get("rows") if "_meta" in data else {
        k: v for k, v in data.items() if k != "_meta"}
    if not isinstance(rows, dict) or not rows:
        errs.append("rows must be a non-empty object")
    else:
        for name, val in rows.items():
            if not isinstance(val, (int, float)) \
                    or not math.isfinite(val) or val <= 0:
                errs.append(f"rows[{name!r}] must be a positive finite "
                            f"number, got {val!r}")
    return errs


def build_dag(rt, n_cmds: int, n_srv: int, seed: int = 0, fanin: int = 3,
              window: int = 50, duration: float = 1e-7):
    """Enqueue a deterministic random command DAG: pure dispatch load
    (fn=None, no buffers). Command i runs on a seeded-random server and
    waits on 1..``fanin`` events drawn from the last ``window`` commands,
    so the graph stays deep and cross-server the whole run."""
    rng = random.Random(seed)
    events = []
    for i in range(n_cmds):
        srv = f"s{rng.randrange(n_srv)}"
        deps = []
        if events:
            lo = max(0, len(events) - window)
            for _ in range(rng.randint(1, fanin)):
                deps.append(events[rng.randrange(lo, len(events))])
        events.append(rt.enqueue_kernel(srv, fn=None, duration=duration,
                                        wait_for=deps, name=f"k{i}"))
    return events


def validate_perfetto(trace, require_fault_markers: bool = False) -> list:
    """Schema check for an emitted Chrome/Perfetto ``trace_event`` JSON
    file (or already-loaded dict): returns a list of error strings
    (empty = valid). Checks the envelope, every event's phase/timestamp
    shape, balanced async begin/end pairs per ``(cat, id)``, and —
    for chaos traces — that fault markers are present. Used by
    scripts/ci.sh on the traced smokes so a malformed export fails CI
    instead of failing silently in the viewer."""
    errs: list = []
    if isinstance(trace, str):
        opener = gzip.open if trace.endswith(".gz") else open
        try:
            with opener(trace, "rt") as f:
                trace = json.load(f)
        except (OSError, ValueError) as e:
            return [f"unreadable trace: {e}"]
    if not isinstance(trace, dict):
        return ["trace must be a JSON object"]
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents must be a non-empty list"]
    known_ph = {"M", "X", "b", "e", "i", "C"}
    async_depth: dict = {}
    fault_markers = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event[{i}] is not an object")
            continue
        ph = ev.get("ph")
        if ph not in known_ph:
            errs.append(f"event[{i}]: unknown ph {ph!r}")
            continue
        if not isinstance(ev.get("pid"), int):
            errs.append(f"event[{i}]: pid must be an int")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts) \
                or ts < 0:
            errs.append(f"event[{i}]: ts must be a finite number >= 0, "
                        f"got {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) \
                    or not math.isfinite(dur) or dur < 0:
                errs.append(f"event[{i}]: X dur must be a finite "
                            f"number >= 0, got {dur!r}")
        elif ph in ("b", "e"):
            key = (ev.get("cat"), ev.get("id"))
            if key[1] is None:
                errs.append(f"event[{i}]: async event without id")
                continue
            d = async_depth.get(key, 0) + (1 if ph == "b" else -1)
            if d < 0:
                errs.append(f"event[{i}]: async 'e' without matching "
                            f"'b' for {key}")
                d = 0
            async_depth[key] = d
        elif ph == "i":
            if ev.get("cat") == "fault":
                fault_markers += 1
    open_pairs = {k: d for k, d in async_depth.items() if d}
    if open_pairs:
        errs.append(f"{len(open_pairs)} async (cat, id) tracks left "
                    f"open (unbalanced b/e)")
    if require_fault_markers and not fault_markers:
        errs.append("no fault markers (cat='fault' instants) in trace")
    return errs
