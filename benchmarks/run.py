"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes
benchmarks/results.json (consumed by EXPERIMENTS.md).

  PYTHONPATH=src python -m benchmarks.run [--only fig11]

``--check-baselines`` instead validates every ``benchmarks/BENCH_*.json``
regression baseline against the shared schema (``common.py``: ``_meta``
stamp with schema version, owning benchmark, metric, direction,
tolerance, regeneration command; positive finite row values) and exits
non-zero on any drift — scripts/ci.sh runs it before the gated smokes so
a mangled baseline fails fast instead of silently gating nothing. With
``--drift-ref`` (or ``$CI_BASE_REF``) it additionally compares each
baseline against that git revision: row values that changed without a
fresh ``_meta.generated_at``/``regenerate`` stamp mean someone nudged a
gate by hand instead of regenerating through ``--write-baseline``.

``--trace=FILE`` installs a process-wide default tracer (DESIGN.md §9)
before any benchmark runs: every cluster built without an explicit
``trace=`` argument attaches to it, and on exit the combined trace is
written to FILE as Perfetto ``trace_event`` JSON (schema-validated,
loadable at https://ui.perfetto.dev; a ``.gz`` suffix gzips it). Pair
with ``--only`` — a full sweep's trace is huge.

``--blame`` prints the causal critical-path attribution table for the
combined trace (core/critpath.py), and ``--whatif=nic_bandwidth=2``
projects the makespan under hypothetical substrate changes — both
install a default tracer themselves, so ``--trace`` is optional.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MODULES = [
    ("fig8", "benchmarks.cmd_overhead"),
    ("dispatch", "benchmarks.dispatch_throughput"),
    ("fig9", "benchmarks.passthrough"),
    ("fig10", "benchmarks.migration_latency"),
    ("migpipe", "benchmarks.migration_pipeline"),
    ("mt", "benchmarks.multi_tenant"),
    ("slo", "benchmarks.slo_burst"),
    ("cfdhalo", "benchmarks.cfd_halo"),
    ("chaos", "benchmarks.chaos"),
    ("fleet", "benchmarks.fleet_sweep"),
    ("breakdown", "benchmarks.latency_breakdown"),
    ("fig11", "benchmarks.rdma_vs_tcp"),
    ("fig12", "benchmarks.matmul_scaling"),
    ("fig13", "benchmarks.rdma_matmul"),
    ("fig15", "benchmarks.ar_pipeline"),
    ("fig16", "benchmarks.cfd_scaling"),
]


def _baseline_rows(data: dict) -> dict:
    if "_meta" in data:
        return data.get("rows", {})
    return {k: v for k, v in data.items() if k != "_meta"}


def _drift_errors(path: str, ref: str) -> list:
    """Baseline-drift guard: against ``ref``'s copy of the file, changed
    row values must arrive with a fresh ``_meta.generated_at`` (or
    ``regenerate``) stamp — i.e. through the owning module's
    ``--write-baseline``, not a hand edit that quietly moves the CI
    gate. Silently passes when git, the ref, or the old copy is
    unavailable (fresh baselines are always fine)."""
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(path)))
    rel = os.path.relpath(os.path.abspath(path), root)
    try:
        old = subprocess.run(
            ["git", "show", f"{ref}:{rel}"], cwd=root, timeout=30,
            capture_output=True, text=True)
    except (OSError, subprocess.TimeoutExpired):
        return []
    if old.returncode != 0:
        return []                   # new file, or ref not fetched
    try:
        with open(path) as f:
            new_data = json.load(f)
        old_data = json.loads(old.stdout)
    except ValueError:
        return []                   # schema validation reports this
    if not isinstance(new_data, dict) or not isinstance(old_data, dict):
        return []
    if _baseline_rows(new_data) == _baseline_rows(old_data):
        return []
    new_meta = new_data.get("_meta") or {}
    old_meta = old_data.get("_meta") or {}
    if (new_meta.get("generated_at") == old_meta.get("generated_at")
            and new_meta.get("regenerate") == old_meta.get("regenerate")):
        return [f"row values differ from {ref} but the "
                f"_meta.generated_at/regenerate stamp does not — "
                f"hand-edited baseline? regenerate with: "
                f"{new_meta.get('regenerate', '--write-baseline')}"]
    return []


def check_baselines(drift_ref=None) -> int:
    """Validate every BENCH_*.json against the shared baseline schema
    (plus, given a git ref, the stamp-drift guard); returns the number
    of invalid files (0 = all good)."""
    import glob

    from benchmarks import common

    paths = sorted(glob.glob(os.path.join(
        os.path.dirname(__file__), "BENCH_*.json")))
    if not paths:
        print("no BENCH_*.json baselines found", file=sys.stderr)
        return 1
    bad = 0
    for path in paths:
        errs = common.validate_baseline(path)
        if drift_ref:
            errs = errs + _drift_errors(path, drift_ref)
        rel = os.path.relpath(path)
        if errs:
            bad += 1
            for e in errs:
                print(f"# {rel}: {e}", file=sys.stderr)
            print(f"# {rel}: INVALID", file=sys.stderr)
        else:
            print(f"# {rel}: ok", file=sys.stderr)
    return bad


def _parse_whatif(spec: str) -> dict:
    """Parse ``--whatif`` knob=value pairs (``nic_bandwidth=2,wire=0``)."""
    valid = {"nic_bandwidth": float, "device_speed": float,
             "wire": float, "overlap_halo": lambda v: v.lower() in
             ("1", "true", "yes", "on")}
    knobs: dict = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        key, _, val = part.partition("=")
        key = key.strip()
        if key not in valid:
            raise SystemExit(f"--whatif: unknown knob {key!r} "
                             f"(choose from {sorted(valid)})")
        try:
            knobs[key] = valid[key](val.strip())
        except ValueError:
            raise SystemExit(f"--whatif: bad value for {key}: {val!r}")
    if not knobs:
        raise SystemExit("--whatif: empty spec")
    return knobs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--check-baselines", action="store_true",
                    help="validate benchmarks/BENCH_*.json against the "
                         "shared schema and exit")
    ap.add_argument("--drift-ref", default=os.environ.get("CI_BASE_REF"),
                    metavar="GITREF",
                    help="with --check-baselines: also fail baselines "
                         "whose row values changed vs this git ref "
                         "without a fresh _meta.generated_at stamp "
                         "(default: $CI_BASE_REF)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile each selected benchmark and print the "
                         "top 25 functions by cumulative time to stderr "
                         "(pair with --only to profile one)")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="also append each profile's top-25 table to this "
                         "file (implies --profile)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="trace every benchmark cluster and write combined "
                         "Perfetto trace_event JSON to FILE on exit "
                         "(.gz suffix gzips the export)")
    ap.add_argument("--blame", action="store_true",
                    help="after the run, print the causal critical-path "
                         "blame table (core/critpath.py) for the combined "
                         "trace — installs a tracer even without --trace")
    ap.add_argument("--whatif", default=None, metavar="SPEC",
                    help="after the run, print what-if makespan projections "
                         "for the combined trace; SPEC is comma-separated "
                         "knob=value (nic_bandwidth=2, device_speed=2, "
                         "wire=0, overlap_halo=1) — implies --blame's "
                         "tracer")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "results.json"))
    args = ap.parse_args()
    if args.check_baselines:
        sys.exit(1 if check_baselines(args.drift_ref) else 0)
    if args.profile_out:
        args.profile = True

    whatif_knobs = None
    if args.whatif is not None:
        whatif_knobs = _parse_whatif(args.whatif)

    tracer = None
    if args.trace or args.blame or whatif_knobs is not None:
        from repro.core import trace as trace_mod
        tracer = trace_mod.Tracer()
        trace_mod.set_default(tracer)

    import importlib
    all_rows = []
    prof_f = open(args.profile_out, "w") if args.profile_out else None
    try:
        print("name,us_per_call,derived")
        for tag, modname in MODULES:
            if args.only and args.only != tag:
                continue
            t0 = time.time()
            mod = importlib.import_module(modname)
            if args.profile:
                import cProfile
                import pstats
                prof = cProfile.Profile()
                rows = prof.runcall(mod.run)
                header = (f"# profile: {tag} ({modname}) "
                          "top 25 by cumulative")
                for stream in (sys.stderr, prof_f):
                    if stream is None:
                        continue
                    print(header, file=stream)
                    pstats.Stats(prof, stream=stream) \
                        .sort_stats("cumulative").print_stats(25)
            else:
                rows = mod.run()
            all_rows.extend({"name": r.name, "us_per_call": r.us_per_call,
                             "derived": r.derived} for r in rows)
            print(f"# {tag} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
    finally:
        if prof_f is not None:
            prof_f.close()
        if tracer is not None:
            from repro.core import trace as trace_mod
            trace_mod.set_default(None)
            if args.trace:
                from benchmarks import common
                tracer.write_perfetto(args.trace)
                errs = common.validate_perfetto(args.trace)
                for e in errs:
                    print(f"# trace: {e}", file=sys.stderr)
                print(f"# trace: {len(tracer.cmds)} commands -> "
                      f"{args.trace} "
                      f"({'INVALID' if errs else 'schema ok'})",
                      file=sys.stderr)
            if args.blame or whatif_knobs is not None:
                title = f"--only {args.only}" if args.only else "full sweep"
                print(tracer.format_blame(title=title), file=sys.stderr)
            if whatif_knobs is not None:
                w = tracer.whatif(**whatif_knobs)
                print(f"# whatif {args.whatif}: recorded "
                      f"{w['recorded_s'] * 1e3:.3f} ms -> projected "
                      f"{w['projected_s'] * 1e3:.3f} ms "
                      f"(speedup {w['speedup']:.3f}x)", file=sys.stderr)
    with open(args.out, "w") as f:
        json.dump(all_rows, f, indent=1)


if __name__ == "__main__":
    main()
