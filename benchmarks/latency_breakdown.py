"""Fig. 9-style command-latency decomposition (DESIGN.md §9).

The paper's Fig. 9/10 break the end-to-end command latency into where
every microsecond goes: client submit + wire, server-side dependency
wait, device run-queue wait, execution, and completion routing back to
the client. This benchmark runs two traced workloads — the dispatch
DAG (``benchmarks.dispatch_throughput``'s seeded random graph) and the
migration pipeline (bulk weights pulled across the peer mesh) — and
prints the tracer's per-stage table for each.

The load-bearing property, gated here and in scripts/ci.sh: computed in
rational arithmetic (``Tracer.breakdown(exact=True)``), the per-stage
sums equal the summed end-to-end command latency EXACTLY — the
decomposition attributes every last tick of latency to exactly one
stage, nothing double-counted, nothing dropped. Each ``*_total`` row
carries ``exact_sum=1`` only if that held.

  PYTHONPATH=src python -m benchmarks.latency_breakdown [--check]

``--check`` exits non-zero unless every workload's exact-sum gate and
Perfetto schema check pass (used by scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import logging
import sys
from fractions import Fraction

import numpy as np

from benchmarks import common
from benchmarks.common import (ETH_1G, ETH_40G, GPU_2080TI, LOOPBACK, MiB,
                               Row, build_dag, emit)
from repro.core import ClientRuntime, DeviceSpec, ServerSpec, Tracer
from repro.core.trace import STAGES

N_CMDS = 2000
N_SRV = 4
BIG = 8 * MiB


def _dispatch_workload() -> Tracer:
    tr = Tracer()
    rt = ClientRuntime(
        servers=[ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                 for i in range(N_SRV)],
        client_link=LOOPBACK, peer_link=LOOPBACK, trace=tr)
    build_dag(rt, N_CMDS, N_SRV, seed=42)
    rt.finish()
    return tr


def _migration_workload() -> Tracer:
    tr = Tracer()
    rt = ClientRuntime(
        servers=[ServerSpec(f"s{i}", [GPU_2080TI]) for i in range(N_SRV)],
        client_link=ETH_1G, peer_link=ETH_40G, transport="tcp",
        trace=tr)
    weights = rt.create_buffer(BIG, name="weights")
    rt.enqueue_write("s0", weights, np.zeros(BIG // 4, np.uint32))
    rt.finish()
    for s in (f"s{i}" for i in range(1, N_SRV)):
        for j in range(2):
            out = rt.create_buffer(4096)
            rt.enqueue_kernel(s, fn=None, inputs=[weights], outputs=[out],
                              duration=1e-5, name=f"{s}_k{j}")
    rt.finish()
    return tr


def _rows_for(tag: str, tr: Tracer) -> tuple:
    """Per-stage rows + the exact-sum verdict for one traced workload.
    The stage means come from the float table (what a user reads); the
    gate itself runs in Fraction arithmetic so float telescoping dust
    can never mask — or fake — a decomposition error."""
    exact = tr.breakdown(exact=True)
    stage_sum = sum((sum(exact[s], Fraction(0)) for s in STAGES),
                    Fraction(0))
    total_sum = sum(exact["total"], Fraction(0))
    ok = stage_sum == total_sum
    bd = tr.breakdown()
    n = len(bd["total"])
    total_us = sum(bd["total"]) * 1e6
    rows = []
    for stage in STAGES:
        s_us = sum(bd[stage]) * 1e6
        share = s_us / total_us if total_us else 0.0
        rows.append(Row(
            f"breakdown_{tag}_{stage}", s_us / n if n else 0.0,
            f"sum_us={s_us:.3f};share={share:.4f}"))
    rows.append(Row(
        f"breakdown_{tag}_total", total_us / n if n else 0.0,
        f"sum_us={total_us:.3f};commands={n};exact_sum={1 if ok else 0}"))
    print(tr.format_breakdown(f"latency breakdown: {tag} "
                              f"({n} commands)"), file=sys.stderr)
    return rows, ok


def run():
    # the deep dispatch DAG overflows the session replay window by
    # design; silence the (expected) warning for this sweep only
    rt_log = logging.getLogger("repro.core.runtime")
    prev_level = rt_log.level
    rt_log.setLevel(logging.ERROR)
    try:
        rows = []
        for tag, workload in (("dispatch", _dispatch_workload),
                              ("migration", _migration_workload)):
            wrows, _ok = _rows_for(tag, workload())
            rows.extend(wrows)
    finally:
        rt_log.setLevel(prev_level)
    return emit(rows)


def check(rows) -> bool:
    """Every workload's exact-sum gate must hold and report commands."""
    ok = True
    for row in rows:
        if not row.name.endswith("_total"):
            continue
        exact = common.derived(row, "exact_sum")
        n = common.derived(row, "commands")
        good = exact == 1 and n > 0
        print(f"# {row.name}: commands={n:.0f} exact_sum={exact:.0f} "
              f"{'ok' if good else 'FAILED'}", file=sys.stderr)
        ok = ok and good
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the exact-sum gates hold")
    ap.add_argument("--json-out", default=None,
                    help="write the result rows to this JSON path")
    args = ap.parse_args()
    rows = run()
    if args.json_out:
        common.dump_rows(rows, args.json_out)
    if args.check and not check(rows):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
