"""Fig. 9-style command-latency decomposition (DESIGN.md §9).

The paper's Fig. 9/10 break the end-to-end command latency into where
every microsecond goes: client submit + wire, server-side dependency
wait, device run-queue wait, execution, and completion routing back to
the client. This benchmark runs two traced workloads — the dispatch
DAG (``benchmarks.dispatch_throughput``'s seeded random graph) and the
migration pipeline (bulk weights pulled across the peer mesh) — and
prints the tracer's per-stage table for each.

The load-bearing property, gated here and in scripts/ci.sh: computed in
rational arithmetic (``Tracer.breakdown(exact=True)``), the per-stage
sums equal the summed end-to-end command latency EXACTLY — the
decomposition attributes every last tick of latency to exactly one
stage, nothing double-counted, nothing dropped. Each ``*_total`` row
carries ``exact_sum=1`` only if that held.

On top of the per-command decomposition, the causal critical-path
analyzer (core/critpath.py, DESIGN.md §11) is gated here on the same
two workloads: the path's segment sum must equal the workload makespan
exactly (rational arithmetic again — the path is a gap-free tiling of
the makespan window), and the what-if projections must land within
``WHATIF_TOLERANCE`` of a ground-truth re-run of the simulator with
the knob actually changed (``device_speed=2`` re-runs the dispatch DAG
with halved kernel durations; ``nic_bandwidth=2`` re-runs the
migration pipeline with doubled link bandwidths). The projected and
recorded makespans also gate against ``BENCH_critpath.json`` so the
analyzer's attribution cannot silently drift.

  PYTHONPATH=src python -m benchmarks.latency_breakdown [--check] \
      [--baseline benchmarks/BENCH_critpath.json] [--write-baseline P]

``--check`` exits non-zero unless every workload's exact-sum gate, the
critical-path identity, and the what-if accuracy gates pass (used by
scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import logging
import sys
from fractions import Fraction

import numpy as np

from benchmarks import common
from benchmarks.common import (ETH_1G, ETH_40G, GPU_2080TI, LOOPBACK, MiB,
                               Row, build_dag, emit)
from repro.core import (ClientRuntime, DeviceSpec, LinkSpec, ServerSpec,
                        Tracer)
from repro.core.trace import STAGES

N_CMDS = 2000
N_SRV = 4
BIG = 8 * MiB
WHATIF_TOLERANCE = 0.10       # projection vs ground-truth re-run
CRITPATH_TOLERANCE = 0.10     # BENCH_critpath.json gate (deterministic)
REGENERATE = (
    "python -m benchmarks.latency_breakdown "
    "--write-baseline benchmarks/BENCH_critpath.json && "
    "python -m benchmarks.cfd_halo "
    "--write-critpath-baseline benchmarks/BENCH_critpath.json")


def _scaled_link(spec: LinkSpec, bw: float) -> LinkSpec:
    return LinkSpec(latency=spec.latency, bandwidth=spec.bandwidth * bw)


def _dispatch_workload(speed: float = 1.0,
                       duration: float = 1e-7) -> Tracer:
    tr = Tracer()
    rt = ClientRuntime(
        servers=[ServerSpec(f"s{i}", [DeviceSpec("gpu0")])
                 for i in range(N_SRV)],
        client_link=LOOPBACK, peer_link=LOOPBACK, trace=tr)
    build_dag(rt, N_CMDS, N_SRV, seed=42, duration=duration / speed)
    rt.finish()
    return tr


def _compute_workload(speed: float = 1.0) -> Tracer:
    """Compute-bound variant of the dispatch DAG (device execution
    dominates, not the wire) — the workload the ``device_speed``
    what-if knob is validated on: a 2x device must roughly halve THIS
    makespan, and the projection has to see that from the trace."""
    return _dispatch_workload(speed=speed, duration=1e-4)


def _migration_workload(nic: float = 1.0) -> Tracer:
    # single-phase on purpose: everything is enqueued up front with
    # explicit dependencies, so the whole makespan is causal structure
    # the what-if re-timing can reason about (a mid-run finish() would
    # pin the second phase's enqueue times to the FIRST run's wall
    # clock, which no projection can know to move)
    tr = Tracer()
    rt = ClientRuntime(
        servers=[ServerSpec(f"s{i}", [GPU_2080TI]) for i in range(N_SRV)],
        client_link=_scaled_link(ETH_1G, nic),
        peer_link=_scaled_link(ETH_40G, nic), transport="tcp",
        trace=tr)
    weights = rt.create_buffer(BIG, name="weights")
    wev = rt.enqueue_write("s0", weights, np.zeros(BIG // 4, np.uint32))
    for s in (f"s{i}" for i in range(1, N_SRV)):
        for j in range(2):
            out = rt.create_buffer(4096)
            rt.enqueue_kernel(s, fn=None, inputs=[weights], outputs=[out],
                              duration=1e-5, wait_for=[wev],
                              name=f"{s}_k{j}")
    rt.finish()
    return tr


def _span_s(tr: Tracer) -> float:
    """First enqueue -> last client-visible completion, over the whole
    trace (the same window ``Tracer.whatif`` projects)."""
    stamps = [Tracer._stamps(rec) for rec in tr.finished()]
    return max(s[5] for s in stamps) - min(s[0] for s in stamps)


def _rows_for(tag: str, tr: Tracer) -> tuple:
    """Per-stage rows + the exact-sum verdict for one traced workload.
    The stage means come from the float table (what a user reads); the
    gate itself runs in Fraction arithmetic so float telescoping dust
    can never mask — or fake — a decomposition error."""
    exact = tr.breakdown(exact=True)
    stage_sum = sum((sum(exact[s], Fraction(0)) for s in STAGES),
                    Fraction(0))
    total_sum = sum(exact["total"], Fraction(0))
    ok = stage_sum == total_sum
    bd = tr.breakdown()
    n = len(bd["total"])
    total_us = sum(bd["total"]) * 1e6
    rows = []
    for stage in STAGES:
        s_us = sum(bd[stage]) * 1e6
        share = s_us / total_us if total_us else 0.0
        rows.append(Row(
            f"breakdown_{tag}_{stage}", s_us / n if n else 0.0,
            f"sum_us={s_us:.3f};share={share:.4f}"))
    rows.append(Row(
        f"breakdown_{tag}_total", total_us / n if n else 0.0,
        f"sum_us={total_us:.3f};commands={n};exact_sum={1 if ok else 0}"))
    print(tr.format_breakdown(f"latency breakdown: {tag} "
                              f"({n} commands)"), file=sys.stderr)
    return rows, ok


def _critpath_rows(tag: str, tr: Tracer, knob,
                   rerun) -> list:
    """Critical-path + what-if rows for one traced workload: the
    rational-arithmetic tiling identity (segment sum == makespan), the
    blame table for the log, and — when a knob is given — the what-if
    projection validated against a ground-truth re-run with the knob
    actually changed."""
    cp = tr.critical_path(exact=True)
    ident = bool(cp.segments) and cp.segment_sum() == cp.makespan
    rows = [Row(f"critpath_{tag}_makespan_us", float(cp.makespan) * 1e6,
                f"segments={len(cp.segments)};"
                f"identity={1 if ident else 0}")]
    print(tr.format_blame(top=8, title=f"critical path: {tag}"),
          file=sys.stderr)
    if knob is None:
        return rows
    knob_name, = knob
    w = tr.whatif(**knob)
    actual = _span_s(rerun())
    err = abs(w["projected_s"] - actual) / actual if actual else 1.0
    rows.append(Row(
        f"critpath_whatif_{knob_name}_projected_us",
        w["projected_s"] * 1e6,
        f"actual_us={actual * 1e6:.3f};"
        f"recorded_us={w['recorded_s'] * 1e6:.3f};err={err:.4f}"))
    return rows


def run():
    # the deep dispatch DAG overflows the session replay window by
    # design; silence the (expected) warning for this sweep only
    rt_log = logging.getLogger("repro.core.runtime")
    prev_level = rt_log.level
    rt_log.setLevel(logging.ERROR)
    try:
        rows = []
        for tag, workload, knob, rerun in (
                ("dispatch", _dispatch_workload, None, None),
                ("compute", _compute_workload, {"device_speed": 2.0},
                 lambda: _compute_workload(speed=2.0)),
                ("migration", _migration_workload, {"nic_bandwidth": 2.0},
                 lambda: _migration_workload(nic=2.0))):
            tr = workload()
            if tag != "compute":      # stage tables: the two originals
                wrows, _ok = _rows_for(tag, tr)
                rows.extend(wrows)
            rows.extend(_critpath_rows(tag, tr, knob, rerun))
    finally:
        rt_log.setLevel(prev_level)
    return emit(rows)


def check(rows) -> bool:
    """Every workload's exact-sum gate must hold and report commands;
    every critical path must tile its makespan exactly; every what-if
    projection must land within WHATIF_TOLERANCE of its re-run."""
    ok = True
    for row in rows:
        if row.name.endswith("_total"):
            exact = common.derived(row, "exact_sum")
            n = common.derived(row, "commands")
            good = exact == 1 and n > 0
            print(f"# {row.name}: commands={n:.0f} "
                  f"exact_sum={exact:.0f} "
                  f"{'ok' if good else 'FAILED'}", file=sys.stderr)
        elif row.name.endswith("_makespan_us"):
            ident = common.derived(row, "identity")
            segs = common.derived(row, "segments")
            good = ident == 1 and segs > 0
            print(f"# {row.name}: segments={segs:.0f} "
                  f"identity={ident:.0f} "
                  f"{'ok' if good else 'FAILED'}", file=sys.stderr)
        elif "_whatif_" in row.name:
            err = common.derived(row, "err")
            good = err <= WHATIF_TOLERANCE
            print(f"# {row.name}: projection err {err:.4f} vs re-run "
                  f"(tolerance {WHATIF_TOLERANCE}) "
                  f"{'ok' if good else 'FAILED'}", file=sys.stderr)
        else:
            continue
        ok = ok and good
    return ok


def _gate_value(row: Row) -> float:
    return row.us_per_call


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the exact-sum, "
                         "critical-path identity, and what-if accuracy "
                         "gates hold")
    ap.add_argument("--baseline", default=None,
                    help="BENCH_critpath.json; fail if a critpath "
                         "makespan/projection row regresses >10%%")
    ap.add_argument("--write-baseline", default=None,
                    help="merge this module's critpath_* rows into the "
                         "shared BENCH_critpath.json at this path")
    ap.add_argument("--json-out", default=None,
                    help="write the result rows to this JSON path")
    args = ap.parse_args()
    rows = run()
    if args.json_out:
        common.dump_rows(rows, args.json_out)
    if args.write_baseline:
        write_critpath_baseline(
            args.write_baseline,
            {r.name: r.us_per_call for r in rows
             if r.name.startswith("critpath_")})
    ok = True
    if args.check:
        ok = check(rows)
    if args.baseline:
        gated = [r for r in rows if r.name.startswith("critpath_")]
        ok = common.check_rows(
            gated, args.baseline, extract=_gate_value,
            tolerance=CRITPATH_TOLERANCE, direction="lower_is_better",
            unit=" us", benchmark="critpath") and ok
    if not ok:
        raise SystemExit(1)


def write_critpath_baseline(path: str, values: dict) -> None:
    """Merge-write into the shared critpath baseline: this module and
    benchmarks/cfd_halo.py each own a disjoint subset of the rows, so a
    regeneration preserves the other module's entries."""
    import os

    merged = {}
    if os.path.exists(path):
        meta, existing = common.load_baseline(path)
        if meta.get("benchmark") in (None, "critpath"):
            merged.update(existing)
    merged.update(values)
    common.write_baseline(
        path, merged, benchmark="critpath", metric="us_or_ratio",
        direction="lower_is_better", tolerance=CRITPATH_TOLERANCE,
        regenerate=REGENERATE)


if __name__ == "__main__":
    main()
