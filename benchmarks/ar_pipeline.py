"""Paper Fig. 15: AR point-cloud rendering — frame rate and energy per
frame across offloading configurations.

Throughput model: the app is a software pipeline, so steady-state fps =
1 / max(stage time). Stage times for the network stages come from the
simulated runtime (so the P2P and content-size machinery is actually
exercised); compute stages use the device models.

Configs (paper Fig. 15 bars):
  igpu           everything on the phone GPU, no AR tracking
  igpu_ar        + AR pose tracking (GPU contention slows the sort)
  rgpu_ar        sort offloaded; buffer migrations via host round-trip
  rgpu_p2p_ar    + P2P migrations (stream source feeds server directly)
  rgpu_p2p_dyn   + cl_pocl_content_size on the variable-size buffers

Calibration targets: offload ≈2.3×, +DYN ≈19× fps vs igpu_ar; energy per
frame down to ~6 % (paper: 5.7 %).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import GPU_1060, Row, WIFI6, emit
from repro.core import ClientRuntime, LinkSpec, ServerSpec

# stage compute times (s)
T_DECODE_LOCAL = 0.008          # HW HEVC decoder (phone)
T_RECON_LOCAL = 0.018
T_RENDER = 0.016
T_TRACK = 0.036                 # AR pose estimation (CPU/DSP stage)
T_SORT_LOCAL = 0.240            # 860k points on the phone GPU
AR_CONTENTION = 2.6             # GPU contention multiplier with AR on
T_DECODE_SRV = 0.0025
T_RECON_SRV = 0.0012
T_SORT_SRV = 0.0035

# buffers: conservatively-allocated (worst case) vs actually used
STREAM_ALLOC, STREAM_USED = 4 << 20, 260_000
IDX_ALLOC, IDX_USED = 8 << 20, 1_050_000   # packed/delta-coded indices

SOC_BUSY_W = 6.5
SOC_LOW_W = 1.9
RADIO_J_PER_BYTE = 42e-9


def _xfer_time(nbytes_alloc: int, used: int, dyn: bool, down: bool = True):
    """Measure one radio transfer through the runtime (content-size aware
    when dyn); returns (seconds, bytes_on_radio)."""
    rt = ClientRuntime(servers=[ServerSpec("edge", [GPU_1060])],
                       client_link=WIFI6,
                       peer_link=LinkSpec(0.2e-3, 1e9 / 8), transport="tcp")
    size_buf = rt.create_buffer(4)
    buf = rt.create_buffer(nbytes_alloc,
                           content_size_buffer=size_buf if dyn else None)
    rt.enqueue_write("edge", size_buf, np.array([used], np.uint32))
    buf.valid_on = {"edge"}
    buf.data = np.zeros(nbytes_alloc // 4, np.uint32)
    rt.finish()
    t0 = rt.clock.now
    rt.enqueue_read("edge", buf)
    rt.finish()
    return rt.clock.now - t0, (used if dyn else nbytes_alloc)


def _fps_energy(stages: dict, radio_bytes: float, busy_w: float):
    bottleneck = max(stages.values())
    fps = 1.0 / bottleneck
    # energy: phone-side busy stages at the SoC power state + radio
    phone_busy = sum(t for k, t in stages.items() if k.startswith("ph_"))
    epf = phone_busy * busy_w + radio_bytes * RADIO_J_PER_BYTE
    return fps, epf


def run():
    rows = []
    # local configs
    fps0, epf0 = _fps_energy(
        {"ph_decode": T_DECODE_LOCAL, "ph_recon": T_RECON_LOCAL,
         "ph_sort": T_SORT_LOCAL, "ph_render": T_RENDER}, 0.0, SOC_BUSY_W)
    fps1, epf1 = _fps_energy(
        {"ph_decode": T_DECODE_LOCAL, "ph_recon": T_RECON_LOCAL,
         "ph_sort": T_SORT_LOCAL * AR_CONTENTION, "ph_render": T_RENDER,
         "ph_track": T_TRACK}, 0.0, SOC_BUSY_W)
    rows.append(Row("fig15_igpu", 1e6 / fps0, f"fps={fps0:.2f};epf_J={epf0:.3f}"))
    rows.append(Row("fig15_igpu_ar", 1e6 / fps1,
                    f"fps={fps1:.2f};x_fps=1.0;epf_J={epf1:.3f}"))

    # offloaded variants: phone stages + network stages
    for name, p2p, dyn in [("rgpu_ar", False, False),
                           ("rgpu_p2p_ar", True, False),
                           ("rgpu_p2p_dyn_ar", True, True)]:
        radio = 0.0
        stages = {"ph_decode": T_DECODE_LOCAL, "ph_recon": T_RECON_LOCAL,
                  "ph_render": T_RENDER, "ph_track": T_TRACK,
                  "srv": T_DECODE_SRV + T_RECON_SRV + T_SORT_SRV}
        if not p2p:
            # stream buffer migrates source-device → GPU via the phone
            t_dn, b_dn = _xfer_time(STREAM_ALLOC, STREAM_USED, dyn)
            stages["net_stream"] = 2 * t_dn          # down + up
            radio += 2 * b_dn
        t_idx, b_idx = _xfer_time(IDX_ALLOC, IDX_USED, dyn)
        stages["net_index"] = t_idx
        radio += b_idx
        fps, epf = _fps_energy(stages, radio, SOC_LOW_W)
        rows.append(Row(
            f"fig15_{name}", 1e6 / fps,
            f"fps={fps:.2f};x_fps={fps/fps1:.1f};epf_J={epf:.3f};"
            f"epf_vs_igpu_ar={epf/epf1:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
