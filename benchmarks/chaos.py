"""Chaos gate: the 32-UE AR workload through scripted drain storms and
server crashes (paper §4.3 robustness; DESIGN.md §7 elastic membership).

Every UE runs the multi-tenant AR frame loop (upload depth map, point
sort, read back the index) against its primary server, but — unlike the
``benchmarks.multi_tenant`` UE — tolerates the cluster changing under
it: when a frame's commands come back ERROR (server crashed) or the
primary stops taking placements (draining), the UE re-places the frame
on the least-loaded eligible survivor with bounded exponential backoff.
A per-UE command ledger counts terminal transitions for every enqueued
command, so the gate can assert *exactly-once*: no command lost (never
terminal), none duplicated (terminal twice).

Rows (TCP peers, DRR scheduler, content-addressed store on):

* ``chaos_steady``: no faults — the reference run the recovery gates
  compare against.
* ``chaos_drain_storm``: drain s1 at 25% of the steady makespan, join a
  fresh s4 at 30%, drain s2 at 60%. Gates: zero lost / duplicated /
  failed / hung frames, the drained servers' replicas all re-homed
  (none left in any ``valid_on``, tenant or store), the joined server
  actually served frames, and the storm makespan within
  ``RECOVERY_CEILING``× steady.
* ``chaos_crash``: crash s1 at 40% of the steady makespan. Gates: the
  crash visibly failed commands (fail-fast, not hangs), every affected
  frame was replayed to completion (zero failed / hung), the bounded
  reconnect path was exercised and gave up (``reconnect_failures``),
  and the post-crash p95 frame latency stays within
  ``POST_CRASH_P95_CEILING``× the steady p95.

Fault times are fractions of the measured steady makespan, which is
deterministic, so the schedule — and every gate — is bit-reproducible.

  PYTHONPATH=src python -m benchmarks.chaos \
      [--baseline benchmarks/BENCH_chaos.json] [--write-baseline P]

With ``--baseline``, exits non-zero on a >20% simulated-time regression
or any chaos-gate violation (used by scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks import common
from benchmarks.common import ETH_40G, GPU_2080TI, MiB, Row, WIFI6, emit
from repro.core import (COMPLETE, ERROR, ClientRuntime, Cluster,
                        DeviceUnavailable, FaultSchedule, ServerSpec)

N_SERVERS = 4
N_UE = 32
FRAMES = 12
DEPTH_BYTES = 96 * 1024
MODEL_BYTES = 2 * MiB
T_KERNEL = 1e-3
NIC_BW = 25e9 / 8
QUANTUM = 2e-3
STAGGER = 1.3e-3
RETRIES = 6                     # frame re-placement attempts
BACKOFF = 2e-3                  # first retry delay (doubles)
REGRESSION_TOLERANCE = 0.20
RECOVERY_CEILING = 1.5          # storm makespan vs steady
POST_CRASH_P95_CEILING = 3.0    # post-crash frame p95 vs steady p95
REGENERATE = ("python -m benchmarks.chaos "
              "--write-baseline benchmarks/BENCH_chaos.json")


def _mk_cluster(trace=None) -> Cluster:
    return Cluster([ServerSpec(f"s{i}", [GPU_2080TI])
                    for i in range(N_SERVERS)],
                   peer_link=ETH_40G, peer_transport="tcp",
                   scheduler="drr", scheduler_quantum=QUANTUM,
                   nic_bandwidth=NIC_BW, store=True, trace=trace)


class ChaosUE:
    """A fault-tolerant AR client: the closed-loop frame pipeline of
    ``benchmarks.multi_tenant.UE`` plus re-placement. Frames prefer the
    primary server while it takes placements; otherwise (and on every
    retry) the least-loaded eligible session wins. A frame whose
    commands error is re-enqueued — fresh command ids — after an
    exponentially growing delay, up to ``RETRIES`` times."""

    def __init__(self, cluster: Cluster, idx: int, frames: int = FRAMES):
        self.cluster = cluster
        self.rt = ClientRuntime(cluster=cluster, client_link=WIFI6,
                                transport="tcp", name=f"ue{idx}")
        self.idx = idx
        self.primary = f"s{idx % N_SERVERS}"
        self.frames = frames
        self.latencies: list = []
        self.frame_t0: list = []        # start time of each landed frame
        self.failed_frames: list = []   # retries exhausted
        self.retries_used = 0
        self.frames_by_server: dict = {}
        self.ledger: dict = {}          # event id -> terminal callbacks
        self.tracked: list = []
        self.errors = 0                 # tracked events that ended ERROR
        self._reconnect_tried = False
        self.depth = self.rt.create_buffer(DEPTH_BYTES)
        self.index = self.rt.create_buffer(DEPTH_BYTES)
        self.model = self.rt.create_buffer(MODEL_BYTES)
        self._model_data = np.full(MODEL_BYTES // 4, idx, np.uint32)
        self._frame_no = 0

    # ---- exactly-once ledger ----
    def _track(self, ev) -> None:
        self.tracked.append(ev)
        self.ledger[ev.id] = 0

        def tick(e, i=ev.id):
            self.ledger[i] += 1
            if e.status == ERROR:
                self.errors += 1

        ev.on_complete(tick)

    # ---- placement-aware server pick ----
    def _pick(self, avoid=None):
        mm = self.cluster.membership
        engine = self.cluster.placement

        def ok(s):
            return (s != avoid and self.rt.sessions[s].available
                    and mm.is_eligible(s))

        if ok(self.primary):
            return self.primary
        best = min(((engine.queue_depth(s), s)
                    for s in sorted(self.rt.sessions) if ok(s)),
                   default=None)
        return best[1] if best is not None else None

    # ---- frame loop ----
    def start(self, delay: float = 0.0) -> None:
        self.rt.clock.schedule(delay, self._seed, RETRIES, BACKOFF)

    def _seed(self, tries: int, delay: float) -> None:
        """Model upload (the app's load phase), retried like a frame."""
        srv = self._pick()
        if srv is None:
            if tries <= 0:
                self.failed_frames.append(-1)
                return
            self.rt.clock.schedule(delay, self._seed, tries - 1,
                                   delay * 2.0)
            return
        ev = self.rt.enqueue_write(srv, self.model, self._model_data)
        self._track(ev)

        def seeded(_e):
            if ev.status == COMPLETE:
                self._next_frame()
            elif tries > 0:
                self.rt.clock.schedule(delay, self._seed, tries - 1,
                                       delay * 2.0)
            else:
                self.failed_frames.append(-1)

        ev.on_complete(seeded)

    def _next_frame(self) -> None:
        i = self._frame_no
        if i >= self.frames:
            return
        self._frame_no += 1
        self._attempt(i, RETRIES, BACKOFF, self.rt.clock.now, None)

    def _attempt(self, i: int, tries: int, delay: float, t0: float,
                 avoid) -> None:
        rt = self.rt
        srv = self._pick(avoid)
        if srv is None:
            # momentarily no eligible host (mid-storm): back off whole
            if tries <= 0:
                self.failed_frames.append(i)
                self._next_frame()
                return
            rt.clock.schedule(delay, self._attempt, i, tries - 1,
                              delay * 2.0, t0, None)
            return
        depth_data = np.full(DEPTH_BYTES // 4,
                             self.idx * 65536 + i, np.uint32)
        try:
            e1 = rt.enqueue_write(srv, self.depth, depth_data)
            e2 = rt.enqueue_kernel(srv, fn=None,
                                   inputs=[self.depth, self.model],
                                   outputs=[self.index, self.model],
                                   duration=T_KERNEL, wait_for=[e1],
                                   name=f"sort{i}")
            e3 = rt.enqueue_read(srv, self.index, wait_for=[e2])
        except DeviceUnavailable:
            if tries <= 0:
                self.failed_frames.append(i)
                self._next_frame()
                return
            rt.clock.schedule(delay, self._attempt, i, tries - 1,
                              delay * 2.0, t0, srv)
            return
        for ev in (e1, e2, e3):
            self._track(ev)

        def settled(_e):
            if all(ev.status == COMPLETE for ev in (e1, e2, e3)):
                self.latencies.append(rt.clock.now - t0)
                self.frame_t0.append(t0)
                self.frames_by_server[srv] = \
                    self.frames_by_server.get(srv, 0) + 1
                self._next_frame()
                return
            # server died under the frame: once, probe the bounded
            # reconnect path (it gives up against a dead host), then
            # re-place on a survivor
            if not self._reconnect_tried and \
                    not self.cluster.membership.is_alive(srv):
                self._reconnect_tried = True
                rt.reconnect(srv)
            if tries > 0:
                self.retries_used += 1
                rt.clock.schedule(delay, self._attempt, i, tries - 1,
                                  delay * 2.0, t0, srv)
            else:
                self.failed_frames.append(i)
                self._next_frame()

        e3.on_complete(settled)


def _percentile(lat, q):
    return float(np.percentile(np.asarray(lat) * 1e3, q))


def _run(fault_fn=None, trace=None):
    """One scenario: build the cluster + UEs, optionally let
    ``fault_fn(cluster, t0)`` script a ``FaultSchedule``, run the
    workload to quiescence, and collect the ledger."""
    cluster = _mk_cluster(trace=trace)
    ues = [ChaosUE(cluster, i) for i in range(N_UE)]
    cluster.run()                           # handshakes drained
    t0 = cluster.clock.now
    if fault_fn is not None:
        fault_fn(cluster, t0).apply(cluster)
    for i, ue in enumerate(ues):
        ue.start(delay=i * STAGGER)
    cluster.run()
    elapsed = cluster.clock.now - t0
    lost = dup = errors = failed = done = retries = reconnects = 0
    for u in ues:
        lost += sum(1 for ev in u.tracked
                    if ev.status not in (COMPLETE, ERROR))
        dup += sum(1 for c in u.ledger.values() if c > 1)
        errors += u.errors
        failed += len(u.failed_frames)
        done += len(u.latencies)
        retries += u.retries_used
        reconnects += sum(u.rt.stats()["reconnect_attempts"].values())
    hung = N_UE * FRAMES - done - failed
    lats = [x for u in ues for x in u.latencies]
    return {
        "cluster": cluster, "ues": ues,
        "sim_ms": elapsed * 1e3, "t0": t0,
        "p95_ms": _percentile(lats, 95),
        "lost": lost, "dup": dup, "errors": errors,
        "failed": failed, "hung": hung, "retries": retries,
        "reconnects": reconnects,
    }


def _leftover_replicas(r, names) -> int:
    """Replicas still recorded on retired servers after the run: any
    tenant buffer or store entry whose valid_on mentions one."""
    n = 0
    for u in r["ues"]:
        for buf in (u.depth, u.index, u.model):
            n += sum(1 for s in names if s in buf.valid_on)
    store = r["cluster"].store
    if store is not None:
        for e in store._entries.values():
            n += sum(1 for s in names if s in e.valid_on)
    return n


def _ledger_derived(r) -> str:
    return (f"sim_ms={r['sim_ms']:.3f};p95_ms={r['p95_ms']:.3f};"
            f"lost={r['lost']};dup={r['dup']};failed={r['failed']};"
            f"hung={r['hung']};errors={r['errors']};"
            f"retries={r['retries']}")


def run(storm_trace=None):
    steady = _run()
    t_steady = steady["sim_ms"] * 1e-3      # makespan, sim seconds

    def storm(cluster, t0):
        return (FaultSchedule()
                .drain(t0 + 0.25 * t_steady, "s1")
                .join(t0 + 0.30 * t_steady,
                      ServerSpec("s4", [GPU_2080TI]))
                .drain(t0 + 0.60 * t_steady, "s2"))

    def crash(cluster, t0):
        return FaultSchedule().crash(t0 + 0.40 * t_steady, "s1")

    st = _run(storm, trace=storm_trace)
    mm = st["cluster"].membership.stats()
    joined_frames = sum(u.frames_by_server.get("s4", 0)
                        for u in st["ues"])
    cr = _run(crash)
    post = [lat for u in cr["ues"]
            for lat, ft0 in zip(u.latencies, u.frame_t0)
            if ft0 >= cr["t0"] + 0.40 * t_steady]
    reconnect_failures = sum(
        len(u.rt.stats()["reconnect_failures"]) for u in cr["ues"])
    rows = [
        Row("chaos_steady", steady["p95_ms"] * 1e3,
            _ledger_derived(steady)),
        Row("chaos_drain_storm", st["p95_ms"] * 1e3,
            _ledger_derived(st)
            + f";requeued={mm['requeued_commands']}"
            f";migrated={mm['replicas_migrated']}"
            f";drain_ms={max(mm['drain_ms']):.3f}"
            f";joined_frames={joined_frames}"
            f";resid={_leftover_replicas(st, ('s1', 's2'))}"
            f";recovery_ratio={st['sim_ms'] / steady['sim_ms']:.3f}"),
        Row("chaos_crash", cr["p95_ms"] * 1e3,
            _ledger_derived(cr)
            + f";post_p95_ms={_percentile(post, 95) if post else 0.0:.3f}"
            f";post_p95_ratio="
            f"{(_percentile(post, 95) / steady['p95_ms']) if post else 0.0:.3f}"
            f";reconnects={cr['reconnects']}"
            f";reconnect_failures={reconnect_failures}"),
    ]
    return emit(rows)


def check_baseline(rows, baseline_path: str) -> bool:
    by_name = {r.name: r for r in rows}
    ok = common.check_rows(rows, baseline_path,
                           extract=lambda r: common.derived(r, "sim_ms"),
                           tolerance=REGRESSION_TOLERANCE,
                           direction="lower_is_better", unit=" sim_ms",
                           benchmark="chaos")

    def gate(cond, msg):
        nonlocal ok
        if cond:
            print(f"# {msg} ok", file=sys.stderr)
        else:
            print(f"# {msg} FAILED", file=sys.stderr)
            ok = False

    # exactly-once ledger, on every scenario
    for r in rows:
        for key in ("lost", "dup", "failed", "hung"):
            v = common.derived(r, key)
            gate(v == 0, f"{r.name}: {key}={v:.0f} (must be 0)")
    st = by_name["chaos_drain_storm"]
    gate(common.derived(st, "resid") == 0,
         "chaos_drain_storm: drained replicas re-homed (resid="
         f"{common.derived(st, 'resid'):.0f})")
    gate(common.derived(st, "migrated") >= 1,
         "chaos_drain_storm: sole-replica migrations ran "
         f"({common.derived(st, 'migrated'):.0f})")
    gate(common.derived(st, "joined_frames") >= 1,
         "chaos_drain_storm: joined server served frames "
         f"({common.derived(st, 'joined_frames'):.0f})")
    ratio = common.derived(st, "recovery_ratio")
    gate(ratio <= RECOVERY_CEILING,
         f"chaos_drain_storm: recovery ratio {ratio:.3f} <= "
         f"{RECOVERY_CEILING}")
    cr = by_name["chaos_crash"]
    gate(common.derived(cr, "errors") >= 1,
         "chaos_crash: crash failed commands fast "
         f"(errors={common.derived(cr, 'errors'):.0f})")
    gate(common.derived(cr, "reconnect_failures") >= 1,
         "chaos_crash: bounded reconnect exhausted against dead host "
         f"({common.derived(cr, 'reconnect_failures'):.0f})")
    pr = common.derived(cr, "post_p95_ratio")
    gate(0.0 < pr <= POST_CRASH_P95_CEILING,
         f"chaos_crash: post-crash p95 ratio {pr:.3f} <= "
         f"{POST_CRASH_P95_CEILING}")
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=None,
                    help="BENCH_chaos.json; fail on >20%% sim-time "
                         "regression or any chaos-gate violation")
    ap.add_argument("--write-baseline", default=None,
                    help="write measured sim_ms to this JSON path")
    ap.add_argument("--json-out", default=None,
                    help="write the result rows to this JSON path")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="trace the drain-storm scenario and write "
                         "Perfetto trace_event JSON to FILE; the export "
                         "must carry fault markers (drain/join/crash "
                         "instants) or the run fails")
    args = ap.parse_args()
    storm_trace = None
    if args.trace:
        from repro.core import Tracer
        storm_trace = Tracer()
    rows = run(storm_trace=storm_trace)
    if storm_trace is not None:
        storm_trace.write_perfetto(args.trace)
        errs = common.validate_perfetto(args.trace,
                                        require_fault_markers=True)
        for e in errs:
            print(f"# trace: {e}", file=sys.stderr)
        print(f"# trace: {len(storm_trace.cmds)} commands, "
              f"{len(storm_trace.faults)} fault markers -> {args.trace} "
              f"({'INVALID' if errs else 'schema ok'})", file=sys.stderr)
        if errs:
            raise SystemExit(1)
    if args.json_out:
        common.dump_rows(rows, args.json_out)
    if args.write_baseline:
        common.write_baseline(
            args.write_baseline,
            {r.name: common.derived(r, "sim_ms") for r in rows},
            benchmark="chaos", metric="sim_ms",
            direction="lower_is_better", tolerance=REGRESSION_TOLERANCE,
            regenerate=REGENERATE)
    if args.baseline and not check_baseline(rows, args.baseline):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
