"""Paper Fig. 9: pass-through kernel (copy one int) runtime duration:
native driver vs PoCL-R vs a SnuCL-like MPI runtime. The paper measures
PoCL-R ≈ 2× native and SnuCL ≈ 6× PoCL-R.

'native' models a direct in-process OpenCL dispatch (~100 µs measured on
the paper-era NVIDIA driver). The SnuCL-like configuration routes
completions through the client AND pays MPI progress-engine polling on
every message hop (the paper attributes SnuCL's overhead to "internal
command management ... and the communication overhead from the MPI
runtime").
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ETH_100M, GPU_2080TI, Row, emit
from repro.core import ClientRuntime, ServerSpec

NATIVE_DISPATCH = 100e-6        # paper-era driver enqueue→complete
MPI_PROGRESS_POLL = 460e-6      # per-message progress-engine delay


def _passthrough(scheduling: str, per_msg_extra: float = 0.0, n=200):
    rt = ClientRuntime(servers=[ServerSpec("s0", [GPU_2080TI]),
                                ServerSpec("s1", [GPU_2080TI])],
                       client_link=ETH_100M, peer_link=ETH_100M,
                       transport="tcp", scheduling=scheduling)
    a = rt.create_buffer(4)
    b = rt.create_buffer(4)
    rt.enqueue_write("s0", a, np.zeros(1, np.int32))
    rt.finish()
    dur = 0.0
    for _ in range(n):
        t0 = rt.clock.now
        ev = rt.enqueue_kernel("s0", fn=None, inputs=[a], outputs=[b],
                               duration=2e-6 + 2 * per_msg_extra)
        rt.finish()
        dur += ev.t_client_ack - t0
    return dur / n


def run():
    ours = _passthrough("decentralized")
    snucl = _passthrough("client", per_msg_extra=MPI_PROGRESS_POLL)
    rows = [
        Row("fig9_passthrough_native", NATIVE_DISPATCH * 1e6, "baseline"),
        Row("fig9_passthrough_poclr", ours * 1e6,
            f"x_native={ours/NATIVE_DISPATCH:.1f}"),
        Row("fig9_passthrough_snucl_like", snucl * 1e6,
            f"x_poclr={snucl/ours:.1f}"),
    ]
    return emit(rows)


if __name__ == "__main__":
    run()
