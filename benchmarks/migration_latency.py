"""Paper Fig. 10: 4-byte buffer migration latency between two devices,
averaged over 1000 migrations, per interconnect. A bump kernel between
migrations forces the copy to really happen (as in the paper)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ETH_100M, ETH_40G, GPU_2080TI, Row, emit
from repro.core import ClientRuntime, ServerSpec


def _migrate_loop(peer_link, p2p=True, n=200):
    rt = ClientRuntime(servers=[ServerSpec("s0", [GPU_2080TI]),
                                ServerSpec("s1", [GPU_2080TI])],
                       client_link=ETH_100M, peer_link=peer_link,
                       transport="tcp", p2p_migration=p2p)
    buf = rt.create_buffer(4)
    rt.enqueue_write("s0", buf, np.zeros(1, np.int32))
    rt.finish()
    total = 0.0
    here, there = "s0", "s1"
    for _ in range(n):
        t0 = rt.clock.now
        mig = rt.enqueue_migration(buf, there)
        rt.finish()
        total += rt.clock.now - t0
        # bump to invalidate the other copy (forces the next migration)
        rt.enqueue_kernel(there, fn=lambda x: x + 1, inputs=[buf],
                          outputs=[buf], duration=2e-6, wait_for=[mig])
        rt.finish()
        here, there = there, here
    return total / n


def run():
    rows = []
    for name, link, p2p in [
        ("p2p_100M_switch", ETH_100M, True),
        ("p2p_40G_direct", ETH_40G, True),
        ("via_client_100M", ETH_100M, False),
    ]:
        lat = _migrate_loop(link, p2p)
        rows.append(Row(f"fig10_migration_{name}", lat * 1e6,
                        f"rtt_us={2*link.latency*1e6:.0f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
